//! Concurrent-scheduler guarantees:
//!
//! (a) **Versioned determinism** — predicts racing a live writer are
//!     bit-wise identical to *sequential* predicts against the snapshot
//!     version each one was served from; since a torn or mixed-version
//!     read could not reproduce any single version's sequential answer,
//!     this also proves no request ever observes a torn snapshot.
//! (b) **Readers don't wait for writers** — a predict storm completes
//!     while a writer holds the session lock for a whole retrain.
//! (c) **Streaming ingestion** — staged rows are absorbed exactly once,
//!     across background refits and the final flush.
//! (d) **No thread growth** — a full concurrent storm with background
//!     refits leaves the process thread count where it started, and
//!     dropping the scheduler joins both the pool and the writer thread
//!     (the `/proc/self/status` census shared with `pool_stress.rs` and
//!     `serving.rs`).
//!
//! The tests serialize on a mutex: (d) counts OS threads, so no sibling
//! test's pools may spawn or die while it runs.

use parlin::data::{synthetic, DenseMatrix};
use parlin::glm::Objective;
use parlin::serve::{
    drive_concurrent, ModelSnapshot, Scheduler, SchedulerConfig, Session, StormConfig,
};
use parlin::solver::{SolverConfig, Variant};
use parlin::sysinfo::Topology;
use std::sync::{Arc, Mutex, MutexGuard};

#[path = "common/census.rs"]
mod census;
use census::settled_census;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn session(n: usize, threads: usize, seed: u64) -> Session<DenseMatrix> {
    let ds = synthetic::dense_classification(n, 8, seed);
    let cfg = SolverConfig::new(Objective::Logistic {
        lambda: 1.0 / n as f64,
    })
    .with_variant(Variant::Domesticated)
    .with_threads(threads)
    .with_topology(Topology::uniform(2, threads.div_ceil(2)))
    .with_tol(1e-3)
    .with_max_epochs(250);
    Session::new(ds, cfg)
}

/// The acceptance-criterion test: concurrent predicts against version `k`
/// race a writer producing `k+1`; afterwards every result is replayed
/// *sequentially* against the retained snapshot of the version that
/// served it and compared bit-for-bit.
#[test]
fn racing_predicts_are_bitwise_sequential_for_their_version() {
    let _g = gate();
    let sched = Scheduler::new(
        session(300, 4, 91),
        SchedulerConfig {
            refit_rows_threshold: 40,
            refit_staleness_s: 1e3,
            max_pending: None,
            ..SchedulerConfig::default()
        },
    );
    // retain version 0 — it must stay fully servable throughout
    let snap0 = sched.snapshot();
    assert_eq!(snap0.version(), 0);

    let outcomes: Mutex<Vec<(u64, Vec<usize>, Vec<f64>)>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for reader in 0..4usize {
            let (sched, outcomes) = (&sched, &outcomes);
            scope.spawn(move || {
                for k in 0..60usize {
                    let idx: Vec<usize> =
                        (0..48).map(|i| (reader * 61 + k * 13 + i * 3) % 300).collect();
                    let out = sched.predict(&idx);
                    outcomes.lock().unwrap().push((out.version, idx, out.margins));
                }
            });
        }
        // the writer: cross the row threshold mid-storm so a background
        // refit trains and publishes version 1 while readers are racing
        let fresh = synthetic::dense_classification(40, 8, 92);
        sched.ingest(fresh);
    });
    let _ = sched.flush();
    let snap1 = sched.snapshot();
    assert_eq!(snap1.version(), 1, "the ingested rows must have published v1");
    assert_eq!(snap1.n(), 340);
    assert_eq!(snap0.n(), 300, "the retained version must be untouched");

    let by_version = |v: u64| -> Arc<ModelSnapshot<DenseMatrix>> {
        match v {
            0 => Arc::clone(&snap0),
            1 => Arc::clone(&snap1),
            other => panic!("request served from unpublished version {other}"),
        }
    };
    let outcomes = outcomes.into_inner().unwrap();
    assert_eq!(outcomes.len(), 240);
    for (version, idx, margins) in &outcomes {
        let sequential = by_version(*version).predict(idx);
        assert_eq!(
            margins, &sequential,
            "a v{version} predict diverged from the sequential answer — torn snapshot"
        );
        // cross-check one level deeper: the sequential answer itself must
        // be the plain batch path on that version's frozen state
        let snap = by_version(*version);
        let batch = parlin::glm::model::margins(snap.dataset(), snap.weights(), idx);
        assert_eq!(margins, &batch);
    }
    let report = sched.report();
    assert_eq!(report.predicts, 240);
    assert_eq!(report.ingested_rows, 40);
    assert!(report.publishes >= 1);
}

/// Readers must complete while a writer holds the session lock for an
/// entire retrain — the "readers never block on a refit" contract.
#[test]
fn predict_storm_completes_while_writer_retrains() {
    let _g = gate();
    let sched = Scheduler::new(session(260, 4, 93), SchedulerConfig::default());
    let snap0 = sched.snapshot();
    std::thread::scope(|scope| {
        let writer = scope.spawn(|| sched.retrain());
        // the storm runs regardless of where the writer currently is;
        // every result must match one published version exactly
        for k in 0..80usize {
            let idx: Vec<usize> = (0..32).map(|i| (k * 7 + i) % 260).collect();
            let out = sched.predict(&idx);
            let expect = if out.version == 0 {
                snap0.predict(&idx)
            } else {
                assert_eq!(out.version, 1);
                sched.snapshot().predict(&idx)
            };
            assert_eq!(out.margins, expect, "storm predict {k}");
        }
        let r = writer.join().expect("writer panicked").expect("clean retrain");
        assert_eq!(r.kind, "retrain");
    });
    assert_eq!(sched.version(), 1);
}

/// Every staged row is absorbed exactly once across background refits and
/// the final flush, and versions advance monotonically.
#[test]
fn ingestion_stream_is_absorbed_exactly_once() {
    let _g = gate();
    let sched = Scheduler::new(
        session(200, 2, 94),
        SchedulerConfig {
            refit_rows_threshold: 25,
            refit_staleness_s: 1e3,
            max_pending: None,
            ..SchedulerConfig::default()
        },
    );
    let mut sent = 0usize;
    for burst in 0..8u64 {
        let rows = 10 + (burst as usize % 3); // 10/11/12-row bursts
        sent += rows;
        sched.ingest(synthetic::dense_classification(rows, 8, 95 + burst));
    }
    let _ = sched.flush();
    assert_eq!(sched.staged_rows(), 0, "flush must drain the buffer");
    assert_eq!(sched.current_n(), 200 + sent, "no row lost or duplicated");
    let report = sched.report();
    assert_eq!(report.ingested_rows, sent as u64);
    assert!(report.publishes >= 1);
    // the final snapshot serves the fully-grown dataset
    let snap = sched.snapshot();
    let idx = [0usize, 199, 200 + sent - 1];
    assert_eq!(snap.predict(&idx).len(), 3);
}

/// A full concurrent closed loop (storm + append stream + background
/// refits) must neither grow the process thread count nor leave threads
/// behind when the scheduler is dropped.
#[test]
fn concurrent_storm_leaks_no_threads() {
    let _g = gate();
    let sess = session(240, 4, 96);
    let workers = sess.workers();
    assert_eq!(workers, 4);
    let sched = Scheduler::new(
        sess,
        SchedulerConfig {
            refit_rows_threshold: 30,
            refit_staleness_s: 0.05,
            max_pending: None,
            ..SchedulerConfig::default()
        },
    );
    // warm up each path once (predict, ingest→background refit, flush)
    let _ = sched.predict(&[0, 1, 2]);
    sched.ingest(synthetic::dense_classification(30, 8, 97));
    let _ = sched.flush();
    let baseline = settled_census(usize::MAX - 1);

    let storm = StormConfig {
        readers: 3,
        predicts: 90,
        predict_batch: 64,
        appends: 3,
        rows_per_append: 15,
    };
    let report = drive_concurrent(&sched, &storm, 98);
    assert_eq!(report.predicts, 90 + 1); // the storm plus the warm-up predict
    let after = settled_census(baseline);
    assert!(
        after <= baseline,
        "concurrent storm grew threads: baseline={baseline}, after={after}"
    );

    // dropping the scheduler joins the writer thread and the pool workers
    drop(sched);
    let target = baseline.saturating_sub(workers);
    let end = settled_census(target);
    if end > 0 {
        // census is 0 on non-Linux; only assert where it means something
        assert!(
            end <= target,
            "scheduler drop did not join its threads: baseline={baseline}, end={end}"
        );
    }
}
