//! Executor-equivalence guarantees for the persistent worker pool: models
//! trained under `Pool`, `Threads` and `Sequential` executors must be
//! **bit-wise identical** for the replica solvers (`dom`, `numa`) — the
//! pool changes where worker jobs run, never what they compute or the
//! order their deltas are reduced in. This extends the two-executor
//! guarantee of `solver_equivalence.rs` to the pool path.

use parlin::data::synthetic;
use parlin::glm::Objective;
use parlin::solver::exec::Executor;
use parlin::solver::pool::WorkerPool;
use parlin::solver::{dom, numa, train, ExecPolicy, SolverConfig, Variant};
use parlin::sysinfo::Topology;

fn logistic(n: usize) -> Objective {
    Objective::Logistic { lambda: 1.0 / n as f64 }
}

/// Fixed-epoch config so trajectories (not just fixed points) must agree.
fn fixed_epochs(n: usize, threads: usize, epochs: usize) -> SolverConfig {
    SolverConfig::new(logistic(n))
        .with_threads(threads)
        .with_tol(0.0)
        .with_max_epochs(epochs)
}

#[test]
fn dom_pool_threads_sequential_bitwise_identical_dense() {
    let ds = synthetic::dense_classification(400, 16, 21);
    for threads in [2usize, 4, 8] {
        let cfg = fixed_epochs(400, threads, 12);
        let pool = Executor::Pool(WorkerPool::new(threads, &Topology::flat(threads)));
        let p = dom::train_domesticated_exec(&ds, &cfg, &pool);
        let t = dom::train_domesticated_exec(&ds, &cfg, &Executor::Threads);
        let s = dom::train_domesticated_exec(&ds, &cfg, &Executor::Sequential);
        assert_eq!(p.state.alpha, t.state.alpha, "dom α pool vs threads, T={threads}");
        assert_eq!(p.state.alpha, s.state.alpha, "dom α pool vs sequential, T={threads}");
        assert_eq!(p.state.v, t.state.v, "dom v pool vs threads, T={threads}");
        assert_eq!(p.state.v, s.state.v, "dom v pool vs sequential, T={threads}");
    }
}

#[test]
fn dom_pool_bitwise_identical_sparse() {
    let ds = synthetic::sparse_classification(600, 150, 0.05, 22);
    let cfg = fixed_epochs(600, 4, 10);
    let pool = Executor::Pool(WorkerPool::new(4, &Topology::flat(4)));
    let p = dom::train_domesticated_exec(&ds, &cfg, &pool);
    let s = dom::train_domesticated_exec(&ds, &cfg, &Executor::Sequential);
    assert_eq!(p.state.alpha, s.state.alpha);
    assert_eq!(p.state.v, s.state.v);
}

#[test]
fn numa_pool_threads_sequential_bitwise_identical() {
    let ds = synthetic::dense_classification(360, 12, 23);
    let topo = Topology::uniform(2, 4);
    for threads in [4usize, 8] {
        let cfg = fixed_epochs(360, threads, 10);
        // pool laid out on the *same* topology the solver partitions by,
        // so node-tagged jobs land on that node's bucket queues
        let pool = Executor::Pool(WorkerPool::new(threads, &topo));
        let p = numa::train_numa_exec(&ds, &cfg, &topo, &pool);
        let t = numa::train_numa_exec(&ds, &cfg, &topo, &Executor::Threads);
        let s = numa::train_numa_exec(&ds, &cfg, &topo, &Executor::Sequential);
        assert_eq!(p.state.alpha, t.state.alpha, "numa α pool vs threads, T={threads}");
        assert_eq!(p.state.alpha, s.state.alpha, "numa α pool vs sequential, T={threads}");
        assert_eq!(p.state.v, t.state.v, "numa v pool vs threads, T={threads}");
        assert_eq!(p.state.v, s.state.v, "numa v pool vs sequential, T={threads}");
    }
}

/// The front door honours `ExecPolicy`: `train()` under Pool / Threads /
/// Sequential policies produces identical models for both replica
/// variants (the config-level version of the executor guarantee).
#[test]
fn front_door_exec_policies_identical() {
    let ds = synthetic::dense_classification(300, 10, 24);
    let topo = Topology::uniform(2, 2);
    for variant in [Variant::Domesticated, Variant::Numa] {
        let base = SolverConfig::new(logistic(300))
            .with_variant(variant)
            .with_threads(4)
            .with_tol(0.0)
            .with_max_epochs(8)
            .with_topology(topo.clone());
        let p = train(&ds, &base.clone().with_exec(ExecPolicy::Pool));
        let t = train(&ds, &base.clone().with_exec(ExecPolicy::Threads));
        let s = train(&ds, &base.clone().with_exec(ExecPolicy::Sequential));
        assert_eq!(p.state.alpha, t.state.alpha, "{variant:?}: pool vs threads");
        assert_eq!(p.state.alpha, s.state.alpha, "{variant:?}: pool vs sequential");
        assert_eq!(p.state.v, t.state.v, "{variant:?}: v pool vs threads");
    }
}

/// Non-logistic objectives go through the same worker plumbing — keep the
/// pool bit-exact there too.
#[test]
fn pool_identical_across_objectives() {
    let ds = synthetic::dense_classification(250, 8, 25);
    for obj in [
        Objective::Hinge { lambda: 1.0 / 250.0 },
        Objective::Ridge { lambda: 0.05 },
    ] {
        let cfg = SolverConfig::new(obj)
            .with_threads(3)
            .with_tol(0.0)
            .with_max_epochs(6);
        let pool = Executor::Pool(WorkerPool::new(3, &Topology::flat(3)));
        let p = dom::train_domesticated_exec(&ds, &cfg, &pool);
        let s = dom::train_domesticated_exec(&ds, &cfg, &Executor::Sequential);
        assert_eq!(p.state.alpha, s.state.alpha, "{obj:?}");
        assert_eq!(p.state.v, s.state.v, "{obj:?}");
    }
}

/// One pool serves many consecutive dispatch rounds of one run AND many
/// runs in sequence (merge rounds reuse queues — nothing is respawned).
#[test]
fn one_pool_reused_across_runs_stays_exact() {
    let ds = synthetic::dense_classification(200, 10, 26);
    let pool = Executor::Pool(WorkerPool::new(4, &Topology::flat(4)));
    let mut cfg = fixed_epochs(200, 4, 5);
    cfg.merges_per_epoch = 4; // 20 dispatch rounds per run over one pool
    let reference = dom::train_domesticated_exec(&ds, &cfg, &Executor::Sequential);
    for run in 0..5 {
        let out = dom::train_domesticated_exec(&ds, &cfg, &pool);
        assert_eq!(out.state.alpha, reference.state.alpha, "run {run} drifted");
        assert_eq!(out.state.v, reference.state.v, "run {run} drifted");
    }
}
