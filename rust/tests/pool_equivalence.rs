//! Executor-equivalence guarantees for the persistent worker pool: models
//! trained under `Pool`, `Threads` and `Sequential` executors must be
//! **bit-wise identical** for the replica solvers (`dom`, `numa`) — the
//! pool changes where worker jobs run, never what they compute or the
//! order their deltas are reduced in. This extends the two-executor
//! guarantee of `solver_equivalence.rs` to the pool path.

use parlin::data::synthetic;
use parlin::glm::Objective;
use parlin::solver::exec::Executor;
use parlin::solver::pool::WorkerPool;
use parlin::solver::{
    dom, numa, train, BucketPolicy, ExecPolicy, LayoutPolicy, SolverConfig, Variant,
};
use parlin::sysinfo::Topology;

fn logistic(n: usize) -> Objective {
    Objective::Logistic { lambda: 1.0 / n as f64 }
}

/// Fixed-epoch config so trajectories (not just fixed points) must agree.
fn fixed_epochs(n: usize, threads: usize, epochs: usize) -> SolverConfig {
    SolverConfig::new(logistic(n))
        .with_threads(threads)
        .with_tol(0.0)
        .with_max_epochs(epochs)
}

#[test]
fn dom_pool_threads_sequential_bitwise_identical_dense() {
    let ds = synthetic::dense_classification(400, 16, 21);
    for threads in [2usize, 4, 8] {
        let cfg = fixed_epochs(400, threads, 12);
        let pool = Executor::Pool(WorkerPool::new(threads, &Topology::flat(threads)));
        let p = dom::train_domesticated_exec(&ds, &cfg, &pool);
        let t = dom::train_domesticated_exec(&ds, &cfg, &Executor::Threads);
        let s = dom::train_domesticated_exec(&ds, &cfg, &Executor::Sequential);
        assert_eq!(p.state.alpha, t.state.alpha, "dom α pool vs threads, T={threads}");
        assert_eq!(p.state.alpha, s.state.alpha, "dom α pool vs sequential, T={threads}");
        assert_eq!(p.state.v, t.state.v, "dom v pool vs threads, T={threads}");
        assert_eq!(p.state.v, s.state.v, "dom v pool vs sequential, T={threads}");
    }
}

#[test]
fn dom_pool_bitwise_identical_sparse() {
    let ds = synthetic::sparse_classification(600, 150, 0.05, 22);
    let cfg = fixed_epochs(600, 4, 10);
    let pool = Executor::Pool(WorkerPool::new(4, &Topology::flat(4)));
    let p = dom::train_domesticated_exec(&ds, &cfg, &pool);
    let s = dom::train_domesticated_exec(&ds, &cfg, &Executor::Sequential);
    assert_eq!(p.state.alpha, s.state.alpha);
    assert_eq!(p.state.v, s.state.v);
}

#[test]
fn numa_pool_threads_sequential_bitwise_identical() {
    let ds = synthetic::dense_classification(360, 12, 23);
    let topo = Topology::uniform(2, 4);
    for threads in [4usize, 8] {
        let cfg = fixed_epochs(360, threads, 10);
        // pool laid out on the *same* topology the solver partitions by,
        // so node-tagged jobs land on that node's bucket queues
        let pool = Executor::Pool(WorkerPool::new(threads, &topo));
        let p = numa::train_numa_exec(&ds, &cfg, &topo, &pool);
        let t = numa::train_numa_exec(&ds, &cfg, &topo, &Executor::Threads);
        let s = numa::train_numa_exec(&ds, &cfg, &topo, &Executor::Sequential);
        assert_eq!(p.state.alpha, t.state.alpha, "numa α pool vs threads, T={threads}");
        assert_eq!(p.state.alpha, s.state.alpha, "numa α pool vs sequential, T={threads}");
        assert_eq!(p.state.v, t.state.v, "numa v pool vs threads, T={threads}");
        assert_eq!(p.state.v, s.state.v, "numa v pool vs sequential, T={threads}");
    }
}

/// The front door honours `ExecPolicy`: `train()` under Pool / Threads /
/// Sequential policies produces identical models for both replica
/// variants (the config-level version of the executor guarantee).
#[test]
fn front_door_exec_policies_identical() {
    let ds = synthetic::dense_classification(300, 10, 24);
    let topo = Topology::uniform(2, 2);
    for variant in [Variant::Domesticated, Variant::Numa] {
        let base = SolverConfig::new(logistic(300))
            .with_variant(variant)
            .with_threads(4)
            .with_tol(0.0)
            .with_max_epochs(8)
            .with_topology(topo.clone());
        let p = train(&ds, &base.clone().with_exec(ExecPolicy::Pool));
        let t = train(&ds, &base.clone().with_exec(ExecPolicy::Threads));
        let s = train(&ds, &base.clone().with_exec(ExecPolicy::Sequential));
        assert_eq!(p.state.alpha, t.state.alpha, "{variant:?}: pool vs threads");
        assert_eq!(p.state.alpha, s.state.alpha, "{variant:?}: pool vs sequential");
        assert_eq!(p.state.v, t.state.v, "{variant:?}: v pool vs threads");
    }
}

/// Non-logistic objectives go through the same worker plumbing — keep the
/// pool bit-exact there too.
#[test]
fn pool_identical_across_objectives() {
    let ds = synthetic::dense_classification(250, 8, 25);
    for obj in [
        Objective::Hinge { lambda: 1.0 / 250.0 },
        Objective::Ridge { lambda: 0.05 },
    ] {
        let cfg = SolverConfig::new(obj)
            .with_threads(3)
            .with_tol(0.0)
            .with_max_epochs(6);
        let pool = Executor::Pool(WorkerPool::new(3, &Topology::flat(3)));
        let p = dom::train_domesticated_exec(&ds, &cfg, &pool);
        let s = dom::train_domesticated_exec(&ds, &cfg, &Executor::Sequential);
        assert_eq!(p.state.alpha, s.state.alpha, "{obj:?}");
        assert_eq!(p.state.v, s.state.v, "{obj:?}");
    }
}

/// The tentpole guarantee of the shard-resident interleaved layout: for
/// every solver variant, training over `LayoutPolicy::Interleaved` (fused
/// single-stream kernels + software prefetch) produces **bit-wise
/// identical** `alpha` and `v` to `LayoutPolicy::Csc` (the split
/// two-pass `DataMatrix` walk). The layout changes how bytes are
/// streamed, never a single floating-point operation or its order.
///
/// `wild` runs under the `Sequential` executor: its multi-threaded mode
/// is intentionally racy, so only the deterministic dispatch admits a
/// bit-wise claim (the kernels themselves are identical either way).
#[test]
fn layouts_bitwise_identical_across_all_solvers() {
    let dense = synthetic::dense_classification(420, 14, 27);
    let sparse = synthetic::sparse_classification(500, 120, 0.06, 28);
    let topo = Topology::uniform(2, 2);
    for variant in [
        Variant::Sequential,
        Variant::Domesticated,
        Variant::Numa,
        Variant::Wild,
    ] {
        let mut base = SolverConfig::new(logistic(420))
            .with_variant(variant)
            .with_threads(if variant == Variant::Sequential { 1 } else { 4 })
            .with_topology(topo.clone())
            .with_bucket(BucketPolicy::Fixed(8))
            .with_tol(0.0)
            .with_max_epochs(8);
        if variant == Variant::Wild {
            base = base.with_exec(ExecPolicy::Sequential);
        }
        let csc = train(&dense, &base.clone().with_layout(LayoutPolicy::Csc));
        let il = train(&dense, &base.clone().with_layout(LayoutPolicy::Interleaved));
        assert_eq!(csc.state.alpha, il.state.alpha, "{variant:?} α, dense");
        assert_eq!(csc.state.v, il.state.v, "{variant:?} v, dense");

        let base = base.with_threads(if variant == Variant::Sequential { 1 } else { 3 });
        let csc = train(&sparse, &base.clone().with_layout(LayoutPolicy::Csc));
        let il = train(&sparse, &base.clone().with_layout(LayoutPolicy::Interleaved));
        assert_eq!(csc.state.alpha, il.state.alpha, "{variant:?} α, sparse");
        assert_eq!(csc.state.v, il.state.v, "{variant:?} v, sparse");
    }
}

/// Layout equivalence holds for the non-logistic duals too (ridge's
/// closed-form step and hinge's box-clipped step go through the same
/// fused kernel).
#[test]
fn layouts_bitwise_identical_across_objectives() {
    let ds = synthetic::dense_classification(260, 9, 29);
    for obj in [
        Objective::Hinge { lambda: 1.0 / 260.0 },
        Objective::Ridge { lambda: 0.05 },
    ] {
        let base = SolverConfig::new(obj)
            .with_variant(Variant::Domesticated)
            .with_threads(3)
            .with_bucket(BucketPolicy::Fixed(4))
            .with_tol(0.0)
            .with_max_epochs(6);
        let csc = train(&ds, &base.clone().with_layout(LayoutPolicy::Csc));
        let il = train(&ds, &base.clone().with_layout(LayoutPolicy::Interleaved));
        assert_eq!(csc.state.alpha, il.state.alpha, "{obj:?}");
        assert_eq!(csc.state.v, il.state.v, "{obj:?}");
    }
}

/// Auto bucket policy + warm starts ride the same interleaved plumbing:
/// a warm interleaved refit resumes bit-wise from where a CSC run left
/// off (the layouts must be interchangeable *mid-trajectory*).
#[test]
fn layouts_interchangeable_mid_trajectory() {
    let ds = synthetic::sparse_classification(300, 60, 0.08, 30);
    let base = SolverConfig::new(logistic(300))
        .with_variant(Variant::Domesticated)
        .with_threads(2)
        .with_tol(0.0)
        .with_max_epochs(5);
    let first = train(&ds, &base.clone().with_layout(LayoutPolicy::Csc));
    let a = train(
        &ds,
        &base
            .clone()
            .with_layout(LayoutPolicy::Csc)
            .with_warm_start(first.state.clone()),
    );
    let b = train(
        &ds,
        &base
            .clone()
            .with_layout(LayoutPolicy::Interleaved)
            .with_warm_start(first.state.clone()),
    );
    assert_eq!(a.state.alpha, b.state.alpha);
    assert_eq!(a.state.v, b.state.v);
}

/// A caller-provided `layout_cache` (the serving session's resident
/// encoding) must be a pure reuse: bit-wise identical to a run that
/// builds its own layout, for matching geometry and for the wild
/// per-example walk where any single shard over the same examples fits.
#[test]
fn layout_cache_reuse_is_bitwise_identical() {
    use parlin::data::ShardedLayout;
    let ds = synthetic::sparse_classification(400, 90, 0.07, 31);
    let bucket = 8usize;
    let layout = std::sync::Arc::new(ShardedLayout::single(
        &ds.x,
        &parlin::solver::Buckets::new(400, bucket),
    ));
    for variant in [Variant::Sequential, Variant::Domesticated, Variant::Wild] {
        let mut base = SolverConfig::new(logistic(400))
            .with_variant(variant)
            .with_threads(if variant == Variant::Sequential { 1 } else { 3 })
            .with_bucket(BucketPolicy::Fixed(bucket))
            .with_tol(0.0)
            .with_max_epochs(6);
        if variant == Variant::Wild {
            base = base.with_exec(ExecPolicy::Sequential);
        }
        let own = train(&ds, &base.clone());
        let shared = train(&ds, &base.clone().with_layout_cache(layout.clone()));
        assert_eq!(own.state.alpha, shared.state.alpha, "{variant:?} α cached vs built");
        assert_eq!(own.state.v, shared.state.v, "{variant:?} v cached vs built");
    }
    // mismatched geometry must fall back to a private build, not misuse
    // the cache: same data, different bucket size
    let cfg = SolverConfig::new(logistic(400))
        .with_variant(Variant::Sequential)
        .with_bucket(BucketPolicy::Fixed(4))
        .with_tol(0.0)
        .with_max_epochs(6);
    let own = train(&ds, &cfg.clone());
    let shared = train(&ds, &cfg.with_layout_cache(layout));
    assert_eq!(own.state.alpha, shared.state.alpha, "mismatched cache must be ignored");
    assert_eq!(own.state.v, shared.state.v);
}

/// One pool serves many consecutive dispatch rounds of one run AND many
/// runs in sequence (merge rounds reuse queues — nothing is respawned).
#[test]
fn one_pool_reused_across_runs_stays_exact() {
    let ds = synthetic::dense_classification(200, 10, 26);
    let pool = Executor::Pool(WorkerPool::new(4, &Topology::flat(4)));
    let mut cfg = fixed_epochs(200, 4, 5);
    cfg.merges_per_epoch = 4; // 20 dispatch rounds per run over one pool
    let reference = dom::train_domesticated_exec(&ds, &cfg, &Executor::Sequential);
    for run in 0..5 {
        let out = dom::train_domesticated_exec(&ds, &cfg, &pool);
        assert_eq!(out.state.alpha, reference.state.alpha, "run {run} drifted");
        assert_eq!(out.state.v, reference.state.v, "run {run} drifted");
    }
}
