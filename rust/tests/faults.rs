//! Fault-containment guarantees of the self-healing serve path, driven
//! end-to-end through the deterministic injection harness
//! (`parlin::fault`):
//!
//! (a) **Containment** — a panic injected mid-refit is caught, the
//!     session rolls back to the last-known-good model, and predicts are
//!     bit-wise identical to the pre-fault answers; a later clean refit
//!     publishes normally (no poisoned mutex, no wedged writer).
//! (b) **Self-healing drain** — a background drain thread killed at its
//!     entry is detected, counted, and respawned by the next request that
//!     finds staged rows; the respawned drain absorbs and publishes.
//! (c) **Health-gated publish** — a refit whose model comes out NaN is
//!     refused at the publish gate on every retry; the offending batch is
//!     quarantined to the dead letter (holding exactly those rows) and
//!     the serving version never changes.
//! (d) **No thread leaks** — repeated kill-and-recover cycles leave the
//!     process thread census flat (shared `/proc/self/status` census).
//!
//! The tests serialize on a mutex: (d) counts OS threads, and armed fault
//! plans are process-wide state.

use parlin::data::synthetic;
use parlin::data::DenseMatrix;
use parlin::fault::FaultPlan;
use parlin::glm::Objective;
use parlin::obs::diag::{DiagCapture, Level};
use parlin::serve::{Scheduler, SchedulerConfig, ServeError, Session};
use parlin::solver::{SolverConfig, Variant};
use parlin::sysinfo::Topology;
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

#[path = "common/census.rs"]
mod census;
use census::settled_census;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn session(n: usize, seed: u64) -> Session<DenseMatrix> {
    let ds = synthetic::dense_classification(n, 6, seed);
    let cfg = SolverConfig::new(Objective::Logistic {
        lambda: 1.0 / n as f64,
    })
    .with_variant(Variant::Domesticated)
    .with_threads(2)
    .with_topology(Topology::flat(2))
    .with_tol(1e-3)
    .with_max_epochs(200);
    Session::new(ds, cfg)
}

/// Poll `f` until it holds; panic with `what` after ~10s. The drain
/// thread's death and respawn are asynchronous, so these tests wait on
/// observable counters instead of sleeping fixed amounts.
fn wait_until(what: &str, mut f: impl FnMut() -> bool) {
    for _ in 0..2000 {
        if f() {
            return;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("timed out waiting for {what}");
}

/// (a) An injected mid-refit panic must be contained: version 0 keeps
/// serving bit-identical answers, the batch is quarantined, and — the
/// no-poisoned-mutex half — a later clean refit publishes version 1.
#[test]
fn injected_refit_panic_leaves_last_good_serving() {
    let _g = gate();
    let sched = Scheduler::new(
        session(150, 21),
        SchedulerConfig {
            // thresholds out of reach: this test drives drains via flush
            refit_rows_threshold: 1_000_000,
            refit_staleness_s: 1e6,
            max_pending: None,
            drain_max_retries: 0,
            ..SchedulerConfig::default()
        },
    );
    let idx: Vec<usize> = (0..40).map(|i| (i * 7) % 150).collect();
    let before = sched.predict(&idx);
    assert_eq!(before.version, 0);

    // x8 so the panic outlasts any retry budget changes
    let guard = FaultPlan::parse("panic@epoch#1x8", 7).unwrap().arm();
    sched.ingest(synthetic::dense_classification(20, 6, 22));
    let failed = sched.flush().expect("rows were staged");
    match failed {
        Err(ServeError::RefitPanicked { kind: "refit-rows", .. }) => {}
        other => panic!("expected a contained refit panic, got {other:?}"),
    }
    drop(guard);

    let after = sched.predict(&idx);
    assert_eq!(after.version, 0, "the failed refit must not have published");
    assert_eq!(after.margins, before.margins, "v0 must serve bit-identical answers");
    let report = sched.report();
    assert!(!report.health.is_healthy());
    assert_eq!(report.rollbacks, 1);
    assert_eq!(report.quarantined_rows, 20);
    assert_eq!(sched.dead_letter_rows(), 20);

    // the writer path survived: a clean refit absorbs and publishes
    sched.ingest(synthetic::dense_classification(10, 6, 23));
    let clean = sched.flush().expect("rows were staged").expect("clean refit");
    assert_eq!(clean.kind, "refit-rows");
    assert_eq!(clean.n, 160);
    assert_eq!(sched.version(), 1);
    assert!(sched.health().is_healthy(), "a clean publish must restore health");
}

/// (b) A drain thread killed at its entry leaves the staged batch in
/// place; the next request that finds it respawns the drain, which then
/// absorbs and publishes.
#[test]
fn dead_drain_thread_is_respawned_and_publishes() {
    let _g = gate();
    let sched = Scheduler::new(
        session(140, 71),
        SchedulerConfig {
            refit_rows_threshold: 10,
            refit_staleness_s: 1e6,
            max_pending: None,
            ..SchedulerConfig::default()
        },
    );
    let guard = FaultPlan::parse("panic@drain#1", 3).unwrap().arm();
    // crossing the threshold spawns the (doomed) background drain
    sched.ingest(synthetic::dense_classification(10, 6, 72));
    wait_until("the injected drain death", || sched.report().drain_deaths >= 1);
    assert_eq!(sched.staged_rows(), 10, "the dead drain must not have taken the batch");
    assert_eq!(sched.version(), 0);
    assert!(!sched.health().is_healthy());
    drop(guard);

    // any request that sees the staged rows brings the drain back
    wait_until("the drain respawn", || {
        let _ = sched.predict(&[0, 1, 2]);
        sched.report().drain_respawns >= 1
    });
    let _ = sched.flush(); // join the respawned writer
    assert_eq!(sched.staged_rows(), 0);
    assert_eq!(sched.version(), 1);
    assert_eq!(sched.current_n(), 150);
    let report = sched.report();
    assert_eq!(report.drain_deaths, 1);
    assert_eq!(report.drain_respawns, 1);
    assert!(report.health.is_healthy(), "a recovered drain must restore health");
}

/// (c) A refit that trains to a NaN model is refused by the publish
/// health gate on the first attempt *and* its retry; the batch lands in
/// the dead letter holding exactly those rows, and the serving version
/// never moves.
#[test]
fn nan_refit_never_publishes_and_quarantines() {
    let _g = gate();
    let sched = Scheduler::new(
        session(150, 31),
        SchedulerConfig {
            refit_rows_threshold: 1_000_000,
            refit_staleness_s: 1e6,
            max_pending: None,
            drain_max_retries: 1,
            ..SchedulerConfig::default()
        },
    );
    let idx: Vec<usize> = (0..32).map(|i| (i * 11) % 150).collect();
    let before = sched.predict(&idx);

    // x4 covers the initial attempt plus the retry (hits 1 and 2)
    let guard = FaultPlan::parse("nan@publish#1x4", 5).unwrap().arm();
    sched.ingest(synthetic::dense_classification(12, 6, 32));
    let failed = sched.flush().expect("rows were staged");
    assert!(
        matches!(failed, Err(ServeError::NonFinite { .. })),
        "a NaN model must be refused by the health gate, got {failed:?}"
    );
    drop(guard);

    let after = sched.predict(&idx);
    assert_eq!(after.version, 0);
    assert_eq!(after.margins, before.margins);
    let report = sched.report();
    assert_eq!(report.rollbacks, 2, "the initial attempt and its retry both roll back");
    assert_eq!(report.publish_rejected, 2);
    assert_eq!(report.drain_retries, 1);
    assert_eq!(report.quarantined_rows, 12);

    // the dead letter holds exactly the quarantined batch
    let letters = sched.dead_letter();
    assert_eq!(letters.len(), 1);
    assert_eq!(letters[0].n(), 12);
    assert_eq!(letters[0].y, synthetic::dense_classification(12, 6, 32).y);

    // a clean batch afterwards publishes normally
    sched.ingest(synthetic::dense_classification(8, 6, 33));
    let clean = sched.flush().expect("rows were staged").expect("clean refit");
    assert_eq!(clean.n, 158);
    assert_eq!(sched.version(), 1);
    assert!(sched.health().is_healthy());
}

/// (d) Three kill-and-recover cycles leave the thread census flat: every
/// dead drain is joined before its replacement spawns, and the respawned
/// writers exit after publishing.
#[test]
fn recoveries_leak_no_threads() {
    let _g = gate();
    let sched = Scheduler::new(
        session(140, 41),
        SchedulerConfig {
            refit_rows_threshold: 12,
            refit_staleness_s: 1e6,
            max_pending: None,
            ..SchedulerConfig::default()
        },
    );
    // warm the drain path once, then take the baseline census
    sched.ingest(synthetic::dense_classification(12, 6, 42));
    let _ = sched.flush();
    assert_eq!(sched.staged_rows(), 0);
    let baseline = settled_census(usize::MAX - 1);

    for round in 0..3u64 {
        let guard = FaultPlan::parse("panic@drain#1", round).unwrap().arm();
        sched.ingest(synthetic::dense_classification(12, 6, 43 + round));
        wait_until("the injected drain death", || {
            sched.report().drain_deaths >= round + 1
        });
        drop(guard);
        wait_until("the drain respawn", || {
            let _ = sched.predict(&[0, 1, 2]);
            sched.report().drain_respawns >= round + 1
        });
        let _ = sched.flush(); // join this round's respawned writer
        assert_eq!(sched.staged_rows(), 0);
    }

    let report = sched.report();
    assert_eq!(report.drain_deaths, 3);
    assert_eq!(report.drain_respawns, 3);
    assert!(report.health.is_healthy());
    assert_eq!(sched.current_n(), 140 + 4 * 12, "every batch absorbed exactly once");
    let after = settled_census(baseline);
    assert!(
        after <= baseline,
        "kill-and-recover cycles grew threads: baseline={baseline}, after={after}"
    );
}

/// An invalid λ is a typed error from the session, before any state is
/// touched — not a panic, not a silent NaN model.
#[test]
fn invalid_lambda_is_a_typed_error_not_a_panic() {
    let _g = gate();
    let mut sess = session(130, 51);
    let w0 = sess.weights().to_vec();
    for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
        match sess.partial_fit_lambda(bad) {
            Err(ServeError::InvalidLambda { .. }) => {}
            other => panic!("λ={bad} must be a typed error, got {other:?}"),
        }
        assert_eq!(sess.weights(), &w0[..], "a rejected λ must not touch the model");
    }
    let ok = sess.partial_fit_lambda(1.0 / 130.0).expect("clean λ refit");
    assert!(ok.epochs >= 1);
}

/// Satellite: rows carrying non-finite values are refused at `ingest` —
/// counted, diagnosed at Warn, and never staged.
#[test]
fn nonfinite_ingest_is_rejected_at_the_door() {
    let _g = gate();
    let sched = Scheduler::new(session(120, 61), SchedulerConfig::default());
    let mut bad = synthetic::dense_classification(6, 6, 62);
    bad.y[2] = f64::NAN;
    let cap = DiagCapture::start();
    sched.ingest(bad);
    let recs = cap.take();
    drop(cap);
    assert!(
        recs.iter()
            .any(|r| r.level == Level::Warn && r.message.contains("non-finite")),
        "the rejection must be diagnosed: {recs:?}"
    );
    assert_eq!(sched.staged_rows(), 0);
    let report = sched.report();
    assert_eq!(report.ingest_rejected_rows, 6);
    assert_eq!(report.ingested_rows, 0);
    assert!(sched.flush().is_none(), "nothing may have been staged");
}

/// Cooperative cancellation: a refit dragged out by an injected
/// `delay@epoch` plan is aborted at its next epoch-boundary checkpoint
/// when the session's [`CancelToken`] trips — the writer returns the
/// *typed* `ServeError::Cancelled` (not a generic panic), the session
/// rolls back to last-known-good (bit-identical predicts, n unchanged),
/// the thread census stays flat, and after `reset()` the very same
/// session refits cleanly. This is the lever the drain watchdog pulls to
/// force-recover a stuck drain instead of merely flagging it.
#[test]
fn cancelled_refit_is_typed_rolled_back_and_recoverable() {
    let _g = gate();
    let mut sess = session(120, 91);
    let idx: Vec<usize> = (0..24).map(|i| (i * 5) % 120).collect();
    let before = sess.predict(&idx);
    let w0 = sess.weights().to_vec();
    let baseline = settled_census(usize::MAX - 1);

    // every refit epoch stalls 80ms: the "stuck drain" the watchdog sees
    let guard = FaultPlan::parse("delay:80@epoch#1x8", 11).unwrap().arm();
    let token = sess.cancel_token();

    // (1) pre-armed token: the stuck refit dies at its first checkpoint
    token.cancel();
    let rows = synthetic::dense_classification(15, 6, 92);
    match sess.partial_fit_rows(&rows) {
        Err(ServeError::Cancelled { kind: "refit-rows", epoch: 1 }) => {}
        other => panic!("expected the typed cancellation at epoch 1, got {other:?}"),
    }
    assert_eq!(sess.n(), 120, "the cancelled refit must roll the appended rows back");
    assert_eq!(sess.weights(), &w0[..], "…and the model");
    assert_eq!(sess.predict(&idx), before, "predicts stay bit-identical after rollback");

    // (2) mid-flight cancel: trip the token from another thread while the
    // first delayed epoch grinds — the abort lands at the next boundary
    token.reset();
    let trip = {
        let token = token.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            token.cancel();
        })
    };
    match sess.partial_fit_rows(&rows) {
        Err(ServeError::Cancelled { kind: "refit-rows", epoch }) => {
            assert!(epoch >= 1, "cancellation is an epoch-boundary event, got {epoch}")
        }
        other => panic!("expected a mid-flight cancellation, got {other:?}"),
    }
    trip.join().unwrap();
    assert_eq!(sess.n(), 120);
    assert_eq!(sess.predict(&idx), before);

    // (3) recovery: reset + disarm, the same session publishes cleanly
    token.reset();
    drop(guard);
    let clean = sess.partial_fit_rows(&rows).expect("reset token must allow a clean refit");
    assert_eq!(clean.kind, "refit-rows");
    assert_eq!(clean.n, 135);
    assert_eq!(sess.n(), 135);

    let after = settled_census(baseline);
    assert!(
        after <= baseline,
        "cancelled refits leaked threads: baseline={baseline}, after={after}"
    );
}

/// Flight forensics: with a tracing session live and the flight recorder
/// armed, the contained refit panic of test (a) leaves a dump pair on
/// disk — a chrome-trace JSON whose trailing window holds the
/// `snapshot_rollback` event, plus a metrics-delta sidecar counting the
/// rollback. CI re-parses the same dump from the outside with
/// `examples/check_trace.rs --require rollback`.
#[test]
fn injected_panic_leaves_a_flight_dump_with_rollback_and_metrics_delta() {
    use parlin::obs::{ObsConfig, TraceSession, DEFAULT_RING_CAPACITY};
    let _g = gate();
    // lock order as the CLI takes it: trace session first, then flight
    let trace = TraceSession::start(ObsConfig::on(DEFAULT_RING_CAPACITY));
    let dir = std::env::temp_dir().join(format!("parlin-flight-faults-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let flight =
        parlin::obs::flight::install(&dir, 30.0).expect("arming the flight recorder");

    let sched = Scheduler::new(
        session(150, 81),
        SchedulerConfig {
            refit_rows_threshold: 1_000_000,
            refit_staleness_s: 1e6,
            max_pending: None,
            drain_max_retries: 0,
            ..SchedulerConfig::default()
        },
    );
    let guard = FaultPlan::parse("panic@epoch#1x8", 9).unwrap().arm();
    sched.ingest(synthetic::dense_classification(20, 6, 82));
    let failed = sched.flush().expect("rows were staged");
    assert!(failed.is_err(), "the injected panic must fail the refit: {failed:?}");
    drop(guard);
    assert_eq!(sched.report().rollbacks, 1);

    drop(flight); // disarm before the tracing session ends
    drop(trace.finish());

    let files: Vec<std::path::PathBuf> = std::fs::read_dir(&dir)
        .expect("the dump directory must exist")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .collect();
    let dump = files
        .iter()
        .find(|p| {
            p.extension().and_then(|e| e.to_str()) == Some("json")
                && p.to_string_lossy().contains("snapshot-rollback")
        })
        .unwrap_or_else(|| panic!("no rollback dump among {files:?}"));
    let json = std::fs::read_to_string(dump).unwrap();
    assert!(
        json.trim_start().starts_with("{\"traceEvents\""),
        "the dump must be a chrome trace check_trace.rs can parse"
    );
    assert!(
        json.contains("\"snapshot_rollback\""),
        "the dump window must hold the rollback event"
    );

    let sidecar = dump.to_string_lossy().replace(".json", ".metrics.txt");
    let metrics = std::fs::read_to_string(&sidecar).expect("metrics delta sidecar");
    assert!(metrics.starts_with("flight dump: snapshot_rollback"), "{metrics}");
    assert!(
        metrics.lines().any(|l| l.contains("sched.rollbacks")),
        "the delta must carry the rollback counter:\n{metrics}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
