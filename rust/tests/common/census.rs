//! Shared OS-thread census for leak tests (`pool_stress.rs`,
//! `serving.rs`): included via `#[path]` so both suites use one parser
//! and one settle policy.

/// Threads currently owned by this process (Linux: `/proc/self/status`;
/// elsewhere: 0, which degrades the assertions to leak-monotonicity).
pub fn thread_census() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines().find_map(|l| {
                l.strip_prefix("Threads:")
                    .and_then(|v| v.trim().parse::<usize>().ok())
            })
        })
        .unwrap_or(0)
}

/// Wait (bounded) for the kernel to reap exiting threads before counting.
pub fn settled_census(target_max: usize) -> usize {
    let mut count = thread_census();
    for _ in 0..200 {
        if count <= target_max {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
        count = thread_census();
    }
    count
}
