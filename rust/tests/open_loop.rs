//! Open-loop traffic-engine guarantees:
//!
//! (a) **Deterministic, rate-correct schedules** — the same seed
//!     reproduces the identical arrival schedule bit-for-bit, the Poisson
//!     draw hits the offered rate, the fixed process spaces arrivals
//!     exactly, and the ingest fraction controls the request mix.
//! (b) **Versioned determinism under open-loop load** — every predict
//!     served during an open-loop run (with an ingestion-triggered refit
//!     racing it) replays bit-wise against the retained snapshot of the
//!     version that served it, through both the sequential snapshot path
//!     and the plain batch path.
//! (c) **Admission control** — with `max_pending = 1` and the pool's only
//!     worker blocked, the one admitted reader holds the budget, every
//!     further `try_predict` is shed with the observed pending count, and
//!     the report's shed/served tallies match exactly.
//! (d) **No thread growth** — a full open-loop run (dispatchers, shedding,
//!     background refits, flush) leaves the process thread count where it
//!     started (the `/proc/self/status` census shared with
//!     `scheduler.rs`).
//!
//! The tests serialize on a mutex: (d) counts OS threads, so no sibling
//! test's pools may spawn or die while it runs.

use parlin::data::{synthetic, DenseMatrix};
use parlin::glm::Objective;
use parlin::serve::{
    arrival_schedule, drive_open_loop, ArrivalKind, ArrivalProcess, ModelSnapshot, OpenLoopConfig,
    PredictAdmission, Scheduler, SchedulerConfig, Session,
};
use parlin::solver::{SolverConfig, Variant};
use parlin::sysinfo::Topology;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

#[path = "common/census.rs"]
mod census;
use census::settled_census;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn session(n: usize, threads: usize, seed: u64) -> Session<DenseMatrix> {
    let ds = synthetic::dense_classification(n, 8, seed);
    let cfg = SolverConfig::new(Objective::Logistic {
        lambda: 1.0 / n as f64,
    })
    .with_variant(Variant::Domesticated)
    .with_threads(threads)
    .with_topology(Topology::uniform(2, threads.div_ceil(2)))
    .with_tol(1e-3)
    .with_max_epochs(250);
    Session::new(ds, cfg)
}

/// Poll until `cond` holds; panic after ~5s so a deadlock fails loudly
/// instead of hanging the suite.
fn wait_until(what: &str, cond: impl Fn() -> bool) {
    for _ in 0..5000 {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("timed out waiting for {what}");
}

#[test]
fn arrival_schedule_is_deterministic_and_rate_correct() {
    // same seed ⇒ the identical schedule, bit for bit
    let cfg = OpenLoopConfig {
        rate_per_s: 2000.0,
        duration_s: 1.0,
        process: ArrivalProcess::Poisson,
        seed: 5,
        ingest_fraction: 0.0,
        ..OpenLoopConfig::default()
    };
    let a = arrival_schedule(&cfg);
    assert_eq!(a, arrival_schedule(&cfg), "same seed must replay exactly");
    assert_ne!(
        a,
        arrival_schedule(&OpenLoopConfig { seed: 6, ..cfg.clone() }),
        "a different seed must produce a different schedule"
    );

    // the Poisson draw realizes the offered rate: E[arrivals] = rate ×
    // duration, and 2000 exponential gaps concentrate well within ±10%
    let realized = a.len() as f64 / cfg.duration_s;
    assert!(
        (realized - cfg.rate_per_s).abs() / cfg.rate_per_s < 0.10,
        "Poisson schedule realized {realized:.0} req/s, offered {:.0}",
        cfg.rate_per_s
    );
    for w in a.windows(2) {
        assert!(w[0].at_s < w[1].at_s, "arrival times must strictly increase");
    }

    // the fixed process is exact: arrival i at (i+1)/rate, no jitter
    let fixed = arrival_schedule(&OpenLoopConfig {
        rate_per_s: 800.0,
        duration_s: 0.25,
        process: ArrivalProcess::Fixed,
        ..cfg.clone()
    });
    assert!(!fixed.is_empty());
    for (i, arr) in fixed.iter().enumerate() {
        let want = (i + 1) as f64 / 800.0;
        assert!(
            (arr.at_s - want).abs() < 1e-9,
            "fixed arrival {i} at {} expected {want}",
            arr.at_s
        );
        assert_eq!(arr.kind, ArrivalKind::Predict);
    }

    // the ingest fraction controls the mix (drawn from the same seed)
    let mixed = arrival_schedule(&OpenLoopConfig {
        ingest_fraction: 0.1,
        ..cfg
    });
    let ingests = mixed.iter().filter(|x| x.kind == ArrivalKind::Ingest).count();
    let share = ingests as f64 / mixed.len() as f64;
    assert!(
        (0.05..0.15).contains(&share),
        "ingest share {share:.3} strayed from the configured 0.1"
    );
}

/// The acceptance-criterion test: predicts served by an open-loop run —
/// with an ingestion burst racing the dispatchers and publishing a new
/// version mid-run — replay bit-wise against the retained snapshot of the
/// version each one was served from.
#[test]
fn open_loop_predicts_replay_bitwise_for_their_version() {
    let _g = gate();
    let sched = Scheduler::new(
        session(300, 2, 71),
        SchedulerConfig {
            refit_rows_threshold: 40,
            refit_staleness_s: 1e3,
            max_pending: None,
            ..SchedulerConfig::default()
        },
    );
    // retain version 0 — it must stay fully servable throughout
    let snap0 = sched.snapshot();
    assert_eq!(snap0.version(), 0);

    let cfg = OpenLoopConfig {
        rate_per_s: 400.0,
        duration_s: 0.5,
        process: ArrivalProcess::Poisson,
        seed: 17,
        predict_batch: 32,
        ingest_fraction: 0.0,
        rows_per_ingest: 32,
        dispatchers: 3,
        record_outcomes: true,
    };
    let report = std::thread::scope(|scope| {
        let driver = scope.spawn(|| drive_open_loop(&sched, &cfg));
        // cross the row threshold mid-run so a background refit trains
        // and publishes version 1 while the dispatchers are serving
        std::thread::sleep(Duration::from_millis(100));
        sched.ingest(synthetic::dense_classification(40, 8, 72));
        driver.join().expect("open-loop driver panicked")
    });
    // the driver flushes on exit; this one is a no-op unless the ingest
    // raced past that flush on a heavily loaded box
    let _ = sched.flush();
    let snap1 = sched.snapshot();
    assert_eq!(snap1.version(), 1, "the ingested rows must have published v1");
    assert_eq!(snap1.n(), 340);
    assert_eq!(snap0.n(), 300, "the retained version must be untouched");

    // nothing shed (unbounded budget): every scheduled arrival has an
    // outcome on record
    assert_eq!(report.rejected_predicts, 0);
    assert_eq!(report.outcomes.len(), report.scheduled_arrivals);
    assert_eq!(report.served(), report.scheduled_arrivals);
    assert!(report.served() > 0, "a 0.5s schedule at 400 req/s must serve");

    let by_version = |v: u64| -> Arc<ModelSnapshot<DenseMatrix>> {
        match v {
            0 => Arc::clone(&snap0),
            1 => Arc::clone(&snap1),
            other => panic!("request served from unpublished version {other}"),
        }
    };
    for out in &report.outcomes {
        assert_eq!(out.kind, ArrivalKind::Predict);
        assert!(out.admitted);
        let version = out.version.expect("admitted predicts carry their version");
        let snap = by_version(version);
        let sequential = snap.predict(&out.idx);
        assert_eq!(
            out.margins, sequential,
            "a v{version} open-loop predict diverged from the sequential \
             answer — torn snapshot"
        );
        // one level deeper: the sequential answer itself must be the plain
        // batch path on that version's frozen state
        let batch = parlin::glm::model::margins(snap.dataset(), snap.weights(), &out.idx);
        assert_eq!(out.margins, batch);
    }
}

/// With a budget of one and the pool's only worker blocked by a writer
/// job, the single admitted reader holds the pending slot for its whole
/// service time: every further `try_predict` must shed (never serve,
/// never block), the shed count must match the report, and the admitted
/// reader's answer must still be bit-wise correct once the worker frees.
#[test]
fn admission_control_sheds_excess_readers_and_counts_them() {
    let _g = gate();
    let sess = session(120, 1, 73);
    // grab the pool before the scheduler owns the session: the blocker
    // job must enter the same single worker the predicts shard onto
    let pool = sess.pool_arc();
    let sched = Scheduler::new(
        sess,
        SchedulerConfig {
            refit_rows_threshold: 1_000_000,
            refit_staleness_s: 1e6,
            max_pending: Some(1),
            ..SchedulerConfig::default()
        },
    );
    let started = AtomicBool::new(false);
    let release = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let (started, release) = (&started, &release);
        // occupy the only worker: reader shards queue behind this writer
        // job until it is released
        let blocker = scope.spawn(move || {
            pool.run(vec![move || {
                started.store(true, Ordering::SeqCst);
                while !release.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }]);
        });
        wait_until("the blocker job to start", || started.load(Ordering::SeqCst));

        // the one admitted reader: enters the budget, then blocks on the
        // occupied pool for its whole service time
        let admitted = scope.spawn(|| sched.try_predict(&[0, 1, 2, 3]));
        wait_until("the admitted reader to hold the pending slot", || {
            sched.pending_readers() == 1
        });

        // every further arrival is shed immediately with the observed
        // pending count — try_predict must never block on the full pool
        for attempt in 0..5 {
            match sched.try_predict(&[4, 5]) {
                PredictAdmission::Rejected { pending } => {
                    assert_eq!(pending, 1, "attempt {attempt} saw a wrong pending count");
                }
                PredictAdmission::Served(_) => {
                    panic!("attempt {attempt} was admitted past a full budget")
                }
            }
        }

        release.store(true, Ordering::SeqCst);
        let out = admitted
            .join()
            .expect("admitted reader panicked")
            .served()
            .expect("the first reader fit the budget and must be served");
        assert_eq!(out.version, 0);
        assert_eq!(
            out.margins,
            sched.snapshot().predict(&[0, 1, 2, 3]),
            "the admitted predict must still be bit-wise correct"
        );
        blocker.join().expect("blocker panicked");
    });
    assert_eq!(sched.pending_readers(), 0, "the budget must drain to zero");

    let report = sched.report();
    assert_eq!(report.rejected_predicts, 5, "every shed arrival is counted");
    assert_eq!(report.predicts, 1, "only the admitted reader was served");
}

/// A full open-loop run — dispatcher threads, a shedding budget, an
/// ingestion trickle with background refits, the final flush — must leave
/// the process thread count where it started and account for every row.
#[test]
fn open_loop_run_leaks_no_threads() {
    let _g = gate();
    let sched = Scheduler::new(
        session(270, 4, 75),
        SchedulerConfig {
            refit_rows_threshold: 30,
            refit_staleness_s: 0.05,
            max_pending: Some(8),
            ..SchedulerConfig::default()
        },
    );
    // warm up each path once (predict, ingest→background refit, flush)
    let _ = sched.predict(&[0, 1, 2]);
    sched.ingest(synthetic::dense_classification(30, 8, 76));
    let _ = sched.flush();
    assert_eq!(sched.current_n(), 300);
    let baseline = settled_census(usize::MAX - 1);

    let cfg = OpenLoopConfig {
        rate_per_s: 300.0,
        duration_s: 0.4,
        process: ArrivalProcess::Poisson,
        seed: 19,
        predict_batch: 48,
        ingest_fraction: 0.1,
        rows_per_ingest: 10,
        dispatchers: 3,
        record_outcomes: false,
    };
    let report = drive_open_loop(&sched, &cfg);
    assert!(report.served() > 0, "the run must have served traffic");
    assert_eq!(sched.staged_rows(), 0, "the final flush must drain staging");
    assert_eq!(
        sched.current_n() as u64,
        300 + report.ingested_rows,
        "every ingested row absorbed exactly once"
    );

    let after = settled_census(baseline);
    assert!(
        after <= baseline,
        "open-loop run grew threads: baseline={baseline}, after={after}"
    );
}
