//! Cross-solver equivalence and convergence-quality guarantees — the
//! repo-level statement of the paper's title: every parallel variant must
//! reach the *same solution quality* as the sequential algorithm.

use parlin::data::{synthetic, DataMatrix};
use parlin::glm::{duality_gap, Objective};
use parlin::solver::exec::Executor;
use parlin::solver::{
    dom, numa, seq, wild, BucketPolicy, Partitioning, SolverConfig, Variant,
};
use parlin::sysinfo::Topology;
use parlin::vthread;

fn logistic(n: usize) -> Objective {
    Objective::Logistic { lambda: 1.0 / n as f64 }
}

/// All solver variants converge to (near-)identical primal solutions.
#[test]
fn all_variants_reach_same_optimum_dense() {
    let ds = synthetic::dense_classification(800, 25, 11);
    let obj = logistic(800);
    let tol_cfg = SolverConfig::new(obj).with_tol(1e-7).with_max_epochs(2000);
    let topo = Topology::uniform(4, 2);

    let w_seq = seq::train_sequential(&ds, &tol_cfg).weights(&obj);
    let runs: Vec<(&str, Vec<f64>)> = vec![
        (
            "wild-1T",
            wild::train_wild(&ds, &tol_cfg.clone().with_variant(Variant::Wild)).weights(&obj),
        ),
        (
            "dom-dyn-4T",
            dom::train_domesticated(&ds, &tol_cfg.clone().with_threads(4)).weights(&obj),
        ),
        (
            "dom-static-4T",
            dom::train_domesticated(
                &ds,
                &tol_cfg
                    .clone()
                    .with_threads(4)
                    .with_partition(Partitioning::Static),
            )
            .weights(&obj),
        ),
        (
            "numa-8T",
            numa::train_numa(&ds, &tol_cfg.clone().with_threads(8), &topo).weights(&obj),
        ),
    ];
    for (name, w) in runs {
        let dist = parlin::util::rel_change(&w_seq, &w);
        assert!(dist < 1e-2, "{name} deviates from sequential by {dist}");
    }
}

#[test]
fn all_variants_reach_same_optimum_sparse() {
    let ds = synthetic::sparse_classification(1000, 300, 0.03, 12);
    let obj = logistic(1000);
    let cfg = SolverConfig::new(obj).with_tol(1e-7).with_max_epochs(2000);
    let topo = Topology::uniform(2, 4);
    let w_seq = seq::train_sequential(&ds, &cfg).weights(&obj);
    let w_dom = dom::train_domesticated(&ds, &cfg.clone().with_threads(8)).weights(&obj);
    let w_numa = numa::train_numa(&ds, &cfg.clone().with_threads(8), &topo).weights(&obj);
    assert!(parlin::util::rel_change(&w_seq, &w_dom) < 1e-2);
    assert!(parlin::util::rel_change(&w_seq, &w_numa) < 1e-2);
}

/// Real threads and the sequential executor produce bitwise-identical
/// trajectories (the basis for the vthread substitution, DESIGN.md §4).
#[test]
fn threaded_and_virtual_execution_identical() {
    let ds = synthetic::dense_classification(400, 16, 13);
    let obj = logistic(400);
    let topo = Topology::uniform(2, 4);
    for threads in [2usize, 4, 8] {
        let cfg = SolverConfig::new(obj)
            .with_threads(threads)
            .with_tol(0.0)
            .with_max_epochs(12);
        let real = dom::train_domesticated_exec(&ds, &cfg, &Executor::Threads);
        let sim = dom::train_domesticated_exec(&ds, &cfg, &Executor::Sequential);
        assert_eq!(real.state.alpha, sim.state.alpha, "dom T={threads}");
        let real_n = numa::train_numa_exec(&ds, &cfg, &topo, &Executor::Threads);
        let sim_n = numa::train_numa_exec(&ds, &cfg, &topo, &Executor::Sequential);
        assert_eq!(real_n.state.alpha, sim_n.state.alpha, "numa T={threads}");
    }
}

/// The paper's Fig 2b/5a effect: static partitioning needs at least as
/// many epochs as dynamic, across thread counts.
#[test]
fn dynamic_partitioning_dominates_static_in_epochs() {
    let ds = synthetic::dense_classification(3000, 40, 14);
    let obj = logistic(3000);
    let mut worse = 0;
    let mut cases = 0;
    for threads in [4usize, 8, 16] {
        let base = SolverConfig::new(obj)
            .with_threads(threads)
            .with_tol(1e-4)
            .with_max_epochs(800);
        let dy = vthread::train_domesticated_sim(
            &ds,
            &base.clone().with_partition(Partitioning::Dynamic),
        );
        let st = vthread::train_domesticated_sim(
            &ds,
            &base.clone().with_partition(Partitioning::Static),
        );
        assert!(dy.converged && st.converged);
        cases += 1;
        if st.epochs_run >= dy.epochs_run {
            worse += 1;
        }
    }
    assert!(
        worse >= cases - 1,
        "static should need >= epochs in (almost) all cases: {worse}/{cases}"
    );
}

/// Bucketing must not change the reachable solution quality (only the
/// constant factors) — trained models agree across bucket sizes.
#[test]
fn bucket_sizes_do_not_change_solution() {
    let ds = synthetic::dense_classification(600, 20, 15);
    let obj = logistic(600);
    let mut ws = Vec::new();
    for bucket in [BucketPolicy::Off, BucketPolicy::Fixed(8), BucketPolicy::Fixed(16)] {
        let cfg = SolverConfig::new(obj)
            .with_tol(1e-8)
            .with_max_epochs(2000)
            .with_bucket(bucket);
        ws.push(seq::train_sequential(&ds, &cfg).weights(&obj));
    }
    for w in &ws[1..] {
        assert!(parlin::util::rel_change(&ws[0], w) < 1e-3);
    }
}

/// Wild-sim convergence degradation is monotone-ish in the collision
/// probability (sanity of the lost-update model).
#[test]
fn wild_sim_degrades_with_collision_probability() {
    let ds = synthetic::dense_classification(1500, 80, 16);
    let obj = logistic(1500);
    let cfg = SolverConfig::new(obj)
        .with_variant(Variant::Wild)
        .with_threads(16)
        .with_tol(1e-4)
        .with_max_epochs(150);
    let mk = |p: f64| vthread::WildSimParams {
        p_collide_local: p,
        p_collide_remote: p,
        topology: Topology::flat(16),
    };
    let clean = vthread::train_wild_sim(&ds, &cfg, &mk(0.0));
    let dirty = vthread::train_wild_sim(&ds, &cfg, &mk(0.4));
    let clean_gap = clean.final_gap.max(1e-12);
    let dirty_gap = dirty.final_gap.max(1e-12);
    assert!(
        !dirty.converged || dirty.epochs_run > clean.epochs_run || dirty_gap > clean_gap,
        "collisions should hurt: clean ({} ep, gap {clean_gap:.1e}) vs dirty ({} ep, gap {dirty_gap:.1e})",
        clean.epochs_run,
        dirty.epochs_run
    );
}

/// Gap certificates: converged runs have small duality gap; the gap is
/// non-negative for every solver's final state.
#[test]
fn gap_certificates_hold() {
    let ds = synthetic::sparse_classification(500, 100, 0.05, 17);
    let obj = logistic(500);
    let topo = Topology::uniform(2, 2);
    let cfg = SolverConfig::new(obj).with_tol(1e-6).with_max_epochs(1500);
    for (name, out) in [
        ("seq", seq::train_sequential(&ds, &cfg)),
        ("dom", dom::train_domesticated(&ds, &cfg.clone().with_threads(4))),
        ("numa", numa::train_numa(&ds, &cfg.clone().with_threads(4), &topo)),
    ] {
        let rep = duality_gap(&ds, &obj, &out.state);
        assert!(rep.gap >= -1e-10, "{name}: negative gap {}", rep.gap);
        assert!(rep.gap < 1e-3, "{name}: loose gap {}", rep.gap);
        assert!(out.state.v_drift(&ds) < 1e-8, "{name}: v drift");
    }
}

/// Hinge and ridge objectives train correctly through the parallel path.
#[test]
fn parallel_solvers_handle_all_objectives() {
    let ds = synthetic::dense_classification(400, 12, 18);
    for obj in [
        Objective::Hinge { lambda: 1.0 / 400.0 },
        Objective::Ridge { lambda: 0.05 },
    ] {
        let cfg = SolverConfig::new(obj)
            .with_threads(4)
            .with_tol(1e-6)
            .with_max_epochs(2000);
        let out = dom::train_domesticated(&ds, &cfg);
        let rep = duality_gap(&ds, &obj, &out.state);
        assert!(rep.gap < 1e-2, "{obj:?}: gap {}", rep.gap);
    }
}

/// Property-style sweep: random small problems, every variant converges
/// to a valid dual point with tight gap (20 random configs).
#[test]
fn random_problem_sweep() {
    let mut rng = parlin::util::Rng::new(99);
    for trial in 0..20 {
        let n = 100 + rng.next_below(300) as usize;
        let d = 5 + rng.next_below(30) as usize;
        let threads = 1 + rng.next_below(8) as usize;
        let ds = synthetic::dense_classification(n, d, 1000 + trial);
        let obj = logistic(n);
        let cfg = SolverConfig::new(obj)
            .with_threads(threads)
            .with_tol(1e-6)
            .with_max_epochs(3000)
            .with_seed(trial);
        let out = dom::train_domesticated(&ds, &cfg);
        assert!(
            out.converged,
            "trial {trial} (n={n}, d={d}, T={threads}) failed to converge"
        );
        let rep = duality_gap(&ds, &obj, &out.state);
        assert!(rep.gap < 1e-2, "trial {trial}: gap {}", rep.gap);
        // dual feasibility: y·α ∈ [0,1]
        for (a, y) in out.state.alpha.iter().zip(&ds.y) {
            let s = a * y;
            assert!((-1e-9..=1.0 + 1e-9).contains(&s), "trial {trial}: α out of domain");
        }
        let _ = ds.x.nnz();
    }
}
