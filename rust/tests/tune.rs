//! Determinism-first lock on the online auto-tuner (ROADMAP item 2, the
//! SySCD follow-on): `--tune off` must leave every solver bit-for-bit
//! untouched, layout decisions must be bit-free even mid-run, and a tuned
//! run's decision log must be a pure, byte-replayable function of its own
//! convergence trace — while tuned runs still reach the convergence
//! monitor's tolerance.

use parlin::data::synthetic;
use parlin::glm::Objective;
use parlin::solver::{
    train, AutoTuner, BucketPolicy, CancelToken, ExecPolicy, Knob, LayoutPolicy, SolverConfig,
    TuneLog, TunePolicy, Variant,
};
use parlin::sysinfo::Topology;

fn logistic(n: usize) -> Objective {
    Objective::Logistic { lambda: 1.0 / n as f64 }
}

/// The four (solver, thread-count) pairs the determinism matrix sweeps.
/// `wild` runs one thread: its shared-vector races are the one documented
/// nondeterminism in the repo, and this suite is about the *tuner* not
/// perturbing runs that are deterministic to begin with.
const SOLVERS: [(&str, Variant, usize); 4] = [
    ("seq", Variant::Sequential, 1),
    ("wild", Variant::Wild, 1),
    ("dom", Variant::Domesticated, 4),
    ("numa", Variant::Numa, 4),
];

/// `--tune off` (the default) constructs no tuner: for every solver and
/// both layouts, a run with the policy spelled out (plus an installed but
/// never-tripped CancelToken, the full new plumbing) is bit-wise
/// identical to a run that never mentions tuning at all — and neither
/// stamps a log.
#[test]
fn tune_off_is_bitwise_invisible_for_all_solvers_and_layouts() {
    let ds = synthetic::dense_classification(300, 12, 21);
    let topo = Topology::uniform(2, 2);
    for (name, variant, threads) in SOLVERS {
        let mut per_layout = Vec::new();
        for layout in [LayoutPolicy::Interleaved, LayoutPolicy::Csc] {
            let cfg = SolverConfig::new(logistic(300))
                .with_variant(variant)
                .with_threads(threads)
                .with_topology(topo.clone())
                .with_exec(ExecPolicy::Sequential)
                .with_layout(layout)
                .with_tol(0.0)
                .with_max_epochs(8);
            let base = train(&ds, &cfg);
            let off = train(
                &ds,
                &cfg.clone()
                    .with_tune(TunePolicy::Off)
                    .with_cancel(CancelToken::new()),
            );
            assert_eq!(
                base.state.alpha, off.state.alpha,
                "{name}/{layout:?}: Off must be bit-identical (alpha)"
            );
            assert_eq!(
                base.state.v, off.state.v,
                "{name}/{layout:?}: Off must be bit-identical (v)"
            );
            assert!(
                base.tune_log.is_none() && off.tune_log.is_none(),
                "{name}/{layout:?}: Off runs must not stamp a tune log"
            );
            per_layout.push(off.state.alpha);
        }
        // and the layouts themselves stay bit-equal, untouched by the
        // tuner plumbing (the dot4_by argument of docs/ARCHITECTURE.md)
        assert_eq!(
            per_layout[0], per_layout[1],
            "{name}: interleaved and csc must stay bit-identical under Off"
        );
    }
}

/// A mid-run layout switch is bit-free: with every numerics-touching knob
/// capability off (fixed bucket, no pool workers to retire), a tuned run
/// makes only `layout` decisions — and lands on exactly the bits of the
/// untuned run, while its log proves at least one switch happened.
#[test]
fn mid_run_layout_switch_is_bit_identical_to_never_switching() {
    let ds = synthetic::dense_classification(400, 16, 22);
    let topo = Topology::uniform(2, 2);
    for (name, variant, threads) in [
        ("seq", Variant::Sequential, 1),
        ("wild", Variant::Wild, 1),
        ("numa", Variant::Numa, 4),
    ] {
        let cfg = SolverConfig::new(logistic(400))
            .with_variant(variant)
            .with_threads(threads)
            .with_topology(topo.clone())
            .with_exec(ExecPolicy::Sequential)
            .with_bucket(BucketPolicy::Fixed(8))
            .with_tol(0.0)
            .with_max_epochs(12);
        let off = train(&ds, &cfg);
        let on = train(&ds, &cfg.clone().with_tune(TunePolicy::On { seed: 5 }));
        let log = on.tune_log.as_ref().expect("tuned run must stamp a log");
        assert!(
            !log.decisions.is_empty(),
            "{name}: 12 epochs cover three windows; the layout probe must fire"
        );
        assert!(
            log.decisions.iter().all(|d| d.knob == Knob::Layout),
            "{name}: only the bit-free knob may move here, got {:?}",
            log.decisions
        );
        assert_eq!(
            off.state.alpha, on.state.alpha,
            "{name}: a mid-run layout switch must be bit-free (alpha)"
        );
        assert_eq!(
            off.state.v, on.state.v,
            "{name}: a mid-run layout switch must be bit-free (v)"
        );
    }
}

/// The decision list is a pure function of (seed, observation stream):
/// replaying a live run's own convergence trace through a fresh tuner
/// reproduces the stamped log byte-for-byte, twice over, and the CSV
/// round-trips exactly.
#[test]
fn same_seed_and_trace_reproduce_the_log_byte_for_byte() {
    let ds = synthetic::dense_classification(500, 20, 23);
    let cfg = SolverConfig::new(logistic(500))
        .with_variant(Variant::Domesticated)
        .with_threads(4)
        .with_topology(Topology::uniform(1, 4))
        .with_tol(0.0)
        .with_max_epochs(16)
        .with_tune(TunePolicy::On { seed: 7 });
    let out = train(&ds, &cfg);
    let log = out.tune_log.expect("tuned run must stamp a log");
    log.verify_replay(&out.convergence.points)
        .expect("a run's own trace must replay its own log");
    let a = AutoTuner::replay(&log.solver, &log.init, &out.convergence.points);
    let b = AutoTuner::replay(&log.solver, &log.init, &out.convergence.points);
    assert_eq!(a, b, "replay is deterministic");
    assert_eq!(
        a.to_csv(),
        log.to_csv(),
        "replayed log is byte-identical to the live log"
    );
    let back = TuneLog::from_csv(&log.to_csv()).expect("a log's own csv must parse");
    assert_eq!(back, log, "csv round trip is exact");
    assert_eq!(back.to_csv(), log.to_csv(), "…and byte-exact");
}

/// Tuning never costs convergence: tuned runs still reach the monitor's
/// tolerance, and across every decision boundary the measured duality gap
/// is non-increasing by the end of the run (a decision may shift the
/// trajectory, but the run keeps converging through it).
#[test]
fn tuned_runs_reach_tolerance_and_gaps_shrink_across_decisions() {
    let ds = synthetic::dense_classification(400, 15, 24);
    for (name, variant, threads) in [
        ("seq", Variant::Sequential, 1),
        ("dom", Variant::Domesticated, 4),
    ] {
        let mut cfg = SolverConfig::new(logistic(400))
            .with_variant(variant)
            .with_threads(threads)
            .with_topology(Topology::uniform(1, 4))
            .with_tol(1e-6)
            .with_max_epochs(600)
            .with_tune(TunePolicy::On { seed: 11 });
        // record a gap on every epoch (the gap_tol itself is unreachable,
        // so the rel-change monitor still decides convergence)
        cfg.gap_tol = Some(1e-14);
        cfg.gap_check_every = 1;
        let out = train(&ds, &cfg);
        assert!(
            out.converged,
            "{name}: tuned run must still reach the monitor tolerance"
        );
        assert!(out.final_gap < 1e-3, "{name}: gap={}", out.final_gap);
        let log = out.tune_log.as_ref().expect("tuned run must stamp a log");
        assert!(
            !log.decisions.is_empty(),
            "{name}: a run this long must cross at least one decision boundary"
        );
        let gap_at = |epoch: usize| {
            out.convergence
                .points
                .iter()
                .filter(|p| p.epoch <= epoch)
                .filter_map(|p| p.gap)
                .next_back()
                .expect("gap recorded every epoch")
        };
        let last_gap = out.convergence.last_gap().expect("gap recorded every epoch");
        for d in &log.decisions {
            let before = gap_at(d.epoch);
            assert!(
                last_gap <= before + 1e-12,
                "{name}: gap grew across the {} decision at epoch {} \
                 (before {before:.3e}, end of run {last_gap:.3e})",
                d.knob.name(),
                d.epoch
            );
        }
    }
}
