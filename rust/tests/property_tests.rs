//! Property-based tests over randomized inputs (deterministic seeds; the
//! offline toolchain carries no proptest, so generation uses the crate's
//! own PRNG — failures print the seed for replay).

use parlin::data::{synthetic, AppendExamples, CscMatrix, DataMatrix, Dataset, DenseMatrix};
use parlin::glm::Objective;
use parlin::runtime::manifest::Json;
use parlin::solver::partition::{EpochAssignment, Partitioner};
use parlin::solver::Partitioning;
use parlin::util::Rng;

/// Build a dense matrix and its exact sparse representation.
fn paired_matrices(rng: &mut Rng, d: usize, n: usize) -> (DenseMatrix, CscMatrix) {
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(n);
    let mut examples: Vec<Vec<(u32, f64)>> = Vec::with_capacity(n);
    for _ in 0..n {
        let mut col = vec![0.0f64; d];
        let mut ex = Vec::new();
        for (i, slot) in col.iter_mut().enumerate() {
            if rng.next_f64() < 0.4 {
                let v = rng.next_gaussian();
                *slot = v;
                ex.push((i as u32, v));
            }
        }
        cols.push(col);
        examples.push(ex);
    }
    let col_refs: Vec<&[f64]> = cols.iter().map(|c| c.as_slice()).collect();
    (
        DenseMatrix::from_columns(d, &col_refs),
        CscMatrix::from_examples(d, &examples),
    )
}

/// Dense and CSC representations of the same data agree on every
/// DataMatrix operation.
#[test]
fn prop_dense_sparse_representation_equivalence() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed);
        let d = 3 + rng.next_below(20) as usize;
        let n = 1 + rng.next_below(30) as usize;
        let (dense, sparse) = paired_matrices(&mut rng, d, n);
        let v: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        for j in 0..n {
            assert!(
                (dense.dot_col(j, &v) - sparse.dot_col(j, &v)).abs() < 1e-10,
                "seed {seed}: dot mismatch at col {j}"
            );
            assert!(
                (dense.norm_sq_col(j) - sparse.norm_sq_col(j)).abs() < 1e-10,
                "seed {seed}: norm mismatch"
            );
            let mut a = vec![0.0; d];
            let mut b = vec![0.0; d];
            dense.axpy_col(j, 1.7, &mut a);
            sparse.axpy_col(j, 1.7, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12, "seed {seed}: axpy mismatch");
            }
            let mut da = vec![0.0; d];
            let mut db = vec![0.0; d];
            dense.write_col_dense(j, &mut da);
            sparse.write_col_dense(j, &mut db);
            assert_eq!(da, db, "seed {seed}: densify mismatch");
        }
    }
}

/// Training on dense vs CSC representations of the *same data* yields the
/// same model (the solver is layout-agnostic).
#[test]
fn prop_solver_layout_invariance() {
    for seed in [3u64, 17, 99] {
        let mut rng = Rng::new(seed);
        let d = 5 + rng.next_below(10) as usize;
        let n = 80 + rng.next_below(120) as usize;
        let (dense, sparse) = paired_matrices(&mut rng, d, n);
        let y: Vec<f64> = (0..n)
            .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let obj = Objective::Logistic { lambda: 0.05 };
        let cfg = parlin::solver::SolverConfig::new(obj)
            .with_tol(1e-8)
            .with_max_epochs(500)
            .with_seed(seed);
        let a = parlin::solver::seq::train_sequential(&Dataset::new(dense, y.clone()), &cfg);
        let b = parlin::solver::seq::train_sequential(&Dataset::new(sparse, y), &cfg);
        let dist = parlin::util::rel_change(&a.weights(&obj), &b.weights(&obj));
        assert!(dist < 1e-6, "seed {seed}: layouts disagree by {dist}");
    }
}

/// The 1-D dual solvers always return a domain-feasible, subproblem-
/// optimal step (randomized version of the unit test, all objectives).
#[test]
fn prop_coordinate_step_feasible_and_optimal() {
    let objs = [
        Objective::Logistic { lambda: 0.08 },
        Objective::Ridge { lambda: 0.08 },
        Objective::Hinge { lambda: 0.08 },
    ];
    let mut rng = Rng::new(2024);
    for trial in 0..400 {
        let obj = objs[(trial % 3) as usize];
        let y = if rng.next_f64() < 0.5 { -1.0 } else { 1.0 };
        let s0 = rng.next_f64() * 0.98 + 0.01;
        let alpha = match obj {
            Objective::Ridge { .. } => rng.next_gaussian(),
            _ => y * s0,
        };
        let xw = rng.next_gaussian() * 3.0;
        let nsq = rng.next_f64() * 5.0 + 1e-3;
        let n = 1 + rng.next_below(50) as usize;
        let delta = obj.delta(alpha, xw, nsq, y, n);
        assert!(delta.is_finite(), "trial {trial}: non-finite step");
        let conj = obj.dual_conjugate(alpha + delta, y);
        assert!(
            conj.is_finite(),
            "trial {trial} ({obj:?}): stepped out of the dual domain"
        );
    }
}

/// Gap certificates: for random feasible dual points, weak duality holds
/// (P ≥ D) on random datasets — all objectives.
#[test]
fn prop_weak_duality() {
    let mut rng = Rng::new(7);
    for trial in 0..30 {
        let n = 30 + rng.next_below(100) as usize;
        let d = 3 + rng.next_below(15) as usize;
        let ds = synthetic::dense_classification(n, d, 1000 + trial);
        for obj in [
            Objective::Logistic { lambda: 0.1 },
            Objective::Hinge { lambda: 0.1 },
            Objective::Ridge { lambda: 0.1 },
        ] {
            let mut st = parlin::glm::ModelState::zeros(n, d);
            for j in 0..n {
                st.alpha[j] = match obj {
                    Objective::Ridge { .. } => rng.next_gaussian(),
                    _ => ds.y[j] * rng.next_f64(),
                };
            }
            st.rebuild_v(&ds);
            let rep = parlin::glm::duality_gap(&ds, &obj, &st);
            assert!(
                rep.gap >= -1e-9,
                "trial {trial} {obj:?}: weak duality violated ({})",
                rep.gap
            );
        }
    }
}

/// The JSON parser round-trips arbitrary manifest-shaped documents and
/// never panics on mutated input.
#[test]
fn prop_json_parser_robustness() {
    let mut rng = Rng::new(11);
    let base = r#"{"a":{"inputs":[{"shape":[2,3],"dtype":"float32"}],"outputs":[{"shape":[1],"dtype":"float32"}]},"b":[1,2.5,-3e2,true,false,null,"s"]}"#;
    assert!(Json::parse(base).is_ok());
    for _ in 0..500 {
        // random single-byte mutations must never panic (Err is fine)
        let mut bytes = base.as_bytes().to_vec();
        let pos = rng.next_below(bytes.len() as u64) as usize;
        bytes[pos] = (rng.next_below(94) + 32) as u8;
        if let Ok(s) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(s); // must not panic
        }
        // random truncations must never panic
        let cut = rng.next_below(base.len() as u64) as usize;
        let _ = Json::parse(&base[..cut]);
    }
}

/// Bucket index spaces cover exactly, for arbitrary (n, size).
#[test]
fn prop_bucket_coverage() {
    let mut rng = Rng::new(13);
    for _ in 0..200 {
        let n = 1 + rng.next_below(5000) as usize;
        let size = 1 + rng.next_below(64) as usize;
        let b = parlin::solver::Buckets::new(n, size);
        let mut count = 0usize;
        let mut last_end = 0usize;
        for id in 0..b.count() {
            let r = b.range(id);
            assert_eq!(r.start, last_end, "gap before bucket {id}");
            assert!(r.end <= n);
            count += r.len();
            last_end = r.end;
        }
        assert_eq!(count, n, "n={n} size={size}");
        assert_eq!(last_end, n);
    }
}

/// Thread placement is total, respects the data node, and uses the
/// minimal node count, for arbitrary topologies.
#[test]
fn prop_thread_placement() {
    let mut rng = Rng::new(17);
    for _ in 0..300 {
        let nodes = 1 + rng.next_below(6) as usize;
        let cores = 1 + rng.next_below(16) as usize;
        let mut topo = parlin::sysinfo::Topology::uniform(nodes, cores);
        topo.data_node = rng.next_below(nodes as u64) as usize;
        let threads = 1 + rng.next_below((nodes * cores * 2) as u64) as usize;
        let p = topo.place_threads(threads);
        assert_eq!(p.iter().sum::<usize>(), threads, "placement must be total");
        assert!(p[topo.data_node] > 0, "data node must participate");
        // minimality: the used node count cannot exceed ceil(threads/cores)
        let used = p.iter().filter(|&&x| x > 0).count();
        let min_nodes = threads.div_ceil(cores).min(nodes);
        assert!(
            used <= min_nodes.max(1),
            "used {used} nodes for {threads} threads ({cores} cores/node)"
        );
    }
}

/// Check one epoch assignment is an exact partition of the bucket space:
/// no bucket dealt twice (disjointness), no bucket dropped (coverage).
/// `replay` is printed on failure so the case can be re-run exactly.
fn assert_exact_partition(a: &EpochAssignment, num_buckets: usize, replay: &str) {
    let mut seen = vec![false; num_buckets];
    for (worker, list) in a.per_worker.iter().enumerate() {
        for &b in list {
            assert!(
                (b as usize) < num_buckets,
                "{replay}: worker {worker} got out-of-range bucket {b}"
            );
            assert!(
                !seen[b as usize],
                "{replay}: bucket {b} dealt to two workers (second: {worker})"
            );
            seen[b as usize] = true;
        }
    }
    let missing = seen.iter().filter(|&&s| !s).count();
    assert_eq!(missing, 0, "{replay}: {missing} bucket(s) never dealt");
}

/// The paper's dynamic partitioning re-deals the *entire* bucket space
/// every epoch. Whatever the (randomized) bucket/worker counts and seed,
/// every epoch's assignment must cover all buckets exactly once across
/// workers — this is what makes the parallel epoch semantically a full
/// pass, i.e. the precondition of the executor-equivalence guarantees.
#[test]
fn prop_dynamic_partition_disjoint_and_covering() {
    let mut seed_src = Rng::new(0xD7DA);
    for trial in 0..60 {
        let seed = seed_src.next_u64();
        let mut rng = Rng::new(seed);
        let num_buckets = 1 + rng.next_below(2500) as usize;
        let workers = 1 + rng.next_below(33) as usize;
        let replay = format!(
            "replay: seed={seed} trial={trial} buckets={num_buckets} workers={workers}"
        );
        let mut p = Partitioner::new(Partitioning::Dynamic, num_buckets, workers);
        for epoch in 0..6 {
            let a = p.assign(&mut rng);
            assert_exact_partition(&a, num_buckets, &format!("{replay} epoch={epoch}"));
            assert_eq!(a.total(), num_buckets, "{replay} epoch={epoch}: total");
            // the deal must stay balanced: worker loads differ by ≤ 1
            let sizes: Vec<usize> = a.per_worker.iter().map(|w| w.len()).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "{replay} epoch={epoch}: unbalanced {sizes:?}");
        }
    }
}

/// Static partitioning must satisfy the same exact-partition invariant,
/// with the extra property that membership never moves across epochs
/// (only the within-chunk order reshuffles).
#[test]
fn prop_static_partition_membership_fixed() {
    let mut seed_src = Rng::new(0x57A71C);
    for trial in 0..30 {
        let seed = seed_src.next_u64();
        let mut rng = Rng::new(seed);
        let num_buckets = 1 + rng.next_below(1200) as usize;
        let workers = 1 + rng.next_below(17) as usize;
        let replay = format!(
            "replay: seed={seed} trial={trial} buckets={num_buckets} workers={workers}"
        );
        let mut p = Partitioner::new(Partitioning::Static, num_buckets, workers);
        let first = p.assign(&mut rng);
        assert_exact_partition(&first, num_buckets, &replay);
        let membership: Vec<Vec<u32>> = first
            .per_worker
            .iter()
            .map(|w| {
                let mut m = w.clone();
                m.sort_unstable();
                m
            })
            .collect();
        for epoch in 1..4 {
            let a = p.assign(&mut rng);
            assert_exact_partition(&a, num_buckets, &format!("{replay} epoch={epoch}"));
            for (t, w) in a.per_worker.iter().enumerate() {
                let mut m = w.clone();
                m.sort_unstable();
                assert_eq!(
                    m, membership[t],
                    "{replay} epoch={epoch}: static membership moved for worker {t}"
                );
            }
        }
    }
}

/// LIBSVM writer/loader round-trip on random sparse datasets.
#[test]
fn prop_libsvm_roundtrip() {
    for seed in 0..5u64 {
        let ds = synthetic::sparse_classification(60, 30, 0.2, seed);
        let dir = std::env::temp_dir().join(format!("parlin_prop_{}_{seed}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.libsvm");
        let mut out = String::new();
        for j in 0..ds.n() {
            let (idx, val) = ds.x.col(j);
            out.push_str(if ds.y[j] > 0.0 { "+1" } else { "-1" });
            for (i, v) in idx.iter().zip(val) {
                out.push_str(&format!(" {}:{:.17}", i + 1, v));
            }
            out.push('\n');
        }
        std::fs::write(&path, out).unwrap();
        let back = parlin::data::loader::load_libsvm(&path, Some(30)).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.d(), 30);
        for j in 0..ds.n() {
            let (ia, va) = ds.x.col(j);
            let (ib, vb) = back.x.col(j);
            assert_eq!(ia, ib, "seed {seed} col {j}");
            for (a, b) in va.iter().zip(vb) {
                assert!((a - b).abs() < 1e-12);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Subset extraction preserves per-example content (dense + sparse).
#[test]
fn prop_subset_preserves_examples() {
    let mut rng = Rng::new(23);
    for seed in 0..10u64 {
        let ds = synthetic::sparse_classification(100, 40, 0.15, seed);
        let idx = rng.sample_indices(100, 37);
        let sub = ds.subset(&idx);
        for (new_j, &old_j) in idx.iter().enumerate() {
            assert_eq!(sub.x.col(new_j), ds.x.col(old_j));
            assert_eq!(sub.y[new_j], ds.y[old_j]);
            assert_eq!(sub.norm_sq(new_j), ds.norm_sq(old_j));
        }
        let dd = synthetic::dense_classification(80, 12, seed);
        let idx2 = rng.sample_indices(80, 20);
        let sub2 = dd.subset(&idx2);
        for (new_j, &old_j) in idx2.iter().enumerate() {
            assert_eq!(sub2.x.col(new_j), dd.x.col(old_j));
        }
    }
}

/// The shard-resident interleaved layout round-trips to the exact
/// `(example, idx, val)` multiset of its source — for random sparse and
/// dense datasets, random bucket sizes, and random shard splits. Entries
/// must also appear in source stream order per example (the fused
/// kernels' bit-wise determinism argument relies on it).
#[test]
fn prop_sharded_layout_roundtrip() {
    use parlin::data::shard::ShardedLayout;
    use parlin::solver::Buckets;

    fn source_entries<M: DataMatrix>(x: &M, j: usize) -> Vec<(u32, u64)> {
        let mut out = Vec::new();
        x.for_each_col_entry(j, |i, v| out.push((i as u32, v.to_bits())));
        out
    }

    fn check_layout<M: DataMatrix>(x: &M, layout: &ShardedLayout, replay: &str) {
        let mut total = 0usize;
        for s in 0..layout.num_shards() {
            let sh = layout.shard(s);
            for j in sh.example_range() {
                let want = source_entries(x, j);
                let got: Vec<(u32, u64)> =
                    sh.entries(j).iter().map(|e| (e.idx, e.val_bits)).collect();
                assert_eq!(got, want, "{replay}: shard {s} example {j}");
                total += got.len();
            }
        }
        assert_eq!(total, x.nnz(), "{replay}: entry multiset size");
    }

    for seed in 0..15u64 {
        let mut rng = Rng::new(seed);
        let d = 3 + rng.next_below(24) as usize;
        let n = 1 + rng.next_below(60) as usize;
        let (dense, sparse) = paired_matrices(&mut rng, d, n);
        let bucket_size = 1 + rng.next_below(9) as usize;
        let buckets = Buckets::new(n, bucket_size);
        let replay = format!("seed={seed} d={d} n={n} bucket={bucket_size}");

        check_layout(&sparse, &ShardedLayout::single(&sparse, &buckets), &replay);
        check_layout(&dense, &ShardedLayout::single(&dense, &buckets), &replay);

        // random 3-way shard split (possibly with empty middle shard)
        let count = buckets.count() as u32;
        let cut_a = rng.next_below(count as u64 + 1) as u32;
        let cut_b = cut_a + rng.next_below((count - cut_a) as u64 + 1) as u32;
        let ranges = [0..cut_a, cut_a..cut_b, cut_b..count];
        let split = format!("{replay} cuts=({cut_a},{cut_b})");
        check_layout(&sparse, &ShardedLayout::for_nodes(&sparse, &buckets, &ranges), &split);
        check_layout(&dense, &ShardedLayout::for_nodes(&dense, &buckets, &ranges), &split);
    }
}

/// A dataset built by K segment appends is indistinguishable from the
/// same rows loaded monolithically: margins are bit-wise equal for any
/// weight vector, and training is bit-wise equal (`alpha` and `v`) for
/// all four solver variants under BOTH data layouts (interleaved and the
/// cursor-walked source matrix). This is the correctness lock on the
/// segment-chunked storage: the per-example visit order — and with it
/// every floating-point reduction — must not depend on how the example
/// axis is chunked.
///
/// Determinism note: all variants run on `ExecPolicy::Sequential`
/// (bit-wise identical to the threaded executors for seq/dom/numa, and
/// the one executor that makes the wild solver's shared-vector updates
/// deterministic), so a bit-for-bit comparison is meaningful.
#[test]
fn prop_segmented_append_matches_monolithic_bitwise() {
    use parlin::solver::{train, ExecPolicy, LayoutPolicy, SolverConfig, Variant};
    use parlin::sysinfo::Topology;

    /// Chunk `0..n` at ascending random cuts (possibly creating empty
    /// chunks — 0-row appends must be transparent too).
    fn random_cuts(rng: &mut Rng, n: usize, pieces: usize) -> Vec<usize> {
        let mut cuts: Vec<usize> = (0..pieces - 1)
            .map(|_| rng.next_below(n as u64 + 1) as usize)
            .collect();
        cuts.sort_unstable();
        let mut bounds = vec![0];
        bounds.extend(cuts);
        bounds.push(n);
        bounds
    }

    fn segmented<M: AppendExamples>(chunks: Vec<Dataset<M>>) -> Dataset<M> {
        let mut it = chunks.into_iter();
        let mut acc = it.next().expect("at least one chunk");
        for c in it {
            acc.append(&c);
        }
        acc
    }

    for seed in [5u64, 41] {
        let mut rng = Rng::new(seed);
        let d = 4 + rng.next_below(8) as usize;
        let n = 60 + rng.next_below(40) as usize;
        let (dense, sparse) = paired_matrices(&mut rng, d, n);
        let y: Vec<f64> = (0..n)
            .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let bounds = random_cuts(&mut rng, n, 4);
        let replay = format!("seed={seed} d={d} n={n} cuts={bounds:?}");

        // build (monolithic, K-append segmented) pairs for both layouts
        let mono_dense = Dataset::new(dense.clone(), y.clone());
        let mono_sparse = Dataset::new(sparse.clone(), y.clone());
        let chunk = |lo: usize, hi: usize| {
            let idx: Vec<usize> = (lo..hi).collect();
            (mono_dense.subset(&idx), mono_sparse.subset(&idx))
        };
        let mut dense_chunks = Vec::new();
        let mut sparse_chunks = Vec::new();
        for w in bounds.windows(2) {
            let (dc, sc) = chunk(w[0], w[1]);
            dense_chunks.push(dc);
            sparse_chunks.push(sc);
        }
        let seg_dense = segmented(dense_chunks);
        let seg_sparse = segmented(sparse_chunks);
        assert_eq!(seg_dense.n(), n, "{replay}");
        assert!(seg_dense.x.num_segments() >= 1);

        // margins: bit-wise equal for an arbitrary weight vector
        let w: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let idx: Vec<usize> = (0..n).rev().chain(0..n).collect();
        let bits = |m: &[f64]| m.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(&parlin::glm::model::margins(&mono_dense, &w, &idx)),
            bits(&parlin::glm::model::margins(&seg_dense, &w, &idx)),
            "{replay}: dense margins"
        );
        assert_eq!(
            bits(&parlin::glm::model::margins(&mono_sparse, &w, &idx)),
            bits(&parlin::glm::model::margins(&seg_sparse, &w, &idx)),
            "{replay}: sparse margins"
        );

        // per-column norms (cached at Dataset::new) agree too
        for j in 0..n {
            assert_eq!(
                mono_sparse.norm_sq(j).to_bits(),
                seg_sparse.norm_sq(j).to_bits(),
                "{replay}: norm {j}"
            );
        }

        // training: every variant × layout, fixed epoch budget
        let obj = Objective::Logistic { lambda: 1.0 / n as f64 };
        for variant in [
            Variant::Sequential,
            Variant::Wild,
            Variant::Domesticated,
            Variant::Numa,
        ] {
            for layout in [LayoutPolicy::Interleaved, LayoutPolicy::Csc] {
                let threads = match variant {
                    Variant::Sequential => 1,
                    Variant::Numa => 4,
                    _ => 2,
                };
                let cfg = SolverConfig::new(obj)
                    .with_variant(variant)
                    .with_threads(threads)
                    .with_topology(Topology::uniform(2, 2))
                    .with_exec(ExecPolicy::Sequential)
                    .with_layout(layout)
                    .with_tol(0.0)
                    .with_max_epochs(5)
                    .with_seed(seed);
                let what = format!("{replay} {variant:?} {layout:?}");
                let a = train(&mono_dense, &cfg);
                let b = train(&seg_dense, &cfg);
                assert_eq!(a.state.alpha, b.state.alpha, "{what}: dense alpha");
                assert_eq!(bits(&a.state.v), bits(&b.state.v), "{what}: dense v");
                let a = train(&mono_sparse, &cfg);
                let b = train(&seg_sparse, &cfg);
                assert_eq!(a.state.alpha, b.state.alpha, "{what}: sparse alpha");
                assert_eq!(bits(&a.state.v), bits(&b.state.v), "{what}: sparse v");
            }
        }
    }
}

/// Incremental tail re-encode (`ShardedLayout::append_tail`) is bit-wise
/// identical to a full rebuild — for random sparse/dense sources, random
/// bucket sizes, and random sequences of append batches (including empty
/// batches and batches that straddle partial tail buckets/lines).
#[test]
fn prop_layout_append_tail_matches_rebuild() {
    use parlin::data::shard::ShardedLayout;
    use parlin::solver::Buckets;

    fn entries_of(l: &ShardedLayout, j: usize) -> Vec<(u32, u64)> {
        l.shard(0).entries(j).iter().map(|e| (e.idx, e.val_bits)).collect()
    }

    fn assert_bitwise_eq<M: DataMatrix>(
        incr: &ShardedLayout,
        rebuilt: &ShardedLayout,
        x: &M,
        replay: &str,
    ) {
        assert_eq!(
            (incr.n(), incr.d(), incr.nnz(), incr.bucket_size()),
            (rebuilt.n(), rebuilt.d(), rebuilt.nnz(), rebuilt.bucket_size()),
            "{replay}: shape"
        );
        for j in 0..x.n() {
            assert_eq!(entries_of(incr, j), entries_of(rebuilt, j), "{replay}: example {j}");
        }
        let buckets = Buckets::new(x.n(), incr.bucket_size());
        for b in 0..buckets.count() {
            assert_eq!(
                incr.shard(0).bucket_entry_range(b),
                rebuilt.shard(0).bucket_entry_range(b),
                "{replay}: bucket {b}"
            );
        }
    }

    for seed in 0..12u64 {
        let mut rng = Rng::new(seed);
        let d = 3 + rng.next_below(16) as usize;
        let n0 = rng.next_below(40) as usize; // empty starts allowed
        let bucket_size = 1 + rng.next_below(7) as usize;
        let (mut dense, mut sparse) = paired_matrices(&mut rng, d, n0);
        let mut incr_dense = ShardedLayout::single(&dense, &Buckets::new(n0, bucket_size));
        let mut incr_sparse = ShardedLayout::single(&sparse, &Buckets::new(n0, bucket_size));
        for step in 0..4u32 {
            let k = rng.next_below(25) as usize; // 0-row appends allowed
            let (fresh_dense, fresh_sparse) = paired_matrices(&mut rng, d, k);
            dense.append_examples(&fresh_dense);
            sparse.append_examples(&fresh_sparse);
            incr_dense.append_tail(&dense);
            incr_sparse.append_tail(&sparse);
            let replay =
                format!("seed={seed} d={d} n0={n0} bucket={bucket_size} step={step} k={k}");
            let rebuilt_dense =
                ShardedLayout::single(&dense, &Buckets::new(dense.n(), bucket_size));
            let rebuilt_sparse =
                ShardedLayout::single(&sparse, &Buckets::new(sparse.n(), bucket_size));
            assert_bitwise_eq(&incr_dense, &rebuilt_dense, &dense, &replay);
            assert_bitwise_eq(&incr_sparse, &rebuilt_sparse, &sparse, &replay);
        }
    }
}

/// The auto-tuner's purity contract, over randomized observation streams:
/// a [`TuneLog`] recorded against a trace must replay against that very
/// trace (same decisions, byte-identical CSV), including after a
/// serialization round trip — for arbitrary windows, capability sets,
/// starting knobs, reverted epochs and missing imbalance samples.
/// Failures print the generator seed for exact replay.
#[test]
fn prop_tune_log_replays_against_its_own_trace() {
    use parlin::obs::ConvergencePoint;
    use parlin::solver::{AutoTuner, TuneCaps, TuneInit, TuneLog};

    let mut seed_src = Rng::new(0x7E4E);
    for trial in 0..60 {
        let seed = seed_src.next_u64();
        let mut rng = Rng::new(seed);
        let caps = TuneCaps {
            bucket: rng.next_f64() < 0.5,
            layout: rng.next_f64() < 0.5,
            workers: rng.next_f64() < 0.5,
        };
        let mut init = TuneInit::new(rng.next_u64(), caps).with_knobs(
            1 << rng.next_below(8),
            rng.next_f64() < 0.5,
            1 + rng.next_below(8) as usize,
            rng.next_f64() < 0.5,
        );
        init.window = 1 + rng.next_below(6) as usize;
        let n = 8 + rng.next_below(40) as usize;
        let mut wall = 0.0;
        let points: Vec<ConvergencePoint> = (1..=n)
            .map(|epoch| {
                wall += 0.001 + rng.next_f64() * 0.01;
                ConvergencePoint {
                    epoch,
                    wall_s: wall,
                    // ~10% adaptive-σ reverted epochs
                    rel_change: if rng.next_f64() < 0.1 { f64::INFINITY } else { rng.next_f64() },
                    gap: (rng.next_f64() < 0.3).then(|| rng.next_f64()),
                    imbalance: (rng.next_f64() < 0.7).then(|| 1.0 + rng.next_f64() * 2.0),
                    busy_s: None,
                }
            })
            .collect();
        let replay = format!(
            "replay: seed={seed} trial={trial} window={} n={n} caps={caps:?}",
            init.window
        );
        let log = AutoTuner::replay("prop", &init, &points);
        log.verify_replay(&points)
            .unwrap_or_else(|e| panic!("{replay}: {e}"));
        let csv = log.to_csv();
        let back =
            TuneLog::from_csv(&csv).unwrap_or_else(|| panic!("{replay}: own csv must parse"));
        assert_eq!(back, log, "{replay}: round trip");
        back.verify_replay(&points)
            .unwrap_or_else(|e| panic!("{replay} (after round trip): {e}"));
        assert_eq!(back.to_csv(), csv, "{replay}: byte-exact serialization");
    }
}

/// The log₂-bucket histogram quantile is the midpoint of the bucket
/// holding the exact k-th smallest sample (k = ⌈q·n⌉): the approximation
/// never leaves the exact percentile's bucket, so it stays within a
/// factor of two of the true value.
#[test]
fn prop_histogram_quantile_stays_in_the_exact_percentiles_bucket() {
    // local mirror of the bucket geometry in obs::registry (bucket 0
    // holds the value 0; bucket i ≥ 1 holds [2^(i-1), 2^i), reported as
    // its midpoint)
    fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }
    fn bucket_mid(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            let lo = 1u64 << (i - 1);
            let hi = if i >= 64 { u64::MAX } else { 1u64 << i };
            lo + (hi - lo) / 2
        }
    }
    for seed in 0..20u64 {
        let mut rng = Rng::new(0x5eed_0000 + seed);
        let n = 1 + rng.next_below(400) as usize;
        let reg = parlin::obs::Registry::new();
        let h = reg.histogram("lat");
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            // draw the magnitude first so samples spread over ~40 buckets
            // instead of clustering at the top of a uniform range
            let mag = rng.next_below(40) as u32;
            let v = rng.next_below(1u64 << (mag + 1));
            h.record(v);
            samples.push(v);
        }
        samples.sort_unstable();
        for q in [0.5, 0.9, 0.99] {
            let k = ((q * n as f64).ceil() as usize).clamp(1, n);
            let exact = samples[k - 1];
            let approx = h.quantile(q);
            assert_eq!(
                approx,
                bucket_mid(bucket_of(exact)),
                "seed {seed} n {n} q {q}: approx {approx} left the bucket of exact {exact}"
            );
            if exact > 0 {
                assert!(
                    approx >= exact / 2 && approx <= exact.saturating_mul(2),
                    "seed {seed} q {q}: {approx} not within 2x of {exact}"
                );
            }
        }
    }
}
