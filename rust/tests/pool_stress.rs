//! Pool lifecycle stress: a process that trains many times in a row, with
//! varying worker counts, must not accumulate OS threads — every
//! `train()`-scoped pool joins all of its workers on drop. The census
//! reads the kernel's thread count for this process, so a leak anywhere
//! in the dispatch path (worker never receiving the close signal, a
//! queue keeping its thread parked forever, a panicked round orphaning
//! workers) fails loudly.
//!
//! Kept as a single `#[test]` so no sibling test's threads run
//! concurrently inside this binary and pollute the census.

use parlin::data::synthetic;
use parlin::glm::Objective;
use parlin::solver::pool::WorkerPool;
use parlin::solver::{dom, numa, train, SolverConfig, Variant};
use parlin::sysinfo::Topology;

#[path = "common/census.rs"]
mod census;
use census::{settled_census, thread_census};

#[test]
fn pool_survives_repeated_training_without_leaking_threads() {
    let ds = synthetic::dense_classification(150, 8, 77);
    let obj = Objective::Logistic { lambda: 1.0 / 150.0 };
    let topo = Topology::uniform(2, 4);

    // Warm-up: one run of each shape so lazily-initialized runtime state
    // (allocator arenas, etc.) is excluded from the baseline.
    let warm = SolverConfig::new(obj)
        .with_threads(4)
        .with_tol(0.0)
        .with_max_epochs(1);
    dom::train_domesticated(&ds, &warm);
    numa::train_numa(&ds, &warm, &topo);
    let baseline = settled_census(usize::MAX - 1);

    // 1) 110 consecutive train() calls with the worker count changing
    //    every call (1..=8): each call builds its pool, runs, joins it.
    for i in 0..110usize {
        let threads = 1 + (i % 8);
        let variant = if i % 3 == 0 { Variant::Numa } else { Variant::Domesticated };
        let cfg = SolverConfig::new(obj)
            .with_variant(variant)
            .with_threads(threads)
            .with_topology(topo.clone())
            .with_tol(0.0)
            .with_max_epochs(2);
        let out = train(&ds, &cfg);
        assert_eq!(out.epochs_run, 2, "call {i} did not run its epochs");
    }
    let after_trains = settled_census(baseline);
    assert!(
        after_trains <= baseline,
        "train() loop leaked threads: baseline={baseline}, after={after_trains}"
    );

    // 2) Raw pool churn across worker-count changes, with work dispatched
    //    between every resize.
    for workers in [1usize, 2, 8, 3, 16, 4, 1, 8] {
        let pool = WorkerPool::new(workers, &topo);
        assert_eq!(pool.workers(), workers);
        let jobs: Vec<_> = (0..workers * 3).map(|k| move || k * k).collect();
        let out = pool.run(jobs);
        assert_eq!(out, (0..workers * 3).map(|k| k * k).collect::<Vec<_>>());
        drop(pool);
    }
    let after_churn = settled_census(baseline);
    assert!(
        after_churn <= baseline,
        "pool churn leaked threads: baseline={baseline}, after={after_churn}"
    );

    // 3) One resident pool hammered with many small rounds (the per-epoch
    //    merge-round shape) keeps exactly its own workers alive.
    {
        let pool = WorkerPool::new(6, &topo);
        let during_expected = baseline + 6;
        for round in 0..300usize {
            let jobs: Vec<_> = (0..6).map(|t| move || t + round).collect();
            let out = pool.run(jobs);
            assert_eq!(out[5], 5 + round);
        }
        let during = thread_census();
        // census may be 0 on non-Linux; only check when it's meaningful
        if during > 0 {
            assert!(
                during <= during_expected,
                "resident pool grew threads mid-run: {during} > {during_expected}"
            );
        }
    }
    let final_count = settled_census(baseline);
    assert!(
        final_count <= baseline,
        "resident pool leaked on drop: baseline={baseline}, final={final_count}"
    );
}
