//! End-to-end system tests: CLI-level flows, figure regeneration, loader
//! round-trips and the full train→evaluate pipeline at small scale.

use parlin::data::{loader, split_indices, synthetic, AnyDataset};
use parlin::figures::{run_figure, DsKind, FigOpts};
use parlin::glm::{accuracy, test_loss, Objective};
use parlin::solver::{train, SolverConfig, Variant};
use parlin::with_ds;

/// Train on a split, evaluate held-out metrics — the basic user workflow.
#[test]
fn train_test_split_workflow() {
    let ds = synthetic::dense_classification(2000, 30, 1);
    let (train_idx, test_idx) = split_indices(ds.n(), 0.25, 2);
    // train on the training half via a filtered copy
    let cols: Vec<Vec<f64>> = train_idx.iter().map(|&j| ds.x.col(j).to_vec()).collect();
    let col_refs: Vec<&[f64]> = cols.iter().map(|c| c.as_slice()).collect();
    let sub = parlin::data::Dataset::new(
        parlin::data::DenseMatrix::from_columns(30, &col_refs),
        train_idx.iter().map(|&j| ds.y[j]).collect(),
    );
    let obj = Objective::Logistic { lambda: 1.0 / sub.n() as f64 };
    let out = train(&sub, &SolverConfig::new(obj).with_threads(2).with_tol(1e-5));
    assert!(out.converged);
    let w = out.weights(&obj);
    let acc = accuracy(&ds, &w, &test_idx);
    assert!(acc > 0.85, "held-out accuracy {acc}");
    let tl = test_loss(&ds, &obj, &w, &test_idx);
    assert!(tl < 0.45, "held-out loss {tl}");
}

/// Every dataset kind trains end-to-end through the Auto variant.
#[test]
fn every_dataset_kind_trains() {
    for kind in [
        DsKind::DenseSynth,
        DsKind::SparseSynth,
        DsKind::HiggsLike,
        DsKind::EpsilonLike,
        DsKind::CriteoLike,
    ] {
        let ds = kind.make(true, 3);
        let cfg = SolverConfig::new(Objective::Logistic {
            lambda: 1.0 / ds.n() as f64,
        })
        .with_threads(2)
        .with_tol(1e-3)
        .with_max_epochs(100);
        let out = with_ds!(&ds, d => train(d, &cfg));
        assert!(out.converged, "{} did not converge", kind.name());
        assert!(out.final_gap.abs() < 1.0, "{} gap {}", kind.name(), out.final_gap);
    }
}

/// LIBSVM round-trip: write → load → train.
#[test]
fn libsvm_load_and_train() {
    let dir = std::env::temp_dir().join(format!("parlin_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("toy.libsvm");
    let mut content = String::new();
    let src = synthetic::sparse_classification(300, 50, 0.1, 4);
    for j in 0..src.n() {
        let (idx, val) = src.x.col(j);
        content.push_str(if src.y[j] > 0.0 { "+1" } else { "-1" });
        for (i, v) in idx.iter().zip(val) {
            content.push_str(&format!(" {}:{}", i + 1, v));
        }
        content.push('\n');
    }
    std::fs::write(&path, content).unwrap();
    let ds = loader::load_libsvm(&path, None).unwrap();
    assert_eq!(ds.n(), 300);
    let out = train(
        &ds,
        &SolverConfig::new(Objective::Logistic { lambda: 1.0 / 300.0 }).with_tol(1e-4),
    );
    assert!(out.converged);
    std::fs::remove_dir_all(&dir).ok();
}

/// Figure pipeline: `figures --all --quick` regenerates every CSV.
#[test]
fn all_figures_regenerate() {
    let mut opts = FigOpts::quick();
    opts.out_dir = std::env::temp_dir().join(format!("parlin_figs_{}", std::process::id()));
    run_figure("all", &opts).unwrap();
    for f in [
        "fig1_wild_scaling.csv",
        "fig2a_ablation.csv",
        "fig2b_cocoa_partitions.csv",
        "fig3_time_to_convergence.csv",
        "fig4_strong_scaling.csv",
        "fig5a_partitioning.csv",
        "fig5b_buckets.csv",
        "fig5c_numa.csv",
        "fig6_solver_comparison.csv",
    ] {
        assert!(opts.out_dir.join(f).exists(), "missing {f}");
        let content = std::fs::read_to_string(opts.out_dir.join(f)).unwrap();
        assert!(content.lines().count() > 2, "{f} nearly empty");
    }
    std::fs::remove_dir_all(&opts.out_dir).ok();
}

/// Reproduction headline: the Fig-3 wild-vs-dom comparison must show the
/// paper's qualitative result on the dense workload — domesticated at high
/// thread counts converges while wild degrades or loses.
#[test]
fn headline_dom_beats_wild_at_scale() {
    let machine = parlin::simcost::xeon4();
    // full-size stand-in (40k × 100): the wild lost-update drift is a
    // cumulative effect — at the quick scale it stays under the
    // correctness threshold, exactly like the paper's effects grow with
    // dataset size
    let ds: AnyDataset = DsKind::DenseSynth.make(false, 5);
    let wild32 = parlin::figures::run_wild(&ds, &machine, 32, 5, 1.0);
    let dom32 = parlin::figures::run_snap(
        &ds,
        &machine,
        32,
        parlin::solver::Partitioning::Dynamic,
        8,
        5,
        1.0,
    );
    assert!(dom32.converged, "domesticated must converge at 32T");
    // quick-mode dataset is only ~6k examples, so 32 partitions sit at an
    // extreme partition/data ratio — allow a generous CoCoA factor; at
    // paper scale (100k examples) the ratio is ~2-3× (see Fig 2b harness)
    let dom_degradation_free = dom32.epochs <= 8 * {
        let seq = parlin::figures::run_snap(
            &ds,
            &machine,
            1,
            parlin::solver::Partitioning::Dynamic,
            8,
            5,
            1.0,
        );
        seq.epochs
    };
    assert!(dom_degradation_free, "dom epochs blew up: {}", dom32.epochs);
    // wild at 32T on dense must fail, diverge, blow up in epochs, or —
    // the PASSCoDe failure mode the paper cites — settle on an incorrect
    // solution (flagged by the duality-gap certificate)
    let wild_hurt = !wild32.converged
        || wild32.diverged
        || !wild32.correct
        || wild32.epochs > 2 * dom32.epochs;
    assert!(
        wild_hurt,
        "expected wild to degrade at 32T on dense (wild {} ep, correct={}, dom {} ep)",
        wild32.epochs, wild32.correct, dom32.epochs
    );
}

/// The e2e example's assertion, in test form at reduced scale: full-stack
/// train + HLO-artifact evaluation reach gap < 1e-3 (requires artifacts).
#[test]
fn reduced_e2e_with_artifacts() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let rt = parlin::runtime::ArtifactRuntime::load(&dir).unwrap();
    let ds = synthetic::dense_classification(3000, 100, 6);
    let obj = Objective::Logistic { lambda: 1.0 / 3000.0 };
    let cfg = SolverConfig::new(obj)
        .with_variant(Variant::Domesticated)
        .with_threads(4)
        .with_tol(1e-5);
    let out = train(&ds, &cfg);
    assert!(out.final_gap < 1e-3, "gap {}", out.final_gap);
    let idx: Vec<usize> = (0..ds.n()).collect();
    let ev = parlin::runtime::TiledEvaluator::new(&rt, &ds, &idx).unwrap();
    let w = out.weights(&obj);
    let hlo = ev.eval(&w).unwrap();
    let native = test_loss(&ds, &obj, &w, &idx);
    assert!(
        (hlo.mean_loss - native).abs() < 1e-3 * native.max(1.0),
        "hlo {} vs native {}",
        hlo.mean_loss,
        native
    );
}
