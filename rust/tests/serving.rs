//! Serving-subsystem guarantees:
//!
//! (a) `Session::predict` (sharded, pool-parallel) is **bit-wise** equal
//!     to the batch path `glm::model::margins` on `TrainOutput::weights`;
//! (b) warm-start `partial_fit` after appending 5% new rows converges in
//!     strictly fewer epochs than a cold retrain of the same dataset;
//! (c) 50 interleaved predict/refit calls on one `Session` cause zero net
//!     thread growth (the resident pool is really reused), and dropping
//!     the session joins its workers.
//!
//! The tests in this binary serialize on a mutex: (c) counts OS threads
//! via `/proc/self/status` (the census shared with `pool_stress.rs`, see
//! `common/census.rs`), so no sibling test's pools may spawn or die while
//! it runs.

use parlin::data::synthetic;
use parlin::glm::Objective;
use parlin::serve::Session;
use parlin::solver::{train, SolverConfig, Variant};
use parlin::sysinfo::Topology;
use std::sync::{Mutex, MutexGuard};

#[path = "common/census.rs"]
mod census;
use census::settled_census;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn logistic(n: usize) -> Objective {
    Objective::Logistic {
        lambda: 1.0 / n as f64,
    }
}

#[test]
fn predict_bitwise_matches_batch_margins() {
    let _g = gate();
    let topo = Topology::uniform(2, 2);
    let cfg = SolverConfig::new(logistic(400))
        .with_variant(Variant::Domesticated)
        .with_threads(4)
        .with_topology(topo)
        .with_tol(1e-4)
        .with_max_epochs(300);
    let ds = synthetic::dense_classification(400, 16, 31);

    // batch reference: the plain train() front door + glm::model::margins
    let reference = train(&ds, &cfg);
    let ref_w = reference.weights(&logistic(400));

    let mut sess = Session::new(ds.clone(), cfg);
    assert_eq!(
        sess.weights(),
        &ref_w[..],
        "session must train the identical model (shared-pool executor equivalence)"
    );

    // any order, any batch size, including shards smaller than the pool
    let mut idx: Vec<usize> = (0..400).rev().collect();
    idx.extend([7usize, 7, 0, 399]); // duplicates are fine
    let got = sess.predict(&idx);
    let want = parlin::glm::model::margins(&ds, &ref_w, &idx);
    assert_eq!(got, want, "sharded predict must be bit-wise identical");

    let tiny = sess.predict(&[3]);
    assert_eq!(tiny, parlin::glm::model::margins(&ds, &ref_w, &[3]));
}

#[test]
fn warm_refit_beats_cold_retrain_in_epochs() {
    let _g = gate();
    let cfg = SolverConfig::new(logistic(400))
        .with_variant(Variant::Domesticated)
        .with_threads(4)
        .with_topology(Topology::flat(4))
        .with_tol(1e-4)
        .with_max_epochs(500);
    let ds = synthetic::dense_classification(400, 15, 32);
    let mut sess = Session::new(ds, cfg);

    // append 5% new rows and warm-start refit
    let fresh = synthetic::dense_classification(20, 15, 33);
    let warm = sess.partial_fit_rows(&fresh).expect("clean warm refit");
    assert_eq!(warm.n, 420);
    assert!(warm.converged, "warm refit must converge");

    // cold retrain of the *same* (appended) dataset on the same pool
    let cold = sess.retrain_same().expect("clean cold retrain");
    assert!(cold.converged, "cold retrain must converge");
    assert!(
        warm.epochs < cold.epochs,
        "warm start must beat cold retrain: warm={} cold={}",
        warm.epochs,
        cold.epochs
    );
    // both end at a served model of equivalent quality
    assert!(sess.gap().gap < 1e-2);
}

/// The ROADMAP's work-stealing decision needs *recorded* imbalance
/// numbers from a real serving workload — this smoke test produces them.
/// Ignored by default (it is a measurement, not a guarantee); run with:
///
/// ```bash
/// cargo test --test serving -- --ignored --nocapture
/// ```
///
/// Decision rule (ROADMAP): if max/mean busy time is materially above 1,
/// add intra-node work stealing.
#[test]
#[ignore = "serving smoke workload: run explicitly to record pool imbalance"]
fn smoke_synthetic_serve_records_pool_imbalance() {
    let _g = gate();
    let topo = Topology::uniform(2, 2);
    let cfg = SolverConfig::new(logistic(3000))
        .with_variant(Variant::Domesticated)
        .with_threads(4)
        .with_topology(topo)
        .with_tol(1e-3)
        .with_max_epochs(150);
    let ds = synthetic::sparse_classification(3000, 300, 0.05, 77);
    let mut sess = Session::new(ds, cfg);

    let reqs = parlin::serve::synthetic_mix(150, 256, 32, 7);
    let report = parlin::serve::drive(&mut sess, &reqs, 7);
    let ps = sess.pool_stats();
    let imb = ps.imbalance();
    println!(
        "serve smoke: {} requests in {:.3}s ({} predicts / {} refits / {} retrains)",
        report.requests(),
        report.total_wall_s,
        report.predict_s.len(),
        report.refit_s.len(),
        report.retrain_s.len()
    );
    println!("pool imbalance (max/mean busy): {imb:.3} over {} jobs", ps.total_jobs());
    for w in &ps.per_worker {
        println!(
            "  worker {:>2} (node {}): {:>7} jobs, {:>8.4}s busy",
            w.worker, w.node, w.jobs, w.busy_s
        );
    }
    assert!(ps.total_jobs() > 0, "the workload must have exercised the pool");
    assert!(imb.is_finite(), "imbalance must be finite, got {imb}");
    assert!(imb >= 1.0 - 1e-9, "max/mean cannot be below 1, got {imb}");
}

#[test]
fn fifty_interleaved_requests_leak_no_threads() {
    let _g = gate();
    let topo = Topology::uniform(2, 2);
    // Variant::Auto resolves to the hierarchical solver at 4 threads on
    // this topology, so refits exercise the node-tagged dispatch path.
    let cfg = SolverConfig::new(logistic(300))
        .with_threads(4)
        .with_topology(topo)
        .with_tol(1e-3)
        .with_max_epochs(200);
    let ds = synthetic::dense_classification(300, 10, 34);
    let mut sess = Session::new(ds, cfg);
    let workers = sess.workers();
    assert_eq!(workers, 4);

    // warm-up one request of each kind, then take the baseline census
    let _ = sess.predict(&[0, 1, 2]);
    let warm = synthetic::dense_classification(5, 10, 99);
    let _ = sess.partial_fit_rows(&warm).expect("clean warm-up refit");
    let baseline = settled_census(usize::MAX - 1);

    for i in 0..50usize {
        match i % 5 {
            0 => {
                let fresh = synthetic::dense_classification(5, 10, 100 + i as u64);
                let r = sess.partial_fit_rows(&fresh).expect("clean rows refit");
                assert!(r.epochs >= 1);
            }
            3 => {
                let r = sess
                    .partial_fit_lambda(1.0 / sess.n() as f64)
                    .expect("clean λ refit");
                assert!(r.epochs >= 1);
            }
            _ => {
                let n = sess.n();
                let idx: Vec<usize> = (0..64).map(|k| (i * 17 + k) % n).collect();
                assert_eq!(sess.predict(&idx).len(), 64);
            }
        }
    }
    let after = settled_census(baseline);
    assert!(
        after <= baseline,
        "50 interleaved requests grew threads: baseline={baseline}, after={after}"
    );

    // the session's drop must join exactly its resident workers
    drop(sess);
    let target = baseline.saturating_sub(workers);
    let end = settled_census(target);
    if end > 0 {
        // census is 0 on non-Linux; only assert where it means something
        assert!(
            end <= target,
            "session drop did not join its pool: baseline={baseline}, end={end}"
        );
    }
}
