//! Integration tests over the PJRT runtime: the AOT artifacts must agree
//! with the rust-native f64 implementations on real data, and the
//! HLO-backed trainer must reach the same solution as the native solver.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise —
//! `make test` always builds artifacts first).

use parlin::data::{synthetic, Dataset, DenseMatrix};
use parlin::glm::{self, Objective};
use parlin::runtime::{ArtifactRuntime, TiledEvaluator};
use parlin::solver::{train, SolverConfig, Variant};
use std::path::Path;

fn runtime() -> Option<ArtifactRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(ArtifactRuntime::load(&dir).expect("load artifacts"))
}

fn full_idx(ds: &Dataset<DenseMatrix>) -> Vec<usize> {
    (0..ds.n()).collect()
}

#[test]
fn artifacts_present_and_tile_shapes_valid() {
    let Some(rt) = runtime() else { return };
    rt.validate_tiles().unwrap();
    for name in ["eval_tile", "matvec_tile", "loss_tile", "grad_tile", "bucket_step"] {
        assert!(rt.get(name).is_ok(), "missing artifact {name}");
    }
}

#[test]
fn eval_tile_matches_native_small_d() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::dense_classification(700, 100, 1); // d=100 ≤ 128
    let idx = full_idx(&ds);
    let ev = TiledEvaluator::new(&rt, &ds, &idx).unwrap();
    let obj = Objective::Logistic { lambda: 1e-3 };
    let mut rng = parlin::util::Rng::new(2);
    let w: Vec<f64> = (0..100).map(|_| rng.next_gaussian() * 0.3).collect();
    let got = ev.eval(&w).unwrap();
    let want_loss = glm::test_loss(&ds, &obj, &w, &idx);
    let want_acc = glm::accuracy(&ds, &w, &idx);
    assert_eq!(got.count, 700);
    assert!(
        (got.mean_loss - want_loss).abs() < 1e-4 * want_loss.max(1.0),
        "loss: hlo={} native={}",
        got.mean_loss,
        want_loss
    );
    assert!((got.accuracy - want_acc).abs() < 1e-9, "acc mismatch");
}

#[test]
fn feature_tiled_path_matches_native_large_d() {
    let Some(rt) = runtime() else { return };
    // d=300 > 128 forces the matvec+loss composition over 3 feature tiles
    let ds = synthetic::dense_classification(300, 300, 3);
    let idx = full_idx(&ds);
    let ev = TiledEvaluator::new(&rt, &ds, &idx).unwrap();
    let obj = Objective::Logistic { lambda: 1e-3 };
    let mut rng = parlin::util::Rng::new(4);
    let w: Vec<f64> = (0..300).map(|_| rng.next_gaussian() * 0.2).collect();
    let got = ev.eval(&w).unwrap();
    let want = glm::test_loss(&ds, &obj, &w, &idx);
    assert!(
        (got.mean_loss - want).abs() < 5e-4 * want.max(1.0),
        "hlo={} native={}",
        got.mean_loss,
        want
    );
}

#[test]
fn grad_tile_matches_finite_difference() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::dense_classification(512, 64, 5);
    let idx = full_idx(&ds);
    let ev = TiledEvaluator::new(&rt, &ds, &idx).unwrap();
    let lambda = 0.01;
    let obj = Objective::Logistic { lambda };
    let mut rng = parlin::util::Rng::new(6);
    let w: Vec<f64> = (0..64).map(|_| rng.next_gaussian() * 0.2).collect();
    let (g, _) = ev.grad(&w, lambda).unwrap();
    // compare a few coordinates against central differences of the native
    // primal objective (f32 artifacts ⇒ loose-ish tolerance)
    for k in [0usize, 13, 63] {
        let h = 1e-4;
        let mut wp = w.clone();
        wp[k] += h;
        let mut wm = w.clone();
        wm[k] -= h;
        let fp = glm::primal_value(&ds, &obj, &wp);
        let fm = glm::primal_value(&ds, &obj, &wm);
        let fd = (fp - fm) / (2.0 * h);
        assert!(
            (g[k] - fd).abs() < 1e-3 * fd.abs().max(1.0),
            "coord {k}: hlo={} fd={}",
            g[k],
            fd
        );
    }
}

#[test]
fn eval_handles_padding_tile() {
    let Some(rt) = runtime() else { return };
    // 300 examples = 1 full tile + 44-row padded tile
    let ds = synthetic::dense_classification(300, 50, 7);
    let idx = full_idx(&ds);
    let ev = TiledEvaluator::new(&rt, &ds, &idx).unwrap();
    let w = vec![0.0; 50];
    let got = ev.eval(&w).unwrap();
    assert_eq!(got.count, 300);
    // at w=0: loss = ln2 exactly, accuracy = 0 (margin 0 counts incorrect)
    assert!((got.mean_loss - std::f64::consts::LN_2).abs() < 1e-6);
    assert!(got.accuracy.abs() < 1e-12);
}

#[test]
fn hlo_bucket_trainer_matches_native_solution() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::dense_classification(600, 100, 8);
    let obj = Objective::Logistic { lambda: 1.0 / 600.0 };
    let cfg = SolverConfig::new(obj).with_tol(1e-5).with_max_epochs(200);
    let hlo = parlin::runtime::hlo_trainer::train_hlo_bucketed(&rt, &ds, &cfg).unwrap();
    assert!(hlo.converged, "hlo trainer did not converge");
    assert!(hlo.final_gap < 1e-2, "gap={}", hlo.final_gap);
    let native = train(&ds, &cfg.clone().with_variant(Variant::Sequential));
    let dist = parlin::util::rel_change(&native.weights(&obj), &hlo.weights(&obj));
    assert!(dist < 5e-2, "hlo vs native weights differ: {dist}");
}

#[test]
fn hlo_trainer_rejects_oversized_d() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::dense_classification(64, 200, 9);
    let cfg = SolverConfig::new(Objective::Logistic { lambda: 0.01 });
    let err = match parlin::runtime::hlo_trainer::train_hlo_bucketed(&rt, &ds, &cfg) {
        Err(e) => e,
        Ok(_) => panic!("expected d-limit error"),
    };
    assert!(format!("{err}").contains("d ≤"), "{err}");
}
