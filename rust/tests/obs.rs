//! Determinism under observation: turning the tracing layer on — even at
//! the smallest possible ring size, where most events are dropped on the
//! floor — must not change a single bit of the trained model. Observation
//! is pull-only: workers push fixed-size events into their own SPSC rings
//! and nothing on the training path ever waits on, reads from, or branches
//! on observability state (beyond the one enable check). These tests are
//! the pool_equivalence-style proof of that contract.
//!
//! Note on sharing: the trace session is process-global and tests in this
//! binary run concurrently, so event-count assertions are lower bounds —
//! a concurrently running test may emit into the live session. Model-bit
//! assertions need no such care.

use parlin::data::synthetic;
use parlin::glm::Objective;
use parlin::obs::{EventKind, ObsConfig, TraceSession, MIN_RING_CAPACITY};
use parlin::solver::exec::Executor;
use parlin::solver::pool::WorkerPool;
use parlin::solver::{dom, numa, SolverConfig};
use parlin::sysinfo::Topology;

/// Fixed-epoch config so trajectories (not just fixed points) must agree.
fn fixed_epochs(n: usize, threads: usize, epochs: usize) -> SolverConfig {
    SolverConfig::new(Objective::Logistic { lambda: 1.0 / n as f64 })
        .with_threads(threads)
        .with_tol(0.0)
        .with_max_epochs(epochs)
}

fn executor(kind: &str, threads: usize) -> Executor {
    match kind {
        "seq" => Executor::Sequential,
        "threads" => Executor::Threads,
        _ => Executor::Pool(WorkerPool::new(threads, &Topology::flat(threads))),
    }
}

/// The headline guarantee: an untraced run and a run traced at
/// [`MIN_RING_CAPACITY`] (rings so small they *must* overflow) produce
/// bit-wise identical `α` and `v` under every executor.
#[test]
fn tracing_at_the_smallest_ring_is_bitwise_invisible_to_the_model() {
    let ds = synthetic::dense_classification(400, 16, 21);
    for kind in ["seq", "threads", "pool"] {
        let cfg = fixed_epochs(400, 4, 12);
        let baseline = dom::train_domesticated_exec(&ds, &cfg, &executor(kind, 4));

        let session = TraceSession::start(ObsConfig::on(MIN_RING_CAPACITY));
        let exec = executor(kind, 4);
        let traced = dom::train_domesticated_exec(&ds, &cfg, &exec);
        // join pool workers so their final post-job events land (or drop)
        // before the rings are drained
        drop(exec);
        let dump = session.finish();

        assert_eq!(baseline.state.alpha, traced.state.alpha, "{kind}: α changed under tracing");
        assert_eq!(baseline.state.v, traced.state.v, "{kind}: v changed under tracing");
        // 12 epochs of begin/end (+ job traffic) through 8-slot rings must
        // overflow — and overflow may only bump the drop counter, never
        // block or corrupt
        assert!(
            dump.total_dropped() > 0,
            "{kind}: expected ring overflow at MIN_RING_CAPACITY, \
             got {} events / {} dropped",
            dump.total_events(),
            dump.total_dropped()
        );
    }
}

/// Same guarantee for the hierarchical NUMA solver, whose node-tagged jobs
/// exercise the per-node bucket queues (and their enqueue/start/finish
/// instrumentation) rather than the flat round-robin path.
#[test]
fn numa_solver_traced_equals_untraced_bitwise() {
    let ds = synthetic::dense_classification(360, 12, 23);
    let topo = Topology::uniform(2, 4);
    let cfg = fixed_epochs(360, 8, 10);
    let baseline =
        numa::train_numa_exec(&ds, &cfg, &topo, &Executor::Pool(WorkerPool::new(8, &topo)));

    let session = TraceSession::start(ObsConfig::on(MIN_RING_CAPACITY));
    let exec = Executor::Pool(WorkerPool::new(8, &topo));
    let traced = numa::train_numa_exec(&ds, &cfg, &topo, &exec);
    drop(exec);
    let dump = session.finish();

    assert_eq!(baseline.state.alpha, traced.state.alpha, "numa α changed under tracing");
    assert_eq!(baseline.state.v, traced.state.v, "numa v changed under tracing");
    assert!(dump.total_events() > 0, "the traced run must have recorded something");
}

/// A comfortably sized ring captures the full event vocabulary of a pool
/// training run, per-thread streams come out time-ordered, and the
/// chrome-trace export carries the events by their stable names.
#[test]
fn traced_pool_run_records_ordered_job_and_epoch_events() {
    let ds = synthetic::dense_classification(300, 12, 33);
    let cfg = fixed_epochs(300, 3, 6);

    let session = TraceSession::start(ObsConfig::on(1 << 12));
    let exec = Executor::Pool(WorkerPool::new(3, &Topology::flat(3)));
    let _out = dom::train_domesticated_exec(&ds, &cfg, &exec);
    drop(exec);
    let dump = session.finish();

    // 6 epochs from this thread; ≥ one 3-job merge round per epoch through
    // the pool (lower bounds — see the module note on session sharing)
    assert!(dump.count_of(EventKind::EpochBegin) >= 6);
    assert!(dump.count_of(EventKind::EpochEnd) >= 6);
    assert!(dump.count_of(EventKind::JobEnqueue) >= 18);
    assert!(dump.count_of(EventKind::JobStart) >= 18);
    assert!(dump.count_of(EventKind::JobFinish) >= 18);

    // FIFO rings drained in push order ⇒ nondecreasing timestamps per thread
    for t in &dump.threads {
        for w in t.events.windows(2) {
            assert!(w[0].ts_ns <= w[1].ts_ns, "thread {} events out of time order", t.name);
        }
    }

    let json = dump.to_chrome_json();
    for name in ["job_enqueue", "job_start", "job_finish", "epoch_begin", "epoch_end"] {
        assert!(json.contains(&format!("\"{name}\"")), "chrome trace is missing {name}");
    }
    assert!(json.contains("parlin-pool-n0-w0"), "worker thread names must be exported");
}
