//! Determinism under observation: turning the tracing layer on — even at
//! the smallest possible ring size, where most events are dropped on the
//! floor — must not change a single bit of the trained model. Observation
//! is pull-only: workers push fixed-size events into their own SPSC rings
//! and nothing on the training path ever waits on, reads from, or branches
//! on observability state (beyond the one enable check). These tests are
//! the pool_equivalence-style proof of that contract.
//!
//! Note on sharing: the trace session is process-global and tests in this
//! binary run concurrently, so event-count assertions are lower bounds —
//! a concurrently running test may emit into the live session. Model-bit
//! assertions need no such care.

use parlin::data::synthetic;
use parlin::glm::Objective;
use parlin::obs::{EventKind, ObsConfig, TraceSession, MIN_RING_CAPACITY};
use parlin::solver::exec::Executor;
use parlin::solver::pool::WorkerPool;
use parlin::solver::{dom, numa, SolverConfig};
use parlin::sysinfo::Topology;

/// Fixed-epoch config so trajectories (not just fixed points) must agree.
fn fixed_epochs(n: usize, threads: usize, epochs: usize) -> SolverConfig {
    SolverConfig::new(Objective::Logistic { lambda: 1.0 / n as f64 })
        .with_threads(threads)
        .with_tol(0.0)
        .with_max_epochs(epochs)
}

fn executor(kind: &str, threads: usize) -> Executor {
    match kind {
        "seq" => Executor::Sequential,
        "threads" => Executor::Threads,
        _ => Executor::Pool(WorkerPool::new(threads, &Topology::flat(threads))),
    }
}

/// The headline guarantee: an untraced run and a run traced at
/// [`MIN_RING_CAPACITY`] (rings so small they *must* overflow) produce
/// bit-wise identical `α` and `v` under every executor.
#[test]
fn tracing_at_the_smallest_ring_is_bitwise_invisible_to_the_model() {
    let ds = synthetic::dense_classification(400, 16, 21);
    for kind in ["seq", "threads", "pool"] {
        let cfg = fixed_epochs(400, 4, 12);
        let baseline = dom::train_domesticated_exec(&ds, &cfg, &executor(kind, 4));

        let session = TraceSession::start(ObsConfig::on(MIN_RING_CAPACITY));
        let exec = executor(kind, 4);
        let traced = dom::train_domesticated_exec(&ds, &cfg, &exec);
        // join pool workers so their final post-job events land (or drop)
        // before the rings are drained
        drop(exec);
        let dump = session.finish();

        assert_eq!(baseline.state.alpha, traced.state.alpha, "{kind}: α changed under tracing");
        assert_eq!(baseline.state.v, traced.state.v, "{kind}: v changed under tracing");
        // 12 epochs of begin/end (+ job traffic) through 8-slot rings must
        // overflow — and overflow may only bump the drop counter, never
        // block or corrupt
        assert!(
            dump.total_dropped() > 0,
            "{kind}: expected ring overflow at MIN_RING_CAPACITY, \
             got {} events / {} dropped",
            dump.total_events(),
            dump.total_dropped()
        );
    }
}

/// Same guarantee for the hierarchical NUMA solver, whose node-tagged jobs
/// exercise the per-node bucket queues (and their enqueue/start/finish
/// instrumentation) rather than the flat round-robin path.
#[test]
fn numa_solver_traced_equals_untraced_bitwise() {
    let ds = synthetic::dense_classification(360, 12, 23);
    let topo = Topology::uniform(2, 4);
    let cfg = fixed_epochs(360, 8, 10);
    let baseline =
        numa::train_numa_exec(&ds, &cfg, &topo, &Executor::Pool(WorkerPool::new(8, &topo)));

    let session = TraceSession::start(ObsConfig::on(MIN_RING_CAPACITY));
    let exec = Executor::Pool(WorkerPool::new(8, &topo));
    let traced = numa::train_numa_exec(&ds, &cfg, &topo, &exec);
    drop(exec);
    let dump = session.finish();

    assert_eq!(baseline.state.alpha, traced.state.alpha, "numa α changed under tracing");
    assert_eq!(baseline.state.v, traced.state.v, "numa v changed under tracing");
    assert!(dump.total_events() > 0, "the traced run must have recorded something");
}

/// A comfortably sized ring captures the full event vocabulary of a pool
/// training run, per-thread streams come out time-ordered, and the
/// chrome-trace export carries the events by their stable names.
#[test]
fn traced_pool_run_records_ordered_job_and_epoch_events() {
    let ds = synthetic::dense_classification(300, 12, 33);
    let cfg = fixed_epochs(300, 3, 6);

    let session = TraceSession::start(ObsConfig::on(1 << 12));
    let exec = Executor::Pool(WorkerPool::new(3, &Topology::flat(3)));
    let _out = dom::train_domesticated_exec(&ds, &cfg, &exec);
    drop(exec);
    let dump = session.finish();

    // 6 epochs from this thread; ≥ one 3-job merge round per epoch through
    // the pool (lower bounds — see the module note on session sharing)
    assert!(dump.count_of(EventKind::EpochBegin) >= 6);
    assert!(dump.count_of(EventKind::EpochEnd) >= 6);
    assert!(dump.count_of(EventKind::JobEnqueue) >= 18);
    assert!(dump.count_of(EventKind::JobStart) >= 18);
    assert!(dump.count_of(EventKind::JobFinish) >= 18);

    // FIFO rings drained in push order ⇒ nondecreasing timestamps per thread
    for t in &dump.threads {
        for w in t.events.windows(2) {
            assert!(w[0].ts_ns <= w[1].ts_ns, "thread {} events out of time order", t.name);
        }
    }

    let json = dump.to_chrome_json();
    for name in ["job_enqueue", "job_start", "job_finish", "epoch_begin", "epoch_end"] {
        assert!(json.contains(&format!("\"{name}\"")), "chrome trace is missing {name}");
    }
    assert!(json.contains("parlin-pool-n0-w0"), "worker thread names must be exported");
}

mod scrape {
    //! Scrape-determinism: the `/metrics`+`/health` endpoint is pull-only,
    //! so a client hammering it concurrently with training and serving
    //! must not move a single bit of the model or the served margins.

    use super::{executor, fixed_epochs};
    use parlin::data::synthetic;
    use parlin::obs::{ExportServer, ExportSources};
    use parlin::serve::{ServeHealth, Session};
    use parlin::solver::dom;
    use std::io::{Read as _, Write as _};
    use std::net::{SocketAddr, TcpStream};
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).expect("connecting to the export server");
        write!(s, "GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).expect("reading the response");
        let status: u16 = text
            .lines()
            .next()
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|c| c.parse().ok())
            .expect("status line");
        let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }

    /// Train under every executor and serve a prediction pass, first with
    /// no endpoint running, then under a scraper thread looping over
    /// `/metrics` and `/health` the whole time. α, v, and the served
    /// margins must be bit-wise identical; the scraper must actually have
    /// scraped while the work ran.
    #[test]
    fn scraping_under_load_is_bitwise_invisible_to_training_and_serving() {
        let ds = synthetic::dense_classification(400, 16, 29);
        let cfg = fixed_epochs(400, 4, 10);
        let idx: Vec<usize> = (0..ds.n()).collect();

        // unobserved baselines
        let kinds = ["seq", "threads", "pool"];
        let baselines: Vec<_> = kinds
            .iter()
            .map(|&k| dom::train_domesticated_exec(&ds, &cfg, &executor(k, 4)))
            .collect();
        let baseline_margins = Session::new(ds.clone(), cfg.clone()).predict(&idx);

        // same work under continuous scraping
        let srv = ExportServer::start(
            "127.0.0.1:0",
            ExportSources::with_health(|| (true, "Healthy".to_string())),
        )
        .expect("binding the export server");
        let addr = srv.local_addr();
        let stop = Arc::new(AtomicBool::new(false));
        let scrapes = Arc::new(AtomicUsize::new(0));
        let scraper = {
            let (stop, scrapes) = (Arc::clone(&stop), Arc::clone(&scrapes));
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let (status, _) = http_get(addr, "/metrics");
                    assert_eq!(status, 200, "/metrics under load");
                    let (status, body) = http_get(addr, "/health");
                    assert_eq!(status, 200, "/health under load");
                    assert_eq!(body.trim_end(), "Healthy");
                    scrapes.fetch_add(1, Ordering::Relaxed);
                }
            })
        };

        for (&kind, baseline) in kinds.iter().zip(&baselines) {
            let scraped = dom::train_domesticated_exec(&ds, &cfg, &executor(kind, 4));
            assert_eq!(
                baseline.state.alpha, scraped.state.alpha,
                "{kind}: α changed under scraping"
            );
            assert_eq!(baseline.state.v, scraped.state.v, "{kind}: v changed under scraping");
        }
        let scraped_margins = Session::new(ds.clone(), cfg).predict(&idx);

        stop.store(true, Ordering::Relaxed);
        scraper.join().expect("the scraper thread must not have panicked");

        assert_eq!(baseline_margins.len(), scraped_margins.len());
        for (i, (a, b)) in baseline_margins.iter().zip(&scraped_margins).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "served margin {i} changed under scraping");
        }
        assert!(
            scrapes.load(Ordering::Relaxed) > 0,
            "the scraper never completed a pass — the determinism claim was not exercised"
        );
        srv.shutdown();
    }

    /// `/health` serves [`ServeHealth`]'s `Display` strings verbatim with
    /// the matching status code — the contract docs/ROBUSTNESS.md states
    /// and examples/check_metrics.rs re-validates from the outside.
    #[test]
    fn health_route_serves_serve_health_display_strings_verbatim() {
        let state = Arc::new(Mutex::new(ServeHealth::Healthy));
        let srv = {
            let state = Arc::clone(&state);
            ExportServer::start(
                "127.0.0.1:0",
                ExportSources::with_health(move || {
                    let h = parlin::util::lock_recover(&state).clone();
                    (h.is_healthy(), h.to_string())
                }),
            )
            .expect("binding the export server")
        };
        let addr = srv.local_addr();

        let (status, body) = http_get(addr, "/health");
        assert_eq!((status, body.trim_end()), (200, "Healthy"));

        *parlin::util::lock_recover(&state) = ServeHealth::degraded("drain failed: injected");
        let (status, body) = http_get(addr, "/health");
        assert_eq!(status, 503);
        assert_eq!(body.trim_end(), "Degraded (drain failed: injected)");
        srv.shutdown();
    }

    /// Labelled families survive the wire: distinct label sets come out of
    /// `/metrics` as separate `name{key="value"}` series — exactly one
    /// sample per label set, one `# TYPE` line per family, and never a
    /// duplicate (name, label-set) pair anywhere in the exposition. The
    /// real producer is exercised too: a tuned training run surfaces its
    /// per-knob decision counters in the same shape.
    #[test]
    fn labelled_series_are_exposed_once_per_label_set() {
        use parlin::obs::registry;
        use parlin::solver::{train, BucketPolicy, TunePolicy, Variant};

        // seed one family with two label sets, touching one of them twice
        // (the registry is process-global, so values are lower bounds; the
        // series *shape* is what this test owns)
        registry().labelled_counter("obs.test.decisions", &[("knob", "layout")]).add(3);
        registry().labelled_counter("obs.test.decisions", &[("knob", "bucket")]).inc();
        registry().labelled_counter("obs.test.decisions", &[("knob", "layout")]).inc();

        // and drive the real producer: 12 fixed epochs cross the tuner's
        // first window boundary, so the layout probe must record a decision
        let ds = synthetic::dense_classification(300, 12, 41);
        let cfg = fixed_epochs(300, 1, 12)
            .with_variant(Variant::Sequential)
            .with_bucket(BucketPolicy::Fixed(8))
            .with_tune(TunePolicy::On { seed: 3 });
        let out = train(&ds, &cfg);
        assert!(
            !out.tune_log.expect("tuned run must stamp a log").decisions.is_empty(),
            "the tuned run never decided anything — no labelled sample to check"
        );

        let srv = ExportServer::start("127.0.0.1:0", ExportSources::default())
            .expect("binding the export server");
        let (status, body) = http_get(srv.local_addr(), "/metrics");
        assert_eq!(status, 200);

        let series = |prefix: &str| body.lines().filter(|l| l.starts_with(prefix)).count();
        assert_eq!(
            series("parlin_obs_test_decisions{knob=\"layout\"} "),
            1,
            "one sample per label set:\n{body}"
        );
        assert_eq!(series("parlin_obs_test_decisions{knob=\"bucket\"} "), 1);
        assert_eq!(
            series("# TYPE parlin_obs_test_decisions counter"),
            1,
            "one TYPE line per labelled family:\n{body}"
        );
        let layout_value: u64 = body
            .lines()
            .find(|l| l.starts_with("parlin_obs_test_decisions{knob=\"layout\"} "))
            .and_then(|l| l.rsplit(' ').next())
            .and_then(|v| v.parse().ok())
            .expect("layout series must carry an integer value");
        assert!(layout_value >= 4, "two bumps landed on one series, got {layout_value}");
        assert!(
            body.lines().any(|l| l.starts_with("parlin_tuner_decisions{knob=\"")),
            "the tuner's decisions never reached the exposition:\n{body}"
        );

        // global uniqueness: the snapshot is sorted maps all the way down,
        // so no (name, label-set) may ever repeat
        let mut seen = std::collections::BTreeSet::new();
        for line in body.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
            let metric = line.rsplit_once(' ').expect("sample line has a value").0;
            assert!(seen.insert(metric.to_string()), "duplicate series {metric} in exposition");
        }
        srv.shutdown();
    }
}

/// The non-perturbation contract of [`parlin::obs::ConvergenceTrace`]:
/// the trace stamped on `TrainOutput` is an exact mirror of the epoch
/// log the solver already keeps — same length, bit-identical rel-change
/// and gaps (the recorder reuses the monitor's evaluations instead of
/// recomputing), and a wall clock that is precisely the prefix sum of
/// the per-epoch timer reads (the recorder reads no clock of its own).
#[test]
fn convergence_trace_mirrors_the_epoch_log_bit_for_bit() {
    use parlin::solver::Variant;
    let ds = synthetic::dense_classification(300, 10, 37);
    for variant in [Variant::Sequential, Variant::Wild, Variant::Domesticated, Variant::Numa] {
        let cfg = SolverConfig::new(Objective::Logistic { lambda: 1.0 / 300.0 })
            .with_variant(variant)
            .with_threads(4)
            .with_topology(Topology::uniform(2, 2))
            .with_tol(1e-6)
            .with_max_epochs(40);
        let out = parlin::solver::train(&ds, &cfg);
        assert_eq!(
            out.convergence.len(),
            out.epochs_run,
            "{variant:?}: one trace point per epoch run"
        );
        assert_eq!(out.convergence.solver, out.record.solver);
        assert_eq!(out.convergence.threads, out.record.threads);
        let mut wall = 0.0f64;
        let mut gap_epochs = 0usize;
        for (p, e) in out.convergence.points.iter().zip(&out.record.epochs) {
            assert_eq!(p.epoch, e.epoch, "{variant:?}: epoch numbering");
            assert_eq!(
                p.rel_change.to_bits(),
                e.rel_change.to_bits(),
                "{variant:?} epoch {}: rel_change is not the monitor's value",
                e.epoch
            );
            match (p.gap, e.gap) {
                (Some(a), Some(b)) => {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{variant:?} epoch {}: gap is not the monitor's evaluation",
                        e.epoch
                    );
                    gap_epochs += 1;
                }
                (None, None) => {}
                (a, b) => panic!(
                    "{variant:?} epoch {}: trace gap {a:?} disagrees with epoch log {b:?}",
                    e.epoch
                ),
            }
            wall += e.wall_s;
            assert_eq!(
                p.wall_s.to_bits(),
                wall.to_bits(),
                "{variant:?} epoch {}: wall clock must be the prefix sum of epoch times",
                e.epoch
            );
        }
        assert!(gap_epochs > 0, "{variant:?}: the gap checker never ran — nothing was mirrored");
    }
}
