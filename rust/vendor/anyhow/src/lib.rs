//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline toolchain cannot fetch registry crates, so this vendored
//! implementation provides exactly the subset the repo uses:
//!
//! * [`Error`] — a message plus an optional cause chain; `Display` prints
//!   the top message, alternate `{:#}` prints the full chain.
//! * [`Result`] — `Result<T, Error>` alias with a defaultable error type.
//! * [`anyhow!`] / [`bail!`] — formatted error construction / early return.
//! * [`Context`] — `.context(...)` / `.with_context(...)` on `Result` and
//!   `Option`.
//! * `impl From<E> for Error` for any `E: std::error::Error + Send + Sync`
//!   so `?` lifts concrete errors (IO, parse, `xla::Error`, …).

use std::fmt;

/// An error message with an optional chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from a displayable message (what [`anyhow!`] expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the chain as rendered strings, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }

    /// The root cause's message (innermost link of the chain).
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(next) = cur.source.as_deref() {
            cur = next;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, colon-separated (anyhow's format)
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for msg in self.chain().skip(1) {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what keeps the blanket `From` below coherent (exactly as in anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // flatten the std source chain into our message chain
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error {
                msg,
                source: err.map(Box::new),
            });
        }
        err.expect("chain is non-empty")
    }
}

/// `Result` with a defaultable error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error variant of a `Result` (or to `None`).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::from(io_err()).context("open config");
        assert_eq!(format!("{e}"), "open config");
        assert_eq!(format!("{e:#}"), "open config: missing file");
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner() -> Result<()> {
            let x: Result<u32, std::num::ParseIntError> = "zz".parse();
            let _ = x.with_context(|| format!("parsing {}", "zz"))?;
            bail!("unreachable {}", 1);
        }
        let err = inner().unwrap_err();
        assert!(format!("{err:#}").starts_with("parsing zz: "));
        let e2 = anyhow!("plain");
        assert_eq!(e2.to_string(), "plain");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("empty").unwrap_err();
        assert_eq!(err.to_string(), "empty");
    }
}
