//! API-compatible stub of the `xla` PJRT bindings used by
//! `parlin::runtime`.
//!
//! The container this repo builds in carries no native XLA/PJRT shared
//! libraries, so every operation that would touch the real runtime
//! returns a clear [`Error`] instead. The artifact-backed code paths
//! gate themselves on `artifacts/manifest.json` existing (see
//! `rust/tests/runtime_integration.rs` and `ArtifactRuntime::load`), so
//! in this build the stub only ever surfaces as a clean "runtime
//! unavailable" message — the full training system is pure rust and does
//! not need PJRT. Swapping this path dependency for the real `xla` crate
//! re-enables the HLO execution paths without code changes.

use std::fmt;

/// Error raised by any stubbed PJRT operation.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT runtime unavailable (offline stub build — link the real `xla` crate to execute HLO artifacts)"
    )))
}

/// Host literal (stub: carries no data).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        assert!(err.to_string().contains("unavailable"), "{err}");
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
