//! Serving benchmark (custom harness — no criterion in the offline
//! toolchain), in three acts:
//!
//! 1. the single-request `Session` loop: replay a synthetic predict/refit
//!    mix, report per-kind p50/p99 latency, pool busy-time imbalance, and
//!    the warm-vs-cold refit epoch comparison;
//! 2. the concurrent `Scheduler` loop: a predict storm on N reader
//!    threads interleaved with an append stream, background refits
//!    publishing versioned snapshots — reporting per-version p50/p99,
//!    the snapshot-age distribution, and how many predicts overlapped an
//!    in-flight refit (the overlap the scheduler exists to create);
//! 3. the open-loop saturation sweep: one scheduler, rising offered
//!    rates from a seeded Poisson schedule, latency measured from each
//!    request's *scheduled* arrival — the sweep walks up the rate ladder
//!    until achieved throughput stops tracking offered load (the knee)
//!    and admission control starts shedding.
//!
//! ```bash
//! cargo bench --bench serving
//! ```

use parlin::data::synthetic;
use parlin::glm::Objective;
use parlin::serve::{
    drive, drive_concurrent, drive_open_loop, synthetic_mix, ArrivalProcess, OpenLoopConfig,
    Scheduler, SchedulerConfig, Session, StormConfig,
};
use parlin::solver::{SolverConfig, Variant};
use parlin::sysinfo::Topology;
use parlin::util::Timer;

fn main() {
    println!("== parlin serving bench (closed loop) ==\n");
    let (n, d) = (20_000usize, 100usize);
    let ds = synthetic::dense_classification(n, d, 1);
    let cfg = SolverConfig::new(Objective::Logistic {
        lambda: 1.0 / n as f64,
    })
    .with_variant(Variant::Domesticated)
    .with_threads(4)
    .with_topology(Topology::flat(4))
    .with_tol(1e-3)
    .with_max_epochs(200);

    let t = Timer::start();
    let mut sess = Session::new(ds, cfg);
    println!(
        "session ready in {:.3}s (n={n}, d={d}, {} pool workers, gap {:.3e})\n",
        t.elapsed_s(),
        sess.workers(),
        sess.gap().gap
    );

    // ---- request mix: ~90% predict(512), ~8% refit-rows(64), ~2% λ ----
    let reqs = synthetic_mix(400, 512, 64, 7);
    let report = drive(&mut sess, &reqs, 7);
    print!("{}", report.summary());

    let ps = sess.pool_stats();
    println!(
        "\npool: {} jobs over {} workers, busy imbalance {:.2} (max/mean)",
        ps.total_jobs(),
        ps.per_worker.len(),
        ps.imbalance()
    );
    for w in &ps.per_worker {
        println!(
            "  worker {:>2} (node {}): {:>8} jobs, {:>9.3}s busy",
            w.worker, w.node, w.jobs, w.busy_s
        );
    }

    // ---- the core serving claim: warm refit ≪ cold retrain -------------
    let fresh = synthetic::dense_classification(n / 20, d, 9); // +5% rows
    let warm = sess.partial_fit_rows(&fresh).expect("clean warm refit");
    let cold = sess.retrain_same().expect("clean cold retrain");
    println!(
        "\nwarm refit after +5% rows: {:>3} epochs ({:.3}s)\n\
         cold retrain, same data:   {:>3} epochs ({:.3}s)\n\
         epoch ratio: {:.2}x (warm start re-enters the solver from the \
         served model instead of α = 0)",
        warm.epochs,
        warm.wall_s,
        cold.epochs,
        cold.wall_s,
        cold.epochs as f64 / warm.epochs.max(1) as f64
    );

    // ==== act 2: concurrent scheduler — predict storm × append stream ===
    println!("\n== concurrent scheduler (storm × stream) ==\n");
    let (n, d) = (12_000usize, 80usize);
    let ds = synthetic::dense_classification(n, d, 11);
    let cfg = SolverConfig::new(Objective::Logistic {
        lambda: 1.0 / n as f64,
    })
    .with_variant(Variant::Domesticated)
    .with_threads(4)
    .with_topology(Topology::flat(4))
    .with_tol(1e-3)
    .with_max_epochs(150);
    let t = Timer::start();
    let sched_cfg = SchedulerConfig {
        refit_rows_threshold: 256,
        refit_staleness_s: 0.05,
        max_pending: None,
        ..SchedulerConfig::default()
    };
    let storm = StormConfig {
        readers: 4,
        predicts: 600,
        predict_batch: 256,
        appends: 6,
        rows_per_append: 128,
    };
    println!(
        "storm: {} readers × {} predicts({}), stream: {} bursts × {} rows \
         (refit at {} rows / {:.0} ms stale)\n",
        storm.readers,
        storm.predicts,
        storm.predict_batch,
        storm.appends,
        storm.rows_per_append,
        sched_cfg.refit_rows_threshold,
        sched_cfg.refit_staleness_s * 1e3
    );
    let sched = Scheduler::new(Session::new(ds, cfg), sched_cfg);
    println!("scheduler ready in {:.3}s (version 0 published)\n", t.elapsed_s());
    let report = drive_concurrent(&sched, &storm, 12);
    print!("{}", report.summary());
    println!(
        "\noverlap: {} of {} predicts completed while a background refit \
         was training — readers kept serving the previous version instead \
         of idling behind the writer",
        report.overlapped_predicts, report.predicts
    );
    let ps = sched.pool_stats();
    println!(
        "pool: {} jobs over {} workers, busy imbalance {:.2} (max/mean)",
        ps.total_jobs(),
        ps.per_worker.len(),
        ps.imbalance()
    );
    println!(
        "final: version {}, n={} (ingested {} rows), gap {:.3e}",
        sched.version(),
        sched.current_n(),
        report.ingested_rows,
        sched.gap().gap
    );

    // ==== act 3: open-loop saturation sweep — find the knee ==============
    println!("\n== open-loop saturation sweep (Poisson arrivals) ==\n");
    let (n, d) = (12_000usize, 80usize);
    let ds = synthetic::dense_classification(n, d, 13);
    let cfg = SolverConfig::new(Objective::Logistic {
        lambda: 1.0 / n as f64,
    })
    .with_variant(Variant::Domesticated)
    .with_threads(4)
    .with_topology(Topology::flat(4))
    .with_tol(1e-3)
    .with_max_epochs(150);
    let sched_cfg = SchedulerConfig {
        // rows-threshold high enough that the sweep's ingest trickle never
        // triggers a mid-rung refit: rung-to-rung latency differences are
        // then pure load response, not refit noise
        refit_rows_threshold: 100_000,
        refit_staleness_s: 1e3,
        max_pending: Some(64),
        ..SchedulerConfig::default()
    };
    let t = Timer::start();
    let sched = Scheduler::new(Session::new(ds, cfg), sched_cfg);
    println!("scheduler ready in {:.3}s (max pending 64 readers)\n", t.elapsed_s());

    let rates = [250.0, 500.0, 1000.0, 2000.0, 4000.0];
    let mut base_p99_s = 0.0f64;
    let mut knee: Option<f64> = None;
    for (rung, &rate) in rates.iter().enumerate() {
        let ol_cfg = OpenLoopConfig {
            rate_per_s: rate,
            duration_s: 0.5,
            process: ArrivalProcess::Poisson,
            seed: 21 + rung as u64,
            predict_batch: 128,
            ingest_fraction: 0.02,
            rows_per_ingest: 32,
            dispatchers: 8,
            record_outcomes: false,
        };
        let r = drive_open_loop(&sched, &ol_cfg);
        println!(
            "rate {:>5.0} req/s: achieved {:>6.1}, predict p50 {:>8.3} ms p99 {:>8.3} ms \
             max {:>8.3} ms, {:>4} shed, reader queue delay {:>7.3} ms mean",
            rate,
            r.achieved_rate_per_s(),
            r.predict.p50_s() * 1e3,
            r.predict.p99_s() * 1e3,
            r.predict.max_s() * 1e3,
            r.rejected_predicts,
            r.queue_delay.reader.mean_wait_s() * 1e3
        );
        if rung == 0 {
            base_p99_s = r.predict.p99_s();
        }
        // the knee: the first rung where the open loop visibly stops
        // keeping up — admission control sheds, or the p99 (measured from
        // scheduled arrival, so queueing is in it) blows past 5× the
        // lightest rung's
        let saturated =
            r.rejected_predicts > 0 || (base_p99_s > 0.0 && r.predict.p99_s() > 5.0 * base_p99_s);
        if knee.is_none() && saturated {
            knee = Some(rate);
        }
    }
    match knee {
        Some(rate) => println!(
            "\nknee: offered {rate:.0} req/s is the first rung that saturates \
             (shedding or p99 > 5x the lightest rung)"
        ),
        None => println!(
            "\nknee: not reached — every offered rate was absorbed without \
             shedding or a 5x p99 blowup"
        ),
    }
    println!(
        "note: absolute knee position is hardware-bound; on a small/shared \
         container this sweep validates the open-loop mechanics, not capacity"
    );
}
