//! Hot-path microbenchmarks (custom harness — the offline toolchain has no
//! criterion). Covers every inner loop the paper's optimizations target:
//! dense/sparse coordinate steps, bucketed vs unbucketed epochs, the
//! serial shuffle, replica merge, and the PJRT dispatch overhead.
//!
//! ```bash
//! cargo bench --bench hot_paths
//! ```
//!
//! Output format: `name  median  p10  p90  [derived throughput]`.

use parlin::data::{synthetic, DataMatrix, Dataset, ShardedLayout};
use parlin::glm::{ModelState, Objective};
use parlin::solver::seq::run_bucket;
use parlin::solver::{kernel, BucketPolicy, Buckets, LayoutPolicy, SolverConfig};
use parlin::util::timer::bench_fn;
use parlin::util::{percentile, Rng};

fn report(name: &str, samples: &[f64], work_items: f64, unit: &str) {
    let med = percentile(samples, 50.0);
    let p10 = percentile(samples, 10.0);
    let p90 = percentile(samples, 90.0);
    println!(
        "{name:<42} {:>9.3} ms  [{:>8.3}, {:>8.3}]  {:>10.1} M{unit}/s",
        med * 1e3,
        p10 * 1e3,
        p90 * 1e3,
        work_items / med / 1e6
    );
}

/// Layout × kernel ablation: one full-epoch sweep through the raw bucket
/// kernels — the split two-pass `DataMatrix` walk against the fused
/// single-stream interleaved kernel — plus the end-to-end solver epochs
/// under both `LayoutPolicy`s. (The two paths train bit-wise identical
/// models; `tests/pool_equivalence.rs` locks that in.)
fn layout_ablation<M: DataMatrix>(label: &str, ds: &Dataset<M>, obj: Objective) {
    let n = ds.n();
    let inv_ln = 1.0 / (obj.lambda() * n as f64);
    let buckets = Buckets::new(n, 8);

    // layout build cost (paid once per train()/Session)
    let samples = bench_fn(1, 5, || ShardedLayout::single(&ds.x, &buckets).nnz());
    report(&format!("{label}: layout build"), &samples, ds.x.nnz() as f64, "nnz");

    // raw kernels: split two-pass CSC/dense walk vs fused interleaved
    let mut raw_meds = Vec::new();
    {
        let mut st = ModelState::zeros(n, ds.d());
        let samples = bench_fn(2, 10, || {
            run_bucket(ds, &obj, 0..n, &mut st.alpha, &mut st.v, inv_ln, n);
        });
        report(&format!("{label}: kernel csc 2-pass"), &samples, ds.x.nnz() as f64, "nnz");
        raw_meds.push(percentile(&samples, 50.0));
    }
    {
        let layout = ShardedLayout::single(&ds.x, &buckets);
        let sh = layout.shard(0);
        let mut st = ModelState::zeros(n, ds.d());
        let samples = bench_fn(2, 10, || {
            for b in 0..buckets.count() {
                if b + 1 < buckets.count() {
                    sh.prefetch_bucket(b + 1);
                }
                kernel::run_bucket(
                    sh,
                    &obj,
                    buckets.range(b),
                    &mut st.alpha,
                    &mut st.v,
                    &ds.y,
                    ds.norms(),
                    inv_ln,
                    n,
                );
            }
        });
        report(&format!("{label}: kernel fused interleaved"), &samples, ds.x.nnz() as f64, "nnz");
        raw_meds.push(percentile(&samples, 50.0));
    }

    // full solver epochs under both layout policies; the interleaved run
    // gets the encoding via layout_cache (its build cost is reported
    // separately above), so the ratio compares steady-state epochs only
    let prebuilt = std::sync::Arc::new(ShardedLayout::single(&ds.x, &buckets));
    let mut solver_meds = Vec::new();
    for (tag, layout) in [
        ("csc", LayoutPolicy::Csc),
        ("interleaved", LayoutPolicy::Interleaved),
    ] {
        let mut cfg = SolverConfig::new(obj)
            .with_tol(0.0)
            .with_max_epochs(3)
            .with_bucket(BucketPolicy::Fixed(8))
            .with_layout(layout);
        if layout == LayoutPolicy::Interleaved {
            cfg = cfg.with_layout_cache(prebuilt.clone());
        }
        let samples = bench_fn(1, 5, || {
            parlin::solver::seq::train_sequential(ds, &cfg).epochs_run
        });
        report(
            &format!("{label}: solver 3 epochs, {tag}"),
            &samples,
            3.0 * ds.x.nnz() as f64,
            "nnz",
        );
        solver_meds.push(percentile(&samples, 50.0));
    }
    println!(
        "    {label}: interleaved/csc ratio — raw kernel {:.3}, solver epoch {:.3} \
         (< 1.0 means the fused layout wins)",
        raw_meds[1] / raw_meds[0],
        solver_meds[1] / solver_meds[0]
    );
}

fn main() {
    println!("== parlin hot-path microbenchmarks ==\n");

    // ---- dense coordinate epoch (the paper's core loop) -------------
    let dense = synthetic::dense_classification(20_000, 100, 1);
    let obj = Objective::Logistic {
        lambda: 1.0 / dense.n() as f64,
    };
    let inv_ln = 1.0 / (obj.lambda() * dense.n() as f64);
    {
        let mut st = ModelState::zeros(dense.n(), dense.d());
        let samples = bench_fn(2, 10, || {
            run_bucket(
                &dense,
                &obj,
                0..dense.n(),
                &mut st.alpha,
                &mut st.v,
                inv_ln,
                dense.n(),
            );
        });
        report("dense epoch (20k x 100, logistic)", &samples, dense.x.nnz() as f64, "nnz");
    }

    // ---- sparse coordinate epoch -------------------------------------
    let sparse = synthetic::sparse_classification(50_000, 1_000, 0.01, 2);
    {
        let inv_ln = 1.0 / (1e-5 * sparse.n() as f64);
        let obj_s = Objective::Logistic { lambda: 1e-5 };
        let mut st = ModelState::zeros(sparse.n(), sparse.d());
        let samples = bench_fn(2, 10, || {
            run_bucket(
                &sparse,
                &obj_s,
                0..sparse.n(),
                &mut st.alpha,
                &mut st.v,
                inv_ln,
                sparse.n(),
            );
        });
        report("sparse epoch (50k x 1k @1%)", &samples, sparse.x.nnz() as f64, "nnz");
    }

    // ---- full solver epochs: bucketed vs not --------------------------
    for (label, policy) in [
        ("solver epoch, buckets OFF", BucketPolicy::Off),
        ("solver epoch, buckets 8", BucketPolicy::Fixed(8)),
    ] {
        let cfg = SolverConfig::new(obj)
            .with_tol(0.0)
            .with_max_epochs(3)
            .with_bucket(policy);
        let samples = bench_fn(1, 5, || {
            parlin::solver::seq::train_sequential(&dense, &cfg).epochs_run
        });
        report(label, &samples, 3.0 * dense.x.nnz() as f64, "nnz");
    }

    // ---- layout × kernel ablation (interleaved shard + fused kernels) --
    layout_ablation("dense 20k x 100", &dense, obj);
    layout_ablation("sparse 50k x 1k @1%", &sparse, Objective::Logistic { lambda: 1e-5 });

    // ---- shuffle (the serial Fig 2a bottleneck) -----------------------
    {
        let mut rng = Rng::new(3);
        let mut idx: Vec<u32> = (0..1_000_000u32).collect();
        let samples = bench_fn(2, 10, || {
            rng.shuffle(&mut idx);
        });
        report("shuffle 1M indices (Fisher-Yates)", &samples, 1e6, "swap");
    }

    // ---- replica merge (domesticated sync point) ----------------------
    {
        let d = 100_000;
        let deltas: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64 * 0.1; d]).collect();
        let mut v = vec![0.0f64; d];
        let samples = bench_fn(2, 20, || {
            for dv in &deltas {
                parlin::util::axpy(1.0, dv, &mut v);
            }
        });
        report("merge 8 replicas of d=100k", &samples, 8.0 * d as f64, "elem");
    }

    // ---- executor dispatch overhead (pool vs spawn-per-round) ---------
    // The replica solvers dispatch one batch of jobs per merge round —
    // up to 8 rounds/epoch × hundreds of epochs. This bench isolates
    // that dispatch cost: many small merge-round-shaped batches, with
    // the persistent WorkerPool against spawn/join-per-batch Threads.
    {
        use parlin::solver::exec::Executor;
        use parlin::solver::pool::WorkerPool;
        use parlin::sysinfo::Topology;

        fn round_work(tid: usize) -> f64 {
            // a small worker-round-sized job (~μs of compute)
            let mut s = 0.0f64;
            for i in 0..2_000usize {
                s += ((tid * 2_000 + i) as f64).sqrt();
            }
            s
        }

        const WORKERS: usize = 4;
        const ROUNDS: usize = 200;

        fn dispatch_bench(exec: &parlin::solver::exec::Executor) -> Vec<f64> {
            parlin::util::timer::bench_fn(1, 7, || {
                let mut acc = 0.0f64;
                for _ in 0..ROUNDS {
                    let jobs: Vec<_> = (0..WORKERS).map(|t| move || round_work(t)).collect();
                    acc += exec.run(jobs).into_iter().sum::<f64>();
                }
                acc
            })
        }

        let workers = WORKERS;
        let rounds = ROUNDS;
        let threads_exec = Executor::Threads;
        let s_threads = dispatch_bench(&threads_exec);
        report(
            "dispatch 200 rounds x 4 jobs (Threads)",
            &s_threads,
            (rounds * workers) as f64,
            "job",
        );

        let pool_exec = Executor::Pool(WorkerPool::new(workers, &Topology::flat(workers)));
        let s_pool = dispatch_bench(&pool_exec);
        report(
            "dispatch 200 rounds x 4 jobs (Pool)",
            &s_pool,
            (rounds * workers) as f64,
            "job",
        );

        let med_threads = percentile(&s_threads, 50.0);
        let med_pool = percentile(&s_pool, 50.0);
        println!(
            "    pool/threads dispatch ratio: {:.3} (< 1.0 means the resident pool wins; \
             spawn/join cost avoided per round: {:.1} us)",
            med_pool / med_threads,
            (med_threads - med_pool) / rounds as f64 * 1e6
        );
    }

    // ---- dot kernel ----------------------------------------------------
    {
        let mut rng = Rng::new(4);
        let a: Vec<f64> = (0..4096).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f64> = (0..4096).map(|_| rng.next_gaussian()).collect();
        let samples = bench_fn(100, 200, || parlin::util::dot(&a, &b));
        report("dot 4096", &samples, 4096.0, "mul");
    }

    // ---- PJRT dispatch overhead (runtime hot path) ---------------------
    match parlin::runtime::ArtifactRuntime::load_default() {
        Ok(rt) => {
            let art = rt.get("loss_tile").expect("loss_tile artifact");
            let z = vec![0.5f32; 256];
            let y = vec![1.0f32; 256];
            let m = vec![1.0f32; 256];
            let samples = bench_fn(5, 50, || art.run(&[&z, &y, &m]).unwrap());
            report("PJRT dispatch (loss_tile 256)", &samples, 256.0, "elem");

            let ds100 = synthetic::dense_classification(4_096, 100, 5);
            let idx: Vec<usize> = (0..ds100.n()).collect();
            let ev = parlin::runtime::TiledEvaluator::new(&rt, &ds100, &idx).unwrap();
            let w = vec![0.1f64; 100];
            let samples = bench_fn(2, 20, || ev.eval(&w).unwrap());
            report("HLO tiled eval (4096 x 100)", &samples, (4096 * 100) as f64, "nnz");
        }
        Err(e) => println!("(skipping PJRT benches: {e})"),
    }
}
