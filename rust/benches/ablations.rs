//! Design-choice ablations (DESIGN.md §9): the knobs this implementation
//! adds around the paper's algorithm, each swept independently on the
//! dense synthetic workload with measured epochs + native wall-clock.
//!
//! * σ′ policy (Safe / Adaptive / Fixed) — the replica-merge aggression;
//! * merges per epoch — replica freshness vs merge traffic;
//! * bucket size — cache-line batching vs sampling randomness;
//! * convergence criterion — relative model change vs duality gap.
//!
//! ```bash
//! cargo bench --bench ablations
//! ```

use parlin::data::synthetic;
use parlin::glm::Objective;
use parlin::metrics::Table;
use parlin::solver::{dom, seq, BucketPolicy, ExecPolicy, SigmaPolicy, SolverConfig};
use parlin::util::Timer;
use parlin::vthread;

fn main() {
    let ds = synthetic::dense_classification(20_000, 100, 42);
    let obj = Objective::Logistic {
        lambda: 1.0 / ds.n() as f64,
    };
    let base = SolverConfig::new(obj).with_tol(1e-4).with_max_epochs(400);

    println!("== ablation: σ′ policy (T = 16 virtual workers) ==");
    let mut t = Table::new(&["policy", "epochs", "gap", "wall_s(host)"]);
    for (name, sigma) in [
        ("Safe (σ′=K)", SigmaPolicy::Safe),
        ("Adaptive", SigmaPolicy::Adaptive),
        ("Fixed(K/2)", SigmaPolicy::Fixed(8.0)),
        ("Fixed(1) unsafe", SigmaPolicy::Fixed(1.0)),
    ] {
        let mut cfg = base.clone().with_threads(16);
        cfg.sigma = sigma;
        let timer = Timer::start();
        let out = vthread::train_domesticated_sim(&ds, &cfg);
        t.row(&[
            name.into(),
            if out.converged {
                out.epochs_run.to_string()
            } else {
                format!("FAIL({})", out.epochs_run)
            },
            format!("{:.1e}", out.final_gap),
            format!("{:.2}", timer.elapsed_s()),
        ]);
    }
    print!("{}", t.render());

    println!("\n== ablation: merges per epoch (T = 16, adaptive σ′) ==");
    let mut t = Table::new(&["merges", "epochs", "gap"]);
    for merges in [1usize, 2, 4, 8, 16] {
        let mut cfg = base.clone().with_threads(16);
        cfg.merges_per_epoch = merges;
        let out = vthread::train_domesticated_sim(&ds, &cfg);
        t.row(&[
            merges.to_string(),
            out.epochs_run.to_string(),
            format!("{:.1e}", out.final_gap),
        ]);
    }
    print!("{}", t.render());

    println!("\n== ablation: bucket size (sequential, native wall-clock) ==");
    let mut t = Table::new(&["bucket", "epochs", "wall_s", "epoch_ms"]);
    for bucket in [1usize, 4, 8, 16, 64, 256] {
        let cfg = base.clone().with_bucket(BucketPolicy::Fixed(bucket));
        let out = seq::train_sequential(&ds, &cfg);
        t.row(&[
            bucket.to_string(),
            out.epochs_run.to_string(),
            format!("{:.3}", out.record.total_wall_s),
            format!("{:.2}", out.record.epoch_wall_mean() * 1e3),
        ]);
    }
    print!("{}", t.render());
    println!("(large buckets trade per-epoch speed against sampling randomness — the paper's §3 trade-off)");

    println!("\n== ablation: executor (dom, 4 real workers, native wall-clock) ==");
    let mut t = Table::new(&["executor", "epochs", "gap", "wall_s"]);
    for (name, policy) in [
        ("pool (persistent)", ExecPolicy::Pool),
        ("threads (spawn/round)", ExecPolicy::Threads),
        ("sequential (1 core)", ExecPolicy::Sequential),
    ] {
        let mut cfg = base.clone().with_threads(4);
        cfg.exec = policy;
        cfg.merges_per_epoch = 8; // stress dispatch: 8 rounds per epoch
        let timer = Timer::start();
        let out = dom::train_domesticated(&ds, &cfg);
        t.row(&[
            name.into(),
            out.epochs_run.to_string(),
            format!("{:.1e}", out.final_gap),
            format!("{:.3}", timer.elapsed_s()),
        ]);
    }
    print!("{}", t.render());
    println!("(identical epochs/gap by construction — executors are bit-wise equivalent; only wall-clock may differ)");

    println!("\n== ablation: stopping rule ==");
    let mut t = Table::new(&["rule", "epochs", "final gap"]);
    for (name, tol, gap_tol) in [
        ("rel-change 1e-3 (paper)", 1e-3, None),
        ("rel-change 1e-5", 1e-5, None),
        ("gap 1e-6", 0.0, Some(1e-6)),
    ] {
        let mut cfg = base.clone().with_tol(tol);
        cfg.gap_tol = gap_tol;
        cfg.gap_check_every = 1;
        cfg.max_epochs = 100;
        let out = seq::train_sequential(&ds, &cfg);
        t.row(&[
            name.into(),
            out.epochs_run.to_string(),
            format!("{:.1e}", out.final_gap),
        ]);
    }
    print!("{}", t.render());
}
