//! End-to-end benches: regenerate every paper figure in quick mode and
//! time each harness. One bench target per table/figure of the paper's
//! evaluation (`cargo bench --bench fig_benches`); the full-resolution run
//! is `parlin figures --all`.

use parlin::figures::{run_figure, FigOpts};
use parlin::util::Timer;

fn main() {
    let mut opts = FigOpts::quick();
    opts.out_dir = std::path::PathBuf::from("artifacts/figures-quick");
    println!("== figure regeneration benches (quick mode) ==");
    let mut total = 0.0;
    for fig in ["1", "2", "3", "4", "5", "6"] {
        let t = Timer::start();
        run_figure(fig, &opts).unwrap_or_else(|e| panic!("figure {fig} failed: {e:#}"));
        let s = t.elapsed_s();
        total += s;
        println!("\n>>> figure {fig}: {s:.2}s\n{}", "=".repeat(60));
    }
    println!("all figures regenerated in {total:.1}s (quick mode)");
}
