//! Bounded lock-free single-producer / single-consumer event ring.
//!
//! Each tracing thread owns exactly one [`EventRing`]: the owning thread is
//! the only producer, and the session finisher (which holds the tracer's
//! ring list) is the only consumer. Under that discipline every operation
//! is a handful of relaxed/acquire-release atomics — no locks, no
//! allocation, no blocking. When the ring is full the producer drops the
//! event and counts it; tracing can therefore never stall a worker, which
//! is one leg of the argument that observation cannot perturb the
//! determinism guarantees (see `docs/OBSERVABILITY.md`).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::obs::trace::TraceEvent;

/// Fixed-capacity SPSC ring of [`TraceEvent`]s. Overflow is counted and
/// dropped — `push` never blocks and never allocates.
pub struct EventRing {
    buf: Box<[UnsafeCell<MaybeUninit<TraceEvent>>]>,
    /// Next write position (monotonically increasing, producer-owned).
    head: AtomicUsize,
    /// Next read position (monotonically increasing, consumer-owned).
    tail: AtomicUsize,
    dropped: AtomicU64,
}

// SAFETY: the producer writes only slots in `[tail, tail + capacity)` that
// it has observed free via an Acquire load of `tail`, and publishes them
// with a Release store of `head`; the consumer reads only slots below the
// `head` it Acquire-loaded and frees them with a Release store of `tail`.
// With one producer and one consumer the two sides never touch the same
// slot concurrently, and `TraceEvent` is `Copy` (no drops to run).
unsafe impl Send for EventRing {}
unsafe impl Sync for EventRing {}

impl EventRing {
    /// A ring holding at most `capacity` undrained events.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "EventRing capacity must be positive");
        let buf = (0..capacity)
            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        EventRing {
            buf,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Producer side: append `ev`, or count it as dropped when the ring is
    /// full. Must only be called from the ring's owning thread.
    pub fn push(&self, ev: TraceEvent) {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head.wrapping_sub(tail) >= self.buf.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let idx = head % self.buf.len();
        // SAFETY: slot `idx` is below `tail + capacity`, so the consumer
        // has released it (see the Sync justification above).
        unsafe { (*self.buf[idx].get()).write(ev) };
        self.head.store(head.wrapping_add(1), Ordering::Release);
    }

    /// Consumer side: pop every published event in FIFO order. Safe to run
    /// concurrently with the producer (it simply stops at the currently
    /// published `head`), but callers must serialize drains among
    /// themselves — the session tracer does so under its ring-list lock.
    pub fn drain(&self) -> Vec<TraceEvent> {
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        let mut out = Vec::with_capacity(head.wrapping_sub(tail));
        while tail != head {
            let idx = tail % self.buf.len();
            // SAFETY: slot `idx` is below the Acquire-loaded `head`, so the
            // producer's write to it has been published.
            out.push(unsafe { (*self.buf[idx].get()).assume_init_read() });
            tail = tail.wrapping_add(1);
        }
        self.tail.store(tail, Ordering::Release);
        out
    }

    /// Number of events currently buffered (racy snapshot).
    pub fn len(&self) -> usize {
        self.head.load(Ordering::Acquire).wrapping_sub(self.tail.load(Ordering::Acquire))
    }

    /// True when no events are buffered (racy snapshot).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events rejected because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// The fixed capacity this ring was built with.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::EventKind;

    fn ev(arg: u64) -> TraceEvent {
        TraceEvent {
            ts_ns: arg,
            kind: EventKind::JobStart,
            class: 0,
            node: 0,
            arg,
        }
    }

    #[test]
    fn fifo_roundtrip() {
        let r = EventRing::new(8);
        for i in 0..5 {
            r.push(ev(i));
        }
        assert_eq!(r.len(), 5);
        let out = r.drain();
        assert_eq!(out.iter().map(|e| e.arg).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn overflow_counts_and_drops_without_blocking() {
        let r = EventRing::new(4);
        for i in 0..10 {
            r.push(ev(i));
        }
        // the first `capacity` events survive; the rest are counted
        assert_eq!(r.dropped(), 6);
        let out = r.drain();
        assert_eq!(out.iter().map(|e| e.arg).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn wraps_around_after_drain() {
        let r = EventRing::new(4);
        for i in 0..3 {
            r.push(ev(i));
        }
        r.drain();
        for i in 10..14 {
            r.push(ev(i));
        }
        assert_eq!(r.dropped(), 0);
        let out = r.drain();
        assert_eq!(out.iter().map(|e| e.arg).collect::<Vec<_>>(), vec![10, 11, 12, 13]);
    }
}
