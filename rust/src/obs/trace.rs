//! The tracing core: per-thread SPSC event rings behind one global
//! enable flag, an RAII [`TraceSession`], and the chrome-trace exporter.
//!
//! # Hot-path contract
//!
//! [`emit`] is the single entry point every instrumented site calls. With
//! tracing off it is one relaxed atomic load and a branch — no locks, no
//! allocation, no time-stamping; the compiler sees a `#[cold]` tail and
//! keeps the instrumented loops tight. With tracing on, the emitting
//! thread looks up its cached ring in a thread-local (re-registering with
//! the live session's tracer only when the session generation changed) and
//! pushes one fixed-size [`TraceEvent`] into its own lock-free
//! [`EventRing`]. A full ring drops the event and bumps the ring's drop
//! counter; emission never blocks, so observation cannot reorder or stall
//! the computation it watches (the determinism argument is spelled out in
//! `docs/OBSERVABILITY.md` and `docs/ARCHITECTURE.md`).
//!
//! # Sessions
//!
//! [`TraceSession::start`] installs a fresh tracer and holds a global
//! session mutex for its lifetime, so concurrently running tests cannot
//! observe each other's events; [`TraceSession::finish`] disables tracing,
//! drains every registered ring and returns a [`TraceDump`] that can be
//! inspected in-process or written as `chrome://tracing` JSON.

use std::cell::RefCell;
use std::io::{self, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::obs::ring::EventRing;

/// Smallest ring the session will build — the determinism-under-overflow
/// tests run at exactly this size to force drops.
pub const MIN_RING_CAPACITY: usize = 8;

/// Default per-thread ring capacity (fixed-size events, so this is
/// `DEFAULT_RING_CAPACITY * size_of::<TraceEvent>()` bytes per thread).
pub const DEFAULT_RING_CAPACITY: usize = 1 << 16;

/// `class` tag for events not tied to a job class.
pub const CLASS_NONE: u8 = 0;
/// `class` tag for reader (predict-side) jobs.
pub const CLASS_READER: u8 = 1;
/// `class` tag for writer (train/refit-side) jobs.
pub const CLASS_WRITER: u8 = 2;

/// What happened. The six groups the trace validator checks for are:
/// job lifecycle (`JobEnqueue`/`JobStart`/`JobFinish`), epochs
/// (`EpochBegin`/`EpochEnd`), snapshot publishes, admission rejects,
/// ingest drains, and snapshot rollbacks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum EventKind {
    /// A job was appended to a worker's queue (`arg` = batch slot index).
    JobEnqueue,
    /// A worker dequeued a job and is about to run it (`arg` = queue wait
    /// in nanoseconds).
    JobStart,
    /// A job's closure returned (`arg` = busy time in nanoseconds).
    JobFinish,
    /// A solver began an epoch (`arg` = epoch number, 1-based).
    EpochBegin,
    /// A solver finished an epoch (`arg` = epoch number, 1-based).
    EpochEnd,
    /// The scheduler published a new model snapshot (`arg` = version).
    SnapshotPublish,
    /// An arrival was shed by admission control (`arg` = pending readers).
    AdmissionReject,
    /// The staging buffer was drained into a refit (`arg` = rows drained).
    IngestDrain,
    /// A writer attempt failed: its publish was refused or its refit
    /// rolled back, and the session was restored to last-known-good
    /// (`arg` = the snapshot version that kept serving).
    SnapshotRollback,
}

impl EventKind {
    /// Every kind, in declaration order — handy for tally tables.
    pub const ALL: [EventKind; 9] = [
        EventKind::JobEnqueue,
        EventKind::JobStart,
        EventKind::JobFinish,
        EventKind::EpochBegin,
        EventKind::EpochEnd,
        EventKind::SnapshotPublish,
        EventKind::AdmissionReject,
        EventKind::IngestDrain,
        EventKind::SnapshotRollback,
    ];

    /// Stable snake_case name used in the chrome-trace export and checked
    /// by `examples/check_trace.rs`.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::JobEnqueue => "job_enqueue",
            EventKind::JobStart => "job_start",
            EventKind::JobFinish => "job_finish",
            EventKind::EpochBegin => "epoch_begin",
            EventKind::EpochEnd => "epoch_end",
            EventKind::SnapshotPublish => "snapshot_publish",
            EventKind::AdmissionReject => "admission_reject",
            EventKind::IngestDrain => "ingest_drain",
            EventKind::SnapshotRollback => "snapshot_rollback",
        }
    }
}

/// One fixed-size, `Copy` trace record. 24 bytes; no heap payload, so a
/// ring push is a plain memcpy into preallocated storage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Monotonic nanoseconds since the process's trace origin.
    pub ts_ns: u64,
    pub kind: EventKind,
    /// [`CLASS_NONE`], [`CLASS_READER`] or [`CLASS_WRITER`].
    pub class: u8,
    /// NUMA node tag for pool events; 0 elsewhere.
    pub node: u16,
    /// Kind-specific payload — see the [`EventKind`] variant docs.
    pub arg: u64,
}

/// Session-level observability switch. `off()` is the default: the entire
/// layer reduces to one relaxed load per instrumented site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ObsConfig {
    /// Master switch; when false no ring is ever built or registered.
    pub enabled: bool,
    /// Per-thread ring capacity, clamped to [`MIN_RING_CAPACITY`].
    pub ring_capacity: usize,
}

impl ObsConfig {
    /// Tracing disabled — the zero-cost no-op path.
    pub fn off() -> Self {
        ObsConfig { enabled: false, ring_capacity: 0 }
    }

    /// Tracing enabled with per-thread rings of (at least) `ring_capacity`
    /// events.
    pub fn on(ring_capacity: usize) -> Self {
        ObsConfig {
            enabled: true,
            ring_capacity: ring_capacity.max(MIN_RING_CAPACITY),
        }
    }
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig::off()
    }
}

// ---------------------------------------------------------------------------
// Global tracer state
// ---------------------------------------------------------------------------

/// The one flag the hot path reads. Relaxed is enough: a thread that races
/// a session boundary either skips an event or writes it into a ring that
/// is about to be (or was just) drained — both harmless by design.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Bumped on every session install/teardown; thread-local ring caches are
/// keyed on it so stale rings from a previous session are never reused.
static GENERATION: AtomicU64 = AtomicU64::new(0);

/// The live session's tracer. Locked only on the registration slow path
/// (once per thread per session) and at session teardown — never per event.
static TRACER: Mutex<Option<Arc<Tracer>>> = Mutex::new(None);

/// Serializes sessions: tests that trace cannot contaminate each other.
static SESSION: Mutex<()> = Mutex::new(());

thread_local! {
    /// (generation, ring) cache — the fast path after a thread's first
    /// event in a session.
    static RING: RefCell<Option<(u64, Arc<EventRing>)>> = const { RefCell::new(None) };
}

fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // the crate-wide poison policy: see util::lock_recover
    crate::util::lock_recover(m)
}

/// Monotonic nanoseconds since the first trace timestamp this process
/// took. Shared across threads so per-thread streams are comparable.
pub fn now_ns() -> u64 {
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    ORIGIN.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// True while a tracing-enabled session is live.
pub fn tracing_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Number of per-thread rings registered with the live session (0 when no
/// session is live or tracing is off) — the zero-cost pool test asserts
/// this stays 0 after dispatching work with `ObsConfig::off()`.
pub fn ring_count() -> usize {
    lock_ignore_poison(&TRACER).as_ref().map_or(0, |t| lock_ignore_poison(&t.rings).len())
}

/// Record one event. **The** instrumentation entry point: with tracing off
/// this is a relaxed load and a predictable branch; with tracing on it
/// timestamps the event and pushes it into the calling thread's own SPSC
/// ring (registering the ring on the thread's first event of the session).
#[inline]
pub fn emit(kind: EventKind, class: u8, node: u16, arg: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    emit_enabled(kind, class, node, arg);
}

#[cold]
fn emit_enabled(kind: EventKind, class: u8, node: u16, arg: u64) {
    let generation = GENERATION.load(Ordering::Acquire);
    let ev = TraceEvent { ts_ns: now_ns(), kind, class, node, arg };
    // A TLS access can fail only during thread teardown; no instrumented
    // site runs from a destructor, but stay silent rather than panic.
    let _ = RING.try_with(|cell| {
        let mut slot = cell.borrow_mut();
        if let Some((cached_gen, ring)) = slot.as_ref() {
            if *cached_gen == generation {
                ring.push(ev);
                return;
            }
        }
        // Slow path: first event of this thread in this session (or a
        // stale cache from a finished one) — register a fresh ring.
        let tracer = lock_ignore_poison(&TRACER);
        let Some(tracer) = tracer.as_ref() else {
            *slot = None;
            return;
        };
        let ring = tracer.register(thread_label());
        ring.push(ev);
        *slot = Some((generation, ring));
    });
}

fn thread_label() -> String {
    let cur = std::thread::current();
    match cur.name() {
        Some(n) => n.to_string(),
        None => format!("thread-{:?}", cur.id()),
    }
}

struct Tracer {
    cfg: ObsConfig,
    /// (thread label, ring) pairs in registration order. Locked on
    /// registration (once per thread) and at collect time only.
    rings: Mutex<Vec<(String, Arc<EventRing>)>>,
    /// Events already pulled out of the rings by earlier collects, indexed
    /// parallel to `rings` (registration order, so duplicate thread labels
    /// cannot merge streams). This mutex doubles as the consumer-side
    /// serialization `EventRing::drain` requires: every collect — live
    /// dump or session finish — holds it for the whole ring walk.
    collected: Mutex<Vec<ThreadTrace>>,
}

impl Tracer {
    fn register(&self, label: String) -> Arc<EventRing> {
        let ring = Arc::new(EventRing::new(self.cfg.ring_capacity));
        lock_ignore_poison(&self.rings).push((label, Arc::clone(&ring)));
        ring
    }

    /// Move every currently published event into the accumulator and
    /// return the guard over it. Lock order is collected → rings;
    /// `register` takes only the rings lock, so a thread emitting its
    /// first event mid-collect cannot deadlock against us. Draining here
    /// also frees ring space, so periodic live dumps extend the effective
    /// coverage of small rings on long runs.
    fn collect(&self) -> MutexGuard<'_, Vec<ThreadTrace>> {
        let mut collected = lock_ignore_poison(&self.collected);
        let rings = lock_ignore_poison(&self.rings);
        for (i, (label, ring)) in rings.iter().enumerate() {
            if collected.len() <= i {
                collected.push(ThreadTrace {
                    name: label.clone(),
                    events: Vec::new(),
                    dropped: 0,
                });
            }
            collected[i].events.extend(ring.drain());
            // the ring's drop counter is cumulative — overwrite, not add
            collected[i].dropped = ring.dropped();
        }
        drop(rings);
        collected
    }

    fn drain(&self) -> TraceDump {
        let mut collected = self.collect();
        let mut threads = std::mem::take(&mut *collected);
        drop(collected);
        threads.sort_by(|a, b| a.name.cmp(&b.name));
        TraceDump { threads }
    }

    /// Snapshot everything recorded so far without ending the session —
    /// the `/trace` endpoint and the flight recorder's data source.
    fn live_dump(&self) -> TraceDump {
        let collected = self.collect();
        let mut threads = collected.clone();
        drop(collected);
        threads.sort_by(|a, b| a.name.cmp(&b.name));
        TraceDump { threads }
    }
}

/// Mid-session snapshot of everything the live tracing session has
/// recorded so far (events stay attributed to the session: a later
/// [`TraceSession::finish`] still returns them). `None` when no
/// tracing-enabled session is live. The tracer `Arc` is cloned out of the
/// global slot before any ring is walked, so a thread registering its
/// first ring never waits on a dump in progress.
pub fn live_dump() -> Option<TraceDump> {
    let tracer = lock_ignore_poison(&TRACER).as_ref().map(Arc::clone)?;
    Some(tracer.live_dump())
}

/// RAII handle over one tracing session. Holds the global session mutex
/// for its whole lifetime (sessions — traced *or* deliberately-off, as in
/// the zero-cost assertions — are mutually exclusive process-wide), and
/// guarantees tracing is disabled again on drop even if the traced code
/// panics.
pub struct TraceSession {
    _serial: MutexGuard<'static, ()>,
    tracer: Option<Arc<Tracer>>,
}

impl TraceSession {
    /// Install `cfg` as the live observability configuration. With
    /// `cfg.enabled == false` this still takes the session mutex (so a
    /// test can assert the no-op path without another test racing it) but
    /// builds no tracer and leaves the hot path on its one-load branch.
    pub fn start(cfg: ObsConfig) -> TraceSession {
        let serial = lock_ignore_poison(&SESSION);
        let tracer = cfg.enabled.then(|| {
            let t = Arc::new(Tracer {
                cfg,
                rings: Mutex::new(Vec::new()),
                collected: Mutex::new(Vec::new()),
            });
            *lock_ignore_poison(&TRACER) = Some(Arc::clone(&t));
            GENERATION.fetch_add(1, Ordering::Release);
            ENABLED.store(true, Ordering::Release);
            t
        });
        TraceSession { _serial: serial, tracer }
    }

    /// Disable tracing, drain every registered ring and return the dump.
    pub fn finish(mut self) -> TraceDump {
        self.disable();
        match self.tracer.take() {
            Some(t) => t.drain(),
            None => TraceDump::default(),
        }
    }

    fn disable(&self) {
        ENABLED.store(false, Ordering::Release);
        *lock_ignore_poison(&TRACER) = None;
        GENERATION.fetch_add(1, Ordering::Release);
    }
}

impl Drop for TraceSession {
    fn drop(&mut self) {
        self.disable();
    }
}

// ---------------------------------------------------------------------------
// Dump + chrome-trace export
// ---------------------------------------------------------------------------

/// All events of one thread, in emission (and therefore timestamp) order.
#[derive(Clone, Debug)]
pub struct ThreadTrace {
    /// The emitting thread's name (pool workers are named
    /// `parlin-pool-n{node}-w{worker}` at spawn).
    pub name: String,
    pub events: Vec<TraceEvent>,
    /// Events lost to ring overflow on this thread.
    pub dropped: u64,
}

/// Everything a finished [`TraceSession`] recorded, grouped per thread and
/// sorted by thread name for deterministic output.
#[derive(Clone, Debug, Default)]
pub struct TraceDump {
    pub threads: Vec<ThreadTrace>,
}

impl TraceDump {
    /// Total recorded events across all threads.
    pub fn total_events(&self) -> usize {
        self.threads.iter().map(|t| t.events.len()).sum()
    }

    /// Total events lost to ring overflow across all threads.
    pub fn total_dropped(&self) -> u64 {
        self.threads.iter().map(|t| t.dropped).sum()
    }

    /// How many events of `kind` were recorded.
    pub fn count_of(&self, kind: EventKind) -> u64 {
        self.threads.iter().flat_map(|t| &t.events).filter(|e| e.kind == kind).count() as u64
    }

    /// Serialize as `chrome://tracing` / Perfetto-compatible JSON: one
    /// metadata record naming each tid, then every event as an instant
    /// event (`"ph":"i"`) with microsecond timestamps and the class/node/
    /// arg payload under `"args"`.
    pub fn write_chrome_json<W: Write>(&self, w: &mut W) -> io::Result<()> {
        writeln!(w, "{{\"traceEvents\":[")?;
        let mut first = true;
        for (tid, t) in self.threads.iter().enumerate() {
            sep(w, &mut first)?;
            write!(
                w,
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
                 \"args\":{{\"name\":\"{}\"}}}}",
                tid,
                escape_json(&t.name)
            )?;
        }
        for (tid, t) in self.threads.iter().enumerate() {
            for e in &t.events {
                sep(w, &mut first)?;
                write!(
                    w,
                    "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":1,\"tid\":{},\
                     \"ts\":{:.3},\"args\":{{\"class\":{},\"node\":{},\"arg\":{}}}}}",
                    e.kind.name(),
                    tid,
                    e.ts_ns as f64 / 1000.0,
                    e.class,
                    e.node,
                    e.arg
                )?;
            }
        }
        writeln!(w, "\n],\"displayTimeUnit\":\"ms\"}}")
    }

    /// [`write_chrome_json`](TraceDump::write_chrome_json) into a `String`.
    pub fn to_chrome_json(&self) -> String {
        let mut buf = Vec::new();
        self.write_chrome_json(&mut buf).expect("writing to a Vec cannot fail");
        String::from_utf8(buf).expect("chrome trace JSON is ASCII-escaped UTF-8")
    }

    /// Write the chrome-trace JSON to `path` (what `--trace` uses).
    pub fn save_chrome_json(&self, path: &str) -> io::Result<()> {
        let mut f = io::BufWriter::new(std::fs::File::create(path)?);
        self.write_chrome_json(&mut f)
    }
}

fn sep<W: Write>(w: &mut W, first: &mut bool) -> io::Result<()> {
    if *first {
        *first = false;
        Ok(())
    } else {
        writeln!(w, ",")
    }
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Other tests in this binary run concurrently and may emit while our
    /// session is live; scope assertions to this test thread's own ring.
    fn my_thread(dump: &TraceDump) -> (Vec<TraceEvent>, u64) {
        let me = std::thread::current().name().unwrap_or("").to_string();
        let mut events = Vec::new();
        let mut dropped = 0;
        for t in dump.threads.iter().filter(|t| t.name == me) {
            events.extend(t.events.iter().copied());
            dropped += t.dropped;
        }
        (events, dropped)
    }

    #[test]
    fn emit_without_session_is_a_no_op() {
        // holding the (off) session serializes us against every traced
        // test in the binary, so the no-tracer state is deterministic here
        let _s = TraceSession::start(ObsConfig::off());
        emit(EventKind::EpochBegin, CLASS_NONE, 0, 1);
        assert!(!tracing_enabled());
        assert_eq!(ring_count(), 0);
        assert!(live_dump().is_none(), "no live session -> no live dump");
    }

    #[test]
    fn session_records_and_finish_disables() {
        let s = TraceSession::start(ObsConfig::on(64));
        assert!(tracing_enabled());
        emit(EventKind::SnapshotPublish, CLASS_NONE, 0, 7);
        emit(EventKind::AdmissionReject, CLASS_READER, 0, 3);
        let dump = s.finish();
        assert!(!tracing_enabled());
        let (events, dropped) = my_thread(&dump);
        assert_eq!(
            events.iter().map(|e| e.kind).collect::<Vec<_>>(),
            vec![EventKind::SnapshotPublish, EventKind::AdmissionReject]
        );
        assert_eq!(events[0].arg, 7);
        assert_eq!(events[1].class, CLASS_READER);
        assert_eq!(dropped, 0);
        // events from one thread carry nondecreasing timestamps
        for t in &dump.threads {
            for pair in t.events.windows(2) {
                assert!(pair[0].ts_ns <= pair[1].ts_ns);
            }
        }
    }

    #[test]
    fn overflow_only_bumps_the_drop_counter() {
        let s = TraceSession::start(ObsConfig::on(MIN_RING_CAPACITY));
        for i in 0..(MIN_RING_CAPACITY as u64 + 5) {
            emit(EventKind::EpochBegin, CLASS_NONE, 0, i);
        }
        let dump = s.finish();
        let (events, dropped) = my_thread(&dump);
        assert_eq!(events.len(), MIN_RING_CAPACITY);
        assert_eq!(dropped, 5);
    }

    #[test]
    fn chrome_json_shape() {
        let s = TraceSession::start(ObsConfig::on(64));
        emit(EventKind::EpochBegin, CLASS_NONE, 0, 1);
        emit(EventKind::EpochEnd, CLASS_NONE, 0, 1);
        let json = s.finish().to_chrome_json();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"thread_name\""));
        assert!(json.contains("\"epoch_begin\""));
        assert!(json.contains("\"epoch_end\""));
        assert!(json.trim_end().ends_with('}'));
    }

    #[test]
    fn stale_thread_cache_reregisters_on_new_session() {
        {
            let s = TraceSession::start(ObsConfig::on(64));
            emit(EventKind::EpochBegin, CLASS_NONE, 0, 1);
            let d = s.finish();
            assert_eq!(my_thread(&d).0.len(), 1);
        }
        // the TLS cache still holds the old ring; a new session must not
        // see events routed into it
        let s = TraceSession::start(ObsConfig::on(64));
        emit(EventKind::EpochEnd, CLASS_NONE, 0, 2);
        let d = s.finish();
        let (events, _) = my_thread(&d);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, EventKind::EpochEnd);
    }

    #[test]
    fn json_escaping_handles_quotes_and_controls() {
        assert_eq!(escape_json("a\"b\\c\nd"), "a\\\"b\\\\c\\u000ad");
    }

    #[test]
    fn live_dump_snapshots_without_ending_the_session() {
        let s = TraceSession::start(ObsConfig::on(64));
        emit(EventKind::EpochBegin, CLASS_NONE, 0, 1);
        let live = live_dump().expect("a tracing session is live");
        assert_eq!(my_thread(&live).0.len(), 1);
        assert!(tracing_enabled(), "a live dump must not end the session");
        // the drained event stays attributed to the session: finish still
        // returns it, followed by anything emitted after the dump
        emit(EventKind::EpochEnd, CLASS_NONE, 0, 1);
        let dump = s.finish();
        let (events, _) = my_thread(&dump);
        assert_eq!(
            events.iter().map(|e| e.kind).collect::<Vec<_>>(),
            vec![EventKind::EpochBegin, EventKind::EpochEnd]
        );
    }

    #[test]
    fn live_dump_frees_ring_space_for_later_events() {
        let s = TraceSession::start(ObsConfig::on(MIN_RING_CAPACITY));
        for i in 0..MIN_RING_CAPACITY as u64 {
            emit(EventKind::EpochBegin, CLASS_NONE, 0, i);
        }
        let live = live_dump().expect("session is live");
        assert_eq!(my_thread(&live).0.len(), MIN_RING_CAPACITY);
        // the ring was emptied by the dump: a second full round fits
        for i in 0..MIN_RING_CAPACITY as u64 {
            emit(EventKind::EpochEnd, CLASS_NONE, 0, i);
        }
        let dump = s.finish();
        let (events, dropped) = my_thread(&dump);
        assert_eq!(events.len(), 2 * MIN_RING_CAPACITY);
        assert_eq!(dropped, 0, "draining mid-session must free ring slots");
    }
}
