//! The `/metrics` exposition endpoint: a dependency-free HTTP/1.0 server
//! over [`std::net::TcpListener`], started by `--metrics-addr HOST:PORT`.
//!
//! # Routes
//!
//! | route      | body                                             | status |
//! |------------|--------------------------------------------------|--------|
//! | `/metrics` | the registry in Prometheus text format           | 200    |
//! | `/health`  | the current [`ServeHealth`] line                 | 200 healthy / 503 degraded |
//! | `/trace`   | the live session's chrome://tracing JSON so far  | 200, or 404 with no session |
//!
//! # Why pull-only, and why one accept thread
//!
//! The observation-without-perturbation argument (`docs/OBSERVABILITY.md`)
//! rests on the instrumented side never waiting on the observer. This
//! endpoint keeps that intact by being strictly pull-based: a scrape reads
//! the same lock-free counters and SPSC rings the registry and tracer
//! already maintain — nothing on the training or serving path knows the
//! server exists, and `rust/tests/obs.rs` asserts a scrape loop leaves
//! models and served margins bit-wise identical. Connections are handled
//! *inline on the single accept thread* (the "bounded handler" model): a
//! slow or hostile scraper can only delay other scrapers, never spawn
//! unbounded handler threads or touch a worker. Read/write timeouts bound
//! each connection's hold on that thread.
//!
//! `ServeHealth` lives in [`crate::serve`], which depends on this module's
//! parent — the server therefore takes its health answer as an injected
//! closure ([`ExportSources::health`]) rather than importing the type.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::obs::registry::registry;
use crate::obs::trace;

/// Per-connection read/write budget: bounds how long one scraper can hold
/// the accept thread.
const IO_TIMEOUT: Duration = Duration::from_secs(2);

/// Where the endpoint's answers come from. [`Default`] serves the global
/// registry and reports permanently-healthy — enough for `train` runs and
/// tests; `parlin serve` injects the scheduler's live health.
#[derive(Clone)]
pub struct ExportSources {
    /// `(healthy, detail)` for `/health`: the detail line is the body
    /// (`Healthy` or `Degraded (reason)`), the flag picks 200 vs 503.
    pub health: Arc<dyn Fn() -> (bool, String) + Send + Sync>,
}

impl Default for ExportSources {
    fn default() -> Self {
        ExportSources { health: Arc::new(|| (true, "Healthy".to_string())) }
    }
}

impl ExportSources {
    /// Sources with an injected health closure.
    pub fn with_health<F>(health: F) -> Self
    where
        F: Fn() -> (bool, String) + Send + Sync + 'static,
    {
        ExportSources { health: Arc::new(health) }
    }
}

/// RAII handle over the running endpoint; shuts down and joins the accept
/// thread on [`ExportServer::shutdown`] or drop.
pub struct ExportServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ExportServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`, or port `0` for an ephemeral
    /// port — read it back via [`ExportServer::local_addr`]) and start the
    /// accept thread.
    pub fn start(addr: &str, sources: ExportSources) -> io::Result<ExportServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("parlin-metrics-export".into())
            .spawn(move || accept_loop(listener, sources, stop2))
            .map_err(|e| io::Error::new(e.kind(), "spawning the metrics export thread"))?;
        Ok(ExportServer { addr: local, stop, handle: Some(handle) })
    }

    /// The address actually bound (resolves port `0` requests).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, join the accept thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // the accept loop blocks in accept(); a throwaway self-connection
        // wakes it so it can observe the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ExportServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(listener: TcpListener, sources: ExportSources, stop: Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match conn {
            Ok(stream) => {
                if let Err(e) = handle_conn(stream, &sources) {
                    // a scraper disconnecting mid-response is routine
                    crate::diag!(Debug, "metrics scrape connection failed: {}", e);
                }
            }
            Err(e) => crate::diag!(Warn, "metrics export accept failed: {}", e),
        }
    }
}

fn handle_conn(mut stream: TcpStream, sources: &ExportSources) -> io::Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let path = read_request_path(&mut stream)?;
    let (status, ctype, body) = respond(&path, sources);
    let reason = match status {
        200 => "OK",
        404 => "Not Found",
        503 => "Service Unavailable",
        _ => "Bad Request",
    };
    write!(
        stream,
        "HTTP/1.0 {status} {reason}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Route one request path to `(status, content-type, body)`.
fn respond(path: &str, sources: &ExportSources) -> (u16, &'static str, String) {
    // ignore any query string — scrapers commonly append cache-busters
    let path = path.split('?').next().unwrap_or(path);
    match path {
        "/metrics" => (
            200,
            "text/plain; version=0.0.4",
            registry().snapshot().render_prometheus(),
        ),
        "/health" => {
            let (healthy, detail) = (sources.health)();
            let status = if healthy { 200 } else { 503 };
            (status, "text/plain", format!("{detail}\n"))
        }
        "/trace" => match trace::live_dump() {
            Some(dump) => (200, "application/json", dump.to_chrome_json()),
            None => (
                404,
                "text/plain",
                "no tracing session is live (run with --trace or --flight-dir)\n".to_string(),
            ),
        },
        _ => (
            404,
            "text/plain",
            "unknown path (routes: /metrics, /health, /trace)\n".to_string(),
        ),
    }
}

/// Read up to the end of the HTTP request line and return its path.
/// Anything after the first line (headers, body) is ignored — every route
/// is a parameterless GET.
fn read_request_path(stream: &mut TcpStream) -> io::Result<String> {
    let mut buf = [0u8; 1024];
    let mut n = 0;
    while n < buf.len() {
        let read = stream.read(&mut buf[n..])?;
        if read == 0 {
            break;
        }
        n += read;
        if buf[..n].windows(2).any(|w| w == b"\r\n") {
            break;
        }
    }
    let text = String::from_utf8_lossy(&buf[..n]);
    let line = text.lines().next().unwrap_or("");
    // "GET /metrics HTTP/1.0" — the middle token is the path
    let mut parts = line.split_whitespace();
    let _method = parts.next().unwrap_or("");
    match parts.next() {
        Some(path) if path.starts_with('/') => Ok(path.to_string()),
        _ => Ok(String::new()), // routed to the 404 arm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ObsConfig, TraceSession};

    /// Minimal scrape client (the same shape examples/check_metrics.rs
    /// uses): one GET, read to EOF, split status line from body.
    fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).expect("connecting to the export server");
        write!(s, "GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).expect("reading the response");
        let status: u16 = text
            .lines()
            .next()
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|c| c.parse().ok())
            .expect("status line");
        let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }

    #[test]
    fn serves_metrics_health_and_404() {
        registry().counter("export.test.requests").inc();
        let srv = ExportServer::start("127.0.0.1:0", ExportSources::default()).unwrap();
        let addr = srv.local_addr();

        let (status, body) = http_get(addr, "/metrics");
        assert_eq!(status, 200);
        assert!(body.contains("parlin_export_test_requests"), "{body}");

        let (status, body) = http_get(addr, "/health");
        assert_eq!(status, 200);
        assert_eq!(body, "Healthy\n");

        let (status, _) = http_get(addr, "/nope");
        assert_eq!(status, 404);
        srv.shutdown();
    }

    #[test]
    fn degraded_health_maps_to_503() {
        let srv = ExportServer::start(
            "127.0.0.1:0",
            ExportSources::with_health(|| (false, "Degraded (drain died)".to_string())),
        )
        .unwrap();
        let (status, body) = http_get(srv.local_addr(), "/health");
        assert_eq!(status, 503);
        assert_eq!(body, "Degraded (drain died)\n");
        srv.shutdown();
    }

    #[test]
    fn trace_route_serves_the_live_session_or_404() {
        let srv = ExportServer::start("127.0.0.1:0", ExportSources::default()).unwrap();
        let addr = srv.local_addr();
        {
            let session = TraceSession::start(ObsConfig::on(64));
            crate::obs::emit(crate::obs::EventKind::EpochBegin, crate::obs::CLASS_NONE, 0, 1);
            let (status, body) = http_get(addr, "/trace");
            assert_eq!(status, 200);
            assert!(body.starts_with("{\"traceEvents\":["), "{body}");
            assert!(body.contains("\"epoch_begin\""), "{body}");
            drop(session.finish());
        }
        // outside a session the route reports, it does not invent a dump —
        // serialize against other traced tests via an off session
        let _off = TraceSession::start(ObsConfig::off());
        let (status, _) = http_get(addr, "/trace");
        assert_eq!(status, 404);
        srv.shutdown();
    }
}
