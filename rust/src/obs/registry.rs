//! Named-metric registry: counters, gauges and log-bucketed histograms
//! with lock-free updates, plus the [`MetricsSnapshot`] view and the
//! periodic [`MetricsTicker`] — the in-process feed the SySCD-style
//! auto-tuner (ROADMAP open item 2) will consume.
//!
//! Handles are `Arc`-backed: get-or-create takes a short registry lock
//! (control-point setup, once per name), after which every `inc`/`set`/
//! `record` is a single atomic RMW on shared storage. Instrumented sites
//! cache their handle outside hot loops; the registry itself is never
//! locked per update.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// Monotonically increasing event count.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (queue depths, pending readers).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Power-of-two bucket index for `v`: 0 holds the value 0, bucket `i ≥ 1`
/// holds `[2^(i-1), 2^i)`. 65 buckets cover the full `u64` range.
const HIST_BUCKETS: usize = 65;

fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// Representative value reported for a bucket (midpoint of its range).
fn bucket_mid(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        let lo = 1u64 << (i - 1);
        let hi = if i >= 64 { u64::MAX } else { 1u64 << i };
        lo + (hi - lo) / 2
    }
}

struct HistInner {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

/// Log₂-bucketed histogram of `u64` samples (latencies in ns/µs, batch
/// sizes). Recording is three relaxed RMWs; quantiles are approximate
/// (bucket midpoint), which is exactly enough for a tuner or a trend line
/// — exact report percentiles stay on [`crate::util::Percentiles`].
#[derive(Clone)]
pub struct Histogram(Arc<HistInner>);

impl Histogram {
    fn new() -> Self {
        Histogram(Arc::new(HistInner {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }))
    }

    pub fn record(&self, v: u64) {
        self.0.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// Approximate quantile `q ∈ [0, 1]`: the midpoint of the bucket where
    /// the cumulative count crosses `q · count`. 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let target = ((q * count as f64).ceil() as u64).clamp(1, count);
        let mut cum = 0u64;
        for (i, b) in self.0.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return bucket_mid(i);
            }
        }
        bucket_mid(HIST_BUCKETS - 1)
    }
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, Counter>,
    gauges: BTreeMap<String, Gauge>,
    hists: BTreeMap<String, Histogram>,
    /// Labelled counters, keyed by `(name, sorted label pairs)` — one
    /// storage cell per distinct label set ("one series per label set").
    labelled: BTreeMap<(String, Vec<(String, String)>), Counter>,
}

/// Canonical (sorted-by-key) owned form of a label set.
fn canonical_labels(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    let mut owned: Vec<(String, String)> =
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    owned.sort();
    owned
}

/// The process-wide metric namespace. Always on — registration and
/// snapshots are cold control-point operations; updates are lock-free
/// through the returned handles.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<RegistryInner>,
}

fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // the crate-wide poison policy: see util::lock_recover
    crate::util::lock_recover(m)
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Get-or-create the counter named `name`.
    pub fn counter(&self, name: &str) -> Counter {
        let mut g = lock_ignore_poison(&self.inner);
        g.counters.entry(name.to_string()).or_default().clone()
    }

    /// Get-or-create the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut g = lock_ignore_poison(&self.inner);
        g.gauges.entry(name.to_string()).or_default().clone()
    }

    /// Get-or-create the histogram named `name`.
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut g = lock_ignore_poison(&self.inner);
        g.hists.entry(name.to_string()).or_insert_with(Histogram::new).clone()
    }

    /// Get-or-create the counter named `name` carrying `labels` — one
    /// series (storage cell) per distinct label set. Label order is
    /// irrelevant: pairs are canonicalized by sorting on the key, so
    /// `&[("a","1"),("b","2")]` and `&[("b","2"),("a","1")]` share a
    /// handle. Don't reuse a plain-counter name for a labelled family
    /// (the exposition would emit two `# TYPE` lines for it).
    pub fn labelled_counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        let key = (name.to_string(), canonical_labels(labels));
        let mut g = lock_ignore_poison(&self.inner);
        g.labelled.entry(key).or_default().clone()
    }

    /// Consistent-enough point-in-time view of every registered metric
    /// (each value is read atomically; the set is read under the registry
    /// lock).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = lock_ignore_poison(&self.inner);
        MetricsSnapshot {
            counters: g.counters.iter().map(|(k, c)| (k.clone(), c.get())).collect(),
            gauges: g.gauges.iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            hists: g
                .hists
                .iter()
                .map(|(k, h)| HistSummary {
                    name: k.clone(),
                    count: h.count(),
                    sum: h.sum(),
                    p50: h.quantile(0.50),
                    p90: h.quantile(0.90),
                    p99: h.quantile(0.99),
                })
                .collect(),
            labelled: g
                .labelled
                .iter()
                .map(|((name, labels), c)| LabelledValue {
                    name: name.clone(),
                    labels: labels.clone(),
                    value: c.get(),
                })
                .collect(),
        }
    }

    /// Zero every registered value (names survive, handles stay valid) —
    /// lets tests assert exact counts against the shared global registry.
    pub fn reset(&self) {
        let g = lock_ignore_poison(&self.inner);
        for c in g.counters.values() {
            c.0.store(0, Ordering::Relaxed);
        }
        for v in g.gauges.values() {
            v.0.store(0, Ordering::Relaxed);
        }
        for h in g.hists.values() {
            for b in &h.0.buckets {
                b.store(0, Ordering::Relaxed);
            }
            h.0.count.store(0, Ordering::Relaxed);
            h.0.sum.store(0, Ordering::Relaxed);
        }
        for c in g.labelled.values() {
            c.0.store(0, Ordering::Relaxed);
        }
    }
}

/// The process-wide registry every instrumented layer shares.
pub fn registry() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Approximate summary of one histogram at snapshot time.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSummary {
    pub name: String,
    pub count: u64,
    pub sum: u64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
}

/// One labelled-counter series at snapshot time: `name{labels} = value`.
/// `labels` are the canonical sorted-by-key pairs.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LabelledValue {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: u64,
}

impl LabelledValue {
    /// Flat display form, `name{k=v;k2=v2}` — semicolon-separated so the
    /// decorated name stays a single unquoted CSV cell.
    pub fn decorated(&self) -> String {
        let pairs: Vec<String> =
            self.labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
        format!("{}{{{}}}", self.name, pairs.join(";"))
    }
}

/// A frozen view of the registry: what reports stamp, what `--trace`-less
/// CLI runs dump, and what the future auto-tuner will diff between ticks.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// `(name, value)` pairs, sorted by name.
    pub counters: Vec<(String, u64)>,
    /// `(name, value)` pairs, sorted by name.
    pub gauges: Vec<(String, u64)>,
    /// Histogram summaries, sorted by name.
    pub hists: Vec<HistSummary>,
    /// Labelled-counter series, sorted by `(name, labels)`.
    pub labelled: Vec<LabelledValue>,
}

impl MetricsSnapshot {
    /// Look up a counter by name (test + tuner convenience).
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    /// Look up a gauge by name.
    pub fn gauge(&self, name: &str) -> Option<u64> {
        self.gauges.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    /// Look up a histogram summary by name.
    pub fn hist(&self, name: &str) -> Option<&HistSummary> {
        self.hists.iter().find(|h| h.name == name)
    }

    /// Look up one labelled-counter series; label order is irrelevant.
    pub fn labelled(&self, name: &str, labels: &[(&str, &str)]) -> Option<u64> {
        let want = canonical_labels(labels);
        self.labelled
            .iter()
            .find(|l| l.name == name && l.labels == want)
            .map(|l| l.value)
    }

    /// True when nothing has been registered.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
            && self.gauges.is_empty()
            && self.hists.is_empty()
            && self.labelled.is_empty()
    }

    /// CSV dump: `kind,name,value,count,sum,p50,p90,p99` (counter/gauge
    /// rows leave the histogram columns empty).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("kind,name,value,count,sum,p50,p90,p99\n");
        for (k, v) in &self.counters {
            let _ = writeln!(s, "counter,{k},{v},,,,,");
        }
        for l in &self.labelled {
            let _ = writeln!(s, "counter,{},{},,,,,", l.decorated(), l.value);
        }
        for (k, v) in &self.gauges {
            let _ = writeln!(s, "gauge,{k},{v},,,,,");
        }
        for h in &self.hists {
            let _ = writeln!(
                s,
                "hist,{},,{},{},{},{},{}",
                h.name, h.count, h.sum, h.p50, h.p90, h.p99
            );
        }
        s
    }

    /// Prometheus text exposition (version 0.0.4), what `/metrics` serves.
    /// Registry names are dotted (`sched.publishes`); Prometheus names
    /// allow `[a-zA-Z0-9_:]`, so every other character maps to `_` and
    /// everything is prefixed `parlin_`. Histograms export as summaries:
    /// three `quantile`-labelled lines plus `_sum`/`_count`.
    pub fn render_prometheus(&self) -> String {
        fn sanitize(name: &str) -> String {
            let mut out = String::with_capacity(name.len() + 7);
            out.push_str("parlin_");
            for c in name.chars() {
                if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                    out.push(c);
                } else {
                    out.push('_');
                }
            }
            out
        }
        // Label keys allow `[a-zA-Z0-9_]` (no ':'); values are free text
        // with `\`, `"` and newline escaped per the exposition format.
        fn label_key(k: &str) -> String {
            k.chars()
                .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
                .collect()
        }
        fn label_value(v: &str) -> String {
            let mut out = String::with_capacity(v.len());
            for c in v.chars() {
                match c {
                    '\\' => out.push_str("\\\\"),
                    '"' => out.push_str("\\\""),
                    '\n' => out.push_str("\\n"),
                    _ => out.push(c),
                }
            }
            out
        }
        let mut s = String::new();
        for (k, v) in &self.counters {
            let n = sanitize(k);
            let _ = writeln!(s, "# TYPE {n} counter");
            let _ = writeln!(s, "{n} {v}");
        }
        // labelled families: one `# TYPE` per name (the vec is sorted by
        // (name, labels), so series of a family are contiguous)
        let mut last_family: Option<&str> = None;
        for l in &self.labelled {
            let n = sanitize(&l.name);
            if last_family != Some(l.name.as_str()) {
                let _ = writeln!(s, "# TYPE {n} counter");
                last_family = Some(l.name.as_str());
            }
            let pairs: Vec<String> = l
                .labels
                .iter()
                .map(|(k, v)| format!("{}=\"{}\"", label_key(k), label_value(v)))
                .collect();
            let _ = writeln!(s, "{n}{{{}}} {}", pairs.join(","), l.value);
        }
        for (k, v) in &self.gauges {
            let n = sanitize(k);
            let _ = writeln!(s, "# TYPE {n} gauge");
            let _ = writeln!(s, "{n} {v}");
        }
        for h in &self.hists {
            let n = sanitize(&h.name);
            let _ = writeln!(s, "# TYPE {n} summary");
            let _ = writeln!(s, "{n}{{quantile=\"0.5\"}} {}", h.p50);
            let _ = writeln!(s, "{n}{{quantile=\"0.9\"}} {}", h.p90);
            let _ = writeln!(s, "{n}{{quantile=\"0.99\"}} {}", h.p99);
            let _ = writeln!(s, "{n}_sum {}", h.sum);
            let _ = writeln!(s, "{n}_count {}", h.count);
        }
        s
    }

    /// Difference view against an earlier snapshot: counters report how
    /// much they advanced since `baseline` (a name absent from the
    /// baseline counts from zero); gauges and histogram summaries are
    /// instantaneous, so they pass through at their current values. This
    /// is what the flight recorder writes next to each dump — "what moved
    /// during the failure window".
    pub fn delta_from(&self, baseline: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: self
                .counters
                .iter()
                .map(|(k, v)| (k.clone(), v.saturating_sub(baseline.counter(k).unwrap_or(0))))
                .collect(),
            gauges: self.gauges.clone(),
            hists: self.hists.clone(),
            labelled: self
                .labelled
                .iter()
                .map(|l| {
                    let before = baseline
                        .labelled
                        .iter()
                        .find(|b| b.name == l.name && b.labels == l.labels)
                        .map_or(0, |b| b.value);
                    LabelledValue {
                        name: l.name.clone(),
                        labels: l.labels.clone(),
                        value: l.value.saturating_sub(before),
                    }
                })
                .collect(),
        }
    }

    /// Fixed-width table (same printer the figure harnesses use).
    pub fn render_table(&self) -> String {
        let mut t = crate::metrics::Table::new(&[
            "kind", "name", "value", "count", "sum", "p50", "p90", "p99",
        ]);
        let blank = String::new;
        for (k, v) in &self.counters {
            t.row(&[
                "counter".into(),
                k.clone(),
                v.to_string(),
                blank(),
                blank(),
                blank(),
                blank(),
                blank(),
            ]);
        }
        for l in &self.labelled {
            t.row(&[
                "counter".into(),
                l.decorated(),
                l.value.to_string(),
                blank(),
                blank(),
                blank(),
                blank(),
                blank(),
            ]);
        }
        for (k, v) in &self.gauges {
            t.row(&[
                "gauge".into(),
                k.clone(),
                v.to_string(),
                blank(),
                blank(),
                blank(),
                blank(),
                blank(),
            ]);
        }
        for h in &self.hists {
            t.row(&[
                "hist".into(),
                h.name.clone(),
                blank(),
                h.count.to_string(),
                h.sum.to_string(),
                h.p50.to_string(),
                h.p90.to_string(),
                h.p99.to_string(),
            ]);
        }
        t.render()
    }
}

/// Background thread that takes a [`MetricsSnapshot`] of the global
/// registry every `interval` and hands it to a callback — the
/// `--metrics-interval` CLI flag and the auto-tuner's sampling loop.
/// Stop (or drop) joins the thread and returns every snapshot taken.
pub struct MetricsTicker {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<Vec<MetricsSnapshot>>>,
}

impl MetricsTicker {
    /// Snapshot the global registry every `interval`, calling `on_tick`
    /// with each snapshot as it is taken.
    pub fn start<F>(interval: Duration, mut on_tick: F) -> MetricsTicker
    where
        F: FnMut(&MetricsSnapshot) + Send + 'static,
    {
        assert!(interval > Duration::ZERO, "metrics interval must be positive");
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("parlin-metrics-ticker".into())
            .spawn(move || {
                let mut taken = Vec::new();
                // sleep in short slices so stop() returns promptly even
                // with multi-second intervals
                let slice = interval.min(Duration::from_millis(20));
                let mut elapsed = Duration::ZERO;
                while !stop2.load(Ordering::Relaxed) {
                    std::thread::sleep(slice);
                    elapsed += slice;
                    if elapsed >= interval {
                        elapsed = Duration::ZERO;
                        let snap = registry().snapshot();
                        on_tick(&snap);
                        taken.push(snap);
                    }
                }
                taken
            })
            .expect("spawning the metrics ticker thread");
        MetricsTicker { stop, handle: Some(handle) }
    }

    /// Signal the thread, join it, and return every snapshot it took.
    pub fn stop(mut self) -> Vec<MetricsSnapshot> {
        self.stop.store(true, Ordering::Relaxed);
        match self.handle.take() {
            Some(h) => h.join().unwrap_or_default(),
            None => Vec::new(),
        }
    }
}

impl Drop for MetricsTicker {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_roundtrip() {
        let reg = Registry::new();
        let c = reg.counter("a.jobs");
        c.inc();
        c.add(4);
        assert_eq!(reg.counter("a.jobs").get(), 5, "handles share storage");
        let g = reg.gauge("a.depth");
        g.set(7);
        g.set(3);
        assert_eq!(reg.gauge("a.depth").get(), 3);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), 64);
        let reg = Registry::new();
        let h = reg.histogram("lat");
        for v in [1u64, 1, 1, 1, 1, 1, 1, 1, 1, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.sum(), 9 + 1000);
        // p50 falls in the bucket holding 1; p99 in the one holding 1000
        assert_eq!(h.quantile(0.5), 1);
        assert!(h.quantile(0.99) >= 512);
        assert_eq!(reg.histogram("empty").quantile(0.5), 0);
    }

    #[test]
    fn snapshot_csv_and_table_carry_every_metric() {
        let reg = Registry::new();
        reg.counter("pub").add(2);
        reg.gauge("pending").set(1);
        reg.histogram("h").record(8);
        let snap = reg.snapshot();
        assert_eq!(snap.counter("pub"), Some(2));
        assert_eq!(snap.gauge("pending"), Some(1));
        assert_eq!(snap.hist("h").unwrap().count, 1);
        let csv = snap.to_csv();
        assert!(csv.starts_with("kind,name,value,count,sum,p50,p90,p99\n"));
        assert!(csv.contains("counter,pub,2,,,,,"));
        assert!(csv.contains("gauge,pending,1,,,,,"));
        assert!(csv.lines().any(|l| l.starts_with("hist,h,,1,8,")));
        let table = snap.render_table();
        assert!(table.contains("pending"));
        assert_eq!(table.lines().count(), 2 + 3);
    }

    #[test]
    fn prometheus_rendering_sanitizes_names_and_types_every_family() {
        let reg = Registry::new();
        reg.counter("sched.publishes").add(3);
        reg.gauge("pool.jobs").set(7);
        reg.histogram("solver.epoch_wall_us").record(100);
        let text = reg.snapshot().render_prometheus();
        assert!(text.contains("# TYPE parlin_sched_publishes counter\n"));
        assert!(text.contains("parlin_sched_publishes 3\n"));
        assert!(text.contains("# TYPE parlin_pool_jobs gauge\n"));
        assert!(text.contains("parlin_pool_jobs 7\n"));
        assert!(text.contains("# TYPE parlin_solver_epoch_wall_us summary\n"));
        assert!(text.contains("parlin_solver_epoch_wall_us{quantile=\"0.5\"}"));
        assert!(text.contains("parlin_solver_epoch_wall_us_sum 100\n"));
        assert!(text.contains("parlin_solver_epoch_wall_us_count 1\n"));
        // every non-comment line is `name[{labels}] value` with a clean
        // charset — the same validation examples/check_metrics.rs applies
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (name, value) = line.split_once(' ').expect("one space per sample line");
            let bare = name.split('{').next().unwrap();
            assert!(
                bare.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name {bare:?}"
            );
            value.parse::<f64>().expect("sample value must be numeric");
        }
    }

    #[test]
    fn delta_from_diffs_counters_and_passes_gauges_through() {
        let reg = Registry::new();
        let c = reg.counter("evts");
        let g = reg.gauge("depth");
        c.add(5);
        g.set(2);
        let base = reg.snapshot();
        c.add(4);
        g.set(9);
        reg.histogram("lat").record(8);
        let delta = reg.snapshot().delta_from(&base);
        assert_eq!(delta.counter("evts"), Some(4), "counters diff against the baseline");
        assert_eq!(delta.gauge("depth"), Some(9), "gauges are instantaneous");
        assert_eq!(delta.hist("lat").unwrap().count, 1);
    }

    #[test]
    fn reset_zeroes_but_keeps_handles_live() {
        let reg = Registry::new();
        let c = reg.counter("x");
        c.add(9);
        reg.reset();
        assert_eq!(c.get(), 0);
        c.inc();
        assert_eq!(reg.snapshot().counter("x"), Some(1));
    }

    #[test]
    fn labelled_counters_are_one_series_per_label_set() {
        let reg = Registry::new();
        let a = reg.labelled_counter("tuner.decisions", &[("knob", "layout")]);
        let b = reg.labelled_counter("tuner.decisions", &[("knob", "bucket")]);
        a.inc();
        a.inc();
        b.inc();
        // label order is canonicalized, so the permuted set shares storage
        let c = reg.labelled_counter("multi", &[("a", "1"), ("b", "2")]);
        c.add(5);
        reg.labelled_counter("multi", &[("b", "2"), ("a", "1")]).inc();
        let snap = reg.snapshot();
        assert_eq!(snap.labelled("tuner.decisions", &[("knob", "layout")]), Some(2));
        assert_eq!(snap.labelled("tuner.decisions", &[("knob", "bucket")]), Some(1));
        assert_eq!(snap.labelled("multi", &[("b", "2"), ("a", "1")]), Some(6));
        assert_eq!(snap.labelled("multi", &[("a", "9")]), None);
        assert_eq!(snap.labelled.len(), 3, "three distinct series");
        assert!(!snap.is_empty());
    }

    #[test]
    fn labelled_counters_render_expose_diff_and_reset() {
        let reg = Registry::new();
        reg.labelled_counter("tuner.decisions", &[("knob", "layout")]).add(3);
        reg.labelled_counter("tuner.decisions", &[("knob", "bucket")]).inc();
        let snap = reg.snapshot();
        let text = snap.render_prometheus();
        // exactly one TYPE line for the family, one sample per label set
        assert_eq!(
            text.matches("# TYPE parlin_tuner_decisions counter\n").count(),
            1,
            "one TYPE line per labelled family:\n{text}"
        );
        assert!(text.contains("parlin_tuner_decisions{knob=\"layout\"} 3\n"));
        assert!(text.contains("parlin_tuner_decisions{knob=\"bucket\"} 1\n"));
        // CSV and table carry the decorated name
        assert!(snap.to_csv().contains("counter,tuner.decisions{knob=layout},3,,,,,"));
        assert!(snap.render_table().contains("tuner.decisions{knob=bucket}"));
        // deltas diff per series; a series absent from the baseline counts
        // from zero
        reg.labelled_counter("tuner.decisions", &[("knob", "layout")]).add(2);
        reg.labelled_counter("tuner.decisions", &[("knob", "workers")]).inc();
        let delta = reg.snapshot().delta_from(&snap);
        assert_eq!(delta.labelled("tuner.decisions", &[("knob", "layout")]), Some(2));
        assert_eq!(delta.labelled("tuner.decisions", &[("knob", "bucket")]), Some(0));
        assert_eq!(delta.labelled("tuner.decisions", &[("knob", "workers")]), Some(1));
        // reset zeroes values but keeps the series and handles live
        let h = reg.labelled_counter("tuner.decisions", &[("knob", "layout")]);
        reg.reset();
        assert_eq!(h.get(), 0);
        h.inc();
        assert_eq!(
            reg.snapshot().labelled("tuner.decisions", &[("knob", "layout")]),
            Some(1)
        );
    }

    #[test]
    fn labelled_exposition_escapes_values_and_sanitizes_keys() {
        let reg = Registry::new();
        reg.labelled_counter("odd.family", &[("bad-key", "a\"b\\c\nd")]).inc();
        let text = reg.snapshot().render_prometheus();
        assert!(
            text.contains("parlin_odd_family{bad_key=\"a\\\"b\\\\c\\nd\"} 1\n"),
            "escaped exposition line missing:\n{text}"
        );
        // still one sample per line: the raw newline must not survive
        assert!(!text.contains("d\"} 1\n\n"));
    }

    #[test]
    fn ticker_collects_snapshots() {
        registry().counter("ticker.test").inc();
        let t = MetricsTicker::start(Duration::from_millis(5), |_| {});
        std::thread::sleep(Duration::from_millis(40));
        let snaps = t.stop();
        assert!(!snaps.is_empty());
        assert!(snaps[0].counter("ticker.test").is_some());
    }
}
