//! Black-box flight recorder: when a serve run degrades, dump the last
//! N seconds of trace events plus the metrics movement since the previous
//! dump, so every PR-8 recovery event leaves forensic evidence on disk.
//!
//! # Triggers
//!
//! [`trip`] is called by the scheduler at every health-relevant moment:
//! a `snapshot_rollback` (writer failure contained), any transition of
//! [`ServeHealth`](crate::serve::ServeHealth) to `Degraded` (drain death,
//! exhausted drain retries, failed foreground refit), and the drain
//! watchdog flagging a stall. The trigger sites emit their trace event
//! *before* tripping on the same thread, so the event is already in that
//! thread's ring when the dump drains it.
//!
//! # Cost discipline
//!
//! The same pattern as [`crate::fault`]: un-installed, every [`trip`] is
//! ONE relaxed atomic load of the `ARMED` flag; the dump path is
//! `#[cold]` and never entered while disarmed. Installed, tripping is
//! still only reached on failure paths — never on the per-request or
//! per-epoch hot path — so the observation-without-perturbation argument
//! is untouched. Dump I/O errors are reported via `diag!` and swallowed:
//! a broken disk must not take down a serving process that just proved it
//! can survive a refit failure.
//!
//! # Dump format
//!
//! Each trip writes two timestamped files into the `--flight-dir`
//! directory (`flight-<unix-secs>-<seq>-<reason>.json` + `.metrics.txt`):
//! the windowed chrome://tracing JSON (same format as `--trace`, parseable
//! by `examples/check_trace.rs`) and a metrics table whose counters are
//! deltas since install (or the previous dump) — "what moved during the
//! failure window". See `docs/OBSERVABILITY.md`.

use std::io::{self, BufWriter};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::obs::registry::{registry, MetricsSnapshot};
use crate::obs::trace;
use crate::util::lock_recover;

/// Default event-retention window for dumps, seconds.
pub const DEFAULT_WINDOW_S: f64 = 30.0;

/// One relaxed load on every [`trip`]; flipped only by [`install`] /
/// [`FlightGuard`] drop.
static ARMED: AtomicBool = AtomicBool::new(false);
static RECORDER: Mutex<Option<Arc<FlightRecorder>>> = Mutex::new(None);
/// Serializes installed recorders across tests, like trace and fault
/// sessions.
static SESSION: Mutex<()> = Mutex::new(());

struct FlightRecorder {
    dir: PathBuf,
    window_ns: u64,
    /// Per-install dump sequence number (several trips in one second must
    /// not collide on the timestamped filename).
    seq: AtomicU64,
    /// Counter baseline for the next dump's delta: the registry at
    /// install time, advanced to the current snapshot after every dump.
    baseline: Mutex<MetricsSnapshot>,
}

/// RAII handle over an installed recorder; uninstalls on drop. Holds the
/// flight session mutex for its lifetime (lock order when combined with
/// tracing: start the [`TraceSession`](crate::obs::TraceSession) first,
/// as the CLI does).
pub struct FlightGuard {
    _serial: MutexGuard<'static, ()>,
}

impl Drop for FlightGuard {
    fn drop(&mut self) {
        ARMED.store(false, Ordering::SeqCst);
        *lock_recover(&RECORDER) = None;
    }
}

/// Install a recorder dumping into `dir` (created if missing) with a
/// `window_s`-second event-retention window. Events only flow if a
/// tracing session is live — `--flight-dir` on the CLI starts one even
/// without `--trace` for exactly that reason.
pub fn install(dir: impl Into<PathBuf>, window_s: f64) -> io::Result<FlightGuard> {
    let dir = dir.into();
    std::fs::create_dir_all(&dir)?;
    let serial = lock_recover(&SESSION);
    *lock_recover(&RECORDER) = Some(Arc::new(FlightRecorder {
        dir,
        window_ns: (window_s.max(1e-3) * 1e9) as u64,
        seq: AtomicU64::new(0),
        baseline: Mutex::new(registry().snapshot()),
    }));
    ARMED.store(true, Ordering::SeqCst);
    Ok(FlightGuard { _serial: serial })
}

/// Is a recorder currently installed?
pub fn armed() -> bool {
    ARMED.load(Ordering::SeqCst)
}

/// Fire the recorder: dump the trailing event window and the metrics
/// delta, tagged with `reason` (it lands in the filenames). One relaxed
/// load and a branch when nothing is installed.
#[inline]
pub fn trip(reason: &str) {
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    trip_armed(reason);
}

#[cold]
fn trip_armed(reason: &str) {
    let rec = match lock_recover(&RECORDER).as_ref() {
        Some(r) => Arc::clone(r),
        // a guard is mid-drop: ARMED read raced the recorder clear
        None => return,
    };
    match rec.dump(reason) {
        Ok(path) => crate::diag!(
            Warn,
            "flight recorder tripped ({}): dump -> {}",
            reason,
            path.display()
        ),
        Err(e) => crate::diag!(Warn, "flight recorder dump failed ({}): {}", reason, e),
    }
}

/// Filename-safe slug of a trip reason.
fn slug(reason: &str) -> String {
    let mut out = String::new();
    for c in reason.chars().take(48) {
        if c.is_ascii_alphanumeric() {
            out.push(c.to_ascii_lowercase());
        } else if !out.ends_with('-') {
            out.push('-');
        }
    }
    let trimmed = out.trim_matches('-');
    if trimmed.is_empty() { "trip".to_string() } else { trimmed.to_string() }
}

impl FlightRecorder {
    /// Write one dump pair; returns the trace JSON path.
    fn dump(&self, reason: &str) -> io::Result<PathBuf> {
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        let stamp = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let base = format!("flight-{stamp}-{seq}-{}", slug(reason));

        // the trailing window of the live trace (empty dump when no
        // tracing session is live — still a valid, parseable file)
        let cutoff = trace::now_ns().saturating_sub(self.window_ns);
        let mut dump = trace::live_dump().unwrap_or_default();
        for t in &mut dump.threads {
            t.events.retain(|e| e.ts_ns >= cutoff);
        }
        let trace_path = self.dir.join(format!("{base}.json"));
        let mut f = BufWriter::new(std::fs::File::create(&trace_path)?);
        dump.write_chrome_json(&mut f)?;

        // counters as deltas since the previous dump (or install);
        // advance the baseline so consecutive dumps partition time
        let delta = {
            let mut baseline = lock_recover(&self.baseline);
            let snap = registry().snapshot();
            let delta = snap.delta_from(&baseline);
            *baseline = snap;
            delta
        };
        let metrics_path = self.dir.join(format!("{base}.metrics.txt"));
        std::fs::write(
            &metrics_path,
            format!(
                "flight dump: {reason}\n\
                 window: last {:.3}s of trace events ({} kept)\n\
                 counters are deltas since the previous dump; gauges and\n\
                 histogram summaries are current values\n\n{}",
                self.window_ns as f64 / 1e9,
                dump.total_events(),
                delta.render_table()
            ),
        )?;
        Ok(trace_path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{emit, EventKind, ObsConfig, TraceSession, CLASS_WRITER};

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("parlin-flight-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn dumps_in(dir: &PathBuf, ext: &str) -> Vec<PathBuf> {
        let mut v: Vec<PathBuf> = std::fs::read_dir(dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .map(|e| e.path())
                    .filter(|p| p.to_string_lossy().ends_with(ext))
                    .collect()
            })
            .unwrap_or_default();
        v.sort();
        v
    }

    #[test]
    fn disarmed_trip_is_a_no_op() {
        // hold the flight session so an installed-recorder test in this
        // binary cannot race the disarmed assertion
        let _serial = lock_recover(&SESSION);
        assert!(!ARMED.load(Ordering::SeqCst));
        trip("nobody listening");
    }

    #[test]
    fn trip_dumps_windowed_trace_and_metrics_delta() {
        let dir = temp_dir("dump");
        // lock order: trace session first, then the recorder (the CLI's
        // order); both are held for the whole test
        let session = TraceSession::start(ObsConfig::on(256));
        let guard = install(&dir, DEFAULT_WINDOW_S).unwrap();
        assert!(armed());

        registry().counter("flight.test.rollbacks").inc();
        emit(EventKind::SnapshotRollback, CLASS_WRITER, 0, 7);
        trip("unit test degraded");

        let traces = dumps_in(&dir, ".json");
        assert_eq!(traces.len(), 1, "one trip -> one trace dump");
        let json = std::fs::read_to_string(&traces[0]).unwrap();
        assert!(json.starts_with("{\"traceEvents\":["), "chrome-trace shape");
        assert!(json.contains("\"snapshot_rollback\""), "{json}");
        assert!(
            traces[0].to_string_lossy().contains("unit-test-degraded"),
            "reason lands in the filename: {traces:?}"
        );

        let metrics = dumps_in(&dir, ".metrics.txt");
        assert_eq!(metrics.len(), 1);
        let table = std::fs::read_to_string(&metrics[0]).unwrap();
        assert!(table.contains("flight.test.rollbacks"), "{table}");

        // a second trip reports only what moved since the first
        registry().counter("flight.test.rollbacks").add(2);
        trip("second");
        let metrics = dumps_in(&dir, ".metrics.txt");
        assert_eq!(metrics.len(), 2);
        let second = std::fs::read_to_string(&metrics[1]).unwrap();
        let row = second
            .lines()
            .find(|l| l.contains("flight.test.rollbacks"))
            .expect("counter row present");
        assert!(row.trim_end().ends_with(" 2"), "delta, not absolute: {row:?}");

        drop(guard);
        assert!(!armed());
        drop(session.finish());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn window_filter_drops_stale_events() {
        let dir = temp_dir("window");
        let session = TraceSession::start(ObsConfig::on(256));
        // a 1 ms window: the event emitted now is stale after the sleep
        let guard = install(&dir, 0.001).unwrap();
        emit(EventKind::EpochBegin, crate::obs::CLASS_NONE, 0, 1);
        std::thread::sleep(std::time::Duration::from_millis(20));
        trip("stale");
        let traces = dumps_in(&dir, ".json");
        assert_eq!(traces.len(), 1);
        let json = std::fs::read_to_string(&traces[0]).unwrap();
        assert!(
            !json.contains("\"epoch_begin\""),
            "events older than the window must be filtered: {json}"
        );
        drop(guard);
        drop(session.finish());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reason_slugs_are_filename_safe() {
        assert_eq!(slug("drain failed: injected #3"), "drain-failed-injected-3");
        assert_eq!(slug(""), "trip");
        assert_eq!(slug("///"), "trip");
    }
}
