//! Leveled diagnostics: the [`diag!`](crate::diag) macro, the `PARLIN_LOG`
//! gate, and a capture sink so tests assert on diagnostic *events* instead
//! of scraping stderr.
//!
//! Call sites are cold control points (pool rebuilds, layout-cache misses,
//! warm-start shape mismatches) — the message is formatted on every call,
//! which is fine there and keeps the macro trivial. Routing:
//!
//! 1. when a [`DiagCapture`] is live, the record goes to its buffer and
//!    stderr stays quiet (tests);
//! 2. otherwise the record prints to stderr iff its level passes the
//!    `PARLIN_LOG` threshold (`error` | `warn` | `info` | `debug`;
//!    `off`/`0`/`none` silences everything; unset defaults to `warn`, so
//!    the pre-existing rebuild warnings keep appearing by default).
//!
//! The env var used to be re-read (and re-parsed) on every call; it is now
//! parsed once into an atomic cache, so the steady-state cost of a gated
//! call is one relaxed load. Embedders that change `PARLIN_LOG` from
//! within the process (tests do) call [`reload_threshold`] to drop the
//! cache.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Severity, ordered: `Error < Warn < Info < Debug`. A record prints when
/// its level is ≤ the configured threshold.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// One captured diagnostic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DiagRecord {
    pub level: Level,
    pub message: String,
}

/// Cached parse of `PARLIN_LOG`: [`Level`] as `u8`, [`THRESHOLD_SILENT`]
/// for "print nothing", [`THRESHOLD_UNINIT`] before the first call.
static THRESHOLD: AtomicU8 = AtomicU8::new(THRESHOLD_UNINIT);
const THRESHOLD_UNINIT: u8 = u8::MAX;
const THRESHOLD_SILENT: u8 = 4;

/// The effective threshold: one relaxed load once the cache is warm
/// (`dispatch` is on cold paths, but "cold" multiplied by every pool
/// rebuild in a long serve run still should not re-parse an env var).
fn threshold() -> Option<Level> {
    match THRESHOLD.load(Ordering::Relaxed) {
        THRESHOLD_UNINIT => init_threshold(),
        THRESHOLD_SILENT => None,
        0 => Some(Level::Error),
        1 => Some(Level::Warn),
        2 => Some(Level::Info),
        _ => Some(Level::Debug),
    }
}

#[cold]
fn init_threshold() -> Option<Level> {
    let t = env_threshold();
    THRESHOLD.store(t.map_or(THRESHOLD_SILENT, |l| l as u8), Ordering::Relaxed);
    t
}

/// Drop the cached threshold so the next diagnostic re-reads `PARLIN_LOG`.
/// For tests and embedders that set the variable from within the process —
/// nothing external can mutate another process's environment anyway, so
/// the cache loses no real flexibility.
pub fn reload_threshold() {
    THRESHOLD.store(THRESHOLD_UNINIT, Ordering::Relaxed);
}

/// Threshold from `PARLIN_LOG`; `None` means fully silent.
fn env_threshold() -> Option<Level> {
    match std::env::var("PARLIN_LOG") {
        Ok(v) => match v.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" | "trace" => Some(Level::Debug),
            "off" | "0" | "none" | "" => None,
            // an unrecognized value keeps the default rather than hiding
            // diagnostics behind a typo
            _ => Some(Level::Warn),
        },
        Err(_) => Some(Level::Warn),
    }
}

/// Capture buffer; `Some` while a [`DiagCapture`] is live.
static CAPTURE: Mutex<Option<Vec<DiagRecord>>> = Mutex::new(None);

/// Serializes captures so concurrently running tests cannot interleave
/// their records.
static CAPTURE_SERIAL: Mutex<()> = Mutex::new(());

fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // the crate-wide poison policy: see util::lock_recover
    crate::util::lock_recover(m)
}

/// RAII capture of every diagnostic emitted while it is alive, process-
/// wide (captures are mutually exclusive, like trace sessions). While
/// capturing, nothing is printed.
pub struct DiagCapture {
    _serial: MutexGuard<'static, ()>,
}

impl DiagCapture {
    pub fn start() -> DiagCapture {
        let serial = lock_ignore_poison(&CAPTURE_SERIAL);
        *lock_ignore_poison(&CAPTURE) = Some(Vec::new());
        DiagCapture { _serial: serial }
    }

    /// Records captured so far, draining the buffer.
    pub fn take(&self) -> Vec<DiagRecord> {
        lock_ignore_poison(&CAPTURE).as_mut().map(std::mem::take).unwrap_or_default()
    }
}

impl Drop for DiagCapture {
    fn drop(&mut self) {
        *lock_ignore_poison(&CAPTURE) = None;
    }
}

/// The macro's runtime. Not called directly — use
/// [`obs::diag!`](crate::diag).
pub fn dispatch(level: Level, args: fmt::Arguments<'_>) {
    {
        let mut cap = lock_ignore_poison(&CAPTURE);
        if let Some(buf) = cap.as_mut() {
            buf.push(DiagRecord { level, message: args.to_string() });
            return;
        }
    }
    // gate before formatting: a silenced record costs one relaxed load
    if threshold().is_some_and(|t| level <= t) {
        eprintln!("{args}");
    }
}

/// Leveled diagnostic, e.g. `obs::diag!(Warn, "rebuilding pool: {why}")`.
/// Levels are the [`obs::diag::Level`](crate::obs::diag::Level) variant
/// names. Routing (capture sink, then `PARLIN_LOG`-gated stderr) is
/// documented on [`obs::diag`](mod@crate::obs::diag).
#[macro_export]
macro_rules! diag {
    ($level:ident, $($arg:tt)*) => {
        $crate::obs::diag::dispatch(
            $crate::obs::diag::Level::$level,
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_collects_and_silences() {
        let cap = DiagCapture::start();
        crate::diag!(Warn, "rebuild {}", 42);
        crate::obs::diag!(Info, "note");
        let recs = cap.take();
        assert_eq!(
            recs,
            vec![
                DiagRecord { level: Level::Warn, message: "rebuild 42".into() },
                DiagRecord { level: Level::Info, message: "note".into() },
            ]
        );
        // drained: a second take is empty
        assert!(cap.take().is_empty());
    }

    #[test]
    fn threshold_parses_once_then_costs_one_relaxed_load() {
        // the capture serial doubles as the env-var serial: no other test
        // in this binary touches PARLIN_LOG while we hold it
        let _serial = lock_ignore_poison(&CAPTURE_SERIAL);
        std::env::set_var("PARLIN_LOG", "debug");
        reload_threshold();
        assert_eq!(threshold(), Some(Level::Debug));
        assert_eq!(THRESHOLD.load(Ordering::Relaxed), Level::Debug as u8);

        // changing the env var is NOT observed — the cache is the point
        std::env::set_var("PARLIN_LOG", "error");
        assert_eq!(threshold(), Some(Level::Debug), "cached, not re-parsed");

        // an explicit reload re-parses
        reload_threshold();
        assert_eq!(threshold(), Some(Level::Error));

        // the silent spelling caches too (distinct from uninitialized)
        std::env::set_var("PARLIN_LOG", "off");
        reload_threshold();
        assert_eq!(threshold(), None);
        assert_eq!(THRESHOLD.load(Ordering::Relaxed), THRESHOLD_SILENT);

        std::env::remove_var("PARLIN_LOG");
        reload_threshold();
        assert_eq!(threshold(), Some(Level::Warn), "unset defaults to warn");
    }

    #[test]
    fn levels_order_error_to_debug() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert_eq!(Level::Debug.name(), "debug");
    }
}
