//! Per-epoch convergence traces: duality gap / model change vs wall
//! clock, the curves the source paper's Figures 5–7 are built on and the
//! measurement feed the SySCD-style auto-tuner (ROADMAP item 2) consumes.
//!
//! Every solver records one [`ConvergencePoint`] per epoch into a
//! [`ConvergenceTrace`], which is stamped on
//! [`TrainOutput`](crate::solver::TrainOutput) and
//! [`RefitReport`](crate::serve::RefitReport) and exported by the CLI via
//! `--convergence-log <csv>`.
//!
//! # Non-perturbation contract
//!
//! Recording *reuses* values the solver epoch loop already computed — the
//! relative change from the convergence monitor, the duality gap only on
//! the epochs the gap checker already evaluated it, and the per-epoch
//! wall time from the timer read the epoch log already takes. The
//! recorder itself reads no clock, computes no gap, and takes no lock:
//! it is a `Vec` push per epoch. `rust/tests/obs.rs` locks this in by
//! asserting the trace is an exact mirror of the epoch log (same length,
//! bit-identical gaps, prefix-sum wall clock).

use std::path::Path;

use crate::metrics::{csv_field, parse_cell, split_csv_row};

/// One epoch's worth of convergence telemetry.
#[derive(Clone, Debug, PartialEq)]
pub struct ConvergencePoint {
    /// Epoch number, 1-based.
    pub epoch: usize,
    /// Wall-clock seconds since training started (cumulative: the sum of
    /// the per-epoch times the solver already measured).
    pub wall_s: f64,
    /// Relative model change vs the previous epoch (the paper's stopping
    /// criterion; `inf` marks an adaptive-σ reverted epoch).
    pub rel_change: f64,
    /// Duality gap, only on epochs where the monitor computed it.
    pub gap: Option<f64>,
    /// Per-worker busy imbalance (max/mean) at the end of this epoch;
    /// absent for non-pool executors.
    pub imbalance: Option<f64>,
    /// Total worker busy seconds (cumulative) at the end of this epoch;
    /// absent for non-pool executors.
    pub busy_s: Option<f64>,
}

/// The convergence-vs-time curve of one training run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConvergenceTrace {
    /// Solver label, same vocabulary as `RunRecord::solver`.
    pub solver: String,
    pub threads: usize,
    pub points: Vec<ConvergencePoint>,
}

impl ConvergenceTrace {
    pub fn new(solver: impl Into<String>, threads: usize) -> Self {
        ConvergenceTrace { solver: solver.into(), threads, points: Vec::new() }
    }

    /// Record one epoch. `epoch_wall_s` is the per-epoch wall time the
    /// solver's existing timer read produced; the stored value is its
    /// running sum, so the recorder adds no clock read of its own.
    pub fn record(
        &mut self,
        epoch: usize,
        epoch_wall_s: f64,
        rel_change: f64,
        gap: Option<f64>,
        imbalance: Option<f64>,
        busy_s: Option<f64>,
    ) {
        let wall_s = self.points.last().map_or(0.0, |p| p.wall_s) + epoch_wall_s;
        self.points.push(ConvergencePoint { epoch, wall_s, rel_change, gap, imbalance, busy_s });
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The last gap the monitor computed, if any epoch had one.
    pub fn last_gap(&self) -> Option<f64> {
        self.points.iter().rev().find_map(|p| p.gap)
    }

    /// Epochs until the gap first dropped below `tol` (what `parlin
    /// report` diffs as "epochs-to-gap"); `None` if it never did.
    pub fn epochs_to_gap(&self, tol: f64) -> Option<usize> {
        self.points.iter().find(|p| p.gap.is_some_and(|g| g <= tol)).map(|p| p.epoch)
    }

    /// Column names emitted by [`ConvergenceTrace::to_csv`].
    pub const CSV_HEADER: &'static str =
        "solver,threads,epoch,wall_s,rel_change,gap,imbalance,busy_s";

    /// Render as CSV. Floats use Rust's shortest round-trippable `{}`
    /// formatting (so [`ConvergenceTrace::from_csv`] is exact, including
    /// `inf` rel-change markers); absent optionals are empty cells.
    pub fn to_csv(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::from(Self::CSV_HEADER);
        s.push('\n');
        let solver = csv_field(&self.solver);
        let opt = |x: Option<f64>| x.map(|v| v.to_string()).unwrap_or_default();
        for p in &self.points {
            let _ = writeln!(
                s,
                "{},{},{},{},{},{},{},{}",
                solver,
                self.threads,
                p.epoch,
                p.wall_s,
                p.rel_change,
                opt(p.gap),
                opt(p.imbalance),
                opt(p.busy_s),
            );
        }
        s
    }

    /// Parse a [`ConvergenceTrace::to_csv`] dump back. `None` on a wrong
    /// header, a short row, or a malformed cell.
    pub fn from_csv(csv: &str) -> Option<ConvergenceTrace> {
        let mut lines = csv.lines();
        if lines.next()? != Self::CSV_HEADER {
            return None;
        }
        let mut trace = ConvergenceTrace::default();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let cells = split_csv_row(line);
            if cells.len() != 8 {
                return None;
            }
            trace.solver.clone_from(&cells[0]);
            trace.threads = cells[1].parse().ok()?;
            trace.points.push(ConvergencePoint {
                epoch: cells[2].parse().ok()?,
                wall_s: cells[3].parse().ok()?,
                rel_change: cells[4].parse().ok()?,
                gap: parse_cell(&cells[5])?,
                imbalance: parse_cell(&cells[6])?,
                busy_s: parse_cell(&cells[7])?,
            });
        }
        Some(trace)
    }

    /// Gap-only view for plotting: `epoch,wall_s,gap` rows restricted to
    /// the epochs where the monitor computed a gap.
    pub fn gap_csv(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::from("epoch,wall_s,gap\n");
        for p in &self.points {
            if let Some(g) = p.gap {
                let _ = writeln!(s, "{},{},{}", p.epoch, p.wall_s, g);
            }
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> ConvergenceTrace {
        let mut t = ConvergenceTrace::new("numa(2n,bucket=4)", 8);
        t.record(1, 0.5, 0.8, Some(0.25), Some(1.5), Some(3.5));
        t.record(2, 0.25, f64::INFINITY, None, None, None);
        t.record(3, 0.25, 0.01, Some(1e-4), Some(1.1), Some(7.25));
        t
    }

    #[test]
    fn record_accumulates_wall_clock() {
        let t = trace();
        assert_eq!(t.len(), 3);
        assert_eq!(t.points[0].wall_s, 0.5);
        assert_eq!(t.points[1].wall_s, 0.75);
        assert_eq!(t.points[2].wall_s, 1.0);
        assert_eq!(t.last_gap(), Some(1e-4));
        assert_eq!(t.epochs_to_gap(1e-3), Some(3));
        assert_eq!(t.epochs_to_gap(1e-9), None);
    }

    #[test]
    fn csv_roundtrips_exactly_including_inf_and_empty_cells() {
        let t = trace();
        let csv = t.to_csv();
        assert!(csv.starts_with(ConvergenceTrace::CSV_HEADER));
        assert!(csv.contains("\"numa(2n,bucket=4)\",8,"), "comma labels must quote");
        let back = ConvergenceTrace::from_csv(&csv).expect("own output must parse");
        assert_eq!(back, t, "shortest-float formatting round-trips bit-exactly");
        assert_eq!(back.to_csv(), csv);
    }

    #[test]
    fn from_csv_rejects_garbage() {
        assert!(ConvergenceTrace::from_csv("nope\n1,2,3").is_none());
        let short = format!("{}\nseq,1,1,0.5\n", ConvergenceTrace::CSV_HEADER);
        assert!(ConvergenceTrace::from_csv(&short).is_none());
        let bad = format!("{}\nseq,1,one,0.5,0.1,,,\n", ConvergenceTrace::CSV_HEADER);
        assert!(ConvergenceTrace::from_csv(&bad).is_none());
    }

    #[test]
    fn gap_csv_keeps_only_evaluated_epochs() {
        let g = trace().gap_csv();
        let lines: Vec<_> = g.lines().collect();
        assert_eq!(lines[0], "epoch,wall_s,gap");
        assert_eq!(lines.len(), 3, "epoch 2 had no gap evaluation");
        assert!(lines[1].starts_with("1,0.5,"));
        assert!(lines[2].starts_with("3,1,"));
    }
}
