//! Observability: lock-free per-thread event tracing, a named-metric
//! registry, leveled diagnostics, and their exporters.
//!
//! The paper's method is *measure the system, then fix what the
//! measurement shows* — this module is the measuring instrument, built so
//! that using it cannot change what it measures:
//!
//! * **Event tracing** ([`trace`], [`ring`]): instrumented sites call
//!   [`emit`], which with tracing off is a single relaxed atomic load.
//!   With a [`TraceSession`] live, each emitting thread — every
//!   [`WorkerPool`](crate::solver::WorkerPool) worker, the solver
//!   coordinator, the scheduler's refit and dispatcher threads — owns a
//!   bounded SPSC [`EventRing`](ring::EventRing) of fixed-size
//!   [`TraceEvent`]s; overflow is counted and dropped, never blocked on.
//!   The hot path takes zero locks either way, which is why the three
//!   determinism arguments of `docs/ARCHITECTURE.md` survive under
//!   observation (asserted bit-wise by `rust/tests/obs.rs`). Dumps export
//!   as `chrome://tracing` JSON via [`TraceDump`].
//! * **Metrics** ([`registry`](mod@registry)): named [`Counter`]s,
//!   [`Gauge`]s and log-bucketed [`Histogram`]s behind lock-free handles;
//!   [`MetricsSnapshot`] is the frozen view that serve reports stamp and
//!   the periodic [`MetricsTicker`] feeds to `--metrics-interval` (and,
//!   next, the SySCD-style auto-tuner of ROADMAP item 2).
//! * **Diagnostics** ([`diag`](mod@diag)): the [`diag!`](crate::diag)
//!   macro replaces ad-hoc `eprintln!` on cold control points — leveled,
//!   `PARLIN_LOG`-gated, and capturable in tests via
//!   [`DiagCapture`](diag::DiagCapture).
//! * **Exposition** ([`export`]): `--metrics-addr` starts a pull-only,
//!   dependency-free HTTP endpoint serving `/metrics` (Prometheus text),
//!   `/health` and `/trace` — scrapers read the same lock-free state the
//!   instruments already maintain, so scraping cannot perturb a run.
//! * **Convergence traces** ([`convergence`]): every solver records a
//!   [`ConvergencePoint`] per epoch (gap / model change / wall clock /
//!   pool imbalance), reusing values the epoch loop already computed;
//!   exported via `--convergence-log`.
//! * **Flight recorder** ([`flight`]): `--flight-dir` arms a black box
//!   that dumps the trailing event window plus a metrics delta whenever
//!   serve health degrades or a snapshot rolls back.

pub mod convergence;
pub mod diag;
pub mod export;
pub mod flight;
pub mod registry;
pub mod ring;
pub mod trace;

pub use convergence::{ConvergencePoint, ConvergenceTrace};
pub use export::{ExportServer, ExportSources};
pub use registry::{
    registry, Counter, Gauge, Histogram, LabelledValue, MetricsSnapshot, MetricsTicker, Registry,
};
pub use trace::{
    emit, live_dump, now_ns, ring_count, tracing_enabled, EventKind, ObsConfig, TraceDump,
    TraceEvent, TraceSession, CLASS_NONE, CLASS_READER, CLASS_WRITER, DEFAULT_RING_CAPACITY,
    MIN_RING_CAPACITY,
};

// Re-export the `diag!` macro at `obs::diag!` (macros and modules live in
// different namespaces, so this coexists with the `diag` module above).
pub use crate::diag;
