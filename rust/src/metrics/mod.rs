//! Run records: per-epoch statistics, training summaries and CSV output —
//! the raw material for EXPERIMENTS.md and every figure harness.

use std::fmt::Write as _;
use std::path::Path;

/// One epoch of a training run.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    /// Wall-clock seconds spent in this epoch (measured, this host).
    pub wall_s: f64,
    /// Relative model change vs the previous epoch (convergence criterion).
    pub rel_change: f64,
    /// Duality gap, if it was computed this epoch.
    pub gap: Option<f64>,
    /// Training primal objective, if computed.
    pub primal: Option<f64>,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct RunRecord {
    /// Solver label ("seq", "wild", "dom-dynamic", …).
    pub solver: String,
    pub threads: usize,
    pub epochs: Vec<EpochStats>,
    pub converged: bool,
    /// `true` when the run stopped because the model diverged (wild mode
    /// at high thread counts — the paper's red markers in Fig. 1a).
    pub diverged: bool,
    pub total_wall_s: f64,
}

impl RunRecord {
    pub fn epochs_run(&self) -> usize {
        self.epochs.len()
    }

    pub fn final_rel_change(&self) -> f64 {
        self.epochs.last().map(|e| e.rel_change).unwrap_or(f64::NAN)
    }

    /// Mean per-epoch wall time, skipping the first (warm-up/alloc) epoch
    /// when there are enough samples.
    pub fn epoch_wall_mean(&self) -> f64 {
        if self.epochs.len() > 2 {
            crate::util::mean(
                &self.epochs[1..]
                    .iter()
                    .map(|e| e.wall_s)
                    .collect::<Vec<_>>(),
            )
        } else {
            crate::util::mean(&self.epochs.iter().map(|e| e.wall_s).collect::<Vec<_>>())
        }
    }

    /// Render as CSV ([`RunRecord::CSV_HEADER`]). The solver label is
    /// RFC 4180-quoted — real labels contain commas (e.g.
    /// `numa(2n,bucket=4)`), which previously sheared the column grid.
    /// `gap`/`primal` emit as *empty* cells (never `NaN`) when absent or
    /// non-finite, so downstream tooling can parse every cell as a float.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(Self::CSV_HEADER);
        s.push('\n');
        let solver = csv_field(&self.solver);
        for e in &self.epochs {
            let _ = writeln!(
                s,
                "{},{},{},{:.6e},{:.6e},{},{}",
                solver,
                self.threads,
                e.epoch,
                e.wall_s,
                e.rel_change,
                finite_cell(e.gap),
                finite_cell(e.primal),
            );
        }
        s
    }

    /// Column names emitted by [`RunRecord::to_csv`].
    pub const CSV_HEADER: &str = "solver,threads,epoch,wall_s,rel_change,gap,primal";

    /// Parse a [`RunRecord::to_csv`] dump back into a record. Fields the
    /// CSV does not carry (`converged`, `diverged`, `total_wall_s`) come
    /// back as their defaults; everything serialized round-trips, including
    /// quoted solver labels and empty `gap`/`primal` cells.
    pub fn from_csv(csv: &str) -> Option<RunRecord> {
        let mut lines = csv.lines();
        if lines.next()? != Self::CSV_HEADER {
            return None;
        }
        let mut solver = String::new();
        let mut threads = 0usize;
        let mut epochs = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let cells = split_csv_row(line);
            if cells.len() != 7 {
                return None;
            }
            solver.clone_from(&cells[0]);
            threads = cells[1].parse().ok()?;
            epochs.push(EpochStats {
                epoch: cells[2].parse().ok()?,
                wall_s: cells[3].parse().ok()?,
                rel_change: cells[4].parse().ok()?,
                gap: parse_cell(&cells[5])?,
                primal: parse_cell(&cells[6])?,
            });
        }
        Some(RunRecord {
            solver,
            threads,
            epochs,
            converged: false,
            diverged: false,
            total_wall_s: 0.0,
        })
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

/// RFC 4180 field quoting: wrap in double quotes (doubling any embedded
/// quote) when the value contains a comma, quote or newline. Shared with
/// the convergence-trace CSV in [`crate::obs::convergence`].
pub(crate) fn csv_field(s: &str) -> String {
    if s.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// A float cell that is empty when the value is absent **or** non-finite —
/// `NaN`/`inf` never reach the file.
fn finite_cell(x: Option<f64>) -> String {
    match x {
        Some(v) if v.is_finite() => format!("{v:.6e}"),
        _ => String::new(),
    }
}

/// `Some(None)` for an empty cell, `Some(Some(v))` for a float, `None` on
/// garbage.
pub(crate) fn parse_cell(cell: &str) -> Option<Option<f64>> {
    if cell.is_empty() {
        Some(None)
    } else {
        cell.parse().ok().map(Some)
    }
}

/// Split one CSV row honoring RFC 4180 quoting (the inverse of
/// [`csv_field`] over a joined row).
pub(crate) fn split_csv_row(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => out.push(std::mem::take(&mut cur)),
                c => cur.push(c),
            }
        }
    }
    out.push(cur);
    out
}

/// Fixed-width table printer for the figure harnesses (`println!`-style
/// output that mirrors the paper's tables).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record() -> RunRecord {
        RunRecord {
            solver: "seq".into(),
            threads: 1,
            epochs: vec![
                EpochStats {
                    epoch: 1,
                    wall_s: 0.5,
                    rel_change: 0.8,
                    gap: Some(0.1),
                    primal: None,
                },
                EpochStats {
                    epoch: 2,
                    wall_s: 0.4,
                    rel_change: 0.01,
                    gap: None,
                    primal: Some(0.3),
                },
            ],
            converged: true,
            diverged: false,
            total_wall_s: 0.9,
        }
    }

    #[test]
    fn csv_has_all_rows() {
        let r = record();
        let csv = r.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert_eq!(csv.lines().next().unwrap(), RunRecord::CSV_HEADER);
        assert!(csv.lines().nth(1).unwrap().starts_with("seq,1,1,"));
        assert!(csv.contains("1.000000e-1"));
    }

    #[test]
    fn csv_roundtrips_comma_labels_and_empty_cells() {
        // a real NUMA label — it contains a comma and must be quoted, and
        // the non-finite primal must land as an empty cell, not NaN
        let mut r = record();
        r.solver = "numa(2n,bucket=4)".into();
        r.threads = 8;
        r.epochs[0].primal = Some(f64::NAN);
        let csv = r.to_csv();
        assert!(csv.contains("\"numa(2n,bucket=4)\",8,"));
        assert!(!csv.contains("NaN"));
        let back = RunRecord::from_csv(&csv).expect("own output must parse");
        assert_eq!(back.solver, r.solver);
        assert_eq!(back.threads, r.threads);
        assert_eq!(back.epochs.len(), r.epochs.len());
        assert_eq!(back.epochs[0].gap, Some(0.1));
        assert_eq!(back.epochs[0].primal, None, "NaN round-trips as absent");
        assert_eq!(back.to_csv(), csv, "serialize → parse → serialize is a fixpoint");
    }

    #[test]
    fn csv_quoting_helpers() {
        assert_eq!(csv_field("plain"), "plain");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("a\"b"), "\"a\"\"b\"");
        assert_eq!(split_csv_row("\"a,b\",1,,x"), vec!["a,b", "1", "", "x"]);
        assert_eq!(split_csv_row("\"a\"\"b\",2"), vec!["a\"b", "2"]);
        assert_eq!(finite_cell(None), "");
        assert_eq!(finite_cell(Some(f64::INFINITY)), "");
        assert_eq!(finite_cell(Some(0.5)), "5.000000e-1");
    }

    #[test]
    fn epoch_mean_skips_warmup_when_long() {
        let mut r = record();
        r.epochs.push(EpochStats {
            epoch: 3,
            wall_s: 0.4,
            rel_change: 0.001,
            gap: None,
            primal: None,
        });
        assert!((r.epoch_wall_mean() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["threads", "time"]);
        t.row(&["1".into(), "10.5".into()]);
        t.row(&["32".into(), "0.9".into()]);
        let s = t.render();
        assert!(s.contains("threads"));
        assert_eq!(s.lines().count(), 4);
    }
}
