//! Run records: per-epoch statistics, training summaries and CSV output —
//! the raw material for EXPERIMENTS.md and every figure harness.

use std::fmt::Write as _;
use std::path::Path;

/// One epoch of a training run.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    /// Wall-clock seconds spent in this epoch (measured, this host).
    pub wall_s: f64,
    /// Relative model change vs the previous epoch (convergence criterion).
    pub rel_change: f64,
    /// Duality gap, if it was computed this epoch.
    pub gap: Option<f64>,
    /// Training primal objective, if computed.
    pub primal: Option<f64>,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct RunRecord {
    /// Solver label ("seq", "wild", "dom-dynamic", …).
    pub solver: String,
    pub threads: usize,
    pub epochs: Vec<EpochStats>,
    pub converged: bool,
    /// `true` when the run stopped because the model diverged (wild mode
    /// at high thread counts — the paper's red markers in Fig. 1a).
    pub diverged: bool,
    pub total_wall_s: f64,
}

impl RunRecord {
    pub fn epochs_run(&self) -> usize {
        self.epochs.len()
    }

    pub fn final_rel_change(&self) -> f64 {
        self.epochs.last().map(|e| e.rel_change).unwrap_or(f64::NAN)
    }

    /// Mean per-epoch wall time, skipping the first (warm-up/alloc) epoch
    /// when there are enough samples.
    pub fn epoch_wall_mean(&self) -> f64 {
        if self.epochs.len() > 2 {
            crate::util::mean(
                &self.epochs[1..]
                    .iter()
                    .map(|e| e.wall_s)
                    .collect::<Vec<_>>(),
            )
        } else {
            crate::util::mean(&self.epochs.iter().map(|e| e.wall_s).collect::<Vec<_>>())
        }
    }

    /// Render as CSV (`epoch,wall_s,rel_change,gap,primal`).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("epoch,wall_s,rel_change,gap,primal\n");
        for e in &self.epochs {
            let _ = writeln!(
                s,
                "{},{:.6e},{:.6e},{},{}",
                e.epoch,
                e.wall_s,
                e.rel_change,
                e.gap.map(|g| format!("{g:.6e}")).unwrap_or_default(),
                e.primal.map(|p| format!("{p:.6e}")).unwrap_or_default(),
            );
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

/// Fixed-width table printer for the figure harnesses (`println!`-style
/// output that mirrors the paper's tables).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record() -> RunRecord {
        RunRecord {
            solver: "seq".into(),
            threads: 1,
            epochs: vec![
                EpochStats {
                    epoch: 1,
                    wall_s: 0.5,
                    rel_change: 0.8,
                    gap: Some(0.1),
                    primal: None,
                },
                EpochStats {
                    epoch: 2,
                    wall_s: 0.4,
                    rel_change: 0.01,
                    gap: None,
                    primal: Some(0.3),
                },
            ],
            converged: true,
            diverged: false,
            total_wall_s: 0.9,
        }
    }

    #[test]
    fn csv_has_all_rows() {
        let r = record();
        let csv = r.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(1).unwrap().starts_with("1,"));
        assert!(csv.contains("1.000000e-1"));
    }

    #[test]
    fn epoch_mean_skips_warmup_when_long() {
        let mut r = record();
        r.epochs.push(EpochStats {
            epoch: 3,
            wall_s: 0.4,
            rel_change: 0.001,
            gap: None,
            primal: None,
        });
        assert!((r.epoch_wall_mean() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["threads", "time"]);
        t.row(&["1".into(), "10.5".into()]);
        t.row(&["32".into(), "0.9".into()]);
        let s = t.render();
        assert!(s.contains("threads"));
        assert_eq!(s.lines().count(), 4);
    }
}
