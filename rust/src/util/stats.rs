//! Tiny statistics helpers used by the bench harnesses and figure
//! generators (mean/geomean over speedups, percentiles over bench samples).

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation; 0 for fewer than 2 points.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Geometric mean — the right way to average speedup ratios (the paper's
/// "×5.1 on average" style numbers).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Linear-interpolated percentile, `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138089935).abs() < 1e-6);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }
}
