//! Tiny statistics helpers used by the bench harnesses and figure
//! generators (mean/geomean over speedups, percentiles over bench samples).

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation; 0 for fewer than 2 points.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Geometric mean — the right way to average speedup ratios (the paper's
/// "×5.1 on average" style numbers).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Linear-interpolated percentile, `p` in `[0, 100]`. One-shot form of
/// [`Percentiles`] — sorts per call, so use `Percentiles` when reading
/// several quantiles of the same sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    Percentiles::of(xs).p(p)
}

/// A sorted sample supporting repeated percentile queries — **the** shared
/// implementation behind every latency/age summary (closed-loop and
/// open-loop serve reports, scheduler snapshot ages), replacing the
/// previously duplicated per-report sorts. Sorts once at construction;
/// each query is two index reads and a linear interpolation.
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    sorted: Vec<f64>,
}

impl Percentiles {
    /// Sort a copy of `xs` (NaNs must not be present — samples are wall
    /// times and ages, which are finite by construction).
    pub fn of(xs: &[f64]) -> Self {
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Percentiles { sorted }
    }

    /// Linear-interpolated percentile, `p` in `[0, 100]`; 0 when empty.
    pub fn p(&self, p: f64) -> f64 {
        let s = &self.sorted;
        if s.is_empty() {
            return 0.0;
        }
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }

    /// Median.
    pub fn p50(&self) -> f64 {
        self.p(50.0)
    }

    /// 99th percentile (the serving tail metric).
    pub fn p99(&self) -> f64 {
        self.p(99.0)
    }

    /// Smallest sample; 0 when empty.
    pub fn min(&self) -> f64 {
        self.sorted.first().copied().unwrap_or(0.0)
    }

    /// Largest sample; 0 when empty.
    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(0.0)
    }

    pub fn count(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138089935).abs() < 1e-6);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentiles_empty_input() {
        let p = Percentiles::of(&[]);
        assert!(p.is_empty());
        assert_eq!(p.count(), 0);
        assert_eq!(p.p(0.0), 0.0);
        assert_eq!(p.p50(), 0.0);
        assert_eq!(p.p99(), 0.0);
        assert_eq!(p.min(), 0.0);
        assert_eq!(p.max(), 0.0);
    }

    #[test]
    fn percentiles_single_element() {
        let p = Percentiles::of(&[3.5]);
        assert_eq!(p.count(), 1);
        assert_eq!(p.p(0.0), 3.5);
        assert_eq!(p.p50(), 3.5);
        assert_eq!(p.p99(), 3.5);
        assert_eq!(p.min(), 3.5);
        assert_eq!(p.max(), 3.5);
    }

    #[test]
    fn percentiles_even_length_interpolates() {
        // unsorted on purpose — construction sorts
        let p = Percentiles::of(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(p.min(), 1.0);
        assert_eq!(p.max(), 4.0);
        assert!((p.p50() - 2.5).abs() < 1e-12);
        assert!((p.p(25.0) - 1.75).abs() < 1e-12);
        // p99 sits between the last two order statistics
        assert!((p.p99() - (3.0 + 0.97 * 1.0)).abs() < 1e-12);
    }

    #[test]
    fn percentiles_odd_length_hits_middle_exactly() {
        let p = Percentiles::of(&[5.0, 1.0, 3.0]);
        assert_eq!(p.p50(), 3.0);
        assert_eq!(p.p(0.0), 1.0);
        assert_eq!(p.p(100.0), 5.0);
    }

    #[test]
    fn percentiles_match_one_shot_percentile() {
        let xs = [0.2, 0.9, 0.4, 0.7, 0.1];
        let p = Percentiles::of(&xs);
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(p.p(q), percentile(&xs, q));
        }
    }
}
