//! Small shared utilities: a fast deterministic PRNG, shuffling, timing and
//! numeric helpers used across the solvers, benches and tests.
//!
//! Everything here is dependency-free and deterministic so that every
//! experiment in `EXPERIMENTS.md` is exactly reproducible from a seed.

pub mod atomic;
pub mod linalg;
pub mod rng;
pub mod stats;
pub mod timer;

pub use atomic::{AtomicF64, PaddedAtomicF64};
pub use rng::Rng;
pub use stats::{geomean, mean, percentile, stddev, Percentiles};
pub use timer::Timer;

/// Lock a mutex, recovering the guard from a poisoned lock instead of
/// panicking. The ONE poison policy for the crate's supervision-style
/// locks (scheduler/session state, trace and diag sinks, the fault
/// plan): a writer that panicked has already been contained and rolled
/// back by `catch_unwind` above it — or crashed a worker thread that
/// held no partial invariant — so the data under the mutex is
/// consistent, and refusing to serve forever because a thread once died
/// would turn one contained failure into a permanent outage.
pub fn lock_recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// The ONE 4-chain dot reduction: `Σ x_k·y_k` over `n` product pairs
/// produced by `pair(k)`, accumulated in four independent chains folded
/// as `(s0+s1)+(s2+s3)` with a sequential tail.
///
/// Four chains let LLVM vectorize and keep the FMA pipeline full; every
/// dot path in the crate — [`dot`] (dense columns, behind
/// `DenseMatrix::dot_col_in`), `CscMatrix::dot_col_in` (sparse gather)
/// and `solver::kernel::dot_entries` (interleaved stream) — routes
/// through this single implementation, so their floating-point
/// evaluation order is identical **by construction**. The
/// layout-equivalence guarantee (`tests/pool_equivalence.rs`) depends on
/// that: change the reduction here and every path changes together.
#[inline]
pub fn dot4_by(n: usize, pair: impl Fn(usize) -> (f64, f64)) -> f64 {
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        let k = c * 4;
        let (x0, y0) = pair(k);
        let (x1, y1) = pair(k + 1);
        let (x2, y2) = pair(k + 2);
        let (x3, y3) = pair(k + 3);
        s0 += x0 * y0;
        s1 += x1 * y1;
        s2 += x2 * y2;
        s3 += x3 * y3;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for k in chunks * 4..n {
        let (x, y) = pair(k);
        s += x * y;
    }
    s
}

/// Dot product of two equal-length slices — the innermost hot loop of the
/// dense SDCA coordinate update (see `solver::seq`); one instance of the
/// shared [`dot4_by`] reduction.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    dot4_by(a.len(), |k| (a[k], b[k]))
}

/// Software-prefetch a slice's bytes toward L1 — one `_mm_prefetch` per
/// 64-byte line on x86_64, a no-op elsewhere. The ONE prefetch loop
/// behind both `DenseMatrix::prefetch_cols` and
/// `data::shard::Shard::prefetch_bucket`/`prefetch_example`.
#[inline]
pub fn prefetch_slice<T>(data: &[T]) {
    #[cfg(target_arch = "x86_64")]
    {
        let mut p = data.as_ptr() as *const i8;
        let end = unsafe { p.add(std::mem::size_of_val(data)) };
        while p < end {
            unsafe {
                std::arch::x86_64::_mm_prefetch(p, std::arch::x86_64::_MM_HINT_T0);
                p = p.add(64);
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = data;
    }
}

/// `y += alpha * x` (axpy), the shared-vector update of the SDCA step.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * *xi;
    }
}

/// Squared L2 norm.
#[inline]
pub fn norm_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Relative L2 change `‖a − b‖ / max(‖a‖, eps)` — the paper's convergence
/// criterion ("relative change in the learned model from one epoch to the
/// next").
pub fn rel_change(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (&x, &y) in a.iter().zip(b.iter()) {
        num += (x - y) * (x - y);
        den += x * x;
    }
    (num / den.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..131).map(|i| (i as f64) * 0.5 - 3.0).collect();
        let b: Vec<f64> = (0..131).map(|i| 1.0 / (i as f64 + 1.0)).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9 * naive.abs().max(1.0));
    }

    #[test]
    fn dot_handles_short_and_empty() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn axpy_accumulates() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
    }

    #[test]
    fn rel_change_zero_for_identical() {
        let a = [1.0, -2.0, 3.0];
        assert_eq!(rel_change(&a, &a), 0.0);
    }

    #[test]
    fn rel_change_scales() {
        let a = [1.0, 0.0];
        let b = [0.0, 0.0];
        assert!((rel_change(&a, &b) - 1.0).abs() < 1e-12);
    }
}
