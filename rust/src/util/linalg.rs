//! Small dense linear algebra for the IRLSM baseline: symmetric
//! positive-definite solves via Cholesky (the normal-equations step of
//! iteratively reweighted least squares).

use anyhow::{bail, Result};

/// Dense symmetric matrix stored row-major (`d × d`).
pub struct SymMatrix {
    pub d: usize,
    pub a: Vec<f64>,
}

impl SymMatrix {
    pub fn zeros(d: usize) -> Self {
        SymMatrix {
            d,
            a: vec![0.0; d * d],
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.d + j]
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.d + j] += v;
    }

    /// Rank-1 update `A += c · x xᵀ` (both triangles).
    pub fn rank1(&mut self, c: f64, x: &[f64]) {
        debug_assert_eq!(x.len(), self.d);
        for i in 0..self.d {
            let cxi = c * x[i];
            if cxi == 0.0 {
                continue;
            }
            let row = &mut self.a[i * self.d..(i + 1) * self.d];
            for (aij, &xj) in row.iter_mut().zip(x.iter()) {
                *aij += cxi * xj;
            }
        }
    }

    /// Add `c` to the diagonal (ridge term).
    pub fn add_diag(&mut self, c: f64) {
        for i in 0..self.d {
            self.a[i * self.d + i] += c;
        }
    }

    /// In-place Cholesky factorization `A = L Lᵀ` (lower triangle).
    /// Fails when the matrix is not (numerically) positive definite.
    pub fn cholesky(&mut self) -> Result<()> {
        let d = self.d;
        for j in 0..d {
            let mut diag = self.at(j, j);
            for k in 0..j {
                let ljk = self.at(j, k);
                diag -= ljk * ljk;
            }
            if diag <= 0.0 || !diag.is_finite() {
                bail!("matrix not positive definite at pivot {j} ({diag})");
            }
            let ljj = diag.sqrt();
            self.a[j * d + j] = ljj;
            for i in (j + 1)..d {
                let mut s = self.at(i, j);
                for k in 0..j {
                    s -= self.at(i, k) * self.at(j, k);
                }
                self.a[i * d + j] = s / ljj;
            }
        }
        // zero the (stale) upper triangle for cleanliness
        for i in 0..d {
            for j in (i + 1)..d {
                self.a[i * d + j] = 0.0;
            }
        }
        Ok(())
    }

    /// Solve `A x = b` given the Cholesky factor computed by
    /// [`Self::cholesky`] (forward then backward substitution).
    pub fn solve_cholesky(&self, b: &[f64]) -> Vec<f64> {
        let d = self.d;
        debug_assert_eq!(b.len(), d);
        // L y = b
        let mut y = vec![0.0; d];
        for i in 0..d {
            let mut s = b[i];
            for k in 0..i {
                s -= self.at(i, k) * y[k];
            }
            y[i] = s / self.at(i, i);
        }
        // Lᵀ x = y
        let mut x = vec![0.0; d];
        for i in (0..d).rev() {
            let mut s = y[i];
            for k in (i + 1)..d {
                s -= self.at(k, i) * x[k];
            }
            x[i] = s / self.at(i, i);
        }
        x
    }
}

/// Convenience: solve the SPD system `A x = b`, consuming `A`.
pub fn spd_solve(mut a: SymMatrix, b: &[f64]) -> Result<Vec<f64>> {
    a.cholesky()?;
    Ok(a.solve_cholesky(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_identity() {
        let mut a = SymMatrix::zeros(3);
        a.add_diag(1.0);
        let x = spd_solve(a, &[1.0, 2.0, 3.0]).unwrap();
        for (xi, want) in x.iter().zip([1.0, 2.0, 3.0]) {
            assert!((xi - want).abs() < 1e-12);
        }
    }

    #[test]
    fn solves_random_spd() {
        // A = B Bᵀ + I is SPD; check residual ‖Ax − b‖
        let d = 8;
        let mut rng = crate::util::Rng::new(3);
        let mut a = SymMatrix::zeros(d);
        for _ in 0..d {
            let col: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
            a.rank1(1.0, &col);
        }
        a.add_diag(1.0);
        let a_copy = a.a.clone();
        let b: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let x = spd_solve(a, &b).unwrap();
        for i in 0..d {
            let mut ax = 0.0;
            for j in 0..d {
                ax += a_copy[i * d + j] * x[j];
            }
            assert!((ax - b[i]).abs() < 1e-9, "row {i}: {ax} vs {}", b[i]);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = SymMatrix::zeros(2);
        a.add_at(0, 0, 1.0);
        a.add_at(1, 1, -1.0);
        assert!(a.cholesky().is_err());
    }

    #[test]
    fn rank1_symmetry() {
        let mut a = SymMatrix::zeros(3);
        a.rank1(2.0, &[1.0, 2.0, 0.5]);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(a.at(i, j), a.at(j, i));
            }
        }
        assert!((a.at(0, 1) - 4.0).abs() < 1e-12);
    }
}
