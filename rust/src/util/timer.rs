//! Wall-clock timing with named sections, used by the metrics layer and the
//! bench harness (we avoid external bench crates; the offline toolchain only
//! carries the `xla` closure).

use std::time::Instant;

/// A simple monotonic stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    /// Seconds since `start()`.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds since `start()`.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Run `f` repeatedly: `warmup` throwaway iterations, then `iters` timed
/// ones; returns per-iteration seconds. The measurement loop consumes the
/// return value through `std::hint::black_box` so the work is not dead-code
/// eliminated.
pub fn bench_fn<T, F: FnMut() -> T>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        std::hint::black_box(f());
        samples.push(t.elapsed_s());
    }
    samples
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn bench_fn_counts() {
        let samples = bench_fn(2, 5, || 1 + 1);
        assert_eq!(samples.len(), 5);
        assert!(samples.iter().all(|&s| s >= 0.0));
    }
}
