//! `AtomicF64` — f64 over `AtomicU64` bit-casts.
//!
//! The "wild" solver (Algorithm 1) updates the shared vector with plain
//! unsynchronized read-modify-writes. In rust a genuine data race is UB, so
//! we express the same *semantics* with relaxed atomics:
//!
//! * [`AtomicF64::add_wild`] — `store(load() + x)` as two independent
//!   relaxed operations. Concurrent `add_wild`s can lose updates exactly
//!   like the paper's unsynchronized `ADD(v_i, δ·A_ij)` — this is the
//!   faithful "wild" primitive, with defined behaviour.
//! * [`AtomicF64::fetch_add`] — CAS loop, never loses updates; used as the
//!   "locked/atomic" comparison point in ablations.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    pub fn new(v: f64) -> Self {
        AtomicF64(AtomicU64::new(v.to_bits()))
    }

    #[inline]
    pub fn load(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    #[inline]
    pub fn store(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Unsynchronized read-modify-write: concurrent callers may lose
    /// updates (the paper's "opportunistic, wild" shared-vector update).
    #[inline]
    pub fn add_wild(&self, x: f64) {
        self.store(self.load() + x);
    }

    /// Lock-free exact accumulate (CAS loop).
    #[inline]
    pub fn fetch_add(&self, x: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + x).to_bits();
            match self
                .0
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }
}

/// [`AtomicF64`] padded out to its own 64-byte cache line.
///
/// The wild solver's shared vector `v` is hammered by unsynchronized
/// read-modify-writes from every thread; with plain 8-byte elements,
/// eight *distinct* coordinates share one line and every `add_wild`
/// ping-pongs that line between cores (false sharing) even when no two
/// threads touch the same coordinate. One element per line removes the
/// coherence traffic for distinct-coordinate updates.
///
/// Only `v` pays for this: the `α` arrays deliberately stay compact
/// `AtomicF64`s — the bucket optimization *wants* eight `α` slots per
/// fetched line (see [`crate::solver::bucket`]).
///
/// The trade-off: padding multiplies `v`'s footprint (and the lines a
/// full-vector margin dot streams) by 8 — it buys write-coherence relief
/// at the price of read amplification, which side wins depends on thread
/// count and `d` (the ROADMAP tracks measuring it on real hardware).
/// Wild is a *baseline* the paper argues against, so its absolute speed
/// is not on any critical path.
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct PaddedAtomicF64(AtomicF64);

impl PaddedAtomicF64 {
    pub fn new(v: f64) -> Self {
        PaddedAtomicF64(AtomicF64::new(v))
    }
}

impl std::ops::Deref for PaddedAtomicF64 {
    type Target = AtomicF64;

    #[inline]
    fn deref(&self) -> &AtomicF64 {
        &self.0
    }
}

/// Allocate a zeroed atomic vector.
pub fn atomic_vec(n: usize) -> Vec<AtomicF64> {
    (0..n).map(|_| AtomicF64::new(0.0)).collect()
}

/// Allocate a zeroed cache-line-padded atomic vector (one element per
/// 64-byte line — the wild shared vector's false-sharing fix).
pub fn padded_atomic_vec(n: usize) -> Vec<PaddedAtomicF64> {
    (0..n).map(|_| PaddedAtomicF64::new(0.0)).collect()
}

/// Snapshot an atomic vector into plain f64s.
pub fn snapshot(v: &[AtomicF64]) -> Vec<f64> {
    v.iter().map(|x| x.load()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let a = AtomicF64::new(1.5);
        assert_eq!(a.load(), 1.5);
        a.store(-2.25);
        assert_eq!(a.load(), -2.25);
    }

    #[test]
    fn adds() {
        let a = AtomicF64::new(1.0);
        a.add_wild(2.0);
        a.fetch_add(3.0);
        assert_eq!(a.load(), 6.0);
    }

    #[test]
    fn fetch_add_exact_under_contention() {
        let a = AtomicF64::new(0.0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        a.fetch_add(1.0);
                    }
                });
            }
        });
        assert_eq!(a.load(), 4000.0);
    }

    #[test]
    fn snapshot_copies() {
        let v = atomic_vec(3);
        v[1].store(7.0);
        assert_eq!(snapshot(&v), vec![0.0, 7.0, 0.0]);
    }

    #[test]
    fn padded_is_one_element_per_line() {
        assert_eq!(std::mem::size_of::<PaddedAtomicF64>(), 64);
        assert_eq!(std::mem::align_of::<PaddedAtomicF64>(), 64);
        let v = padded_atomic_vec(3);
        let base = v.as_ptr() as usize;
        assert_eq!(base % 64, 0);
        assert_eq!(&v[1] as *const _ as usize - base, 64);
        v[2].store(1.5);
        v[2].add_wild(0.5); // Deref: the AtomicF64 API carries over
        v[2].fetch_add(1.0);
        assert_eq!(v[2].load(), 3.0);
        assert_eq!(v[0].load(), 0.0);
    }
}
