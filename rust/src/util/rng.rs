//! Deterministic pseudo-random number generation.
//!
//! The paper's solvers shuffle example (or bucket) indices every epoch; the
//! shuffle itself shows up as a measurable bottleneck (Fig. 2a), so the
//! generator must be cheap. We use `xoshiro256**` — a few ns per draw, good
//! statistical quality, trivially seedable, no dependencies — and splittable
//! streams so each (virtual) thread owns an independent generator.

/// `xoshiro256**` PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed using the SplitMix64 expansion
    /// (recommended by the xoshiro authors so that low-entropy seeds such as
    /// 0, 1, 2… still yield well-mixed states).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream for worker `i` (thread / node / virtual
    /// thread). Streams from different `i` are statistically independent.
    pub fn split(&self, i: u64) -> Rng {
        // Mix the child index into a fresh SplitMix64 chain seeded from the
        // parent state so children never collide with the parent sequence.
        let mix = self.s[0]
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(i.wrapping_mul(0xD1B54A32D192ED03))
            ^ self.s[2].rotate_left(17);
        Rng::new(mix)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection —
    /// unbiased and avoids the modulo in the shuffle hot loop.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (used by the synthetic generators).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-300 {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// In-place Fisher–Yates shuffle. This is the `RANDOMPERMUTATION` of
    /// Algorithm 1; with the bucket optimization it runs over `n / bucket`
    /// indices instead of `n`.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (floyd's algorithm is not
    /// needed at our sizes; partial Fisher–Yates over a scratch vec).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_independent() {
        let root = Rng::new(42);
        let mut c0 = root.split(0);
        let mut c1 = root.split(1);
        let same = (0..64).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn next_f64_unit_interval_mean() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..1000).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(xs, (0..1000).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn shuffle_uniformity_rough() {
        // Position of element 0 after shuffle should be ~uniform.
        let mut counts = [0usize; 8];
        for seed in 0..4000 {
            let mut r = Rng::new(seed);
            let mut xs: Vec<usize> = (0..8).collect();
            r.shuffle(&mut xs);
            let pos = xs.iter().position(|&x| x == 0).unwrap();
            counts[pos] += 1;
        }
        for &c in &counts {
            assert!(c > 350 && c < 650, "counts={counts:?}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
    }
}
