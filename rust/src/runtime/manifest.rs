//! Parser for `artifacts/manifest.json` written by `python/compile/aot.py`.
//!
//! The manifest records every artifact's input/output shapes + dtypes so
//! the runtime can validate buffers before handing them to PJRT. The
//! offline toolchain carries no serde, so this is a minimal recursive-
//! descent JSON reader specialized to (but validating of) the manifest's
//! actual schema.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Shape+dtype of one tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One artifact's interface.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct ArtifactSpec {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// name → spec for every artifact in the manifest.
pub type Manifest = BTreeMap<String, ArtifactSpec>;

pub fn parse_manifest(text: &str) -> Result<Manifest> {
    let v = Json::parse(text)?;
    let obj = v.as_object().ok_or_else(|| anyhow!("manifest root must be an object"))?;
    let mut out = Manifest::new();
    for (name, spec) in obj {
        let spec_obj = spec
            .as_object()
            .ok_or_else(|| anyhow!("artifact {name} must be an object"))?;
        let mut art = ArtifactSpec::default();
        for (key, target) in [("inputs", &mut art.inputs), ("outputs", &mut art.outputs)] {
            let arr = spec_obj
                .get(key)
                .and_then(Json::as_array)
                .ok_or_else(|| anyhow!("artifact {name} missing '{key}' array"))?;
            for t in arr {
                let t = t.as_object().ok_or_else(|| anyhow!("{name}.{key}: bad tensor"))?;
                let shape = t
                    .get("shape")
                    .and_then(Json::as_array)
                    .ok_or_else(|| anyhow!("{name}.{key}: missing shape"))?
                    .iter()
                    .map(|d| {
                        d.as_f64()
                            .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                            .map(|x| x as usize)
                            .ok_or_else(|| anyhow!("{name}.{key}: bad dim"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                let dtype = t
                    .get("dtype")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}.{key}: missing dtype"))?
                    .to_string();
                target.push(TensorSpec { shape, dtype });
            }
        }
        out.insert(name.clone(), art);
    }
    Ok(out)
}

// ------------------------------------------------------------------ JSON

/// Minimal JSON value (enough for the manifest schema).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let bytes = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at byte {pos}");
        }
        Ok(v)
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&Vec<Json>> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => bail!("unexpected end of input"),
        Some(b'{') => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    _ => bail!("object key must be a string (byte {pos})"),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    bail!("expected ':' at byte {pos}");
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                m.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => bail!("expected ',' or '}}' at byte {pos}"),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut a = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(a));
            }
            loop {
                a.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(a));
                    }
                    _ => bail!("expected ',' or ']' at byte {pos}"),
                }
            }
        }
        Some(b'"') => {
            *pos += 1;
            let mut s = String::new();
            loop {
                match b.get(*pos) {
                    None => bail!("unterminated string"),
                    Some(b'"') => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    Some(b'\\') => {
                        *pos += 1;
                        match b.get(*pos) {
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'/') => s.push('/'),
                            Some(b'n') => s.push('\n'),
                            Some(b't') => s.push('\t'),
                            Some(b'r') => s.push('\r'),
                            Some(b'u') => {
                                let hex = b
                                    .get(*pos + 1..*pos + 5)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                                let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                                s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                                *pos += 4;
                            }
                            other => bail!("bad escape {:?}", other),
                        }
                        *pos += 1;
                    }
                    Some(&c) => {
                        // manifest content is ASCII-ish; pass UTF-8 through
                        let start = *pos;
                        let width = utf8_width(c);
                        let chunk = b
                            .get(start..start + width)
                            .ok_or_else(|| anyhow!("truncated utf8"))?;
                        s.push_str(std::str::from_utf8(chunk)?);
                        *pos += width;
                    }
                }
            }
        }
        Some(b't') => {
            expect(b, pos, b"true")?;
            Ok(Json::Bool(true))
        }
        Some(b'f') => {
            expect(b, pos, b"false")?;
            Ok(Json::Bool(false))
        }
        Some(b'n') => {
            expect(b, pos, b"null")?;
            Ok(Json::Null)
        }
        Some(_) => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let txt = std::str::from_utf8(&b[start..*pos])?;
            Ok(Json::Num(txt.parse::<f64>().map_err(|_| anyhow!("bad number '{txt}'"))?))
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &[u8]) -> Result<()> {
    if b.get(*pos..*pos + lit.len()) == Some(lit) {
        *pos += lit.len();
        Ok(())
    } else {
        bail!("expected {:?} at byte {pos}", std::str::from_utf8(lit));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_schema() {
        let text = r#"{
          "eval_tile": {
            "inputs": [{"shape": [256, 128], "dtype": "float32"},
                       {"shape": [256], "dtype": "float32"}],
            "outputs": [{"shape": [3], "dtype": "float32"}]
          }
        }"#;
        let m = parse_manifest(text).unwrap();
        let spec = &m["eval_tile"];
        assert_eq!(spec.inputs.len(), 2);
        assert_eq!(spec.inputs[0].shape, vec![256, 128]);
        assert_eq!(spec.inputs[0].element_count(), 256 * 128);
        assert_eq!(spec.outputs[0].dtype, "float32");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_manifest("{").is_err());
        assert!(parse_manifest(r#"{"a": }"#).is_err());
        assert!(parse_manifest(r#"[1,2]"#).is_err()); // root must be object
        assert!(Json::parse(r#"{"a":1} trailing"#).is_err());
    }

    #[test]
    fn json_scalars() {
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn json_nesting() {
        let v = Json::parse(r#"{"a": [1, {"b": []}], "c": ""}"#).unwrap();
        let o = v.as_object().unwrap();
        assert_eq!(o["a"].as_array().unwrap().len(), 2);
        assert_eq!(o["c"].as_str().unwrap(), "");
    }

    #[test]
    fn scalar_spec_element_count() {
        let t = TensorSpec { shape: vec![], dtype: "float32".into() };
        assert_eq!(t.element_count(), 1);
    }
}
