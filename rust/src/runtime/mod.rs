//! PJRT runtime: load the AOT HLO artifacts and execute them from the rust
//! training path — Python never runs at training time.
//!
//! * [`manifest`] — parse `artifacts/manifest.json` (shapes/dtypes).
//! * [`ArtifactRuntime`] — PJRT CPU client + one compiled executable per
//!   artifact, compiled once at startup, shape-checked against the
//!   manifest on every call.
//! * [`evaluator`] — the tiled evaluator composing fixed-shape artifacts
//!   over arbitrary datasets (loss/accuracy/gradient of any `(n, d)`).
//! * [`hlo_trainer`] — SDCA trainer whose bucket update runs through the
//!   `bucket_step` artifact (the end-to-end L1→L3 composition demo).

pub mod evaluator;
pub mod hlo_trainer;
pub mod manifest;

pub use evaluator::TiledEvaluator;
pub use manifest::{parse_manifest, ArtifactSpec, Manifest, TensorSpec};

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Canonical AOT tile shapes — must match
/// `python/compile/kernels/sdca_kernels.py` (validated against the
/// manifest at load time).
pub const TILE_M: usize = 256;
pub const TILE_D: usize = 128;
pub const BUCKET_B: usize = 8;

/// A loaded-and-compiled artifact.
pub struct Artifact {
    pub name: String,
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with f32 input buffers (shape-checked against the
    /// manifest); returns the decomposed output tuple as f32 vectors.
    pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&self.spec.inputs) {
            if buf.len() != spec.element_count() {
                bail!(
                    "{}: input length {} != manifest element count {} (shape {:?})",
                    self.name,
                    buf.len(),
                    spec.element_count(),
                    spec.shape
                );
            }
            let lit = xla::Literal::vec1(buf);
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(if dims.len() <= 1 {
                lit
            } else {
                lit.reshape(&dims)
                    .with_context(|| format!("{}: reshape to {:?}", self.name, spec.shape))?
            });
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("{}: execute", self.name))?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: executable returned {} outputs, manifest says {}",
                self.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}

/// The runtime: a PJRT CPU client plus every compiled artifact.
pub struct ArtifactRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    artifacts: BTreeMap<String, Artifact>,
    dir: PathBuf,
}

impl ArtifactRuntime {
    /// Load every artifact listed in `<dir>/manifest.json`, compiling each
    /// HLO text module on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {} (run `make artifacts`)", manifest_path.display()))?;
        let manifest = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in manifest {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("{name}: parse HLO text: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("{name}: compile: {e:?}"))?;
            artifacts.insert(name.clone(), Artifact { name, spec, exe });
        }
        Ok(ArtifactRuntime {
            client,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Load from the conventional `artifacts/` directory (the Makefile's
    /// output location).
    pub fn load_default() -> Result<Self> {
        Self::load(Path::new("artifacts"))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest ({:?})", self.names()))
    }

    /// Sanity check: the canonical tile constants baked into the rust
    /// evaluator must agree with what the python side compiled.
    pub fn validate_tiles(&self) -> Result<()> {
        let eval = self.get("eval_tile")?;
        let shape = &eval.spec.inputs[0].shape;
        if shape != &[TILE_M, TILE_D] {
            bail!(
                "eval_tile compiled for {shape:?}, runtime expects [{TILE_M}, {TILE_D}] — \
                 rebuild artifacts"
            );
        }
        let bucket = self.get("bucket_step")?;
        if bucket.spec.inputs[0].shape != [BUCKET_B, TILE_D] {
            bail!(
                "bucket_step shape mismatch: {:?}",
                bucket.spec.inputs[0].shape
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // Integration tests against real artifacts live in
    // rust/tests/runtime_integration.rs (they need `make artifacts`).
    use super::*;

    #[test]
    fn missing_dir_is_clean_error() {
        let err = match ArtifactRuntime::load(Path::new("/nonexistent/dir")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
