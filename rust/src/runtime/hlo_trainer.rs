//! SDCA trainer whose inner bucket update executes the AOT `bucket_step`
//! artifact — the end-to-end composition proof for the three-layer stack:
//! rust coordinator (epochs, shuffling, convergence) → L2 JAX graph →
//! L1 Pallas kernel, all through one compiled HLO executable.
//!
//! This path is compiled for dense data with `d ≤ TILE_D` (the paper's
//! synthetic dense workload, 100 features, fits with padding) and exists
//! to *validate the stack*, not to beat the native hot loop: each bucket
//! costs a PJRT dispatch, which is exactly the kind of per-coordinate
//! overhead the paper's CPU-native design avoids. `examples/e2e_train.rs`
//! runs it on the paper's Fig. 1 workload and logs the loss curve.

use super::{ArtifactRuntime, BUCKET_B, TILE_D};
use crate::data::{Dataset, DenseMatrix};
use crate::glm::{ModelState, Objective};
use crate::metrics::{EpochStats, RunRecord};
use crate::solver::{ConvergenceMonitor, SolverConfig, TrainOutput};
use crate::util::{Rng, Timer};
use anyhow::{bail, Result};

/// Train logistic regression with the HLO-backed bucket kernel.
pub fn train_hlo_bucketed(
    rt: &ArtifactRuntime,
    ds: &Dataset<DenseMatrix>,
    cfg: &SolverConfig,
) -> Result<TrainOutput> {
    let n = ds.n();
    let d = ds.d();
    if d > TILE_D {
        bail!("bucket_step artifact is compiled for d ≤ {TILE_D} (got {d})");
    }
    if !matches!(cfg.obj, Objective::Logistic { .. }) {
        bail!("bucket_step artifact implements the logistic objective");
    }
    rt.validate_tiles()?;
    let bucket_art = rt.get("bucket_step")?;
    let lambda = cfg.obj.lambda();
    let inv_lambda_n = 1.0 / (lambda * n as f64);

    // pre-pack every bucket's X tile (B × TILE_D, zero-padded), labels and
    // norms once; α and v flow through f32 buffers per call
    let n_buckets = n.div_ceil(BUCKET_B);
    let mut x_bufs = Vec::with_capacity(n_buckets);
    let mut y_bufs = Vec::with_capacity(n_buckets);
    let mut nsq_bufs = Vec::with_capacity(n_buckets);
    for b in 0..n_buckets {
        let lo = b * BUCKET_B;
        let hi = ((b + 1) * BUCKET_B).min(n);
        let mut x = vec![0.0f32; BUCKET_B * TILE_D];
        let mut y = vec![1.0f32; BUCKET_B]; // label of padded rows is inert (nsq=0)
        let mut nsq = vec![0.0f32; BUCKET_B];
        for (r, j) in (lo..hi).enumerate() {
            for (k, &value) in ds.x.col(j).iter().enumerate() {
                x[r * TILE_D + k] = value as f32;
            }
            y[r] = ds.y[j] as f32;
            nsq[r] = ds.norm_sq(j) as f32;
        }
        x_bufs.push(x);
        y_bufs.push(y);
        nsq_bufs.push(nsq);
    }
    let scalars: Vec<f32> = vec![
        inv_lambda_n as f32,
        n as f32, // n_eff = n (single worker ⇒ σ′ = 1)
        1.0,
        n as f32,
    ];

    let mut alpha = vec![0.0f64; n];
    let mut v32 = vec![0.0f32; TILE_D];
    let mut ids: Vec<u32> = (0..n_buckets as u32).collect();
    let mut rng = Rng::new(cfg.seed);
    let mut mon = ConvergenceMonitor::new(n, cfg.tol, cfg.divergence_factor);

    let total = Timer::start();
    let mut epochs = Vec::new();
    let mut converged = false;
    for epoch in 1..=cfg.max_epochs {
        let t = Timer::start();
        rng.shuffle(&mut ids);
        for &b in &ids {
            let b = b as usize;
            let lo = b * BUCKET_B;
            let hi = ((b + 1) * BUCKET_B).min(n);
            let mut a_buf = vec![0.0f32; BUCKET_B];
            for (r, j) in (lo..hi).enumerate() {
                a_buf[r] = alpha[j] as f32;
            }
            let out = bucket_art.run(&[
                &x_bufs[b],
                &y_bufs[b],
                &a_buf,
                &nsq_bufs[b],
                &v32,
                &scalars,
            ])?;
            for (r, j) in (lo..hi).enumerate() {
                alpha[j] = out[0][r] as f64;
            }
            v32.copy_from_slice(&out[1]);
        }
        let rel = mon.observe(&alpha);
        epochs.push(EpochStats {
            epoch,
            wall_s: t.elapsed_s(),
            rel_change: rel,
            gap: None,
            primal: None,
        });
        if mon.converged() {
            converged = true;
            break;
        }
    }

    // exact f64 model from the learned duals
    let mut st = ModelState {
        alpha,
        v: vec![0.0; d],
    };
    st.rebuild_v(ds);
    let record = RunRecord {
        solver: "hlo-bucket".into(),
        threads: 1,
        epochs,
        converged,
        diverged: false,
        total_wall_s: total.elapsed_s(),
    };
    Ok(TrainOutput::assemble(ds, &cfg.obj, st, record))
}
