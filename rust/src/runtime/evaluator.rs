//! Tiled evaluator: full-dataset loss / accuracy / gradient through the
//! AOT artifacts.
//!
//! The artifacts are compiled for a fixed `(TILE_M, TILE_D)` tile. The
//! evaluator decomposes an arbitrary `(n, d)` dense dataset:
//!
//! * example dimension: ceil(n / TILE_M) tiles, last one zero-padded with a
//!   `mask` that removes the padding from every reduction;
//! * feature dimension: for `d ≤ TILE_D` the fused `eval_tile`/`grad_tile`
//!   artifacts run directly; for `d > TILE_D` the margins are accumulated
//!   with `matvec_tile` per feature tile and finished with `loss_tile`
//!   (margin additivity: `z = Σ_t X[:, t·128:(t+1)·128] · w_tile`).
//!
//! Example tiles are gathered and padded **once** at construction — the
//! per-call work is only the `w` buffers and the PJRT executions.

use super::{Artifact, ArtifactRuntime, TILE_D, TILE_M};
use crate::data::{Dataset, DenseMatrix};
use anyhow::Result;

/// Metrics accumulated over all tiles.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalMetrics {
    /// Mean loss over the (unmasked) examples.
    pub mean_loss: f64,
    /// Classification accuracy.
    pub accuracy: f64,
    /// Number of examples evaluated.
    pub count: usize,
}

/// Pre-tiled view of a dense dataset's selected examples.
pub struct TiledEvaluator<'rt> {
    rt: &'rt ArtifactRuntime,
    /// Row-major f32 tiles: each `TILE_M × (feat_tiles · TILE_D)`, laid out
    /// as `feat_tiles` contiguous `TILE_M × TILE_D` blocks.
    x_tiles: Vec<Vec<f32>>,
    y_tiles: Vec<Vec<f32>>,
    mask_tiles: Vec<Vec<f32>>,
    d: usize,
    feat_tiles: usize,
    count: usize,
}

impl<'rt> TiledEvaluator<'rt> {
    /// Gather + pad the examples `idx` of a dense dataset into tiles.
    pub fn new(rt: &'rt ArtifactRuntime, ds: &Dataset<DenseMatrix>, idx: &[usize]) -> Result<Self> {
        rt.validate_tiles()?;
        let d = ds.d();
        let feat_tiles = d.div_ceil(TILE_D).max(1);
        let n_tiles = idx.len().div_ceil(TILE_M).max(1);
        let mut x_tiles = Vec::with_capacity(n_tiles);
        let mut y_tiles = Vec::with_capacity(n_tiles);
        let mut mask_tiles = Vec::with_capacity(n_tiles);
        for t in 0..n_tiles {
            let rows = &idx[t * TILE_M..((t + 1) * TILE_M).min(idx.len())];
            // feature-tile-major layout: block ft holds the TILE_D-wide
            // slice of every row (zero-padded), each a ready PJRT buffer.
            let mut x = vec![0.0f32; feat_tiles * TILE_M * TILE_D];
            let mut y = vec![0.0f32; TILE_M];
            let mut mask = vec![0.0f32; TILE_M];
            for (r, &j) in rows.iter().enumerate() {
                let col = ds.x.col(j);
                for ft in 0..feat_tiles {
                    let base = ft * TILE_M * TILE_D + r * TILE_D;
                    let lo = ft * TILE_D;
                    let hi = ((ft + 1) * TILE_D).min(d);
                    for (k, &value) in col[lo..hi].iter().enumerate() {
                        x[base + k] = value as f32;
                    }
                }
                y[r] = ds.y[j] as f32;
                mask[r] = 1.0;
            }
            x_tiles.push(x);
            y_tiles.push(y);
            mask_tiles.push(mask);
        }
        Ok(TiledEvaluator {
            rt,
            x_tiles,
            y_tiles,
            mask_tiles,
            d,
            feat_tiles,
            count: idx.len(),
        })
    }

    fn w_tiles(&self, w: &[f64]) -> Vec<Vec<f32>> {
        (0..self.feat_tiles)
            .map(|ft| {
                let mut buf = vec![0.0f32; TILE_D];
                let lo = ft * TILE_D;
                let hi = ((ft + 1) * TILE_D).min(self.d);
                for (k, &value) in w[lo..hi].iter().enumerate() {
                    buf[k] = value as f32;
                }
                buf
            })
            .collect()
    }

    fn x_block<'a>(&'a self, tile: usize, ft: usize) -> &'a [f32] {
        let base = ft * TILE_M * TILE_D;
        &self.x_tiles[tile][base..base + TILE_M * TILE_D]
    }

    /// Logistic loss + accuracy of `w` over the tiled examples.
    pub fn eval(&self, w: &[f64]) -> Result<EvalMetrics> {
        assert_eq!(w.len(), self.d);
        let w_tiles = self.w_tiles(w);
        let (mut loss, mut correct, mut count) = (0.0f64, 0.0f64, 0.0f64);
        if self.feat_tiles == 1 {
            let eval: &Artifact = self.rt.get("eval_tile")?;
            for t in 0..self.x_tiles.len() {
                let out = eval.run(&[
                    self.x_block(t, 0),
                    &self.y_tiles[t],
                    &self.mask_tiles[t],
                    &w_tiles[0],
                ])?;
                loss += out[0][0] as f64;
                correct += out[0][1] as f64;
                count += out[0][2] as f64;
            }
        } else {
            let matvec = self.rt.get("matvec_tile")?;
            let loss_art = self.rt.get("loss_tile")?;
            for t in 0..self.x_tiles.len() {
                let mut z = vec![0.0f32; TILE_M];
                for (ft, w_tile) in w_tiles.iter().enumerate() {
                    let out = matvec.run(&[self.x_block(t, ft), w_tile])?;
                    for (zi, p) in z.iter_mut().zip(&out[0]) {
                        *zi += p;
                    }
                }
                let out = loss_art.run(&[&z, &self.y_tiles[t], &self.mask_tiles[t]])?;
                loss += out[0][0] as f64;
                correct += out[0][1] as f64;
                count += out[0][2] as f64;
            }
        }
        Ok(EvalMetrics {
            mean_loss: if count > 0.0 { loss / count } else { 0.0 },
            accuracy: if count > 0.0 { correct / count } else { 0.0 },
            count: count as usize,
        })
    }

    /// Full logistic gradient `∇P(w) = (1/n)Σ ∇ℓ + λw` over the tiled
    /// examples (for the HLO-backed L-BFGS baseline), plus the mean loss.
    pub fn grad(&self, w: &[f64], lambda: f64) -> Result<(Vec<f64>, f64)> {
        assert_eq!(w.len(), self.d);
        assert_eq!(
            self.feat_tiles, 1,
            "grad path is compiled for d ≤ TILE_D (use the rust-native baseline beyond)"
        );
        let w_tiles = self.w_tiles(w);
        let grad_art = self.rt.get("grad_tile")?;
        let mut g = vec![0.0f64; self.d];
        let mut loss = 0.0f64;
        for t in 0..self.x_tiles.len() {
            let out = grad_art.run(&[
                self.x_block(t, 0),
                &self.y_tiles[t],
                &self.mask_tiles[t],
                &w_tiles[0],
            ])?;
            for (gi, p) in g.iter_mut().zip(&out[0]) {
                *gi += *p as f64;
            }
            loss += out[1][0] as f64;
        }
        let n = self.count.max(1) as f64;
        for (gi, wi) in g.iter_mut().zip(w) {
            *gi = *gi / n + lambda * wi;
        }
        Ok((g, loss / n))
    }

    pub fn count(&self) -> usize {
        self.count
    }
}
