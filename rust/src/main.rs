//! `parlin` — CLI launcher for the training system.
//!
//! ```text
//! parlin train   --dataset <kind|file.libsvm> [--solver auto|seq|wild|dom|numa]
//!                [--threads N] [--lambda X] [--tol X] [--max-epochs N]
//!                [--bucket auto|off|K] [--partition dynamic|static]
//!                [--objective logistic|ridge|hinge] [--seed N] [--csv out.csv]
//!                [--trace out.json] [--metrics-interval S]
//! parlin serve   --dataset <kind|file.libsvm> [--requests <script|synthetic>]
//!                [--count N] [--predict-batch N] [--refit-rows N]
//!                [--arrival-rate R --duration S --arrival-process poisson|fixed
//!                 --open-loop-seed N] [--max-pending K] [train opts]
//! parlin figures [--fig 1|2|3|4|5|6|all] [--quick] [--out DIR]
//! parlin inspect               # host topology, cache geometry, artifacts
//! parlin eval    --dataset <kind> --artifacts DIR   # HLO-path evaluation demo
//! parlin report  --baseline <artifact> --current <artifact> [--threshold X]
//! ```
//!
//! Telemetry flags shared by `train` and `serve`: `--metrics-addr` starts
//! the pull-only `/metrics` exposition endpoint, `--flight-dir` arms the
//! degradation flight recorder, `--convergence-log` (train) and
//! `--bench-json` (serve) persist run artifacts that `parlin report` can
//! diff against a committed baseline.
//!
//! The argument parser is hand-rolled: the offline toolchain ships only the
//! `xla` crate closure (no clap). Both `--flag value` and `--flag=value`
//! are accepted.

use anyhow::{anyhow, bail, Context, Result};
use parlin::data::{loader, AnyDataset};
use parlin::fault::FaultPlan;
use parlin::figures::{run_figure, DsKind, FigOpts};
use parlin::glm::Objective;
use parlin::obs::{
    ExportServer, ExportSources, MetricsTicker, ObsConfig, TraceSession, DEFAULT_RING_CAPACITY,
};
use parlin::report::BenchRecord;
use parlin::serve::{ArrivalProcess, ServeHealth};
use parlin::solver::{
    train, BucketPolicy, ExecPolicy, LayoutPolicy, Partitioning, SolverConfig, TunePolicy,
    Variant,
};
use parlin::sysinfo::Topology;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("train") => cmd_train(&parse_flags(&args[1..])?),
        Some("serve") => cmd_serve(&parse_flags(&args[1..])?),
        Some("figures") => cmd_figures(&parse_flags(&args[1..])?),
        Some("inspect") => cmd_inspect(),
        Some("eval") => cmd_eval(&parse_flags(&args[1..])?),
        Some("report") => cmd_report(&parse_flags(&args[1..])?),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

const USAGE: &str = "\
parlin — parallel GLM training (SDCA) without compromising convergence

USAGE:
  parlin train --dataset <kind|file.libsvm> [options]
  parlin serve --dataset <kind|file.libsvm> [--requests <script|synthetic>] [options]
  parlin figures [--fig 1|2|3|4|5|6|all] [--quick] [--out DIR]
  parlin inspect
  parlin eval --dataset <kind> [--artifacts DIR]
  parlin report --baseline <artifact> --current <artifact> [--threshold X]

Flags accept both `--flag value` and `--flag=value`.

TRAIN OPTIONS:
  --dataset     dense-synth | sparse-synth | higgs-like | epsilon-like |
                criteo-like | path to a LIBSVM file
  --solver      auto | seq | wild | dom | numa        (default auto)
  --threads     worker threads                        (default 1)
  --objective   logistic | ridge | hinge              (default logistic)
  --lambda      L2 regularization                     (default 1/n)
  --tol         relative-model-change stop            (default 1e-3)
  --max-epochs  epoch cap                             (default 200)
  --bucket      auto | off | <size>                   (default auto)
  --partition   dynamic | static                      (default dynamic)
  --exec        pool | threads | seq                  (default pool)
  --layout      interleaved | csc                     (default interleaved)
                interleaved streams the shard-resident fused-kernel
                layout; csc walks the source matrix (bit-wise identical
                models either way)
  --n / --d     synthetic dataset size overrides
  --seed        RNG seed                              (default 42)
  --csv         write the per-epoch log to a CSV file
  --tune        off | on | on:<seed>                  (default off)
                online auto-tuner for bucket size, layout and worker
                count; `off` keeps every run bit-wise identical to the
                untuned solver, `on` seeds the tuner from --seed
  --tune-log    write the tuner's decision log (replayable CSV) to this
                path; requires --tune on              (train only)

OBSERVABILITY OPTIONS (train and serve):
  --trace             record per-thread event rings for the whole run and
                      write chrome://tracing JSON to this path (open it at
                      chrome://tracing or ui.perfetto.dev)
  --metrics-interval  print a metrics-registry snapshot table to stderr
                      every S seconds while the run is live (S finite, > 0)
  --metrics-addr      bind a pull-only exposition endpoint on HOST:PORT
                      (port 0 picks a free one; the bound address is
                      printed to stderr). Routes: /metrics (Prometheus
                      text), /health (200 Healthy / 503 Degraded; live
                      scheduler health in the concurrent and open-loop
                      serve modes, permanently Healthy otherwise),
                      /trace (live chrome://tracing JSON, 404 without a
                      tracing session)
  --flight-dir        arm the degradation flight recorder: every health
                      degradation, snapshot rollback or drain-watchdog
                      stall dumps the last 30s of trace events plus a
                      metrics delta into this directory (starts a tracing
                      session even without --trace)
  --convergence-log   write the solver's per-epoch convergence trace
                      (epoch, wall clock, rel-change, duality gap, worker
                      imbalance) as CSV                       (train only)
  --bench-json        write the run's headline numbers (throughput,
                      p50/p99, gap, wall, final health) as a bench-record
                      JSON artifact for `parlin report`       (serve only)

SERVE OPTIONS (plus the train options above):
  --requests       'synthetic' or a request-script path   (default synthetic)
                   script lines: predict K | refit-rows K |
                   refit-lambda X | retrain   (# comments allowed)
  --count          synthetic request count               (default 200)
  --predict-batch  examples per synthetic predict        (default 256)
  --refit-rows     rows per synthetic refit              (default 32)
  One resident Session (dataset + model + worker pool) answers every
  request: predicts run as NUMA-sharded parallel margins, refits
  warm-start from the current model, retrains reuse the same pool.
  Output: per-kind p50/p99 latency, throughput and per-worker busy time.

CONCURRENT SERVE OPTIONS (scheduler mode, enabled by --concurrency > 1):
  --concurrency          concurrent predict reader threads      (default 1)
  --refit-rows-threshold staged rows that trigger a background
                         refit                                  (default 64)
  --refit-staleness      seconds staged rows may wait before a
                         refit is forced (the deadline is
                         checked on the request path, so it
                         needs ongoing traffic to fire)         (default 0.25)
  A request scheduler serves --count predicts from --concurrency readers
  against immutable versioned model snapshots while an append stream
  (--count/10 bursts of --refit-rows rows) feeds staged ingestion;
  refits run in the background and publish new versions atomically.
  Request scripts (--requests <path>) are single-request mode only.
  Output: per-version p50/p99 predict latency, snapshot-age distribution,
  and how many predicts overlapped an in-flight refit.

OPEN-LOOP SERVE OPTIONS (open-loop mode, enabled by --arrival-rate):
  --arrival-rate     offered load in requests/second; arrivals follow a
                     pre-generated seeded schedule, independent of how
                     fast the system serves (must be finite and positive)
  --duration         schedule length in seconds             (default 2.0)
  --arrival-process  poisson | fixed inter-arrival gaps (default poisson)
  --open-loop-seed   arrival-schedule seed              (default --seed)
  --max-pending      admission budget: max predict readers in flight;
                     arrivals beyond it are shed and counted, must be
                     >= 1 when given                  (default unbounded)
  Latency is measured from each request's *scheduled* arrival, so
  queueing delay is part of every percentile — the saturation knee a
  closed loop cannot see. ~2% of arrivals are ingestion bursts of
  --refit-rows rows; --concurrency sets the dispatcher thread count in
  this mode (default 8). Request scripts are single-request mode only.
  Output: offered vs achieved rate, per-kind p50/p99/max latency from
  scheduled arrival, shed count and per-class pool queue delay.
  (--max-pending parses in every serve mode, but only the open loop's
  try_predict admission path sheds on it.)

ROBUSTNESS OPTIONS (serve, scheduler modes):
  --drain-retries    background drain attempts after the first failure,
                     with exponential backoff between attempts (0 means
                     fail fast)                               (default 2)
  --drain-stall      seconds without a drain heartbeat before the run is
                     flagged Degraded as stuck                (default 30)
  --dead-letter-rows bound on quarantined rows kept after refits are
                     rolled back; oldest batches are evicted  (default 1024)
  --fault-plan       deterministic fault injection, armed only after the
                     session and scheduler are built. Spec: clauses
                     'action@site[#k][xN]' separated by ';' — actions
                     panic | error | nan | delay:<ms>; sites epoch |
                     drain | publish (nan is publish-only); '#k' fires on
                     the k-th hit, 'xN' for N consecutive hits. Example:
                     --fault-plan 'panic@epoch#1x8;nan@publish#2'
  A failed refit never unpublishes the serving model: the last-known-good
  snapshot keeps answering predicts, the offending rows are quarantined,
  and the run is marked Degraded until a later refit publishes cleanly.
  `parlin serve` exits nonzero unless the final health is Healthy.

REPORT OPTIONS:
  --baseline / --current  artifacts to diff: a bench-record JSON
                          (--bench-json), a convergence-trace CSV
                          (--convergence-log) or a per-epoch CSV (--csv);
                          formats are sniffed by content and may be mixed
  --threshold             worseness ratio that fails the diff; must be
                          > 1, e.g. 1.5 means 50% worse     (default 1.5)
  Prints a side-by-side metric table and exits nonzero when any metric
  regressed past the threshold or a healthy baseline turned degraded.
";

/// Flag parser accepting `--key value` and `--key=value` (flags without a
/// value get "true"). The `=` form is what shells and scripts commonly
/// emit; it used to be silently mis-parsed as a flag named `key=value`.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --flag, got '{}'", args[i]))?;
        if let Some((k, v)) = key.split_once('=') {
            if k.is_empty() {
                bail!("empty flag name in '{}'", args[i]);
            }
            map.insert(k.to_string(), v.to_string());
            i += 1;
            continue;
        }
        let has_value = i + 1 < args.len() && !args[i + 1].starts_with("--");
        if has_value {
            map.insert(key.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            map.insert(key.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok(map)
}

fn get_parse<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
    default: T,
) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|e| anyhow!("--{key} {v}: {e}")),
    }
}

/// Parse a count flag that must be ≥ 1 (`--concurrency`,
/// `--refit-rows-threshold`): zero would mean "no readers" / "refit on
/// every arrival" — always a spelling mistake, so reject it at the
/// parser instead of letting the scheduler panic mid-run.
fn get_positive_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> Result<usize> {
    let v = get_parse(flags, key, default)?;
    if v == 0 {
        bail!("--{key} must be >= 1, got 0");
    }
    Ok(v)
}

/// Parse a duration/threshold flag that must be finite and positive
/// (`--refit-staleness`): NaN/∞ would make the staleness trigger never
/// (or always) fire, and a negative budget is meaningless.
fn get_positive_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    let v: f64 = get_parse(flags, key, default)?;
    if !v.is_finite() || v <= 0.0 {
        bail!("--{key} must be finite and positive, got {v}");
    }
    Ok(v)
}

/// Scheduler modes (`--concurrency > 1` closed loop, `--arrival-rate`
/// open loop) drive their own synthetic workloads; a `--requests` script
/// would be silently ignored, so reject the combination loudly instead.
fn check_concurrent_requests_flag(flags: &HashMap<String, String>) -> Result<()> {
    match flags.get("requests").map(String::as_str) {
        None | Some("synthetic") | Some("true") => Ok(()),
        Some(path) => bail!(
            "--concurrency > 1 and --arrival-rate run synthetic scheduler drivers; \
             request scripts are not supported in these modes (got --requests {path})"
        ),
    }
}

/// Parse an optional bounded-budget flag (`--max-pending`): absent means
/// unbounded admission; when given it must be ≥ 1, since a budget of zero
/// would shed every reader — always a spelling mistake.
fn get_optional_positive_usize(
    flags: &HashMap<String, String>,
    key: &str,
) -> Result<Option<usize>> {
    if flags.contains_key(key) {
        Ok(Some(get_positive_usize(flags, key, 1)?))
    } else {
        Ok(None)
    }
}

/// Parse a flag whose value is a path or address: absent is fine, but a
/// bare `--key` (which the flag parser records as "true") or `--key=` is
/// a missing value, not a value named "true".
fn get_path_flag(flags: &HashMap<String, String>, key: &str) -> Result<Option<String>> {
    match flags.get(key).map(String::as_str) {
        None => Ok(None),
        Some("") | Some("true") => bail!("--{key} needs a value (e.g. --{key} <path>)"),
        Some(v) => Ok(Some(v.to_string())),
    }
}

/// Parse `--fault-plan` (deterministic fault injection; grammar on
/// [`FaultPlan::parse`], taxonomy in `docs/ROBUSTNESS.md`). The plan is
/// returned *unarmed*: the serve drivers arm it only after the session
/// and scheduler are built, so the construction-time initial train is
/// never injected.
fn parse_fault_plan(flags: &HashMap<String, String>, seed: u64) -> Result<Option<FaultPlan>> {
    match flags.get("fault-plan").map(String::as_str) {
        None => Ok(None),
        // a bare `--fault-plan` parses to "true"; both it and
        // `--fault-plan=` mean the spec is missing
        Some("") | Some("true") => {
            bail!("--fault-plan needs a spec (e.g. --fault-plan 'panic@epoch#1')")
        }
        Some(spec) => {
            let plan = FaultPlan::parse(spec, seed)
                .map_err(|e| anyhow!("--fault-plan '{spec}': {e}"))?;
            Ok(Some(plan))
        }
    }
}

/// `parlin serve` exits 0 only when the run's final health is Healthy.
/// A rollback the system later recovered from is fine; ending the run
/// degraded (quarantined rows never re-published cleanly, a dead drain
/// thread, a stalled watchdog) must fail scripts and CI, not just leave
/// a line in the report.
fn check_final_health(health: &ServeHealth) -> Result<()> {
    match health {
        ServeHealth::Healthy => Ok(()),
        ServeHealth::Degraded { reason } => bail!("serve finished degraded: {reason}"),
    }
}

/// Late-bound `/health` answer for the exposition endpoint. The endpoint
/// starts before the scheduler exists (binding the port early is what
/// lets CI poll it), so the server holds this slot and the scheduler
/// serve modes bind their live health into it once constructed. Unbound,
/// it answers permanently-Healthy — correct for `train` and the
/// single-request serve mode, which have no live health to report.
#[derive(Clone, Default)]
struct LiveHealth(Arc<Mutex<Option<Arc<dyn Fn() -> (bool, String) + Send + Sync>>>>);

impl LiveHealth {
    fn bind(&self, f: impl Fn() -> (bool, String) + Send + Sync + 'static) {
        *parlin::util::lock_recover(&self.0) = Some(Arc::new(f));
    }

    fn read(&self) -> (bool, String) {
        match parlin::util::lock_recover(&self.0).as_ref() {
            Some(f) => f(),
            None => (true, "Healthy".to_string()),
        }
    }
}

/// The observability flags `train` and `serve` share: `--trace <path>`
/// wraps the whole run in a [`TraceSession`] and writes chrome://tracing
/// JSON when the run finishes; `--metrics-interval <s>` starts a
/// [`MetricsTicker`] that prints a registry snapshot table to stderr every
/// interval; `--metrics-addr <host:port>` binds the pull-only exposition
/// endpoint; `--flight-dir <dir>` arms the degradation flight recorder
/// (and starts a tracing session even without `--trace`, since dumps are
/// drained from the live rings). All default to off, leaving the hot
/// paths on their no-op branch.
struct ObsCli {
    trace_path: Option<String>,
    session: Option<TraceSession>,
    ticker: Option<MetricsTicker>,
    exporter: Option<ExportServer>,
    flight: Option<parlin::obs::flight::FlightGuard>,
    health: LiveHealth,
}

impl ObsCli {
    /// Validate the flags and start whatever they ask for.
    fn start(flags: &HashMap<String, String>) -> Result<ObsCli> {
        let trace_path = match flags.get("trace").map(String::as_str) {
            None => None,
            // a bare `--trace` parses to "true"; both it and `--trace=`
            // mean the path is missing
            Some("") | Some("true") => {
                bail!("--trace needs an output path (e.g. --trace trace.json)")
            }
            Some(p) => Some(p.to_string()),
        };
        let flight_dir = get_path_flag(flags, "flight-dir")?;
        let metrics_addr = get_path_flag(flags, "metrics-addr")?;
        let ticker = if flags.contains_key("metrics-interval") {
            let secs = get_positive_f64(flags, "metrics-interval", 1.0)?;
            Some(MetricsTicker::start(
                Duration::from_secs_f64(secs),
                |snap| eprint!("metrics tick:\n{}", snap.render_table()),
            ))
        } else {
            None
        };
        // lock order: the trace session first, then the flight recorder
        // (the flight guard documents this order)
        let session = (trace_path.is_some() || flight_dir.is_some())
            .then(|| TraceSession::start(ObsConfig::on(DEFAULT_RING_CAPACITY)));
        let flight = match &flight_dir {
            Some(dir) => {
                let guard =
                    parlin::obs::flight::install(dir, parlin::obs::flight::DEFAULT_WINDOW_S)
                        .with_context(|| format!("arming flight recorder in {dir}"))?;
                eprintln!("flight recorder: armed, dumps -> {dir}");
                Some(guard)
            }
            None => None,
        };
        let health = LiveHealth::default();
        let exporter = match &metrics_addr {
            Some(addr) => {
                let h = health.clone();
                let srv = ExportServer::start(addr, ExportSources::with_health(move || h.read()))
                    .with_context(|| format!("binding metrics endpoint {addr}"))?;
                // CI and scripts poll this line for the resolved port
                eprintln!(
                    "metrics: listening on http://{} (/metrics /health /trace)",
                    srv.local_addr()
                );
                Some(srv)
            }
            None => None,
        };
        Ok(ObsCli { trace_path, session, ticker, exporter, flight, health })
    }

    /// Stop the ticker and exposition endpoint, disarm the flight
    /// recorder, finish the trace session and write the JSON file.
    fn finish(self) -> Result<()> {
        if let Some(t) = self.ticker {
            let _ = t.stop();
        }
        if let Some(srv) = self.exporter {
            srv.shutdown();
        }
        // disarm before the trace session ends (reverse install order)
        drop(self.flight);
        if let Some(s) = self.session {
            match &self.trace_path {
                Some(path) => {
                    let dump = s.finish();
                    dump.save_chrome_json(path)
                        .with_context(|| format!("writing trace {path}"))?;
                    eprintln!(
                        "trace: {} events across {} threads ({} dropped) -> {path}",
                        dump.total_events(),
                        dump.threads.len(),
                        dump.total_dropped()
                    );
                }
                // a --flight-dir session without --trace: the rings only
                // existed to feed dumps, nothing to save on a clean exit
                None => drop(s.finish()),
            }
        }
        Ok(())
    }
}

/// Parse `--arrival-process` for open-loop serve mode.
fn parse_arrival_process(flags: &HashMap<String, String>) -> Result<ArrivalProcess> {
    match flags
        .get("arrival-process")
        .map(String::as_str)
        .unwrap_or("poisson")
    {
        "poisson" => Ok(ArrivalProcess::Poisson),
        "fixed" => Ok(ArrivalProcess::Fixed),
        other => bail!("unknown arrival process '{other}' (expected poisson | fixed)"),
    }
}

fn load_dataset(flags: &HashMap<String, String>) -> Result<AnyDataset> {
    let spec = flags
        .get("dataset")
        .ok_or_else(|| anyhow!("--dataset is required"))?;
    let seed: u64 = get_parse(flags, "seed", 42u64)?;
    let kind = match spec.as_str() {
        "dense-synth" => Some(DsKind::DenseSynth),
        "sparse-synth" => Some(DsKind::SparseSynth),
        "higgs-like" => Some(DsKind::HiggsLike),
        "epsilon-like" => Some(DsKind::EpsilonLike),
        "criteo-like" => Some(DsKind::CriteoLike),
        _ => None,
    };
    if let Some(kind) = kind {
        // allow --n/--d overrides for the plain synthetic kinds
        let n_override = get_parse(flags, "n", 0usize)?;
        if n_override > 0 && kind == DsKind::DenseSynth {
            let d = get_parse(flags, "d", 100usize)?;
            return Ok(AnyDataset::Dense(
                parlin::data::synthetic::dense_classification(n_override, d, seed),
            ));
        }
        return Ok(kind.make(false, seed));
    }
    let path = Path::new(spec);
    if path.exists() {
        let ds = loader::load_libsvm(path, None)
            .with_context(|| format!("loading {}", path.display()))?;
        return Ok(AnyDataset::Sparse(ds));
    }
    bail!("unknown dataset '{spec}' (not a kind, not a file)");
}

/// Build a [`SolverConfig`] from the shared CLI flags (`train` and
/// `serve` accept the same solver knobs).
fn solver_cfg_from_flags(flags: &HashMap<String, String>, n: usize) -> Result<SolverConfig> {
    let lambda: f64 = get_parse(flags, "lambda", 1.0 / n as f64)?;
    let obj = match flags
        .get("objective")
        .map(String::as_str)
        .unwrap_or("logistic")
    {
        "logistic" => Objective::Logistic { lambda },
        "ridge" => Objective::Ridge { lambda },
        "hinge" => Objective::Hinge { lambda },
        other => bail!("unknown objective '{other}'"),
    };
    let variant = match flags.get("solver").map(String::as_str).unwrap_or("auto") {
        "auto" => Variant::Auto,
        "seq" => Variant::Sequential,
        "wild" => Variant::Wild,
        "dom" => Variant::Domesticated,
        "numa" => Variant::Numa,
        other => bail!("unknown solver '{other}'"),
    };
    let bucket = match flags.get("bucket").map(String::as_str).unwrap_or("auto") {
        "auto" => BucketPolicy::Auto,
        "off" => BucketPolicy::Off,
        k => BucketPolicy::Fixed(k.parse().map_err(|e| anyhow!("--bucket {k}: {e}"))?),
    };
    let partition = match flags
        .get("partition")
        .map(String::as_str)
        .unwrap_or("dynamic")
    {
        "dynamic" => Partitioning::Dynamic,
        "static" => Partitioning::Static,
        other => bail!("unknown partitioning '{other}'"),
    };
    let exec = match flags.get("exec").map(String::as_str).unwrap_or("pool") {
        "pool" => ExecPolicy::Pool,
        "threads" => ExecPolicy::Threads,
        "seq" | "sequential" => ExecPolicy::Sequential,
        other => bail!("unknown executor '{other}'"),
    };
    let layout = match flags
        .get("layout")
        .map(String::as_str)
        .unwrap_or("interleaved")
    {
        "interleaved" => LayoutPolicy::Interleaved,
        "csc" | "native" => LayoutPolicy::Csc,
        other => bail!("unknown layout '{other}'"),
    };
    let seed = get_parse(flags, "seed", 42u64)?;
    let tune = parse_tune_policy(flags, seed)?;
    Ok(SolverConfig::new(obj)
        .with_variant(variant)
        .with_threads(get_parse(flags, "threads", 1usize)?)
        .with_tol(get_parse(flags, "tol", 1e-3f64)?)
        .with_max_epochs(get_parse(flags, "max-epochs", 200usize)?)
        .with_bucket(bucket)
        .with_partition(partition)
        .with_exec(exec)
        .with_layout(layout)
        .with_tune(tune)
        .with_seed(seed))
}

/// Parse `--tune off|on|on:<seed>` into a [`TunePolicy`]. A bare `on`
/// seeds the tuner from `--seed`, so one flag reproduces a run; `on:<s>`
/// decouples the tuner's probe order from the solver's data shuffles.
fn parse_tune_policy(flags: &HashMap<String, String>, seed: u64) -> Result<TunePolicy> {
    let Some(v) = flags.get("tune") else {
        return Ok(TunePolicy::Off);
    };
    match v.as_str() {
        // a bare `--tune` parses to "true": insist on an explicit policy
        "" | "true" => bail!("--tune needs a policy (off | on | on:<seed>)"),
        "off" => Ok(TunePolicy::Off),
        "on" => Ok(TunePolicy::On { seed }),
        other => match other.strip_prefix("on:") {
            Some(s) => Ok(TunePolicy::On {
                seed: s.parse().map_err(|e| anyhow!("--tune on:{s}: {e}"))?,
            }),
            None => bail!("unknown tune policy '{other}' (expected off | on | on:<seed>)"),
        },
    }
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let obs = ObsCli::start(flags)?;
    let run = cmd_train_inner(flags);
    // write the trace even when the run failed (it shows *where*), but
    // report the run's error first
    run.and(obs.finish())
}

fn cmd_train_inner(flags: &HashMap<String, String>) -> Result<()> {
    let ds = load_dataset(flags)?;
    let n = ds.n();
    let cfg = solver_cfg_from_flags(flags, n)?;

    println!(
        "training: n={n} d={} nnz={} solver={:?} threads={} λ={:.3e}",
        ds.d(),
        ds.nnz(),
        cfg.variant,
        cfg.threads,
        cfg.obj.lambda()
    );
    let out = parlin::figures::with_ds!(&ds, d => train(d, &cfg));
    println!(
        "{}: {} epochs, converged={}, diverged={}, gap={:.3e}, {:.3}s",
        out.record.solver,
        out.epochs_run,
        out.converged,
        out.record.diverged,
        out.final_gap,
        out.record.total_wall_s
    );
    for e in out.record.epochs.iter().take(5) {
        println!(
            "  epoch {:>3}: rel_change={:.3e} wall={:.4}s",
            e.epoch, e.rel_change, e.wall_s
        );
    }
    if out.record.epochs.len() > 5 {
        println!("  … ({} more epochs)", out.record.epochs.len() - 5);
    }
    if let Some(csv) = flags.get("csv") {
        out.record.write_csv(Path::new(csv))?;
        println!("per-epoch log -> {csv}");
    }
    if let Some(path) = get_path_flag(flags, "convergence-log")? {
        out.convergence
            .write_csv(Path::new(&path))
            .with_context(|| format!("writing convergence trace {path}"))?;
        println!(
            "convergence trace: {} epochs ({}) -> {path}",
            out.convergence.len(),
            match out.convergence.last_gap() {
                Some(g) => format!("last gap {g:.3e}"),
                None => "no gap evaluations".to_string(),
            }
        );
    }
    if let Some(path) = get_path_flag(flags, "tune-log")? {
        let Some(log) = &out.tune_log else {
            bail!("--tune-log requires --tune on (the run was not tuned, so there is no log)");
        };
        log.write_csv(Path::new(&path))
            .with_context(|| format!("writing tune log {path}"))?;
        println!(
            "tune log: {} decision(s), seed {} -> {path}",
            log.decisions.len(),
            log.init.seed
        );
    }
    Ok(())
}

/// Stand up a resident serving session and replay a request stream
/// against it (closed loop), then print latency and pool-load statistics.
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let obs = ObsCli::start(flags)?;
    let run = cmd_serve_inner(flags, obs.health.clone());
    run.and(obs.finish())
}

fn cmd_serve_inner(flags: &HashMap<String, String>, health: LiveHealth) -> Result<()> {
    if flags.contains_key("convergence-log") {
        bail!(
            "--convergence-log applies to `parlin train` (serve refits expose \
             their traces on RefitReport; use --bench-json for serve artifacts)"
        );
    }
    if flags.contains_key("tune-log") {
        bail!(
            "--tune-log applies to `parlin train` (serve refits expose their \
             tune logs on RefitReport; use --bench-json for serve artifacts)"
        );
    }
    let bench = get_path_flag(flags, "bench-json")?.map(PathBuf::from);
    let ds = load_dataset(flags)?;
    let n = ds.n();
    let cfg = solver_cfg_from_flags(flags, n)?;
    let seed = get_parse(flags, "seed", 42u64)?;
    // concurrency knobs are validated even in single-request mode so a
    // typo fails fast instead of silently degrading to defaults
    let concurrency = get_positive_usize(flags, "concurrency", 1)?;
    let sched_cfg = parlin::serve::SchedulerConfig {
        refit_rows_threshold: get_positive_usize(flags, "refit-rows-threshold", 64)?,
        refit_staleness_s: get_positive_f64(flags, "refit-staleness", 0.25)?,
        max_pending: get_optional_positive_usize(flags, "max-pending")?,
        // 0 retries is legitimate (fail fast); stall budget and dead-letter
        // capacity must be positive to mean anything
        drain_max_retries: get_parse(flags, "drain-retries", 2usize)?,
        drain_stall_s: get_positive_f64(flags, "drain-stall", 30.0)?,
        dead_letter_rows: get_positive_usize(flags, "dead-letter-rows", 1024)?,
    };
    let fault_plan = parse_fault_plan(flags, seed)?;
    if flags.contains_key("arrival-rate") {
        check_concurrent_requests_flag(flags)?;
        let ol_cfg = parlin::serve::OpenLoopConfig {
            rate_per_s: get_positive_f64(flags, "arrival-rate", 500.0)?,
            duration_s: get_positive_f64(flags, "duration", 2.0)?,
            process: parse_arrival_process(flags)?,
            seed: get_parse(flags, "open-loop-seed", seed)?,
            predict_batch: get_positive_usize(flags, "predict-batch", 256)?,
            ingest_fraction: 0.02,
            rows_per_ingest: get_positive_usize(flags, "refit-rows", 32)?,
            // --concurrency doubles as the dispatcher count in open-loop
            // mode; left unset, 8 dispatchers keep a bursty schedule from
            // serializing behind a single issuing thread
            dispatchers: if flags.contains_key("concurrency") {
                concurrency
            } else {
                8
            },
            record_outcomes: false,
        };
        println!(
            "serving (open loop): n={n} d={} threads={} offered {:.0} req/s for {:.2}s \
             ({:?} arrivals, {} dispatchers, max pending {:?})",
            ds.d(),
            cfg.threads,
            ol_cfg.rate_per_s,
            ol_cfg.duration_s,
            ol_cfg.process,
            ol_cfg.dispatchers,
            sched_cfg.max_pending
        );
        return parlin::figures::with_ds!(ds, d => {
            run_serve_open_loop(d, cfg, sched_cfg, ol_cfg, fault_plan, health.clone(), bench.clone())
        });
    }
    if concurrency > 1 {
        check_concurrent_requests_flag(flags)?;
        let storm = parlin::serve::StormConfig {
            readers: concurrency,
            predicts: get_parse(flags, "count", 200usize)?,
            predict_batch: get_parse(flags, "predict-batch", 256usize)?,
            appends: (get_parse(flags, "count", 200usize)? / 10).max(1),
            rows_per_append: get_parse(flags, "refit-rows", 32usize)?,
        };
        println!(
            "serving (concurrent): n={n} d={} threads={} readers={} \
             predicts={} appends={}×{} rows (refit at {} rows / {:.3}s stale)",
            ds.d(),
            cfg.threads,
            storm.readers,
            storm.predicts,
            storm.appends,
            storm.rows_per_append,
            sched_cfg.refit_rows_threshold,
            sched_cfg.refit_staleness_s
        );
        return parlin::figures::with_ds!(ds, d => {
            run_serve_concurrent(d, cfg, sched_cfg, storm, seed, fault_plan, health.clone(), bench.clone())
        });
    }
    let reqs = match flags.get("requests").map(String::as_str) {
        None | Some("synthetic") | Some("true") => parlin::serve::synthetic_mix(
            get_parse(flags, "count", 200usize)?,
            get_parse(flags, "predict-batch", 256usize)?,
            get_parse(flags, "refit-rows", 32usize)?,
            seed,
        ),
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading request script {path}"))?;
            parlin::serve::parse_script(&text)?
        }
    };
    println!(
        "serving: n={n} d={} threads={} requests={}",
        ds.d(),
        cfg.threads,
        reqs.len()
    );
    parlin::figures::with_ds!(ds, d => run_serve(d, cfg, &reqs, seed, fault_plan, bench.clone()))
}

fn run_serve<M>(
    ds: parlin::data::Dataset<M>,
    cfg: SolverConfig,
    reqs: &[parlin::serve::Request],
    seed: u64,
    fault_plan: Option<FaultPlan>,
    bench: Option<PathBuf>,
) -> Result<()>
where
    M: parlin::serve::SynthRows,
{
    let t = parlin::util::Timer::start();
    let mut sess = parlin::serve::Session::new(ds, cfg);
    println!(
        "session ready in {:.3}s ({} pool workers, initial gap {:.3e})",
        t.elapsed_s(),
        sess.workers(),
        sess.gap().gap
    );
    // arm only now: the initial train above must never be injected
    let _fault = fault_plan.map(FaultPlan::arm);
    let report = parlin::serve::drive(&mut sess, reqs, seed);
    print!("{}", report.summary());
    let ps = sess.pool_stats();
    println!(
        "pool: {} workers, {} jobs, busy imbalance {:.2} (max/mean)",
        ps.per_worker.len(),
        ps.total_jobs(),
        ps.imbalance()
    );
    for w in &ps.per_worker {
        println!(
            "  worker {:>2} (node {}): {:>8} jobs, {:>9.3}s busy",
            w.worker, w.node, w.jobs, w.busy_s
        );
    }
    let s = sess.stats();
    println!(
        "session: {} predicts ({} examples), {} refits ({} epochs), \
         {} retrains ({} epochs); final n={}, gap {:.3e}",
        s.predicts,
        s.predicted_examples,
        s.refits,
        report.refit_epochs,
        s.retrains,
        report.retrain_epochs,
        sess.n(),
        sess.gap().gap
    );
    if let Some(path) = &bench {
        let lat = parlin::util::Percentiles::of(&report.predict_s);
        let mut rec = BenchRecord::new("serve");
        rec.throughput_rps =
            Some(report.requests() as f64 / report.total_wall_s.max(1e-9));
        rec.p50_ms = Some(lat.p50() * 1e3);
        rec.p99_ms = Some(lat.p99() * 1e3);
        rec.epochs = Some((report.refit_epochs + report.retrain_epochs) as f64);
        rec.gap = Some(sess.gap().gap);
        rec.wall_s = Some(report.total_wall_s);
        rec.healthy = matches!(report.health, ServeHealth::Healthy);
        write_bench(&rec, path)?;
    }
    check_final_health(&report.health)
}

/// Persist a serve run's bench record and say where it went.
fn write_bench(rec: &BenchRecord, path: &Path) -> Result<()> {
    rec.write_json(path)
        .with_context(|| format!("writing bench record {}", path.display()))?;
    println!("bench record ({}) -> {}", rec.kind, path.display());
    Ok(())
}

/// Stand up a scheduler over a resident session and run the concurrent
/// closed loop: a predict storm on `storm.readers` threads interleaved
/// with an append stream, background refits publishing versioned
/// snapshots. Prints per-version latency, snapshot age and overlap.
fn run_serve_concurrent<M>(
    ds: parlin::data::Dataset<M>,
    cfg: SolverConfig,
    sched_cfg: parlin::serve::SchedulerConfig,
    storm: parlin::serve::StormConfig,
    seed: u64,
    fault_plan: Option<FaultPlan>,
    health: LiveHealth,
    bench: Option<PathBuf>,
) -> Result<()>
where
    M: parlin::serve::SynthRows + Send + 'static,
{
    let t = parlin::util::Timer::start();
    let sess = parlin::serve::Session::new(ds, cfg);
    println!(
        "session ready in {:.3}s ({} pool workers, initial gap {:.3e})",
        t.elapsed_s(),
        sess.workers(),
        sess.gap().gap
    );
    let sched = std::sync::Arc::new(parlin::serve::Scheduler::new(sess, sched_cfg));
    bind_scheduler_health(&health, &sched);
    // arm only now: construction-time refits must never be injected
    let _fault = fault_plan.map(FaultPlan::arm);
    let report = parlin::serve::drive_concurrent(&sched, &storm, seed);
    print!("{}", report.summary());
    let ps = sched.pool_stats();
    println!(
        "pool: {} workers, {} jobs, busy imbalance {:.2} (max/mean)",
        ps.per_worker.len(),
        ps.total_jobs(),
        ps.imbalance()
    );
    println!(
        "final: version {}, n={}, gap {:.3e}",
        sched.version(),
        sched.current_n(),
        sched.gap().gap
    );
    if let Some(path) = &bench {
        let all: Vec<f64> = report
            .per_version
            .iter()
            .flat_map(|v| v.predict_s.iter().copied())
            .collect();
        let lat = parlin::util::Percentiles::of(&all);
        let mut rec = BenchRecord::new("serve-concurrent");
        rec.throughput_rps =
            Some(report.predicts as f64 / report.total_wall_s.max(1e-9));
        rec.p50_ms = Some(lat.p50() * 1e3);
        rec.p99_ms = Some(lat.p99() * 1e3);
        rec.gap = Some(sched.gap().gap);
        rec.wall_s = Some(report.total_wall_s);
        rec.healthy = matches!(report.health, ServeHealth::Healthy);
        write_bench(&rec, path)?;
    }
    check_final_health(&report.health)
}

/// Point the exposition endpoint's `/health` at the live scheduler. The
/// closure holds its own `Arc` on the scheduler, so a scrape arriving
/// after the drive loop returned still answers from real state.
fn bind_scheduler_health<M>(health: &LiveHealth, sched: &std::sync::Arc<parlin::serve::Scheduler<M>>)
where
    M: parlin::serve::SynthRows + Send + 'static,
{
    let sched = std::sync::Arc::clone(sched);
    health.bind(move || {
        let h = sched.health();
        (matches!(h, ServeHealth::Healthy), h.to_string())
    });
}

/// Stand up a scheduler over a resident session and push a pre-generated
/// open-loop arrival schedule at it: latencies measured from scheduled
/// arrival, overload shed via `--max-pending` admission control, per-class
/// pool queue delay printed alongside the per-kind percentiles.
fn run_serve_open_loop<M>(
    ds: parlin::data::Dataset<M>,
    cfg: SolverConfig,
    sched_cfg: parlin::serve::SchedulerConfig,
    ol_cfg: parlin::serve::OpenLoopConfig,
    fault_plan: Option<FaultPlan>,
    health: LiveHealth,
    bench: Option<PathBuf>,
) -> Result<()>
where
    M: parlin::serve::SynthRows + Send + 'static,
{
    let t = parlin::util::Timer::start();
    let sess = parlin::serve::Session::new(ds, cfg);
    println!(
        "session ready in {:.3}s ({} pool workers, initial gap {:.3e})",
        t.elapsed_s(),
        sess.workers(),
        sess.gap().gap
    );
    let sched = std::sync::Arc::new(parlin::serve::Scheduler::new(sess, sched_cfg));
    bind_scheduler_health(&health, &sched);
    // arm only now: construction-time refits must never be injected
    let _fault = fault_plan.map(FaultPlan::arm);
    let report = parlin::serve::drive_open_loop(&sched, &ol_cfg);
    print!("{}", report.summary());
    let ps = sched.pool_stats();
    println!(
        "pool: {} workers, {} jobs, busy imbalance {:.2} (max/mean)",
        ps.per_worker.len(),
        ps.total_jobs(),
        ps.imbalance()
    );
    println!(
        "final: version {}, n={}, gap {:.3e}",
        sched.version(),
        sched.current_n(),
        sched.gap().gap
    );
    if let Some(path) = &bench {
        let mut rec = BenchRecord::new("serve-open-loop");
        rec.throughput_rps = Some(report.achieved_rate_per_s());
        rec.p50_ms = Some(report.predict.p50_s() * 1e3);
        rec.p99_ms = Some(report.predict.p99_s() * 1e3);
        rec.gap = Some(sched.gap().gap);
        rec.wall_s = Some(report.total_wall_s);
        rec.healthy = matches!(report.health, ServeHealth::Healthy);
        write_bench(&rec, path)?;
    }
    check_final_health(&report.health)
}

fn cmd_figures(flags: &HashMap<String, String>) -> Result<()> {
    let mut opts = FigOpts::default();
    if flags.contains_key("quick") {
        opts.quick = true;
    }
    if let Some(dir) = flags.get("out") {
        opts.out_dir = PathBuf::from(dir);
    }
    opts.seed = get_parse(flags, "seed", 42u64)?;
    let id = flags
        .get("fig")
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let id = if flags.contains_key("all") {
        "all".to_string()
    } else {
        id
    };
    run_figure(&id, &opts)
}

fn cmd_inspect() -> Result<()> {
    let topo = Topology::detect();
    println!(
        "host topology : {} node(s), cores/node {:?}",
        topo.num_nodes(),
        topo.cores_per_node
    );
    println!("cache line    : {} B", parlin::sysinfo::cache_line_size());
    println!("LLC           : {} MiB", parlin::sysinfo::llc_size() >> 20);
    println!(
        "bucket policy : size {} for a 1M-example model",
        BucketPolicy::Auto.resolve_host(1_000_000)
    );
    match parlin::runtime::ArtifactRuntime::load_default() {
        Ok(rt) => {
            println!("artifacts     : {:?} in {}", rt.names(), rt.dir().display());
            rt.validate_tiles()?;
            println!("tile check    : OK (TILE_M=256, TILE_D=128, BUCKET_B=8)");
        }
        Err(e) => println!("artifacts     : not loaded ({e})"),
    }
    for m in parlin::simcost::paper_machines() {
        println!(
            "machine model : {} — {} nodes × {} cores @ {} GHz, line {} B",
            m.name,
            m.topology.num_nodes(),
            m.topology.cores_per_node[0],
            m.ghz,
            m.cache_line
        );
    }
    Ok(())
}

/// Demonstrate the AOT evaluation path: load artifacts, tile a dataset,
/// evaluate loss/accuracy of a trained model through PJRT.
fn cmd_eval(flags: &HashMap<String, String>) -> Result<()> {
    let ds = load_dataset(flags)?;
    let AnyDataset::Dense(ds) = ds else {
        bail!("eval demo needs a dense dataset kind");
    };
    let dir = flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    let rt = parlin::runtime::ArtifactRuntime::load(&dir)?;
    let lambda = 1.0 / ds.n() as f64;
    let cfg = SolverConfig::new(Objective::Logistic { lambda }).with_tol(1e-4);
    let out = train(&ds, &cfg);
    let w = out.weights(&Objective::Logistic { lambda });
    let idx: Vec<usize> = (0..ds.n()).collect();
    let ev = parlin::runtime::TiledEvaluator::new(&rt, &ds, &idx)?;
    let m = ev.eval(&w)?;
    println!(
        "HLO eval: n={} loss={:.5} acc={:.4} (trained {} epochs, gap {:.2e})",
        m.count, m.mean_loss, m.accuracy, out.epochs_run, out.final_gap
    );
    Ok(())
}

/// Diff two run artifacts (`--bench-json` JSON, `--convergence-log` CSV
/// or `--csv` per-epoch CSV — formats sniffed by content) and exit
/// nonzero when any metric regressed past `--threshold`, or when a
/// healthy baseline turned degraded. This is the CI gate: the committed
/// baseline lives in `ci/`, the current run's artifact comes fresh from
/// the workflow.
fn cmd_report(flags: &HashMap<String, String>) -> Result<()> {
    let baseline_path = get_path_flag(flags, "baseline")?
        .ok_or_else(|| anyhow!("--baseline is required (bench json or csv artifact)"))?;
    let current_path = get_path_flag(flags, "current")?
        .ok_or_else(|| anyhow!("--current is required (bench json or csv artifact)"))?;
    let threshold = get_positive_f64(flags, "threshold", 1.5)?;
    if threshold <= 1.0 {
        bail!(
            "--threshold is a worseness ratio and must be > 1 \
             (e.g. 1.5 fails anything 50% worse), got {threshold}"
        );
    }
    let baseline = BenchRecord::load(Path::new(&baseline_path))
        .map_err(|e| anyhow!("--baseline: {e}"))?;
    let current = BenchRecord::load(Path::new(&current_path))
        .map_err(|e| anyhow!("--current: {e}"))?;
    print!("{}", parlin::report::render_comparison(&baseline, &current, threshold));
    let regressions = parlin::report::compare(&baseline, &current, threshold);
    if regressions.is_empty() {
        println!("report: ok — no metric more than {threshold}x worse than baseline");
        return Ok(());
    }
    for r in &regressions {
        eprintln!(
            "report: {} regressed — baseline {:.4}, current {:.4} ({:.2}x worse)",
            r.metric, r.baseline, r.current, r.ratio
        );
    }
    bail!(
        "{} metric(s) regressed beyond {threshold}x vs {baseline_path}",
        regressions.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_flags_space_and_equals_forms_agree() {
        let a = parse_flags(&args(&["--threads", "4", "--tol", "1e-4", "--quick"])).unwrap();
        let b = parse_flags(&args(&["--threads=4", "--tol=1e-4", "--quick"])).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.get("threads").map(String::as_str), Some("4"));
        assert_eq!(a.get("tol").map(String::as_str), Some("1e-4"));
        assert_eq!(a.get("quick").map(String::as_str), Some("true"));
    }

    #[test]
    fn parse_flags_equals_values_keep_equals_and_dashes() {
        let m = parse_flags(&args(&["--out=a=b", "--lambda=-0.5", "--csv="])).unwrap();
        assert_eq!(m.get("out").map(String::as_str), Some("a=b"));
        assert_eq!(m.get("lambda").map(String::as_str), Some("-0.5"));
        assert_eq!(m.get("csv").map(String::as_str), Some(""));
    }

    #[test]
    fn parse_flags_mixed_forms_in_one_command() {
        let m = parse_flags(&args(&["--dataset=dense-synth", "--threads", "8"])).unwrap();
        assert_eq!(m.get("dataset").map(String::as_str), Some("dense-synth"));
        assert_eq!(m.get("threads").map(String::as_str), Some("8"));
    }

    #[test]
    fn parse_flags_rejects_bad_input() {
        assert!(parse_flags(&args(&["positional"])).is_err());
        assert!(parse_flags(&args(&["--=3"])).is_err());
    }

    #[test]
    fn solver_cfg_respects_equals_form_flags() {
        let flags = parse_flags(&args(&["--threads=4", "--lambda=0.01", "--solver=dom"])).unwrap();
        let cfg = solver_cfg_from_flags(&flags, 100).unwrap();
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.variant, Variant::Domesticated);
        assert!((cfg.obj.lambda() - 0.01).abs() < 1e-15);
    }

    #[test]
    fn concurrency_flags_validated_positive_at_the_parser() {
        // defaults pass untouched
        let empty = parse_flags(&args(&[])).unwrap();
        assert_eq!(get_positive_usize(&empty, "concurrency", 1).unwrap(), 1);
        assert_eq!(
            get_positive_usize(&empty, "refit-rows-threshold", 64).unwrap(),
            64
        );
        assert!((get_positive_f64(&empty, "refit-staleness", 0.25).unwrap() - 0.25).abs() < 1e-15);

        // good explicit values pass through both flag forms
        let ok = parse_flags(&args(&[
            "--concurrency=8",
            "--refit-rows-threshold",
            "128",
            "--refit-staleness=0.5",
        ]))
        .unwrap();
        assert_eq!(get_positive_usize(&ok, "concurrency", 1).unwrap(), 8);
        assert_eq!(
            get_positive_usize(&ok, "refit-rows-threshold", 64).unwrap(),
            128
        );
        assert!((get_positive_f64(&ok, "refit-staleness", 0.25).unwrap() - 0.5).abs() < 1e-15);

        // zero / negative / non-finite / garbage are rejected loudly
        for bad in ["--concurrency=0", "--concurrency=-2", "--concurrency=x"] {
            let f = parse_flags(&args(&[bad])).unwrap();
            assert!(
                get_positive_usize(&f, "concurrency", 1).is_err(),
                "{bad} must be rejected"
            );
        }
        let f = parse_flags(&args(&["--refit-rows-threshold=0"])).unwrap();
        assert!(get_positive_usize(&f, "refit-rows-threshold", 64).is_err());
        for bad in [
            "--refit-staleness=0",
            "--refit-staleness=-0.5",
            "--refit-staleness=NaN",
            "--refit-staleness=inf",
            "--refit-staleness=soon",
        ] {
            let f = parse_flags(&args(&[bad])).unwrap();
            assert!(
                get_positive_f64(&f, "refit-staleness", 0.25).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn max_pending_is_optional_but_must_be_positive() {
        let empty = parse_flags(&args(&[])).unwrap();
        assert_eq!(
            get_optional_positive_usize(&empty, "max-pending").unwrap(),
            None
        );
        let ok = parse_flags(&args(&["--max-pending=64"])).unwrap();
        assert_eq!(
            get_optional_positive_usize(&ok, "max-pending").unwrap(),
            Some(64)
        );
        let zero = parse_flags(&args(&["--max-pending=0"])).unwrap();
        let err = get_optional_positive_usize(&zero, "max-pending").unwrap_err();
        assert!(
            err.to_string().contains("--max-pending must be >= 1, got 0"),
            "{err}"
        );
        let bad = parse_flags(&args(&["--max-pending=lots"])).unwrap();
        assert!(get_optional_positive_usize(&bad, "max-pending").is_err());
    }

    #[test]
    fn arrival_process_flag_parses_and_rejects_unknown() {
        let empty = parse_flags(&args(&[])).unwrap();
        assert_eq!(
            parse_arrival_process(&empty).unwrap(),
            ArrivalProcess::Poisson
        );
        let fixed = parse_flags(&args(&["--arrival-process=fixed"])).unwrap();
        assert_eq!(parse_arrival_process(&fixed).unwrap(), ArrivalProcess::Fixed);
        let bad = parse_flags(&args(&["--arrival-process=uniform"])).unwrap();
        let err = parse_arrival_process(&bad).unwrap_err();
        assert!(
            err.to_string()
                .contains("unknown arrival process 'uniform' (expected poisson | fixed)"),
            "{err}"
        );
    }

    #[test]
    fn scheduler_mode_rejects_request_scripts() {
        for ok in [
            &[][..],
            &["--requests=synthetic"][..],
            &["--requests"][..], // bare flag parses to "true"
        ] {
            let f = parse_flags(&args(ok)).unwrap();
            assert!(check_concurrent_requests_flag(&f).is_ok(), "{ok:?}");
        }
        let f = parse_flags(&args(&["--requests=trace.txt"])).unwrap();
        assert!(check_concurrent_requests_flag(&f).is_err());
    }

    #[test]
    fn trace_flag_requires_a_path() {
        for bad in [&["--trace"][..], &["--trace="][..]] {
            let f = parse_flags(&args(bad)).unwrap();
            let err = ObsCli::start(&f).unwrap_err();
            assert!(err.to_string().contains("--trace needs an output path"), "{bad:?}: {err}");
        }
    }

    #[test]
    fn metrics_interval_must_be_finite_and_positive() {
        for bad in [
            "--metrics-interval=0",
            "--metrics-interval=-1",
            "--metrics-interval=NaN",
            "--metrics-interval=soon",
        ] {
            let f = parse_flags(&args(&[bad])).unwrap();
            assert!(ObsCli::start(&f).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn obs_flags_default_off_and_trace_runs_a_session() {
        let empty = parse_flags(&args(&[])).unwrap();
        let obs = ObsCli::start(&empty).unwrap();
        assert!(obs.session.is_none() && obs.ticker.is_none());
        obs.finish().unwrap();

        let path = "/tmp/parlin-cli-trace-flag-test.json";
        let flag = format!("--trace={path}");
        let f = parse_flags(&args(&[flag.as_str()])).unwrap();
        let obs = ObsCli::start(&f).unwrap();
        assert!(parlin::obs::tracing_enabled());
        obs.finish().unwrap();
        assert!(!parlin::obs::tracing_enabled());
        let json = std::fs::read_to_string(path).unwrap();
        assert!(json.starts_with("{\"traceEvents\":["));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn fault_plan_flag_parses_and_requires_a_spec() {
        let empty = parse_flags(&args(&[])).unwrap();
        assert!(parse_fault_plan(&empty, 42).unwrap().is_none());
        let ok =
            parse_flags(&args(&["--fault-plan=panic@epoch#1x8;nan@publish#2"])).unwrap();
        assert!(parse_fault_plan(&ok, 42).unwrap().is_some());
        for bad in [&["--fault-plan"][..], &["--fault-plan="][..]] {
            let f = parse_flags(&args(bad)).unwrap();
            let err = parse_fault_plan(&f, 42).unwrap_err();
            assert!(err.to_string().contains("--fault-plan needs a spec"), "{bad:?}: {err}");
        }
        // a malformed spec reports through the flag, not a bare parse error
        let garbage = parse_flags(&args(&["--fault-plan=explode@everywhere"])).unwrap();
        let err = parse_fault_plan(&garbage, 42).unwrap_err();
        assert!(err.to_string().contains("--fault-plan"), "{err}");
    }

    #[test]
    fn degraded_final_health_fails_the_run() {
        assert!(check_final_health(&ServeHealth::Healthy).is_ok());
        let err =
            check_final_health(&ServeHealth::degraded("drain failed: injected")).unwrap_err();
        assert!(
            err.to_string().contains("serve finished degraded: drain failed: injected"),
            "{err}"
        );
    }

    #[test]
    fn drain_robustness_flags_validate() {
        let empty = parse_flags(&args(&[])).unwrap();
        assert_eq!(get_parse(&empty, "drain-retries", 2usize).unwrap(), 2);
        assert!((get_positive_f64(&empty, "drain-stall", 30.0).unwrap() - 30.0).abs() < 1e-12);
        assert_eq!(get_positive_usize(&empty, "dead-letter-rows", 1024).unwrap(), 1024);
        // zero retries is a legitimate fail-fast setting…
        let zero = parse_flags(&args(&["--drain-retries=0"])).unwrap();
        assert_eq!(get_parse(&zero, "drain-retries", 2usize).unwrap(), 0);
        // …but a zero-capacity dead letter or non-positive stall budget is not
        let f = parse_flags(&args(&["--dead-letter-rows=0"])).unwrap();
        assert!(get_positive_usize(&f, "dead-letter-rows", 1024).is_err());
        for bad in ["--drain-stall=0", "--drain-stall=-1", "--drain-stall=NaN"] {
            let f = parse_flags(&args(&[bad])).unwrap();
            assert!(get_positive_f64(&f, "drain-stall", 30.0).is_err(), "{bad}");
        }
    }

    #[test]
    fn path_flags_require_a_value() {
        for key in ["metrics-addr", "flight-dir", "bench-json", "convergence-log", "tune-log"] {
            let empty = parse_flags(&args(&[])).unwrap();
            assert_eq!(get_path_flag(&empty, key).unwrap(), None);
            let bare = format!("--{key}");
            let eq = format!("--{key}=");
            for bad in [bare.as_str(), eq.as_str()] {
                let f = parse_flags(&args(&[bad])).unwrap();
                let err = get_path_flag(&f, key).unwrap_err();
                assert!(err.to_string().contains("needs a value"), "{bad}: {err}");
            }
            let good = parse_flags(&args(&[&format!("--{key}=some/where")])).unwrap();
            assert_eq!(
                get_path_flag(&good, key).unwrap().as_deref(),
                Some("some/where")
            );
        }
    }

    #[test]
    fn live_health_defaults_healthy_and_follows_the_binding() {
        let h = LiveHealth::default();
        assert_eq!(h.read(), (true, "Healthy".to_string()));
        let shared = h.clone();
        shared.bind(|| (false, "Degraded (drain died)".to_string()));
        // clones share the slot, exactly how the export server sees it
        assert_eq!(h.read(), (false, "Degraded (drain died)".to_string()));
    }

    #[test]
    fn serve_rejects_convergence_log() {
        let f = parse_flags(&args(&["--convergence-log=conv.csv"])).unwrap();
        let err = cmd_serve_inner(&f, LiveHealth::default()).unwrap_err();
        assert!(err.to_string().contains("applies to `parlin train`"), "{err}");
    }

    #[test]
    fn serve_rejects_tune_log() {
        let f = parse_flags(&args(&["--tune-log=tune.csv"])).unwrap();
        let err = cmd_serve_inner(&f, LiveHealth::default()).unwrap_err();
        assert!(err.to_string().contains("--tune-log applies to `parlin train`"), "{err}");
    }

    #[test]
    fn tune_policy_parses_and_defaults_off() {
        let empty = parse_flags(&args(&[])).unwrap();
        assert_eq!(parse_tune_policy(&empty, 42).unwrap(), TunePolicy::Off);
        let off = parse_flags(&args(&["--tune=off"])).unwrap();
        assert_eq!(parse_tune_policy(&off, 42).unwrap(), TunePolicy::Off);
        // a bare `on` inherits the solver seed…
        let on = parse_flags(&args(&["--tune=on"])).unwrap();
        assert_eq!(parse_tune_policy(&on, 7).unwrap(), TunePolicy::On { seed: 7 });
        // …and `on:<seed>` decouples the tuner seed from --seed
        let seeded = parse_flags(&args(&["--tune=on:99"])).unwrap();
        assert_eq!(parse_tune_policy(&seeded, 7).unwrap(), TunePolicy::On { seed: 99 });
        // the parse threads all the way through the builder chain
        let cfg = solver_cfg_from_flags(
            &parse_flags(&args(&["--tune=on", "--seed=13"])).unwrap(),
            100,
        )
        .unwrap();
        assert_eq!(cfg.tune, TunePolicy::On { seed: 13 });

        for bad in [&["--tune"][..], &["--tune="][..]] {
            let f = parse_flags(&args(bad)).unwrap();
            let err = parse_tune_policy(&f, 42).unwrap_err();
            assert!(
                err.to_string().contains("--tune needs a policy (off | on | on:<seed>)"),
                "{bad:?}: {err}"
            );
        }
        let unk = parse_flags(&args(&["--tune=sometimes"])).unwrap();
        let err = parse_tune_policy(&unk, 42).unwrap_err();
        assert!(
            err.to_string()
                .contains("unknown tune policy 'sometimes' (expected off | on | on:<seed>)"),
            "{err}"
        );
        let bad_seed = parse_flags(&args(&["--tune=on:not-a-seed"])).unwrap();
        assert!(parse_tune_policy(&bad_seed, 42).is_err());
    }

    #[test]
    fn report_requires_both_artifacts_and_a_sane_threshold() {
        let empty = parse_flags(&args(&[])).unwrap();
        let err = cmd_report(&empty).unwrap_err();
        assert!(err.to_string().contains("--baseline is required"), "{err}");

        let half = parse_flags(&args(&["--baseline=a.json"])).unwrap();
        let err = cmd_report(&half).unwrap_err();
        assert!(err.to_string().contains("--current is required"), "{err}");

        // the threshold is validated before the artifacts are touched
        let f =
            parse_flags(&args(&["--baseline=a.json", "--current=b.json", "--threshold=0.9"]))
                .unwrap();
        let err = cmd_report(&f).unwrap_err();
        assert!(err.to_string().contains("must be > 1"), "{err}");
    }

    #[test]
    fn report_diffs_bench_artifacts_end_to_end() {
        let dir = std::env::temp_dir();
        let base_path = dir.join(format!("parlin-cli-report-base-{}.json", std::process::id()));
        let cur_path = dir.join(format!("parlin-cli-report-cur-{}.json", std::process::id()));
        let mut base = BenchRecord::new("serve-open-loop");
        base.throughput_rps = Some(900.0);
        base.p99_ms = Some(4.0);
        base.write_json(&base_path).unwrap();

        // same numbers: the gate passes
        base.write_json(&cur_path).unwrap();
        let f = parse_flags(&args(&[
            &format!("--baseline={}", base_path.display()),
            &format!("--current={}", cur_path.display()),
        ]))
        .unwrap();
        cmd_report(&f).expect("identical artifacts must pass");

        // a 10x tail: the gate fails and names the metric
        let mut cur = base.clone();
        cur.p99_ms = Some(40.0);
        cur.write_json(&cur_path).unwrap();
        let err = cmd_report(&f).unwrap_err();
        assert!(err.to_string().contains("regressed"), "{err}");

        let _ = std::fs::remove_file(&base_path);
        let _ = std::fs::remove_file(&cur_path);
    }

    #[test]
    fn flight_dir_flag_arms_the_recorder_and_starts_tracing() {
        let dir = std::env::temp_dir()
            .join(format!("parlin-cli-flight-flag-{}", std::process::id()));
        let flag = format!("--flight-dir={}", dir.display());
        let f = parse_flags(&args(&[flag.as_str()])).unwrap();
        let obs = ObsCli::start(&f).unwrap();
        // no --trace, yet the rings are live: dumps need events to drain
        assert!(parlin::obs::tracing_enabled());
        assert!(parlin::obs::flight::armed());
        assert!(obs.trace_path.is_none());
        obs.finish().unwrap();
        assert!(!parlin::obs::tracing_enabled());
        assert!(!parlin::obs::flight::armed());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn layout_flag_parses_and_defaults_to_interleaved() {
        let default = solver_cfg_from_flags(&parse_flags(&args(&[])).unwrap(), 100).unwrap();
        assert_eq!(default.layout, LayoutPolicy::Interleaved);
        let csc =
            solver_cfg_from_flags(&parse_flags(&args(&["--layout=csc"])).unwrap(), 100).unwrap();
        assert_eq!(csc.layout, LayoutPolicy::Csc);
        assert!(
            solver_cfg_from_flags(&parse_flags(&args(&["--layout", "rowmajor"])).unwrap(), 100)
                .is_err()
        );
    }
}
