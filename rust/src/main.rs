//! `parlin` — CLI launcher for the training system.
//!
//! ```text
//! parlin train   --dataset <kind|file.libsvm> [--solver auto|seq|wild|dom|numa]
//!                [--threads N] [--lambda X] [--tol X] [--max-epochs N]
//!                [--bucket auto|off|K] [--partition dynamic|static]
//!                [--objective logistic|ridge|hinge] [--seed N] [--csv out.csv]
//! parlin figures [--fig 1|2|3|4|5|6|all] [--quick] [--out DIR]
//! parlin inspect               # host topology, cache geometry, artifacts
//! parlin eval    --dataset <kind> --artifacts DIR   # HLO-path evaluation demo
//! ```
//!
//! The argument parser is hand-rolled: the offline toolchain ships only the
//! `xla` crate closure (no clap).

use anyhow::{anyhow, bail, Context, Result};
use parlin::data::{loader, AnyDataset};
use parlin::figures::{run_figure, DsKind, FigOpts};
use parlin::glm::Objective;
use parlin::solver::{train, BucketPolicy, ExecPolicy, Partitioning, SolverConfig, Variant};
use parlin::sysinfo::Topology;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("train") => cmd_train(&parse_flags(&args[1..])?),
        Some("figures") => cmd_figures(&parse_flags(&args[1..])?),
        Some("inspect") => cmd_inspect(),
        Some("eval") => cmd_eval(&parse_flags(&args[1..])?),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

const USAGE: &str = "\
parlin — parallel GLM training (SDCA) without compromising convergence

USAGE:
  parlin train --dataset <kind|file.libsvm> [options]
  parlin figures [--fig 1|2|3|4|5|6|all] [--quick] [--out DIR]
  parlin inspect
  parlin eval --dataset <kind> [--artifacts DIR]

TRAIN OPTIONS:
  --dataset     dense-synth | sparse-synth | higgs-like | epsilon-like |
                criteo-like | path to a LIBSVM file
  --solver      auto | seq | wild | dom | numa        (default auto)
  --threads     worker threads                        (default 1)
  --objective   logistic | ridge | hinge              (default logistic)
  --lambda      L2 regularization                     (default 1/n)
  --tol         relative-model-change stop            (default 1e-3)
  --max-epochs  epoch cap                             (default 200)
  --bucket      auto | off | <size>                   (default auto)
  --partition   dynamic | static                      (default dynamic)
  --exec        pool | threads | seq                  (default pool)
  --n / --d     synthetic dataset size overrides
  --seed        RNG seed                              (default 42)
  --csv         write the per-epoch log to a CSV file
";

/// `--key value` flag parser (flags without a value get "true").
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --flag, got '{}'", args[i]))?;
        let has_value = i + 1 < args.len() && !args[i + 1].starts_with("--");
        if has_value {
            map.insert(key.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            map.insert(key.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok(map)
}

fn get_parse<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
    default: T,
) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|e| anyhow!("--{key} {v}: {e}")),
    }
}

fn load_dataset(flags: &HashMap<String, String>) -> Result<AnyDataset> {
    let spec = flags
        .get("dataset")
        .ok_or_else(|| anyhow!("--dataset is required"))?;
    let seed: u64 = get_parse(flags, "seed", 42u64)?;
    let kind = match spec.as_str() {
        "dense-synth" => Some(DsKind::DenseSynth),
        "sparse-synth" => Some(DsKind::SparseSynth),
        "higgs-like" => Some(DsKind::HiggsLike),
        "epsilon-like" => Some(DsKind::EpsilonLike),
        "criteo-like" => Some(DsKind::CriteoLike),
        _ => None,
    };
    if let Some(kind) = kind {
        // allow --n/--d overrides for the plain synthetic kinds
        let n_override = get_parse(flags, "n", 0usize)?;
        if n_override > 0 && kind == DsKind::DenseSynth {
            let d = get_parse(flags, "d", 100usize)?;
            return Ok(AnyDataset::Dense(
                parlin::data::synthetic::dense_classification(n_override, d, seed),
            ));
        }
        return Ok(kind.make(false, seed));
    }
    let path = Path::new(spec);
    if path.exists() {
        let ds = loader::load_libsvm(path, None)
            .with_context(|| format!("loading {}", path.display()))?;
        return Ok(AnyDataset::Sparse(ds));
    }
    bail!("unknown dataset '{spec}' (not a kind, not a file)");
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let ds = load_dataset(flags)?;
    let n = ds.n();
    let lambda: f64 = get_parse(flags, "lambda", 1.0 / n as f64)?;
    let obj = match flags
        .get("objective")
        .map(String::as_str)
        .unwrap_or("logistic")
    {
        "logistic" => Objective::Logistic { lambda },
        "ridge" => Objective::Ridge { lambda },
        "hinge" => Objective::Hinge { lambda },
        other => bail!("unknown objective '{other}'"),
    };
    let variant = match flags.get("solver").map(String::as_str).unwrap_or("auto") {
        "auto" => Variant::Auto,
        "seq" => Variant::Sequential,
        "wild" => Variant::Wild,
        "dom" => Variant::Domesticated,
        "numa" => Variant::Numa,
        other => bail!("unknown solver '{other}'"),
    };
    let bucket = match flags.get("bucket").map(String::as_str).unwrap_or("auto") {
        "auto" => BucketPolicy::Auto,
        "off" => BucketPolicy::Off,
        k => BucketPolicy::Fixed(k.parse().map_err(|e| anyhow!("--bucket {k}: {e}"))?),
    };
    let partition = match flags
        .get("partition")
        .map(String::as_str)
        .unwrap_or("dynamic")
    {
        "dynamic" => Partitioning::Dynamic,
        "static" => Partitioning::Static,
        other => bail!("unknown partitioning '{other}'"),
    };
    let exec = match flags.get("exec").map(String::as_str).unwrap_or("pool") {
        "pool" => ExecPolicy::Pool,
        "threads" => ExecPolicy::Threads,
        "seq" | "sequential" => ExecPolicy::Sequential,
        other => bail!("unknown executor '{other}'"),
    };
    let cfg = SolverConfig::new(obj)
        .with_variant(variant)
        .with_threads(get_parse(flags, "threads", 1usize)?)
        .with_tol(get_parse(flags, "tol", 1e-3f64)?)
        .with_max_epochs(get_parse(flags, "max-epochs", 200usize)?)
        .with_bucket(bucket)
        .with_partition(partition)
        .with_exec(exec)
        .with_seed(get_parse(flags, "seed", 42u64)?);

    println!(
        "training: n={n} d={} nnz={} solver={:?} threads={} λ={lambda:.3e}",
        ds.d(),
        ds.nnz(),
        variant,
        cfg.threads
    );
    let out = parlin::figures::with_ds!(&ds, d => train(d, &cfg));
    println!(
        "{}: {} epochs, converged={}, diverged={}, gap={:.3e}, {:.3}s",
        out.record.solver,
        out.epochs_run,
        out.converged,
        out.record.diverged,
        out.final_gap,
        out.record.total_wall_s
    );
    for e in out.record.epochs.iter().take(5) {
        println!(
            "  epoch {:>3}: rel_change={:.3e} wall={:.4}s",
            e.epoch, e.rel_change, e.wall_s
        );
    }
    if out.record.epochs.len() > 5 {
        println!("  … ({} more epochs)", out.record.epochs.len() - 5);
    }
    if let Some(csv) = flags.get("csv") {
        out.record.write_csv(Path::new(csv))?;
        println!("per-epoch log -> {csv}");
    }
    Ok(())
}

fn cmd_figures(flags: &HashMap<String, String>) -> Result<()> {
    let mut opts = FigOpts::default();
    if flags.contains_key("quick") {
        opts.quick = true;
    }
    if let Some(dir) = flags.get("out") {
        opts.out_dir = PathBuf::from(dir);
    }
    opts.seed = get_parse(flags, "seed", 42u64)?;
    let id = flags
        .get("fig")
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let id = if flags.contains_key("all") {
        "all".to_string()
    } else {
        id
    };
    run_figure(&id, &opts)
}

fn cmd_inspect() -> Result<()> {
    let topo = Topology::detect();
    println!(
        "host topology : {} node(s), cores/node {:?}",
        topo.num_nodes(),
        topo.cores_per_node
    );
    println!("cache line    : {} B", parlin::sysinfo::cache_line_size());
    println!("LLC           : {} MiB", parlin::sysinfo::llc_size() >> 20);
    println!(
        "bucket policy : size {} for a 1M-example model",
        BucketPolicy::Auto.resolve_host(1_000_000)
    );
    match parlin::runtime::ArtifactRuntime::load_default() {
        Ok(rt) => {
            println!("artifacts     : {:?} in {}", rt.names(), rt.dir().display());
            rt.validate_tiles()?;
            println!("tile check    : OK (TILE_M=256, TILE_D=128, BUCKET_B=8)");
        }
        Err(e) => println!("artifacts     : not loaded ({e})"),
    }
    for m in parlin::simcost::paper_machines() {
        println!(
            "machine model : {} — {} nodes × {} cores @ {} GHz, line {} B",
            m.name,
            m.topology.num_nodes(),
            m.topology.cores_per_node[0],
            m.ghz,
            m.cache_line
        );
    }
    Ok(())
}

/// Demonstrate the AOT evaluation path: load artifacts, tile a dataset,
/// evaluate loss/accuracy of a trained model through PJRT.
fn cmd_eval(flags: &HashMap<String, String>) -> Result<()> {
    let ds = load_dataset(flags)?;
    let AnyDataset::Dense(ds) = ds else {
        bail!("eval demo needs a dense dataset kind");
    };
    let dir = flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    let rt = parlin::runtime::ArtifactRuntime::load(&dir)?;
    let lambda = 1.0 / ds.n() as f64;
    let cfg = SolverConfig::new(Objective::Logistic { lambda }).with_tol(1e-4);
    let out = train(&ds, &cfg);
    let w = out.weights(&Objective::Logistic { lambda });
    let idx: Vec<usize> = (0..ds.n()).collect();
    let ev = parlin::runtime::TiledEvaluator::new(&rt, &ds, &idx)?;
    let m = ev.eval(&w)?;
    println!(
        "HLO eval: n={} loss={:.5} acc={:.4} (trained {} epochs, gap {:.2e})",
        m.count, m.mean_loss, m.accuracy, out.epochs_run, out.final_gap
    );
    Ok(())
}
