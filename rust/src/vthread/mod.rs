//! Virtual-thread execution engine.
//!
//! This host may have fewer cores than the paper's testbeds (up to 32), but
//! *epochs-to-converge* — the algorithmic half of every figure — depends
//! only on update semantics and interleaving, not on physical parallelism.
//! This module executes `T` logical threads deterministically on one core:
//!
//! * **Replica solvers** (`dom`, `numa`): workers are independent between
//!   merge barriers, so the sequential executor in [`crate::solver::exec`]
//!   already reproduces the threaded run bit-for-bit; the wrappers here
//!   just select it.
//! * **Wild solver**: racy by construction, so we model it with a lockstep
//!   round schedule: in each round every live vthread computes its update
//!   from the round-start shared vector (concurrent stale reads), then the
//!   writes are applied subject to a *lost-update* model — when several
//!   vthreads RMW the same `v` element in one round, each non-final
//!   writer's delta survives only with probability `1 − p`, where `p` is
//!   the pairwise collision probability of unsynchronized RMWs
//!   (machine-dependent: larger across NUMA nodes, see
//!   [`WildSimParams`]). Sparse data rarely collides (Fig. 1b); dense data
//!   collides on every element (Fig. 1a).

use crate::data::{DataMatrix, Dataset};
use crate::glm::ModelState;
use crate::metrics::{EpochStats, RunRecord};
use crate::solver::exec::Executor;
use crate::solver::{ConvergenceMonitor, SolverConfig, TrainOutput};
use crate::sysinfo::Topology;
use crate::util::{Rng, Timer};

/// Collision model for simulated wild execution.
#[derive(Clone, Debug)]
pub struct WildSimParams {
    /// Probability that two unsynchronized RMWs of the same element by
    /// threads on the *same* NUMA node interleave (lost update).
    pub p_collide_local: f64,
    /// Same, for threads on *different* NUMA nodes — far larger because the
    /// RMW window stretches over a cross-node cache-line transfer.
    pub p_collide_remote: f64,
    /// Topology used to decide which vthread pairs are remote.
    pub topology: Topology,
}

impl WildSimParams {
    /// Single-node machine defaults: MESI ownership serializes same-node
    /// RMWs, so element-level losses are effectively zero — wild on one
    /// node suffers only stale reads (the Fig. 1b "works fine" regime).
    pub fn single_node(threads: usize) -> Self {
        WildSimParams {
            p_collide_local: 0.0,
            p_collide_remote: 0.0,
            topology: Topology::flat(threads),
        }
    }

    /// Multi-node machine: unsynchronized RMWs straddling a cross-node
    /// line transfer can lose updates (the Fig. 1a failure regime).
    pub fn multi_node(topology: Topology) -> Self {
        WildSimParams {
            p_collide_local: 0.0,
            p_collide_remote: 0.06,
            topology,
        }
    }

    /// Node id of vthread `t` under this topology's thread placement.
    fn node_of(&self, placement: &[usize], t: usize) -> usize {
        let mut acc = 0;
        for (k, &p) in placement.iter().enumerate() {
            acc += p;
            if t < acc {
                return k;
            }
        }
        placement.len().saturating_sub(1)
    }
}

/// Simulate Algorithm 1 ("wild") with `cfg.threads` logical threads.
///
/// Epoch counts and the converged/diverged verdicts are the reproduction
/// targets; wall-clock comes from `simcost`, not from this function.
pub fn train_wild_sim<M: DataMatrix>(
    ds: &Dataset<M>,
    cfg: &SolverConfig,
    params: &WildSimParams,
) -> TrainOutput {
    let n = ds.n();
    let d = ds.d();
    let t_threads = cfg.threads.max(1);
    let obj = cfg.obj;
    let inv_lambda_n = 1.0 / (obj.lambda() * n as f64);
    let placement = params.topology.place_threads(t_threads);

    let mut alpha = vec![0.0f64; n];
    let mut v = vec![0.0f64; d];
    let mut perm: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::new(cfg.seed);
    let mut coin = Rng::new(cfg.seed ^ 0x5eed_c011_1de5);
    let mut mon = ConvergenceMonitor::new(n, cfg.tol, cfg.divergence_factor);

    // scratch: per-round writer bookkeeping over v elements
    let mut last_writer: Vec<u32> = vec![u32::MAX; d];
    let mut round_stamp: Vec<u32> = vec![u32::MAX; d];
    let mut stamp: u32 = 0;

    let total = Timer::start();
    let mut epochs = Vec::new();
    let mut converged = false;
    let mut diverged = false;
    'outer: for epoch in 1..=cfg.max_epochs {
        let t = Timer::start();
        rng.shuffle(&mut perm);
        let chunk = n.div_ceil(t_threads);
        let rounds = chunk;
        // deltas computed this round: (thread, coordinate j, δ)
        let mut round_updates: Vec<(usize, usize, f64)> = Vec::with_capacity(t_threads);
        for r in 0..rounds {
            round_updates.clear();
            // 1) concurrent reads: every vthread computes its δ from the
            //    round-start state of v
            for tid in 0..t_threads {
                let idx = tid * chunk + r;
                if idx >= ((tid + 1) * chunk).min(n) {
                    continue;
                }
                let j = perm[idx] as usize;
                let xw = ds.x.dot_col(j, &v) * inv_lambda_n;
                let delta = obj.delta(alpha[j], xw, ds.norm_sq(j), ds.y[j], n);
                if delta != 0.0 {
                    round_updates.push((tid, j, delta));
                }
            }
            // 2) writes: α is exclusive; v suffers lost updates on
            //    same-element same-round RMWs. We sweep writers in thread
            //    order; a non-final writer loses its contribution to an
            //    element with probability p(pair) against the *next* writer
            //    of that element (last writer always survives).
            stamp = stamp.wrapping_add(1);
            if round_updates.len() == 1 {
                let (_, j, delta) = round_updates[0];
                alpha[j] += delta;
                ds.x.axpy_col(j, delta, &mut v);
            } else {
                // mark, per element, which thread writes it last this round
                for &(tid, j, _) in &round_updates {
                    mark_last_writer(ds, j, tid as u32, stamp, &mut last_writer, &mut round_stamp);
                }
                for &(tid, j, delta) in &round_updates {
                    alpha[j] += delta;
                    apply_wild_axpy(
                        ds,
                        j,
                        delta,
                        tid as u32,
                        stamp,
                        &last_writer,
                        &round_stamp,
                        params,
                        &placement,
                        &mut coin,
                        &mut v,
                    );
                }
            }
        }
        let rel = mon.observe(&alpha);
        epochs.push(EpochStats {
            epoch,
            wall_s: t.elapsed_s(),
            rel_change: rel,
            gap: None,
            primal: None,
        });
        if mon.diverged(&alpha) {
            diverged = true;
            break 'outer;
        }
        if mon.converged() {
            converged = true;
            break 'outer;
        }
    }

    let mut st = ModelState { alpha, v };
    st.rebuild_v(ds); // the usable model is w(α), as in the real wild solver
    let record = RunRecord {
        solver: format!("wild-sim(T={t_threads})"),
        threads: t_threads,
        epochs,
        converged,
        diverged,
        total_wall_s: total.elapsed_s(),
    };
    TrainOutput::assemble(ds, &obj, st, record)
}

/// Record `tid` as (currently) the last writer of every element of col `j`.
fn mark_last_writer<M: DataMatrix>(
    ds: &Dataset<M>,
    j: usize,
    tid: u32,
    stamp: u32,
    last_writer: &mut [u32],
    round_stamp: &mut [u32],
) {
    ds.x.for_each_col_index(j, |i| {
        last_writer[i] = tid; // sweep order = thread order ⇒ final value is last writer
        round_stamp[i] = stamp;
    });
}

/// Apply `v += δ·x_j` for vthread `tid`, dropping per-element contributions
/// that lose a same-round RMW race.
#[allow(clippy::too_many_arguments)]
fn apply_wild_axpy<M: DataMatrix>(
    ds: &Dataset<M>,
    j: usize,
    delta: f64,
    tid: u32,
    stamp: u32,
    last_writer: &[u32],
    round_stamp: &[u32],
    params: &WildSimParams,
    placement: &[usize],
    coin: &mut Rng,
    v: &mut [f64],
) {
    let my_node = params.node_of(placement, tid as usize);
    ds.x.for_each_col_entry(j, |i, x| {
        debug_assert_eq!(round_stamp[i], stamp);
        let last = last_writer[i];
        if last != tid {
            // someone writes this element after us this round — we may lose
            let their_node = params.node_of(placement, last as usize);
            let p = if their_node == my_node {
                params.p_collide_local
            } else {
                params.p_collide_remote
            };
            if coin.next_f64() < p {
                return; // our RMW was overwritten: delta lost
            }
        }
        v[i] += delta * x;
    });
}

/// Convergence-faithful simulated runs of the replica solvers: identical
/// model trajectory to real threads (see `solver::exec`), any `T`.
pub fn train_domesticated_sim<M: DataMatrix>(ds: &Dataset<M>, cfg: &SolverConfig) -> TrainOutput {
    crate::solver::dom::train_domesticated_exec(ds, cfg, &Executor::Sequential)
}

/// Simulated NUMA-hierarchical run (see [`train_domesticated_sim`]).
pub fn train_numa_sim<M: DataMatrix>(
    ds: &Dataset<M>,
    cfg: &SolverConfig,
    topo: &Topology,
) -> TrainOutput {
    crate::solver::numa::train_numa_exec(ds, cfg, topo, &Executor::Sequential)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::Objective;
    use crate::data::synthetic;
    use crate::solver::Variant;

    fn cfg(lambda: f64, threads: usize) -> SolverConfig {
        SolverConfig::new(Objective::Logistic { lambda })
            .with_variant(Variant::Wild)
            .with_threads(threads)
            .with_tol(1e-4)
            .with_max_epochs(200)
    }

    #[test]
    fn one_vthread_is_exact_sdca() {
        let ds = synthetic::dense_classification(300, 10, 1);
        let p = WildSimParams::single_node(1);
        let out = train_wild_sim(&ds, &cfg(1.0 / 300.0, 1), &p);
        assert!(out.converged);
        assert!(out.final_gap < 1e-3, "gap={}", out.final_gap);
    }

    #[test]
    fn sparse_scales_in_epochs() {
        // uniform sparse data: almost no collisions → epoch count barely
        // grows with T (the Fig 1b premise)
        let ds = synthetic::sparse_classification(1000, 500, 0.01, 2);
        let p1 = WildSimParams::single_node(1);
        let e1 = train_wild_sim(&ds, &cfg(1.0 / 1000.0, 1), &p1).epochs_run;
        let p8 = WildSimParams::single_node(8);
        let e8 = train_wild_sim(&ds, &cfg(1.0 / 1000.0, 8), &p8).epochs_run;
        assert!(e8 <= e1 * 3, "sparse wild should not blow up: {e1} -> {e8}");
    }

    #[test]
    fn dense_multinode_degrades() {
        // dense data on a 4-node topology at high T: epochs blow up or the
        // run fails to converge (the Fig 1a regime)
        let ds = synthetic::dense_classification(800, 60, 3);
        let c1 = cfg(1.0 / 800.0, 1);
        let base = train_wild_sim(&ds, &c1, &WildSimParams::single_node(1));
        assert!(base.converged);
        let topo = Topology::uniform(4, 4);
        let c16 = cfg(1.0 / 800.0, 16);
        let hot = train_wild_sim(&ds, &c16, &WildSimParams::multi_node(topo));
        let degraded = !hot.converged
            || hot.record.diverged
            || hot.epochs_run > base.epochs_run * 2
            || hot.final_gap > base.final_gap * 10.0;
        assert!(
            degraded,
            "expected wild degradation: base {} epochs (gap {:.1e}), 16T {} epochs (gap {:.1e})",
            base.epochs_run, base.final_gap, hot.epochs_run, hot.final_gap
        );
    }

    #[test]
    fn deterministic() {
        let ds = synthetic::dense_classification(200, 10, 4);
        let p = WildSimParams::single_node(4);
        let a = train_wild_sim(&ds, &cfg(0.01, 4), &p);
        let b = train_wild_sim(&ds, &cfg(0.01, 4), &p);
        assert_eq!(a.state.alpha, b.state.alpha);
        assert_eq!(a.epochs_run, b.epochs_run);
    }

    #[test]
    fn sim_wrappers_converge() {
        let ds = synthetic::dense_classification(300, 10, 5);
        let c = SolverConfig::new(Objective::Logistic { lambda: 1e-3 })
            .with_threads(8)
            .with_tol(1e-5);
        let out = train_domesticated_sim(&ds, &c);
        assert!(out.converged);
        let topo = Topology::uniform(4, 2);
        let out2 = train_numa_sim(&ds, &c, &topo);
        assert!(out2.converged);
    }
}
