//! Evaluation datasets for the figure harnesses.
//!
//! The paper evaluates on criteo-kaggle (45 GB), HIGGS (11M × 28) and
//! epsilon (400k × 2k), plus two synthetic sets (§2). We cannot ship the
//! real corpora; these generators produce stand-ins with the statistics
//! the measured effects depend on (DESIGN.md §4), at a scale that runs in
//! seconds per figure. `paper_workload()` returns the *full-size* shape so
//! the cost model charges paper-scale per-epoch time while epochs come
//! from the scaled run.

use crate::data::{synthetic, AnyDataset};
use crate::simcost::Workload;

/// Which evaluation dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DsKind {
    /// §2 dense synthetic: 100k × 100.
    DenseSynth,
    /// §2 sparse synthetic: 100k × 1k @ 1%.
    SparseSynth,
    /// HIGGS stand-in (11M × 28 dense in the paper).
    HiggsLike,
    /// epsilon stand-in (400k × 2000 dense, unit-norm rows).
    EpsilonLike,
    /// criteo-kaggle stand-in (~45M × 1M sparse, ~39 nnz/row).
    CriteoLike,
}

impl DsKind {
    pub fn name(&self) -> &'static str {
        match self {
            DsKind::DenseSynth => "dense-synth",
            DsKind::SparseSynth => "sparse-synth",
            DsKind::HiggsLike => "higgs-like",
            DsKind::EpsilonLike => "epsilon-like",
            DsKind::CriteoLike => "criteo-like",
        }
    }

    /// The three paper evaluation datasets (Fig. 3–6).
    pub fn eval_trio() -> [DsKind; 3] {
        [DsKind::CriteoLike, DsKind::HiggsLike, DsKind::EpsilonLike]
    }

    /// Build the scaled stand-in (`quick` halves sizes again for CI).
    pub fn make(&self, quick: bool, seed: u64) -> AnyDataset {
        let s = |full: usize, q: usize| if quick { q } else { full };
        match self {
            DsKind::DenseSynth => AnyDataset::Dense(synthetic::dense_classification(
                s(40_000, 6_000),
                100,
                seed,
            )),
            DsKind::SparseSynth => AnyDataset::Sparse(synthetic::sparse_classification(
                s(40_000, 6_000),
                1_000,
                0.01,
                seed,
            )),
            DsKind::HiggsLike => {
                AnyDataset::Dense(synthetic::higgs_like(s(60_000, 8_000), seed))
            }
            DsKind::EpsilonLike => {
                AnyDataset::Dense(synthetic::epsilon_like(s(6_000, 1_500), seed))
            }
            DsKind::CriteoLike => AnyDataset::Sparse(synthetic::criteo_like(
                s(60_000, 8_000),
                s(50_000, 10_000),
                seed,
            )),
        }
    }

    /// Full paper-scale workload shape (feeds the cost model so per-epoch
    /// seconds correspond to the paper's testbed runs).
    pub fn paper_workload(&self) -> Workload {
        match self {
            DsKind::DenseSynth => Workload {
                n: 100_000,
                d: 100,
                nnz: 10_000_000,
                dense: true,
            },
            DsKind::SparseSynth => Workload {
                n: 100_000,
                d: 1_000,
                nnz: 1_000_000,
                dense: false,
            },
            DsKind::HiggsLike => Workload {
                n: 11_000_000,
                d: 28,
                nnz: 11_000_000 * 28,
                dense: true,
            },
            DsKind::EpsilonLike => Workload {
                n: 400_000,
                d: 2_000,
                nnz: 400_000 * 2_000,
                dense: true,
            },
            DsKind::CriteoLike => Workload {
                n: 45_000_000,
                d: 1_000_000,
                nnz: 45_000_000 * 39,
                dense: false,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kinds_build_quick() {
        for kind in [
            DsKind::DenseSynth,
            DsKind::SparseSynth,
            DsKind::HiggsLike,
            DsKind::EpsilonLike,
            DsKind::CriteoLike,
        ] {
            let ds = kind.make(true, 1);
            assert!(ds.n() > 0, "{}", kind.name());
            let w = kind.paper_workload();
            assert!(w.nnz >= w.n, "{}", kind.name());
        }
    }

    #[test]
    fn sparse_kinds_are_sparse() {
        assert!(DsKind::CriteoLike.make(true, 2).is_sparse());
        assert!(DsKind::SparseSynth.make(true, 2).is_sparse());
        assert!(!DsKind::HiggsLike.make(true, 2).is_sparse());
    }
}
