//! Figure 3 — time-to-convergence vs thread count: "wild" vs the
//! "domesticated" solver (this paper), on the three evaluation datasets ×
//! both machines. Also prints the paper's headline comparison: speedup of
//! domesticated over the best *converging* wild configuration.

use super::{bucket_for, run_snap, run_wild, DsKind, FigOpts, SweepPoint};
use crate::metrics::Table;
use crate::simcost::{epoch_seconds, paper_machines, CostOpts, SolverKind};
use crate::solver::Partitioning;
use anyhow::Result;
use std::fmt::Write as _;

pub fn run(opts: &FigOpts) -> Result<()> {
    println!("\n=== Figure 3: time to convergence, wild vs domesticated ===");
    let mut csv =
        String::from("machine,dataset,solver,threads,epochs,converged,diverged,epoch_s,total_s\n");
    let mut speedups = Vec::new();
    for machine in paper_machines() {
        for kind in DsKind::eval_trio() {
            let ds = kind.make(opts.quick, opts.seed);
            let w = kind.paper_workload();
            let bucket = bucket_for(kind, &machine);
            let grid = opts.thread_grid(&machine);
            let mut table = Table::new(&[
                "threads", "wild-ep", "wild-s", "dom-ep", "dom-s", "dom/wild",
            ]);
            let mut best_wild: Option<f64> = None;
            let mut best_dom: Option<f64> = None;
            for &t in &grid {
                let mut wild: SweepPoint = run_wild(&ds, &machine, t, opts.seed, 10.0);
                wild.epoch_s = epoch_seconds(&machine, &w, SolverKind::Wild, &CostOpts::new(t));
                let mut dom: SweepPoint =
                    run_snap(&ds, &machine, t, Partitioning::Dynamic, bucket, opts.seed, 10.0);
                let mut o = CostOpts::new(t);
                o.bucket_size = bucket;
                o.numa_aware = true;
                dom.epoch_s = epoch_seconds(
                    &machine,
                    &w,
                    SolverKind::Numa(Partitioning::Dynamic),
                    &o,
                );
                // paper: compare against the best wild config "that
                // converges to a similar test loss" — i.e. correct ones
                if wild.correct {
                    let tt = wild.total_s();
                    best_wild = Some(best_wild.map_or(tt, |b: f64| b.min(tt)));
                }
                if dom.correct {
                    let tt = dom.total_s();
                    best_dom = Some(best_dom.map_or(tt, |b: f64| b.min(tt)));
                }
                let ratio = if wild.correct && dom.correct {
                    format!("{:.1}x", wild.total_s() / dom.total_s())
                } else {
                    "-".into()
                };
                table.row(&[
                    t.to_string(),
                    wild.verdict(),
                    if wild.converged {
                        format!("{:.2}", wild.total_s())
                    } else {
                        "-".into()
                    },
                    dom.verdict(),
                    format!("{:.2}", dom.total_s()),
                    ratio,
                ]);
                for (name, pt) in [("wild", &wild), ("dom", &dom)] {
                    let _ = writeln!(
                        csv,
                        "{},{},{name},{t},{},{},{},{:.6},{:.4}",
                        machine.name,
                        kind.name(),
                        pt.epochs,
                        pt.converged,
                        pt.diverged,
                        pt.epoch_s,
                        pt.total_s()
                    );
                }
            }
            println!("\n[{} | {}] (bucket={bucket})", machine.name, kind.name());
            print!("{}", table.render());
            if let (Some(bw), Some(bd)) = (best_wild, best_dom) {
                let s = bw / bd;
                println!("headline: best-wild {bw:.2}s / best-dom {bd:.2}s = ×{s:.1}");
                speedups.push(s);
            } else if best_dom.is_some() {
                println!("headline: wild never converged — domesticated wins outright");
            }
        }
    }
    if !speedups.is_empty() {
        println!(
            "\nAverage convergence speedup over best wild (geomean): ×{:.1} (paper: ×5.1 avg, ×12 max)",
            crate::util::geomean(&speedups)
        );
    }
    opts.write_csv("fig3_time_to_convergence.csv", &csv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_runs_quick() {
        let mut opts = FigOpts::quick();
        opts.out_dir = std::env::temp_dir().join("parlin_fig3_test");
        run(&opts).unwrap();
        assert!(opts.out_dir.join("fig3_time_to_convergence.csv").exists());
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
