//! Regeneration harnesses for every figure in the paper's evaluation
//! (DESIGN.md §6 maps figure → harness → modules).
//!
//! Methodology (per DESIGN.md §4/§5): *epochs-to-converge*, convergence
//! verdicts and test losses are **measured** by really executing each
//! algorithm (the vthread engine supplies any logical thread count on this
//! host); *seconds per epoch* on the paper's testbeds come from the
//! `simcost` machine models at paper-scale workload shapes. Each harness
//! prints a table mirroring the paper's plot and writes a CSV under
//! `artifacts/figures/`.

pub mod datasets;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;

pub use datasets::DsKind;

use crate::data::AnyDataset;
use crate::glm::Objective;
use crate::simcost::MachineModel;
use crate::solver::{BucketPolicy, Partitioning, SolverConfig, Variant};
use crate::sysinfo::Topology;
use anyhow::Result;
use std::path::PathBuf;

/// Dispatch a generic closure over the concrete dataset type.
#[macro_export]
macro_rules! with_ds {
    ($any:expr, $ds:ident => $body:expr) => {
        match $any {
            $crate::data::AnyDataset::Dense($ds) => $body,
            $crate::data::AnyDataset::Sparse($ds) => $body,
        }
    };
}
pub use crate::with_ds;

/// Options shared by all figure harnesses.
#[derive(Clone, Debug)]
pub struct FigOpts {
    /// Smaller datasets / sparser thread grids (CI mode).
    pub quick: bool,
    /// Where CSVs land (`artifacts/figures`).
    pub out_dir: PathBuf,
    pub seed: u64,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts {
            quick: false,
            out_dir: PathBuf::from("artifacts/figures"),
            seed: 42,
        }
    }
}

impl FigOpts {
    pub fn quick() -> Self {
        FigOpts {
            quick: true,
            ..Default::default()
        }
    }

    pub(crate) fn write_csv(&self, name: &str, content: &str) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = self.out_dir.join(name);
        std::fs::write(&path, content)?;
        println!("  -> {}", path.display());
        Ok(())
    }

    /// Thread sweep matching the paper's x-axes.
    pub(crate) fn thread_grid(&self, machine: &MachineModel) -> Vec<usize> {
        let max = machine.topology.total_cores();
        let full: Vec<usize> = [1usize, 2, 4, 8, 16, 32, 40]
            .iter()
            .copied()
            .filter(|&t| t <= max)
            .collect();
        if self.quick {
            full.into_iter().filter(|&t| t <= 8 || t == max).collect()
        } else {
            full
        }
    }
}

/// Relative duality-gap threshold above which a "converged" run is flagged
/// as an *incorrect solution* (gap / primal > this) — the paper verifies
/// all implementations reach the same test loss "apart from the wild
/// implementation which can converge to an incorrect solution when using
/// many threads" (§4, citing PASSCoDe). On the Fig-1 dense workload this
/// admits wild at 4–8 threads and rejects 16–32, matching the paper's
/// choice of "best wild that converges to a similar test loss".
pub const CORRECTNESS_REL_GAP: f64 = 0.05;

/// Certify a finished run: converged and gap small relative to the primal.
pub(crate) fn certify(out: &crate::solver::TrainOutput, primal_scale: f64) -> bool {
    out.converged && out.final_gap < CORRECTNESS_REL_GAP * primal_scale.max(1e-12)
}

/// Result of one measured training run in a figure sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    pub threads: usize,
    pub epochs: usize,
    pub converged: bool,
    pub diverged: bool,
    /// Stopping criterion fired AND the solution is certified by a small
    /// duality gap (see [`CORRECTNESS_GAP`]).
    pub correct: bool,
    /// Modeled seconds per epoch on the figure's machine.
    pub epoch_s: f64,
}

impl SweepPoint {
    pub fn total_s(&self) -> f64 {
        self.epochs as f64 * self.epoch_s
    }

    /// The paper marks non-converging points in red; we print `FAIL`, and
    /// `WRONG` for runs that settled on an incorrect solution.
    pub fn verdict(&self) -> String {
        if self.diverged {
            "DIVERGED".into()
        } else if !self.converged {
            "FAIL".into()
        } else if !self.correct {
            format!("{} (WRONG)", self.epochs)
        } else {
            format!("{}", self.epochs)
        }
    }
}

/// λ = mult/n. SDCA convention is λ = Θ(1/n). Fig. 1/2 replicate the
/// paper's §2 synthetic experiment at mult = 1; the Fig. 3/5/6 dataset
/// stand-ins run at mult = 10 so the *reduced-scale* problems keep a
/// conditioning comparable to the paper's full-size datasets (at 1/n the
/// small-n stand-ins are an order of magnitude less regularized, which
/// inflates partitioned-solver epochs beyond the paper's regime — see
/// EXPERIMENTS.md §Scale).
pub(crate) fn lambda_for(ds: &AnyDataset, mult: f64) -> f64 {
    mult / ds.n() as f64
}

/// Bucket size per the paper's runtime heuristic *evaluated at paper
/// scale* on the given machine (model vector vs LLC).
pub(crate) fn bucket_for(kind: DsKind, machine: &MachineModel) -> usize {
    let w = kind.paper_workload();
    BucketPolicy::Auto.resolve(w.n, machine.cache_line, machine.llc_bytes)
}

/// Base solver config for figure runs.
pub(crate) fn fig_config(
    ds: &AnyDataset,
    threads: usize,
    bucket: usize,
    seed: u64,
    lam_mult: f64,
) -> SolverConfig {
    SolverConfig::new(Objective::Logistic {
        lambda: lambda_for(ds, lam_mult),
    })
    .with_threads(threads)
    .with_tol(1e-3)
    .with_max_epochs(400)
    .with_bucket(if bucket > 1 {
        BucketPolicy::Fixed(bucket)
    } else {
        BucketPolicy::Off
    })
    .with_seed(seed)
}

/// Measured epochs of the **wild** solver at `threads` logical threads
/// under `machine`'s collision parameters.
pub fn run_wild(
    ds: &AnyDataset,
    machine: &MachineModel,
    threads: usize,
    seed: u64,
    lam_mult: f64,
) -> SweepPoint {
    let params = machine.wild_params(threads);
    let cfg = fig_config(ds, threads, 1, seed, lam_mult);
    let out = with_ds!(ds, d => crate::vthread::train_wild_sim(d, &cfg, &params));
    SweepPoint {
        threads,
        epochs: out.epochs_run,
        converged: out.converged,
        diverged: out.record.diverged,
        correct: certify(&out, out.final_primal),
        epoch_s: 0.0,
    }
}

/// Measured epochs of the paper's solver ("snap"): domesticated while the
/// threads fit one node, hierarchical numa beyond (§3 runtime policy).
pub fn run_snap(
    ds: &AnyDataset,
    machine: &MachineModel,
    threads: usize,
    partitioning: Partitioning,
    bucket: usize,
    seed: u64,
    lam_mult: f64,
) -> SweepPoint {
    let topo: Topology = machine.topology.clone();
    let mut cfg = fig_config(ds, threads, bucket, seed, lam_mult).with_partition(partitioning);
    let node_cores = topo.cores_per_node[topo.data_node];
    let out = if threads <= 1 {
        cfg.variant = Variant::Sequential;
        with_ds!(ds, d => crate::solver::seq::train_sequential(d, &cfg))
    } else if threads <= node_cores {
        with_ds!(ds, d => crate::vthread::train_domesticated_sim(d, &cfg))
    } else {
        with_ds!(ds, d => crate::vthread::train_numa_sim(d, &cfg, &topo))
    };
    SweepPoint {
        threads,
        epochs: out.epochs_run,
        converged: out.converged,
        diverged: out.record.diverged,
        correct: certify(&out, out.final_primal),
        epoch_s: 0.0,
    }
}

/// Run one figure (or all) by id.
pub fn run_figure(id: &str, opts: &FigOpts) -> Result<()> {
    match id {
        "1" => fig1::run(opts),
        "2" | "2a" | "2b" => fig2::run(opts),
        "3" => fig3::run(opts),
        "4" => fig4::run(opts),
        "5" => fig5::run(opts),
        "6" => fig6::run(opts),
        "all" => {
            for f in ["1", "2", "3", "4", "5", "6"] {
                run_figure(f, opts)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown figure '{other}' (1, 2, 3, 4, 5, 6, all)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_grids_respect_machine() {
        let opts = FigOpts::default();
        let g = opts.thread_grid(&crate::simcost::xeon4());
        assert_eq!(g, vec![1, 2, 4, 8, 16, 32]);
        let g = opts.thread_grid(&crate::simcost::power9());
        assert_eq!(g, vec![1, 2, 4, 8, 16, 32, 40]);
    }

    #[test]
    fn bucket_heuristic_at_paper_scale() {
        let xeon = crate::simcost::xeon4();
        // higgs: 11M examples · 8 B = 88 MB > 16 MiB LLC ⇒ bucket 8
        assert_eq!(bucket_for(DsKind::HiggsLike, &xeon), 8);
        // epsilon: 400k · 8B = 3.2 MB < LLC ⇒ no bucketing (paper §4)
        assert_eq!(bucket_for(DsKind::EpsilonLike, &xeon), 1);
        // criteo on power9: 128 B lines ⇒ bucket 16
        assert_eq!(
            bucket_for(DsKind::CriteoLike, &crate::simcost::power9()),
            16
        );
    }

    #[test]
    fn run_wild_and_snap_smoke() {
        let opts = FigOpts::quick();
        let ds = DsKind::DenseSynth.make(true, opts.seed);
        let m = crate::simcost::xeon4();
        let w = run_wild(&ds, &m, 2, 1, 1.0);
        assert!(w.epochs > 0);
        let s = run_snap(&ds, &m, 4, Partitioning::Dynamic, 1, 1, 1.0);
        assert!(s.converged, "snap must converge: {s:?}");
    }

    #[test]
    fn unknown_figure_errors() {
        assert!(run_figure("99", &FigOpts::quick()).is_err());
    }
}
