//! Figure 1 — training time of the **wild** multi-threaded solver on the
//! two §2 synthetic datasets, on one vs four NUMA nodes of the Xeon.
//!
//! Reproduction targets: (a) dense — barely scales on one node, collapses
//! (or diverges, red in the paper) across nodes; (b) sparse — scales well
//! on one node, deteriorates across nodes.

use super::{run_wild, DsKind, FigOpts, SweepPoint};
use crate::metrics::Table;
use crate::simcost::{epoch_seconds, xeon4, CostOpts, SolverKind};
use crate::sysinfo::Topology;
use anyhow::Result;
use std::fmt::Write as _;

pub fn run(opts: &FigOpts) -> Result<()> {
    println!("\n=== Figure 1: wild solver, 1 vs 4 numa nodes (xeon4) ===");
    let mut csv = String::from("dataset,nodes,threads,epochs,converged,diverged,epoch_s,total_s\n");
    for kind in [DsKind::DenseSynth, DsKind::SparseSynth] {
        let ds = kind.make(opts.quick, opts.seed);
        let w = kind.paper_workload();
        for nodes in [1usize, 4] {
            let mut machine = xeon4();
            if nodes == 1 {
                // the paper pins the solver to a single node
                machine.topology = Topology::flat(8);
            }
            let grid: Vec<usize> = opts
                .thread_grid(&machine)
                .into_iter()
                .filter(|&t| t <= machine.topology.total_cores())
                .collect();
            let mut table = Table::new(&["threads", "epochs", "epoch_s", "total_s", "speedup"]);
            let mut base_total = None;
            for &t in &grid {
                let mut pt: SweepPoint = run_wild(&ds, &machine, t, opts.seed, 1.0);
                pt.epoch_s = epoch_seconds(&machine, &w, SolverKind::Wild, &CostOpts::new(t));
                let total = pt.total_s();
                if t == 1 {
                    base_total = Some(total);
                }
                let speedup = base_total
                    .map(|b| if pt.correct { b / total } else { f64::NAN })
                    .unwrap_or(f64::NAN);
                table.row(&[
                    t.to_string(),
                    pt.verdict(),
                    format!("{:.4}", pt.epoch_s),
                    if pt.correct {
                        format!("{total:.2}")
                    } else {
                        "-".into()
                    },
                    if speedup.is_nan() {
                        "-".into()
                    } else {
                        format!("{speedup:.2}x")
                    },
                ]);
                let _ = writeln!(
                    csv,
                    "{},{},{},{},{},{},{:.6},{:.4}",
                    kind.name(),
                    nodes,
                    t,
                    pt.epochs,
                    pt.converged,
                    pt.diverged,
                    pt.epoch_s,
                    total
                );
            }
            println!("\n[{} | {} node(s)]", kind.name(), nodes);
            print!("{}", table.render());
        }
    }
    opts.write_csv("fig1_wild_scaling.csv", &csv)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_runs_quick() {
        let mut opts = FigOpts::quick();
        opts.out_dir = std::env::temp_dir().join("parlin_fig1_test");
        run(&opts).unwrap();
        assert!(opts.out_dir.join("fig1_wild_scaling.csv").exists());
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
