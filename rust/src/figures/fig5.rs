//! Figure 5 — ablations of the three proposed optimizations on the 4-node
//! Xeon (solid lines = time, dashed = epochs in the paper; we print both):
//!
//! * (a) static vs **dynamic** partitioning,
//! * (b) buckets on vs off,
//! * (c) NUMA-aware hierarchy vs flat threading.

use super::{bucket_for, fig_config, run_snap, with_ds, DsKind, FigOpts};
use crate::metrics::Table;
use crate::simcost::{epoch_seconds, xeon4, CostOpts, SolverKind};
use crate::solver::Partitioning;
use anyhow::Result;
use std::fmt::Write as _;

pub fn run(opts: &FigOpts) -> Result<()> {
    fig5a(opts)?;
    fig5b(opts)?;
    fig5c(opts)
}

/// (a) static vs dynamic partitioning: epochs measured, time = epochs ×
/// modeled epoch (identical epoch cost up to the shuffle term).
fn fig5a(opts: &FigOpts) -> Result<()> {
    println!("\n=== Figure 5a: static vs dynamic partitioning (xeon4) ===");
    let machine = xeon4();
    let mut csv = String::from("dataset,threads,scheme,epochs,total_s\n");
    let mut improvements = Vec::new();
    for kind in [DsKind::CriteoLike, DsKind::EpsilonLike, DsKind::HiggsLike] {
        let ds = kind.make(opts.quick, opts.seed);
        let w = kind.paper_workload();
        let bucket = bucket_for(kind, &machine);
        let mut table = Table::new(&[
            "threads", "static-ep", "static-s", "dynamic-ep", "dynamic-s", "gain",
        ]);
        for &t in &opts.thread_grid(&machine) {
            if t < 2 {
                continue;
            }
            let mut results = Vec::new();
            for scheme in [Partitioning::Static, Partitioning::Dynamic] {
                let mut pt = run_snap(&ds, &machine, t, scheme, bucket, opts.seed, 10.0);
                let mut o = CostOpts::new(t);
                o.bucket_size = bucket;
                o.numa_aware = true;
                pt.epoch_s = epoch_seconds(&machine, &w, SolverKind::Numa(scheme), &o);
                let _ = writeln!(
                    csv,
                    "{},{t},{scheme:?},{},{:.4}",
                    kind.name(),
                    pt.epochs,
                    pt.total_s()
                );
                results.push(pt);
            }
            let (st, dy) = (results[0], results[1]);
            let gain = 1.0 - dy.total_s() / st.total_s();
            if st.converged && dy.converged {
                improvements.push(gain);
            }
            table.row(&[
                t.to_string(),
                st.verdict(),
                format!("{:.2}", st.total_s()),
                dy.verdict(),
                format!("{:.2}", dy.total_s()),
                format!("{:.0}%", gain * 100.0),
            ]);
        }
        println!("\n[{}]", kind.name());
        print!("{}", table.render());
    }
    println!(
        "mean training-time gain from dynamic partitioning: {:.0}% (paper: 49% criteo, 67% epsilon, ~0% higgs)",
        crate::util::mean(&improvements) * 100.0
    );
    opts.write_csv("fig5a_partitioning.csv", &csv)
}

/// (b) bucket optimization on/off: epochs measured with/without buckets,
/// epoch time modeled with/without the cache-line batching.
fn fig5b(opts: &FigOpts) -> Result<()> {
    println!("\n=== Figure 5b: bucket optimization (xeon4) ===");
    let machine = xeon4();
    let mut csv = String::from("dataset,threads,buckets,epochs,total_s\n");
    for kind in [DsKind::CriteoLike, DsKind::HiggsLike, DsKind::EpsilonLike] {
        let ds = kind.make(opts.quick, opts.seed);
        let w = kind.paper_workload();
        let auto_bucket = bucket_for(kind, &machine);
        let mut table = Table::new(&["threads", "off-ep", "off-s", "on-ep", "on-s", "speedup"]);
        for &t in &opts.thread_grid(&machine) {
            let mut row = Vec::new();
            let mut totals = Vec::new();
            for bucket in [1usize, auto_bucket.max(machine.entries_per_line())] {
                let mut pt =
                    run_snap(&ds, &machine, t, Partitioning::Dynamic, bucket, opts.seed, 10.0);
                let mut o = CostOpts::new(t);
                o.bucket_size = bucket;
                o.numa_aware = true;
                let kind_sim = if t == 1 {
                    SolverKind::Sequential
                } else {
                    SolverKind::Numa(Partitioning::Dynamic)
                };
                pt.epoch_s = epoch_seconds(&machine, &w, kind_sim, &o);
                row.push(pt.verdict());
                row.push(format!("{:.2}", pt.total_s()));
                totals.push(pt.total_s());
                let _ = writeln!(
                    csv,
                    "{},{t},{bucket},{},{:.4}",
                    kind.name(),
                    pt.epochs,
                    pt.total_s()
                );
            }
            let speedup = totals[0] / totals[1];
            let mut cells = vec![t.to_string()];
            cells.extend(row);
            cells.push(format!("{speedup:.2}x"));
            table.row(&cells);
        }
        let note = if auto_bucket == 1 {
            " (heuristic would DISABLE buckets: model fits LLC — paper §4 epsilon case)"
        } else {
            ""
        };
        println!("\n[{}]{}", kind.name(), note);
        print!("{}", table.render());
    }
    opts.write_csv("fig5b_buckets.csv", &csv)
}

/// (c) NUMA-aware hierarchy vs flat (numa-oblivious) threading.
fn fig5c(opts: &FigOpts) -> Result<()> {
    println!("\n=== Figure 5c: numa-aware hierarchy vs flat threading (xeon4) ===");
    let machine = xeon4();
    let mut csv = String::from("dataset,threads,numa_aware,epochs,total_s\n");
    for kind in DsKind::eval_trio() {
        let ds = kind.make(opts.quick, opts.seed);
        let w = kind.paper_workload();
        let bucket = bucket_for(kind, &machine);
        let mut table = Table::new(&["threads", "flat-ep", "flat-s", "numa-ep", "numa-s", "gain"]);
        for &t in &opts.thread_grid(&machine) {
            if t <= machine.topology.cores_per_node[0] {
                continue; // numa handling only differs beyond one node
            }
            // flat: dynamic partitioning across all threads, oblivious
            // placement (remote streaming, cross-node merges)
            let cfg = fig_config(&ds, t, bucket, opts.seed, 10.0)
                .with_partition(Partitioning::Dynamic);
            let flat_out = with_ds!(&ds, d => crate::vthread::train_domesticated_sim(d, &cfg));
            let mut o_flat = CostOpts::new(t);
            o_flat.bucket_size = bucket;
            o_flat.numa_aware = false;
            let flat_es = epoch_seconds(
                &machine,
                &w,
                SolverKind::Domesticated(Partitioning::Dynamic),
                &o_flat,
            );
            let flat_total = flat_out.epochs_run as f64 * flat_es;
            // numa-aware hierarchical
            let mut numa =
                run_snap(&ds, &machine, t, Partitioning::Dynamic, bucket, opts.seed, 10.0);
            let mut o = CostOpts::new(t);
            o.bucket_size = bucket;
            o.numa_aware = true;
            numa.epoch_s = epoch_seconds(&machine, &w, SolverKind::Numa(Partitioning::Dynamic), &o);
            let gain = 1.0 - numa.total_s() / flat_total;
            table.row(&[
                t.to_string(),
                flat_out.epochs_run.to_string(),
                format!("{flat_total:.2}"),
                numa.verdict(),
                format!("{:.2}", numa.total_s()),
                format!("{:.0}%", gain * 100.0),
            ]);
            let _ = writeln!(
                csv,
                "{},{t},false,{},{flat_total:.4}",
                kind.name(),
                flat_out.epochs_run
            );
            let _ = writeln!(
                csv,
                "{},{t},true,{},{:.4}",
                kind.name(),
                numa.epochs,
                numa.total_s()
            );
        }
        println!("\n[{}]", kind.name());
        print!("{}", table.render());
    }
    opts.write_csv("fig5c_numa.csv", &csv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_runs_quick() {
        let mut opts = FigOpts::quick();
        opts.out_dir = std::env::temp_dir().join("parlin_fig5_test");
        run(&opts).unwrap();
        for f in ["fig5a_partitioning.csv", "fig5b_buckets.csv", "fig5c_numa.csv"] {
            assert!(opts.out_dir.join(f).exists(), "{f}");
        }
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
