//! Figure 4 — strong scalability of the domesticated implementation
//! w.r.t. *time per epoch* (speedup over the sequential version), per
//! dataset and machine. Pure epoch-cost comparison — convergence plays no
//! role here, matching the paper's metric.

use super::{bucket_for, DsKind, FigOpts};
use crate::metrics::Table;
use crate::simcost::{epoch_time, paper_machines, CostOpts, SolverKind};
use crate::solver::Partitioning;
use anyhow::Result;
use std::fmt::Write as _;

pub fn run(opts: &FigOpts) -> Result<()> {
    println!("\n=== Figure 4: strong scaling of per-epoch time (domesticated) ===");
    let mut csv = String::from("machine,dataset,threads,epoch_s,speedup\n");
    for machine in paper_machines() {
        for kind in DsKind::eval_trio() {
            let w = kind.paper_workload();
            let bucket = bucket_for(kind, &machine);
            let mut o1 = CostOpts::new(1);
            o1.bucket_size = bucket;
            o1.numa_aware = true;
            let t1 = epoch_time(&machine, &w, SolverKind::Sequential, &o1).total();
            let mut table = Table::new(&["threads", "epoch_s", "speedup", "ideal"]);
            for &t in &opts.thread_grid(&machine) {
                let mut o = CostOpts::new(t);
                o.bucket_size = bucket;
                o.numa_aware = true;
                let kind_sim = if t <= machine.topology.cores_per_node[0] {
                    SolverKind::Domesticated(Partitioning::Dynamic)
                } else {
                    SolverKind::Numa(Partitioning::Dynamic)
                };
                let es = epoch_time(&machine, &w, kind_sim, &o).total();
                let speedup = t1 / es;
                table.row(&[
                    t.to_string(),
                    format!("{es:.4}"),
                    format!("{speedup:.1}x"),
                    format!("{t}x"),
                ]);
                let _ = writeln!(csv, "{},{},{t},{es:.6},{speedup:.3}", machine.name, kind.name());
            }
            println!("\n[{} | {}]", machine.name, kind.name());
            print!("{}", table.render());
        }
    }
    opts.write_csv("fig4_strong_scaling.csv", &csv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_runs_quick() {
        let mut opts = FigOpts::quick();
        opts.out_dir = std::env::temp_dir().join("parlin_fig4_test");
        run(&opts).unwrap();
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }

    #[test]
    fn scaling_is_mostly_monotone() {
        // per-epoch time should not increase with threads for the
        // numa-aware solver (the property Fig 4 plots)
        let m = crate::simcost::xeon4();
        let w = DsKind::CriteoLike.paper_workload();
        let mut prev = f64::INFINITY;
        for t in [1usize, 2, 4, 8, 16, 32] {
            let mut o = CostOpts::new(t);
            o.bucket_size = 8;
            o.numa_aware = true;
            let es = epoch_time(&m, &w, SolverKind::Numa(Partitioning::Dynamic), &o).total();
            assert!(
                es <= prev * 1.05,
                "epoch time rose at T={t}: {prev} -> {es}"
            );
            prev = es;
        }
    }
}
