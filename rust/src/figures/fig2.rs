//! Figure 2 — (a) where the wild solver's scalability goes: per-epoch
//! speedup of the original algorithm vs variants with shared updates
//! disabled and with shuffling disabled; (b) the CoCoA partitioning
//! trade-off: epochs and time to converge vs number of partitions
//! (1 per thread) under *static* partitioning.

use super::{fig_config, with_ds, DsKind, FigOpts};
use crate::metrics::Table;
use crate::simcost::{epoch_time, xeon4, CostOpts, SolverKind};
use crate::solver::Partitioning;
use anyhow::Result;
use std::fmt::Write as _;

pub fn run(opts: &FigOpts) -> Result<()> {
    fig2a(opts)?;
    fig2b(opts)
}

/// (a): per-epoch scaling decomposition on the dense synthetic dataset.
/// "no shared updates" removes the coherence term; "no shuffle" removes
/// the serial shuffle term — exactly the ablations the paper plots.
fn fig2a(opts: &FigOpts) -> Result<()> {
    println!("\n=== Figure 2a: wild per-epoch scalability ablations (dense, xeon4) ===");
    let machine = xeon4();
    let w = DsKind::DenseSynth.paper_workload();
    let mut csv = String::from("threads,original_s,no_shared_s,no_shuffle_s,neither_s\n");
    let mut table = Table::new(&[
        "threads",
        "original",
        "-shared",
        "-shuffle",
        "-both",
        "speedup(-both)",
    ]);
    let t1_base = {
        let b = epoch_time(&machine, &w, SolverKind::Wild, &CostOpts::new(1));
        b.total()
    };
    for &t in &opts.thread_grid(&machine) {
        let o = CostOpts::new(t);
        let full = epoch_time(&machine, &w, SolverKind::Wild, &o);
        let no_shared = full.total() - full.shared;
        let no_shuffle = full.total() - full.shuffle;
        let neither = full.total() - full.shared - full.shuffle;
        table.row(&[
            t.to_string(),
            format!("{:.4}", full.total()),
            format!("{no_shared:.4}"),
            format!("{no_shuffle:.4}"),
            format!("{neither:.4}"),
            format!("{:.1}x", t1_base / neither),
        ]);
        let _ = writeln!(
            csv,
            "{t},{:.6},{no_shared:.6},{no_shuffle:.6},{neither:.6}",
            full.total()
        );
    }
    print!("{}", table.render());
    opts.write_csv("fig2a_ablation.csv", &csv)
}

/// (b): static (CoCoA) partitions vs epochs & time on the dense dataset.
fn fig2b(opts: &FigOpts) -> Result<()> {
    println!("\n=== Figure 2b: CoCoA partitions (static, 1/thread) — dense synth ===");
    let machine = xeon4();
    let ds = DsKind::DenseSynth.make(opts.quick, opts.seed);
    let w = DsKind::DenseSynth.paper_workload();
    let mut csv = String::from("partitions,epochs,epoch_s,total_s\n");
    let mut table = Table::new(&["partitions", "epochs", "epoch_s", "total_s"]);
    for &k in &opts.thread_grid(&machine) {
        let cfg = fig_config(&ds, k, 1, opts.seed, 1.0).with_partition(Partitioning::Static);
        let out = with_ds!(&ds, d => crate::vthread::train_domesticated_sim(d, &cfg));
        let mut o = CostOpts::new(k);
        o.numa_aware = true;
        let es =
            epoch_time(&machine, &w, SolverKind::Domesticated(Partitioning::Static), &o).total();
        let total = out.epochs_run as f64 * es;
        table.row(&[
            k.to_string(),
            out.epochs_run.to_string(),
            format!("{es:.4}"),
            format!("{total:.2}"),
        ]);
        let _ = writeln!(csv, "{k},{},{es:.6},{total:.4}", out.epochs_run);
    }
    print!("{}", table.render());
    println!("(epochs grow with partitions — the degradation dynamic partitioning removes)");
    opts.write_csv("fig2b_cocoa_partitions.csv", &csv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_runs_quick() {
        let mut opts = FigOpts::quick();
        opts.out_dir = std::env::temp_dir().join("parlin_fig2_test");
        run(&opts).unwrap();
        assert!(opts.out_dir.join("fig2a_ablation.csv").exists());
        assert!(opts.out_dir.join("fig2b_cocoa_partitions.csv").exists());
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
