//! Figure 6 — training time vs test loss: our solver (1 thread and max
//! threads) against the scikit-learn solver classes (liblinear / lbfgs /
//! sag) and H2O's auto solver, on the three evaluation datasets × both
//! machines.
//!
//! Test loss is **measured** (held-out stand-in set, same generator,
//! different seed). Training time = measured passes × modeled per-pass
//! cost on the figure's machine; each baseline's pass cost charges its own
//! algorithmic extras (L-BFGS line-search evaluations, SAG's dense `w`
//! update per step, IRLSM's Hessian assembly + Cholesky).

use super::{bucket_for, lambda_for, run_snap, with_ds, DsKind, FigOpts};
use crate::baselines::{dual_cd, h2o_auto, lbfgs, sag, BaselineConfig};
use crate::data::AnyDataset;
use crate::glm::Objective;
use crate::metrics::Table;
use crate::simcost::{epoch_seconds, paper_machines, CostOpts, MachineModel, SolverKind, Workload};
use crate::solver::Partitioning;
use anyhow::Result;
use std::fmt::Write as _;

/// Modeled seconds for one full pass of a given baseline at paper scale.
fn baseline_pass_s(machine: &MachineModel, w: &Workload, which: &str) -> f64 {
    let compute = |flops: f64| flops / (machine.core_flops() * machine.compute_eff);
    let stream = w.stream_bytes() / machine.stream_bw;
    let sweep = compute(2.0 * w.nnz as f64) + stream;
    match which {
        // cyclic dual CD: one sweep + random α access (no buckets)
        "liblinear" => sweep + w.n as f64 * machine.local_line_s * 0.5,
        // L-BFGS: gradient pass + ~1.5 line-search objective passes
        "lbfgs" => 2.5 * sweep,
        // SAG: dense data pays the full `w` update per step (n·d flops +
        // bytes); sparse data uses scikit-learn's lazy just-in-time
        // updates, costing only another sweep's worth of work
        "sag" => {
            if w.dense {
                sweep
                    + compute(2.0 * (w.n * w.d) as f64)
                    + (w.n * w.d * 8) as f64 / machine.stream_bw
            } else {
                2.0 * sweep
            }
        }
        // H2O auto = IRLSM (gradient pass + Hessian assembly nnz·d +
        // Cholesky d³/3) up to its ~5000-predictor limit — epsilon's 2k
        // features stay on IRLSM, which is why the paper finds H2O "by far
        // the slowest" there; criteo's 1M features fall back to L-BFGS
        // (the paper could not run H2O on criteo at all, footnote 2)
        "h2o" => {
            if w.d <= 5_000 {
                sweep + compute((w.nnz * w.d) as f64) + compute(w.d.pow(3) as f64 / 3.0)
            } else {
                2.5 * sweep
            }
        }
        _ => sweep,
    }
}

/// Measured test loss of weights `w` on the held-out split.
fn test_loss_of(test: &AnyDataset, lambda: f64, w: &[f64]) -> f64 {
    let obj = Objective::Logistic { lambda };
    with_ds!(test, d => {
        let idx: Vec<usize> = (0..d.n()).collect();
        crate::glm::test_loss(d, &obj, w, &idx)
    })
}

pub fn run(opts: &FigOpts) -> Result<()> {
    println!("\n=== Figure 6: solver comparison (train time vs test loss) ===");
    let mut csv = String::from("machine,dataset,solver,passes,modeled_s,test_loss\n");
    for machine in paper_machines() {
        let max_t = machine.topology.total_cores();
        for kind in DsKind::eval_trio() {
            // hold out 20% of the stand-in as the test set (same
            // generator draw ⇒ same ground truth, disjoint examples)
            let (ds, test) = kind.make(opts.quick, opts.seed).split(0.2, opts.seed ^ 0x7e57);
            let w_shape = kind.paper_workload();
            let lambda = lambda_for(&ds, 10.0);
            let bucket = bucket_for(kind, &machine);
            let bcfg = BaselineConfig::new(Objective::Logistic { lambda })
                .with_tol(1e-5)
                .with_max_epochs(if opts.quick { 60 } else { 150 });
            let mut table = Table::new(&["solver", "passes", "time_s", "test_loss"]);
            let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();

            // ---- snap 1T and snap MT (this paper)
            for (label, threads) in [("snap.ml 1T", 1usize), ("snap.ml MT", max_t)] {
                let pt = run_snap(
                    &ds,
                    &machine,
                    threads,
                    Partitioning::Dynamic,
                    bucket,
                    opts.seed,
                    10.0,
                );
                let mut o = CostOpts::new(threads);
                o.bucket_size = bucket;
                o.numa_aware = true;
                let kind_sim = if threads == 1 {
                    SolverKind::Sequential
                } else {
                    SolverKind::Numa(Partitioning::Dynamic)
                };
                let es = epoch_seconds(&machine, &w_shape, kind_sim, &o);
                // retrain to extract weights (run_snap reports epochs only)
                let cfg = super::fig_config(&ds, threads, bucket, opts.seed, 10.0)
                    .with_partition(Partitioning::Dynamic)
                    .with_tol(1e-3);
                let out = if threads == 1 {
                    with_ds!(&ds, d => crate::solver::seq::train_sequential(d, &cfg))
                } else {
                    let topo = machine.topology.clone();
                    with_ds!(&ds, d => crate::vthread::train_numa_sim(d, &cfg, &topo))
                };
                let wv = out.weights(&Objective::Logistic { lambda });
                let tl = test_loss_of(&test, lambda, &wv);
                rows.push((label.into(), pt.epochs as f64, pt.epochs as f64 * es, tl));
            }

            // ---- baseline classes
            let runs: Vec<(&str, &str, crate::baselines::BaselineOutput)> = vec![
                (
                    "sklearn liblinear",
                    "liblinear",
                    with_ds!(&ds, d => dual_cd::train_dual_cd(d, &bcfg)),
                ),
                ("sklearn lbfgs", "lbfgs", with_ds!(&ds, d => lbfgs::train_lbfgs(d, &bcfg))),
                ("sklearn sag", "sag", with_ds!(&ds, d => sag::train_sag(d, &bcfg))),
                ("h2o auto", "h2o", with_ds!(&ds, d => h2o_auto(d, &bcfg))),
            ];
            for (label, key, out) in runs {
                let passes = out.record.epochs_run() as f64;
                let time = passes * baseline_pass_s(&machine, &w_shape, key);
                let tl = test_loss_of(&test, lambda, &out.w);
                rows.push((label.into(), passes, time, tl));
            }

            let snap_mt_time = rows[1].2;
            let best_other = rows[2..]
                .iter()
                .map(|r| r.2)
                .fold(f64::INFINITY, f64::min);
            for (label, passes, time, tl) in &rows {
                table.row(&[
                    label.clone(),
                    format!("{passes:.0}"),
                    format!("{time:.2}"),
                    format!("{tl:.4}"),
                ]);
                let _ = writeln!(
                    csv,
                    "{},{},{label},{passes:.0},{time:.4},{tl:.6}",
                    machine.name,
                    kind.name()
                );
            }
            println!("\n[{} | {}]", machine.name, kind.name());
            print!("{}", table.render());
            println!(
                "snap.ml MT vs best alternative: ×{:.1} (paper range ×4.1–×41.7)",
                best_other / snap_mt_time
            );
        }
    }
    opts.write_csv("fig6_solver_comparison.csv", &csv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_pass_costs_ordered_sanely() {
        let m = crate::simcost::xeon4();
        let w = DsKind::EpsilonLike.paper_workload();
        let ll = baseline_pass_s(&m, &w, "liblinear");
        let lb = baseline_pass_s(&m, &w, "lbfgs");
        let sg = baseline_pass_s(&m, &w, "sag");
        let h2 = baseline_pass_s(&m, &w, "h2o");
        assert!(lb > ll, "lbfgs pass costs more than one sweep");
        assert!(sg > ll, "sag's dense w update is charged");
        // epsilon (d=2k): H2O's d³ Cholesky makes it by far the slowest —
        // the paper's "somewhat extreme" observation
        assert!(h2 > lb && h2 > sg, "h2o={h2} lbfgs={lb} sag={sg}");
    }

    #[test]
    fn fig6_runs_quick() {
        let mut opts = FigOpts::quick();
        opts.out_dir = std::env::temp_dir().join("parlin_fig6_test");
        run(&opts).unwrap();
        assert!(opts.out_dir.join("fig6_solver_comparison.csv").exists());
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
