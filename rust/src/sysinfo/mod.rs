//! System discovery — the runtime knobs the paper reads from the OS:
//!
//! * cache-line size (`sysfs coherency_line_size`) → bucket size,
//! * last-level-cache size → the "does the model vector fit in LLC?"
//!   heuristic that gates the bucket optimization,
//! * NUMA topology (`/sys/devices/system/node/*`) → the hierarchical
//!   solver's node/thread placement (the paper uses libnuma + `move_pages`;
//!   we read the same sysfs the library reads).
//!
//! Every probe has a deterministic fallback, and [`Topology`] is a plain
//! value type so tests and the cost model can inject the paper's testbeds
//! (4-node Xeon, 2-node POWER9) regardless of the host.

use std::fs;
use std::path::Path;

/// Cache-line size in bytes (fallback: 64).
pub fn cache_line_size() -> usize {
    read_usize(Path::new(
        "/sys/devices/system/cpu/cpu0/cache/index0/coherency_line_size",
    ))
    .unwrap_or(64)
}

/// Last-level cache size in bytes. Scans `cpu0/cache/index*` for the
/// highest level unified/data cache (fallback: 16 MiB).
pub fn llc_size() -> usize {
    let base = Path::new("/sys/devices/system/cpu/cpu0/cache");
    let mut best: Option<(usize, usize)> = None; // (level, bytes)
    if let Ok(entries) = fs::read_dir(base) {
        for e in entries.flatten() {
            let p = e.path();
            if !p
                .file_name()
                .map(|f| f.to_string_lossy().starts_with("index"))
                .unwrap_or(false)
            {
                continue;
            }
            let ty = fs::read_to_string(p.join("type")).unwrap_or_default();
            let ty = ty.trim();
            if ty != "Unified" && ty != "Data" {
                continue;
            }
            let level = read_usize(&p.join("level")).unwrap_or(0);
            let size = read_size_kb(&p.join("size")).unwrap_or(0);
            if size > 0 && best.map(|(l, _)| level > l).unwrap_or(true) {
                best = Some((level, size));
            }
        }
    }
    best.map(|(_, s)| s).unwrap_or(16 * 1024 * 1024)
}

fn read_usize(p: &Path) -> Option<usize> {
    fs::read_to_string(p).ok()?.trim().parse().ok()
}

/// Parse "20480K"-style sysfs cache sizes into bytes.
fn read_size_kb(p: &Path) -> Option<usize> {
    let s = fs::read_to_string(p).ok()?;
    parse_size(s.trim())
}

fn parse_size(s: &str) -> Option<usize> {
    if let Some(v) = s.strip_suffix(['K', 'k']) {
        Some(v.trim().parse::<usize>().ok()? * 1024)
    } else if let Some(v) = s.strip_suffix(['M', 'm']) {
        Some(v.trim().parse::<usize>().ok()? * 1024 * 1024)
    } else {
        s.parse().ok()
    }
}

/// A machine's NUMA shape as the solvers see it.
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    /// `cores[k]` = number of physical cores on node `k`.
    pub cores_per_node: Vec<usize>,
    /// NUMA node holding the training dataset (paper: found via
    /// `move_pages`; we default to 0 and let callers override).
    pub data_node: usize,
}

impl Topology {
    pub fn num_nodes(&self) -> usize {
        self.cores_per_node.len()
    }

    pub fn total_cores(&self) -> usize {
        self.cores_per_node.iter().sum()
    }

    /// Single-node topology with `c` cores.
    pub fn flat(c: usize) -> Self {
        Topology {
            cores_per_node: vec![c],
            data_node: 0,
        }
    }

    /// Uniform multi-node topology.
    pub fn uniform(nodes: usize, cores_each: usize) -> Self {
        Topology {
            cores_per_node: vec![cores_each; nodes],
            data_node: 0,
        }
    }

    /// Discover the host topology from sysfs (fallback: one node with all
    /// available cores).
    pub fn detect() -> Self {
        let node_dir = Path::new("/sys/devices/system/node");
        let mut cores_per_node = Vec::new();
        if let Ok(entries) = fs::read_dir(node_dir) {
            let mut nodes: Vec<usize> = entries
                .flatten()
                .filter_map(|e| {
                    let name = e.file_name().to_string_lossy().into_owned();
                    name.strip_prefix("node")?.parse::<usize>().ok()
                })
                .collect();
            nodes.sort_unstable();
            for k in nodes {
                let cpulist = node_dir.join(format!("node{k}/cpulist"));
                if let Ok(s) = fs::read_to_string(&cpulist) {
                    cores_per_node.push(parse_cpulist(s.trim()).len());
                }
            }
        }
        if cores_per_node.is_empty() || cores_per_node.iter().sum::<usize>() == 0 {
            let c = std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1);
            return Topology::flat(c);
        }
        Topology {
            cores_per_node,
            data_node: 0,
        }
    }

    /// The paper's thread-placement policy (§3 "Numa-level optimizations"):
    /// spread `threads` over the *minimum* number of nodes that can hold
    /// them w.r.t. physical cores, always including the node where the
    /// dataset lives. Returns `threads_per_node` (0 for unused nodes).
    pub fn place_threads(&self, threads: usize) -> Vec<usize> {
        let mut placement = vec![0usize; self.num_nodes()];
        if threads == 0 {
            return placement;
        }
        // order nodes: data node first, then by core count descending
        let mut order: Vec<usize> = (0..self.num_nodes()).collect();
        order.sort_by_key(|&k| {
            (
                if k == self.data_node { 0 } else { 1 },
                usize::MAX - self.cores_per_node[k],
            )
        });
        // pick the minimal prefix of nodes whose cores cover the request
        let mut chosen = Vec::new();
        let mut capacity = 0;
        for &k in &order {
            chosen.push(k);
            capacity += self.cores_per_node[k];
            if capacity >= threads {
                break;
            }
        }
        // distribute evenly over the chosen nodes (proportional to cores,
        // never exceeding a node's physical core count when avoidable)
        let mut left = threads;
        let chosen_n = chosen.len();
        for (i, &k) in chosen.iter().enumerate() {
            let nodes_left = chosen_n - i;
            let share = left.div_ceil(nodes_left).min(self.cores_per_node[k].max(1));
            let share = if capacity >= threads {
                share
            } else {
                // oversubscribed request: spill proportionally
                left.div_ceil(nodes_left)
            };
            placement[k] = share.min(left);
            left -= placement[k];
        }
        // any residue (oversubscription) piles onto the data node
        placement[self.data_node] += left;
        placement
    }
}

/// Parse a sysfs cpulist like `0-3,8,10-11` into CPU ids.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.parse::<usize>(), b.parse::<usize>()) {
                out.extend(a..=b);
            }
        } else if let Ok(v) = part.parse::<usize>() {
            out.push(v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_line_reasonable() {
        let c = cache_line_size();
        assert!(c == 32 || c == 64 || c == 128 || c == 256, "line={c}");
    }

    #[test]
    fn llc_reasonable() {
        let s = llc_size();
        assert!(s >= 256 * 1024, "llc={s}");
    }

    #[test]
    fn parse_sizes() {
        assert_eq!(parse_size("64"), Some(64));
        assert_eq!(parse_size("20480K"), Some(20480 * 1024));
        assert_eq!(parse_size("16M"), Some(16 * 1024 * 1024));
        assert_eq!(parse_size("junk"), None);
    }

    #[test]
    fn cpulist_parsing() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0,2,4-5"), vec![0, 2, 4, 5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
    }

    #[test]
    fn detect_has_cores() {
        let t = Topology::detect();
        assert!(t.total_cores() >= 1);
        assert!(t.num_nodes() >= 1);
    }

    #[test]
    fn placement_single_node_fits() {
        // 4-node Xeon, 8 cores each; 4 threads fit on the data node
        let t = Topology::uniform(4, 8);
        let p = t.place_threads(4);
        assert_eq!(p, vec![4, 0, 0, 0]);
    }

    #[test]
    fn placement_spills_to_min_nodes() {
        let t = Topology::uniform(4, 8);
        let p = t.place_threads(16);
        assert_eq!(p.iter().sum::<usize>(), 16);
        assert_eq!(p.iter().filter(|&&x| x > 0).count(), 2, "{p:?}");
        assert!(p[0] > 0, "data node must be used: {p:?}");
    }

    #[test]
    fn placement_includes_data_node() {
        let mut t = Topology::uniform(4, 8);
        t.data_node = 2;
        let p = t.place_threads(8);
        assert_eq!(p.iter().sum::<usize>(), 8);
        assert!(p[2] > 0, "{p:?}");
    }

    #[test]
    fn placement_all_cores() {
        let t = Topology::uniform(2, 20); // POWER9
        let p = t.place_threads(40);
        assert_eq!(p, vec![20, 20]);
    }

    #[test]
    fn placement_oversubscribed() {
        let t = Topology::uniform(2, 4);
        let p = t.place_threads(12);
        assert_eq!(p.iter().sum::<usize>(), 12);
    }

    #[test]
    fn placement_zero() {
        let t = Topology::uniform(2, 4);
        assert_eq!(t.place_threads(0), vec![0, 0]);
    }
}
