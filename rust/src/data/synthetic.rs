//! Synthetic dataset generators.
//!
//! Two roles:
//! 1. the paper's own synthetic workloads (§2, Fig. 1/2): a dense
//!    `100k × 100` dataset and a sparse `100k × 1k` dataset with uniform 1%
//!    sparsity;
//! 2. stand-ins for the evaluation datasets we cannot ship (criteo-kaggle
//!    45 GB, HIGGS 11M examples, epsilon 400k×2k) with the *statistics the
//!    paper's effects depend on* matched — dimensionality, sparsity,
//!    feature-popularity skew, label balance — at tractable scale
//!    (documented per experiment in EXPERIMENTS.md).

use super::{CscMatrix, Dataset, DenseMatrix};
use crate::util::Rng;

/// Linearly-separable-ish dense classification data: `x ~ N(0, I)`,
/// `y = sign(⟨w*, x⟩ + 0.1·noise)`. The paper's dense synthetic dataset is
/// `dense_classification(100_000, 100, seed)`.
pub fn dense_classification(n: usize, d: usize, seed: u64) -> Dataset<DenseMatrix> {
    let mut rng = Rng::new(seed);
    let w_star: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let mut data = vec![0.0f64; d * n];
    let mut y = Vec::with_capacity(n);
    for j in 0..n {
        let col = &mut data[j * d..(j + 1) * d];
        let mut z = 0.0;
        for (k, x) in col.iter_mut().enumerate() {
            *x = rng.next_gaussian() / (d as f64).sqrt();
            z += *x * w_star[k];
        }
        let noisy = z + 0.1 * rng.next_gaussian();
        y.push(if noisy >= 0.0 { 1.0 } else { -1.0 });
    }
    Dataset::new(DenseMatrix::new(d, n, data), y)
}

/// Uniform-sparsity classification data (the paper's sparse synthetic
/// dataset is `sparse_classification(100_000, 1000, 0.01, seed)`): each
/// example draws `round(density·d)` features uniformly at random — no skew,
/// which is what makes "wild" updates nearly collision-free (Fig. 1b).
pub fn sparse_classification(n: usize, d: usize, density: f64, seed: u64) -> Dataset<CscMatrix> {
    let mut rng = Rng::new(seed);
    let w_star: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let nnz_per = ((density * d as f64).round() as usize).max(1);
    let scale = 1.0 / (nnz_per as f64).sqrt();
    let mut examples = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let feats = rng.sample_indices(d, nnz_per);
        let mut ex: Vec<(u32, f64)> = Vec::with_capacity(nnz_per);
        let mut z = 0.0;
        for f in feats {
            let v = rng.next_gaussian() * scale;
            z += v * w_star[f];
            ex.push((f as u32, v));
        }
        ex.sort_unstable_by_key(|&(i, _)| i);
        examples.push(ex);
        y.push(if z + 0.1 * rng.next_gaussian() >= 0.0 {
            1.0
        } else {
            -1.0
        });
    }
    Dataset::new(CscMatrix::from_examples(d, &examples), y)
}

/// HIGGS stand-in: 28 dense physics features — a mix of unit-Gaussian
/// "low-level" features and heavier-tailed "high-level" ones, weakly
/// separable (HIGGS test error plateaus ~36% for linear models).
pub fn higgs_like(n: usize, seed: u64) -> Dataset<DenseMatrix> {
    let d = 28;
    let mut rng = Rng::new(seed);
    let w_star: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let mut data = vec![0.0f64; d * n];
    let mut y = Vec::with_capacity(n);
    for j in 0..n {
        let col = &mut data[j * d..(j + 1) * d];
        let mut z = 0.0;
        for (k, x) in col.iter_mut().enumerate() {
            let g = rng.next_gaussian();
            // last 7 "high-level" features: log-normal-ish heavy tails
            *x = if k >= 21 { (0.5 * g).exp() - 1.0 } else { g };
            z += *x * w_star[k];
        }
        // strong label noise => weak separability, like real HIGGS
        let noisy = z / (d as f64).sqrt() + 1.5 * rng.next_gaussian();
        y.push(if noisy >= 0.0 { 1.0 } else { -1.0 });
    }
    Dataset::new(DenseMatrix::new(d, n, data), y)
}

/// epsilon stand-in: 2000 dense features, every example normalized to unit
/// L2 norm (the PASCAL epsilon dataset ships pre-normalized).
pub fn epsilon_like(n: usize, seed: u64) -> Dataset<DenseMatrix> {
    let d = 2000;
    let mut rng = Rng::new(seed);
    let w_star: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let mut data = vec![0.0f64; d * n];
    let mut y = Vec::with_capacity(n);
    for j in 0..n {
        let col = &mut data[j * d..(j + 1) * d];
        let mut norm_sq = 0.0;
        let mut z = 0.0;
        for (k, x) in col.iter_mut().enumerate() {
            *x = rng.next_gaussian();
            norm_sq += *x * *x;
            z += *x * w_star[k];
        }
        let norm = norm_sq.sqrt().max(1e-12);
        for x in col.iter_mut() {
            *x /= norm;
        }
        y.push(if z / norm + 0.05 * rng.next_gaussian() >= 0.0 {
            1.0
        } else {
            -1.0
        });
    }
    Dataset::new(DenseMatrix::new(d, n, data), y)
}

/// criteo-kaggle stand-in: 13 numeric features (indices 0..13, log-normal,
/// always present) + 26 categorical features one-hot hashed into the
/// remaining space with a Zipf popularity distribution — ~39 non-zeros per
/// example, heavy feature-popularity skew. The skew is the property that
/// makes wild updates collide on hot cache lines (§2).
pub fn criteo_like(n: usize, d: usize, seed: u64) -> Dataset<CscMatrix> {
    assert!(d > 64, "criteo-like needs room for hashed categoricals");
    let mut rng = Rng::new(seed);
    let n_numeric = 13usize;
    let n_cat = 26usize;
    let cat_space = d - n_numeric;
    let w_star: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 0.3).collect();
    let mut examples = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    // Zipf sampler over the categorical space via inverse-CDF on a
    // truncated power law (alpha ≈ 1.1, like hashed-categorical traffic).
    let alpha = 1.1f64;
    let zipf = |u: f64| -> usize {
        // inverse CDF of p(k) ∝ k^-alpha over [1, cat_space]
        let k_max = cat_space as f64;
        let exp = 1.0 - alpha;
        let c = (k_max.powf(exp) - 1.0) / exp;
        let k = (1.0 + c * u * exp).powf(1.0 / exp);
        (k as usize).min(cat_space - 1)
    };
    for _ in 0..n {
        let mut ex: Vec<(u32, f64)> = Vec::with_capacity(n_numeric + n_cat);
        let mut z = 0.0;
        for k in 0..n_numeric {
            let v = (0.8 * rng.next_gaussian()).exp() - 1.0;
            z += v * w_star[k];
            ex.push((k as u32, v));
        }
        for c in 0..n_cat {
            // each categorical field hashes into its own slice of the space
            let field_off = n_numeric + (c * cat_space / n_cat);
            let field_sz = cat_space / n_cat;
            let f = field_off + zipf(rng.next_f64()) % field_sz;
            z += w_star[f];
            ex.push((f as u32, 1.0));
        }
        ex.sort_unstable_by_key(|&(i, _)| i);
        ex.dedup_by_key(|&mut (i, _)| i);
        examples.push(ex);
        // CTR-like imbalance: ~25% positive
        let p = 1.0 / (1.0 + (-(z - 1.0)).exp());
        y.push(if rng.next_f64() < p { 1.0 } else { -1.0 });
    }
    Dataset::new(CscMatrix::from_examples(d, &examples), y)
}

/// Dense ridge-regression data: `y = ⟨w*, x⟩ + σ·noise`.
pub fn dense_regression(n: usize, d: usize, noise: f64, seed: u64) -> Dataset<DenseMatrix> {
    let mut rng = Rng::new(seed);
    let w_star: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let mut data = vec![0.0f64; d * n];
    let mut y = Vec::with_capacity(n);
    for j in 0..n {
        let col = &mut data[j * d..(j + 1) * d];
        let mut z = 0.0;
        for (k, x) in col.iter_mut().enumerate() {
            *x = rng.next_gaussian() / (d as f64).sqrt();
            z += *x * w_star[k];
        }
        y.push(z + noise * rng.next_gaussian());
    }
    Dataset::new(DenseMatrix::new(d, n, data), y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_shapes_and_labels() {
        let ds = dense_classification(200, 10, 1);
        assert_eq!((ds.n(), ds.d()), (200, 10));
        assert!(ds.y.iter().all(|&y| y == 1.0 || y == -1.0));
        let pos = ds.y.iter().filter(|&&y| y > 0.0).count();
        assert!(pos > 40 && pos < 160, "labels should be roughly balanced");
    }

    #[test]
    fn sparse_density_matches() {
        let ds = sparse_classification(500, 100, 0.05, 2);
        let expect = 5.0;
        assert!((ds.x.avg_nnz() - expect).abs() < 1e-9);
        assert_eq!(ds.d(), 100);
    }

    #[test]
    fn criteo_like_statistics() {
        let ds = criteo_like(500, 10_000, 3);
        // 13 numeric + up to 26 categorical (dedup can only remove a few)
        assert!(ds.x.avg_nnz() > 35.0 && ds.x.avg_nnz() <= 39.0);
        // label imbalance: positives should be a minority but present
        let pos = ds.y.iter().filter(|&&y| y > 0.0).count();
        assert!(pos > 25 && pos < 350, "pos={pos}");
        // skew: most-popular categorical feature should dominate uniform share
        let mut counts = vec![0usize; ds.d()];
        for j in 0..ds.n() {
            let (idx, _) = ds.x.col(j);
            for &i in idx {
                counts[i as usize] += 1;
            }
        }
        let max_cat = counts[13..].iter().max().copied().unwrap();
        // uniform over a field would give ~500/(9987/26) ≈ 1.3
        assert!(max_cat > 20, "expected popularity skew, max_cat={max_cat}");
    }

    #[test]
    fn epsilon_like_unit_norm() {
        let ds = epsilon_like(20, 4);
        for j in 0..ds.n() {
            assert!((ds.norm_sq(j) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn higgs_like_shape() {
        let ds = higgs_like(100, 5);
        assert_eq!(ds.d(), 28);
    }

    #[test]
    fn regression_recoverable() {
        // noiseless targets should be exactly linear in x
        let ds = dense_regression(50, 5, 0.0, 6);
        // fit via normal equations on the tiny system to confirm consistency
        // (just sanity: targets correlate strongly with features)
        let var_y: f64 = ds.y.iter().map(|y| y * y).sum::<f64>() / 50.0;
        assert!(var_y > 0.01);
    }

    #[test]
    fn generators_deterministic() {
        let a = dense_classification(50, 8, 9);
        let b = dense_classification(50, 8, 9);
        assert_eq!(a.y, b.y);
        for j in 0..a.n() {
            assert_eq!(a.x.col(j), b.x.col(j));
        }
    }
}
