//! Training-data substrate.
//!
//! Following the paper (Algorithm 1), the data matrix is stored
//! **example-major**: `A = [x_1, …, x_n] ∈ R^{d×n}`, i.e. each training
//! example is one contiguous column. SDCA touches one example per step, so
//! example-contiguity is what makes the inner products stream.
//!
//! Two concrete source layouts are provided:
//! * [`dense::DenseMatrix`] — column-major dense (higgs / epsilon style),
//! * [`sparse::CscMatrix`] — compressed sparse column (criteo style),
//!
//! plus a derived *training* layout, [`shard::ShardedLayout`]: a
//! shard-resident, bucket-major interleaved encoding the solvers stream
//! through fused kernels by default (see [`shard`] and
//! [`crate::solver::kernel`]; selected by [`LayoutPolicy`]).
//!
//! Solvers are generic over [`DataMatrix`] and get monomorphized per layout
//! (no dynamic dispatch in the coordinate loop). [`AnyDataset`] is the
//! type-erased wrapper used by the CLI and figure harnesses.

pub mod dense;
pub mod loader;
pub mod shard;
pub mod sparse;
pub mod synthetic;

pub use dense::DenseMatrix;
pub use shard::{LayoutPolicy, ShardedLayout};
pub use sparse::CscMatrix;

/// Column access interface shared by dense and sparse layouts.
///
/// `Sync` is required: the multi-threaded solvers share the (read-only)
/// matrix across threads — the paper's NUMA design explicitly relies on the
/// dataset being read-only so it never generates coherence traffic.
pub trait DataMatrix: Sync {
    /// Number of examples (columns).
    fn n(&self) -> usize;
    /// Number of features (rows).
    fn d(&self) -> usize;
    /// Total stored non-zeros.
    fn nnz(&self) -> usize;
    /// Non-zeros in example `j`.
    fn nnz_col(&self, j: usize) -> usize;
    /// `⟨x_j, v⟩` where `v` has length `d`.
    fn dot_col(&self, j: usize, v: &[f64]) -> f64;
    /// `v += scale · x_j`.
    fn axpy_col(&self, j: usize, scale: f64, v: &mut [f64]);
    /// `‖x_j‖²`.
    fn norm_sq_col(&self, j: usize) -> f64;
    /// Densify example `j` into a length-`d` buffer (runtime tiling path).
    fn write_col_dense(&self, j: usize, out: &mut [f64]);
    /// Visit the feature indices of example `j`.
    fn for_each_col_index(&self, j: usize, f: impl FnMut(usize))
    where
        Self: Sized;
    /// Visit the `(index, value)` entries of example `j`.
    fn for_each_col_entry(&self, j: usize, f: impl FnMut(usize, f64))
    where
        Self: Sized;
    /// `⟨x_j, v⟩` against the atomically-shared vector (wild solver
    /// reads). The elements are cache-line padded so concurrent updates
    /// of *distinct* coordinates never contend on one line.
    fn dot_col_atomic(&self, j: usize, v: &[crate::util::PaddedAtomicF64]) -> f64;
    /// `v += scale·x_j` with *unsynchronized* per-element RMWs — the wild
    /// solver's `ADD(v_i, δ·A_ij)`; concurrent callers may lose updates.
    fn axpy_col_wild(&self, j: usize, scale: f64, v: &[crate::util::PaddedAtomicF64]);
    /// Hint that examples `j_lo..j_hi` will be read next (software
    /// prefetch for the bucketed random-order walk). Default: no-op.
    #[inline]
    fn prefetch_cols(&self, j_lo: usize, j_hi: usize) {
        let _ = (j_lo, j_hi);
    }
}

/// Growable example axis: matrix layouts that can take freshly arrived
/// examples in place. The serving subsystem ([`crate::serve`]) appends new
/// rows to a resident dataset and warm-restarts training from the existing
/// dual state instead of re-loading and re-training from scratch.
///
/// `Clone` is required: the request scheduler publishes versioned
/// [`ModelSnapshot`](crate::serve::ModelSnapshot)s whose datasets are
/// shared with concurrent readers via `Arc`; the writer mutates its copy
/// through `Arc::make_mut`, which clones only when a reader still holds
/// the previous version.
pub trait AppendExamples: DataMatrix + Sized + Clone {
    /// Append `other`'s examples (columns) after this matrix's own; the
    /// feature dimension must match.
    fn append_examples(&mut self, other: &Self);
}

/// A labelled dataset: matrix + targets + cached per-example squared norms.
///
/// Labels are `±1` for classification objectives and real-valued for ridge
/// regression; the objective decides the interpretation.
#[derive(Clone)]
pub struct Dataset<M: DataMatrix> {
    pub x: M,
    pub y: Vec<f64>,
    norms_sq: Vec<f64>,
}

impl<M: DataMatrix> Dataset<M> {
    pub fn new(x: M, y: Vec<f64>) -> Self {
        assert_eq!(x.n(), y.len(), "label count must match example count");
        let norms_sq = (0..x.n()).map(|j| x.norm_sq_col(j)).collect();
        Dataset { x, y, norms_sq }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.x.n()
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.x.d()
    }

    /// Cached `‖x_j‖²` — used in every coordinate update, so it is computed
    /// once at load time rather than per step.
    #[inline]
    pub fn norm_sq(&self, j: usize) -> f64 {
        self.norms_sq[j]
    }

    /// The cached squared norms as a slice (the fused interleaved kernels
    /// index it directly instead of going through [`Self::norm_sq`]).
    #[inline]
    pub fn norms(&self) -> &[f64] {
        &self.norms_sq
    }

    /// Bytes of matrix payload — feeds the cost model's streaming term.
    pub fn payload_bytes(&self) -> usize {
        // dense: 8B per value; sparse: 8B value + 4B index.
        if self.x.nnz() == self.n() * self.d() {
            self.x.nnz() * 8
        } else {
            self.x.nnz() * 12
        }
    }
}

impl<M: AppendExamples> Dataset<M> {
    /// Append another dataset's examples in place (labels and cached norms
    /// included) — the serving-side ingestion path.
    pub fn append(&mut self, other: &Dataset<M>) {
        assert_eq!(self.d(), other.d(), "feature dimension mismatch");
        self.x.append_examples(&other.x);
        self.y.extend_from_slice(&other.y);
        self.norms_sq.extend_from_slice(&other.norms_sq);
    }
}

/// Type-erased dataset for the CLI / figure harness boundary.
pub enum AnyDataset {
    Dense(Dataset<DenseMatrix>),
    Sparse(Dataset<CscMatrix>),
}

impl AnyDataset {
    pub fn n(&self) -> usize {
        match self {
            AnyDataset::Dense(ds) => ds.n(),
            AnyDataset::Sparse(ds) => ds.n(),
        }
    }

    pub fn d(&self) -> usize {
        match self {
            AnyDataset::Dense(ds) => ds.d(),
            AnyDataset::Sparse(ds) => ds.d(),
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            AnyDataset::Dense(ds) => ds.x.nnz(),
            AnyDataset::Sparse(ds) => ds.x.nnz(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, AnyDataset::Sparse(_))
    }
}

impl Dataset<DenseMatrix> {
    /// Materialize the selected examples as a new dataset.
    pub fn subset(&self, idx: &[usize]) -> Dataset<DenseMatrix> {
        Dataset::new(self.x.subset(idx), idx.iter().map(|&j| self.y[j]).collect())
    }
}

impl Dataset<CscMatrix> {
    /// Materialize the selected examples as a new dataset.
    pub fn subset(&self, idx: &[usize]) -> Dataset<CscMatrix> {
        Dataset::new(self.x.subset(idx), idx.iter().map(|&j| self.y[j]).collect())
    }
}

impl AnyDataset {
    /// Deterministic train/test split: the examples are i.i.d. by
    /// construction, so an index split is a valid held-out set.
    pub fn split(&self, test_frac: f64, seed: u64) -> (AnyDataset, AnyDataset) {
        let (tr, te) = split_indices(self.n(), test_frac, seed);
        match self {
            AnyDataset::Dense(ds) => (
                AnyDataset::Dense(ds.subset(&tr)),
                AnyDataset::Dense(ds.subset(&te)),
            ),
            AnyDataset::Sparse(ds) => (
                AnyDataset::Sparse(ds.subset(&tr)),
                AnyDataset::Sparse(ds.subset(&te)),
            ),
        }
    }
}

/// Deterministic train/test split by hashing indices (keeps both halves
/// reproducible without materializing a permutation of the data).
pub fn split_indices(n: usize, test_frac: f64, seed: u64) -> (Vec<usize>, Vec<usize>) {
    let mut rng = crate::util::Rng::new(seed);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let test = idx[..n_test].to_vec();
    let train = idx[n_test..].to_vec();
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_caches_norms() {
        let m = DenseMatrix::from_columns(2, &[&[3.0, 4.0], &[1.0, 0.0]]);
        let ds = Dataset::new(m, vec![1.0, -1.0]);
        assert!((ds.norm_sq(0) - 25.0).abs() < 1e-12);
        assert!((ds.norm_sq(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn dataset_rejects_label_mismatch() {
        let m = DenseMatrix::from_columns(2, &[&[1.0, 2.0]]);
        let _ = Dataset::new(m, vec![1.0, -1.0]);
    }

    #[test]
    fn append_dense_examples() {
        let a = DenseMatrix::from_columns(2, &[&[1.0, 2.0], &[3.0, 4.0]]);
        let mut dsa = Dataset::new(a, vec![1.0, -1.0]);
        let b = DenseMatrix::from_columns(2, &[&[5.0, 6.0]]);
        let dsb = Dataset::new(b, vec![1.0]);
        dsa.append(&dsb);
        assert_eq!(dsa.n(), 3);
        assert_eq!(dsa.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(dsa.x.col(2), &[5.0, 6.0]);
        assert!((dsa.norm_sq(2) - 61.0).abs() < 1e-12);
    }

    #[test]
    fn append_sparse_examples() {
        let a = CscMatrix::from_examples(3, &[vec![(0, 1.0)], vec![(2, 2.0)]]);
        let mut dsa = Dataset::new(a, vec![1.0, -1.0]);
        let b = CscMatrix::from_examples(3, &[vec![(1, 3.0), (2, 4.0)]]);
        let dsb = Dataset::new(b, vec![1.0]);
        dsa.append(&dsb);
        assert_eq!((dsa.n(), dsa.x.nnz()), (3, 4));
        let (idx, val) = dsa.x.col(2);
        assert_eq!(idx, &[1, 2]);
        assert_eq!(val, &[3.0, 4.0]);
        assert!((dsa.norm_sq(2) - 25.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn append_rejects_dimension_mismatch() {
        let mut a = Dataset::new(DenseMatrix::zeros(2, 1), vec![1.0]);
        let b = Dataset::new(DenseMatrix::zeros(3, 1), vec![1.0]);
        a.append(&b);
    }

    #[test]
    fn split_is_partition() {
        let (tr, te) = split_indices(100, 0.2, 7);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        let mut all: Vec<usize> = tr.iter().chain(te.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_deterministic() {
        let (a, _) = split_indices(50, 0.1, 3);
        let (b, _) = split_indices(50, 0.1, 3);
        assert_eq!(a, b);
    }
}
