//! Training-data substrate: the segment-chunked dataset.
//!
//! Following the paper (Algorithm 1), the data matrix is stored
//! **example-major**: `A = [x_1, …, x_n] ∈ R^{d×n}`, i.e. each training
//! example is one contiguous column. SDCA touches one example per step, so
//! example-contiguity is what makes the inner products stream.
//!
//! ## The segment model
//!
//! The example axis of a matrix is an ordered list of **immutable
//! segments** (`Arc<DenseSegment>` / `Arc<CscSegment>`), each holding a
//! contiguous run of columns. Invariants:
//!
//! * segments are **sealed at construction** — no code path mutates a
//!   segment after it is wrapped in its `Arc`;
//! * segments **partition the example axis**: segment `s` owns the global
//!   examples `segment_range(s)`, ranges are contiguous, ascending and
//!   non-empty, and every column lives entirely inside one segment;
//! * [`AppendExamples::append_examples`] **seals and pushes**: the
//!   appended matrix's segments are attached to the tail by `Arc` clone —
//!   existing storage is *shared*, never copied. Appending `k` rows costs
//!   `O(segments + rows added)`, independent of the resident `nnz`. This
//!   is what makes streaming refits clone-free while concurrent readers
//!   hold [`ModelSnapshot`](crate::serve::ModelSnapshot)s of earlier
//!   dataset versions (see `docs/ARCHITECTURE.md`, "copy-on-write
//!   appends");
//! * a freshly loaded matrix has exactly **one** segment, so the
//!   monolithic fast path (no per-access segment search) is preserved for
//!   batch training.
//!
//! The cost of chunking is one indirection on column access: locating the
//! owning segment. Random access pays a `partition_point` over the segment
//! offsets (with a single-segment fast path); loop-shaped access goes
//! through a [`ColCursor`], which caches the current segment and re-seats
//! only when a walk crosses a segment boundary — the solvers, the layout
//! encoder and [`glm::model::margins`](crate::glm::model::margins) all
//! walk columns through cursors.
//!
//! Two concrete source layouts are provided:
//! * [`dense::DenseMatrix`] — column-major dense (higgs / epsilon style),
//! * [`sparse::CscMatrix`] — compressed sparse column (criteo style),
//!
//! plus a derived *training* layout, [`shard::ShardedLayout`]: a
//! shard-resident, bucket-major interleaved encoding the solvers stream
//! through fused kernels by default (see [`shard`] and
//! [`crate::solver::kernel`]; selected by [`LayoutPolicy`]).
//!
//! Solvers are generic over [`DataMatrix`] and get monomorphized per layout
//! (no dynamic dispatch in the coordinate loop). [`AnyDataset`] is the
//! type-erased wrapper used by the CLI and figure harnesses.
//!
//! The full layer map (data → layout → kernels → solvers → pool →
//! serve/scheduler) and the memory cost of each resident encoding are
//! documented in `docs/ARCHITECTURE.md`.

pub mod dense;
pub mod loader;
pub mod shard;
pub mod sparse;
pub mod synthetic;

pub use dense::DenseMatrix;
pub use shard::{LayoutPolicy, ShardedLayout};
pub use sparse::CscMatrix;

/// Column access interface shared by dense and sparse layouts.
///
/// `Sync` is required: the multi-threaded solvers share the (read-only)
/// matrix across threads — the paper's NUMA design explicitly relies on the
/// dataset being read-only so it never generates coherence traffic.
///
/// Storage is segmented along the example axis (see the module docs). The
/// `*_in` methods are the segment-scoped primitives: they take the segment
/// `s` known to contain global example `j` and skip the lookup. The plain
/// per-column methods are provided on top of them (locating the segment
/// per call); loops should prefer a [`ColCursor`] (via
/// [`DataMatrix::col_cursor`]), which amortizes the lookup across
/// consecutive visits.
pub trait DataMatrix: Sync {
    /// Number of examples (columns).
    fn n(&self) -> usize;
    /// Number of features (rows).
    fn d(&self) -> usize;
    /// Total stored non-zeros.
    fn nnz(&self) -> usize;
    /// Non-zeros in example `j`.
    fn nnz_col(&self, j: usize) -> usize;
    /// `‖x_j‖²`.
    fn norm_sq_col(&self, j: usize) -> f64;
    /// Densify example `j` into a length-`d` buffer (runtime tiling path).
    fn write_col_dense(&self, j: usize, out: &mut [f64]);
    /// Visit the feature indices of example `j`.
    fn for_each_col_index(&self, j: usize, f: impl FnMut(usize))
    where
        Self: Sized;

    // ---- segment geometry ------------------------------------------------

    /// Number of immutable storage segments the example axis is chunked
    /// into (1 for a freshly loaded matrix; +1 per appended batch).
    fn num_segments(&self) -> usize;
    /// The segment containing global example `j`.
    fn segment_of(&self, j: usize) -> usize;
    /// Global example range `[lo, hi)` owned by segment `s`. Ranges are
    /// contiguous, ascending and partition `0..n`.
    fn segment_range(&self, s: usize) -> std::ops::Range<usize>;

    // ---- segment-scoped column primitives --------------------------------
    // `j` is always the GLOBAL example index; `s` must be the segment
    // containing it (callers obtain `s` from `segment_of` or a cursor).

    /// `⟨x_j, v⟩` where `v` has length `d` and `s` contains `j`.
    fn dot_col_in(&self, s: usize, j: usize, v: &[f64]) -> f64;
    /// `v += scale · x_j` where `s` contains `j`.
    fn axpy_col_in(&self, s: usize, j: usize, scale: f64, v: &mut [f64]);
    /// Non-zeros in example `j` where `s` contains `j`.
    fn nnz_col_in(&self, s: usize, j: usize) -> usize;
    /// Visit the `(index, value)` entries of example `j` (`s` contains `j`).
    fn for_each_col_entry_in(&self, s: usize, j: usize, f: impl FnMut(usize, f64))
    where
        Self: Sized;
    /// `⟨x_j, v⟩` against the atomically-shared vector (wild solver
    /// reads; `s` contains `j`). The elements are cache-line padded so
    /// concurrent updates of *distinct* coordinates never contend on one
    /// line.
    fn dot_col_atomic_in(&self, s: usize, j: usize, v: &[crate::util::PaddedAtomicF64]) -> f64;
    /// `v += scale·x_j` with *unsynchronized* per-element RMWs — the wild
    /// solver's `ADD(v_i, δ·A_ij)`; concurrent callers may lose updates
    /// (`s` contains `j`).
    fn axpy_col_wild_in(&self, s: usize, j: usize, scale: f64, v: &[crate::util::PaddedAtomicF64]);

    // ---- per-column conveniences (one segment lookup per call) -----------

    /// `⟨x_j, v⟩` where `v` has length `d`.
    #[inline]
    fn dot_col(&self, j: usize, v: &[f64]) -> f64 {
        self.dot_col_in(self.segment_of(j), j, v)
    }
    /// `v += scale · x_j`.
    #[inline]
    fn axpy_col(&self, j: usize, scale: f64, v: &mut [f64]) {
        self.axpy_col_in(self.segment_of(j), j, scale, v)
    }
    /// Visit the `(index, value)` entries of example `j`.
    #[inline]
    fn for_each_col_entry(&self, j: usize, f: impl FnMut(usize, f64))
    where
        Self: Sized,
    {
        self.for_each_col_entry_in(self.segment_of(j), j, f)
    }
    /// `⟨x_j, v⟩` against the atomically-shared vector (wild reads).
    #[inline]
    fn dot_col_atomic(&self, j: usize, v: &[crate::util::PaddedAtomicF64]) -> f64 {
        self.dot_col_atomic_in(self.segment_of(j), j, v)
    }
    /// Unsynchronized `v += scale·x_j` (the wild `ADD`).
    #[inline]
    fn axpy_col_wild(&self, j: usize, scale: f64, v: &[crate::util::PaddedAtomicF64]) {
        self.axpy_col_wild_in(self.segment_of(j), j, scale, v)
    }

    /// Hint that examples `j_lo..j_hi` will be read next (software
    /// prefetch for the bucketed random-order walk). Default: no-op.
    #[inline]
    fn prefetch_cols(&self, j_lo: usize, j_hi: usize) {
        let _ = (j_lo, j_hi);
    }

    /// A cursor that amortizes the segment lookup across consecutive
    /// column visits — the intended access path for every loop over
    /// examples (solver inner loops, margins, layout encoding).
    #[inline]
    fn col_cursor(&self) -> ColCursor<'_, Self>
    where
        Self: Sized,
    {
        ColCursor::new(self)
    }
}

/// Amortized column walker over a segmented [`DataMatrix`]: caches the
/// segment containing the last visited example and re-resolves it only
/// when a visit leaves the cached range. Within one segment — the common
/// case for bucket walks, whole-dataset sweeps and tail appends — every
/// operation is a direct segment access, exactly the pre-segmentation
/// cost.
///
/// A cursor borrows the matrix immutably, so any number of cursors can
/// walk the same matrix from concurrent workers.
pub struct ColCursor<'a, M: DataMatrix> {
    m: &'a M,
    /// Cached segment, valid for global examples in `lo..hi` (the empty
    /// initial range forces the first visit to seat).
    seg: usize,
    lo: usize,
    hi: usize,
}

impl<'a, M: DataMatrix> ColCursor<'a, M> {
    #[inline]
    pub fn new(m: &'a M) -> Self {
        ColCursor {
            m,
            seg: 0,
            lo: 0,
            hi: 0,
        }
    }

    /// Resolve (and cache) the segment containing `j`.
    #[inline]
    fn seat(&mut self, j: usize) -> usize {
        if j < self.lo || j >= self.hi {
            self.seg = self.m.segment_of(j);
            let r = self.m.segment_range(self.seg);
            self.lo = r.start;
            self.hi = r.end;
        }
        self.seg
    }

    /// `⟨x_j, v⟩`.
    #[inline]
    pub fn dot(&mut self, j: usize, v: &[f64]) -> f64 {
        let s = self.seat(j);
        self.m.dot_col_in(s, j, v)
    }

    /// `v += scale · x_j`.
    #[inline]
    pub fn axpy(&mut self, j: usize, scale: f64, v: &mut [f64]) {
        let s = self.seat(j);
        self.m.axpy_col_in(s, j, scale, v)
    }

    /// Non-zeros in example `j`.
    #[inline]
    pub fn nnz_col(&mut self, j: usize) -> usize {
        let s = self.seat(j);
        self.m.nnz_col_in(s, j)
    }

    /// Visit the `(index, value)` entries of example `j`.
    #[inline]
    pub fn for_each_entry(&mut self, j: usize, f: impl FnMut(usize, f64)) {
        let s = self.seat(j);
        self.m.for_each_col_entry_in(s, j, f)
    }

    /// `⟨x_j, v⟩` against the wild solver's padded atomic vector.
    #[inline]
    pub fn dot_atomic(&mut self, j: usize, v: &[crate::util::PaddedAtomicF64]) -> f64 {
        let s = self.seat(j);
        self.m.dot_col_atomic_in(s, j, v)
    }

    /// Unsynchronized `v += scale·x_j` (the wild `ADD`).
    #[inline]
    pub fn axpy_wild(&mut self, j: usize, scale: f64, v: &[crate::util::PaddedAtomicF64]) {
        let s = self.seat(j);
        self.m.axpy_col_wild_in(s, j, scale, v)
    }
}

/// Growable example axis: matrix layouts that can take freshly arrived
/// examples. The serving subsystem ([`crate::serve`]) appends new rows to
/// a resident dataset and warm-restarts training from the existing dual
/// state instead of re-loading and re-training from scratch.
///
/// Appending is **structural sharing**, not copying: the appended
/// matrix's sealed segments are pushed onto the tail by `Arc` clone, so
/// every snapshot of the pre-append dataset keeps serving its own segment
/// list while the successor shares all of it. `Clone` is consequently
/// cheap — `O(segments)` `Arc` bumps, never an `O(nnz)` payload copy —
/// which is what the scheduler's versioned-snapshot publishing relies on.
pub trait AppendExamples: DataMatrix + Sized + Clone {
    /// Append `other`'s examples (columns) after this matrix's own by
    /// sharing `other`'s sealed segments; the feature dimension must
    /// match.
    fn append_examples(&mut self, other: &Self);
}

/// A labelled dataset: matrix + targets + cached per-example squared norms.
///
/// Labels are `±1` for classification objectives and real-valued for ridge
/// regression; the objective decides the interpretation.
///
/// `y` and the cached norms stay *flat* (`Vec<f64>`) rather than chunked:
/// the fused kernels index them directly as slices on the hot path, and
/// at 16 B per example they are dwarfed by the matrix payload. An append
/// therefore copies `O(n)` label/norm floats but never the `O(nnz)`
/// matrix storage (see [`Dataset::appended`]).
#[derive(Clone)]
pub struct Dataset<M: DataMatrix> {
    pub x: M,
    pub y: Vec<f64>,
    norms_sq: Vec<f64>,
}

impl<M: DataMatrix> Dataset<M> {
    pub fn new(x: M, y: Vec<f64>) -> Self {
        assert_eq!(x.n(), y.len(), "label count must match example count");
        let norms_sq = (0..x.n()).map(|j| x.norm_sq_col(j)).collect();
        Dataset { x, y, norms_sq }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.x.n()
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.x.d()
    }

    /// Cached `‖x_j‖²` — used in every coordinate update, so it is computed
    /// once at load time rather than per step.
    #[inline]
    pub fn norm_sq(&self, j: usize) -> f64 {
        self.norms_sq[j]
    }

    /// The cached squared norms as a slice (the fused interleaved kernels
    /// index it directly instead of going through [`Self::norm_sq`]).
    #[inline]
    pub fn norms(&self) -> &[f64] {
        &self.norms_sq
    }

    /// Bytes of matrix payload — feeds the cost model's streaming term.
    pub fn payload_bytes(&self) -> usize {
        // dense: 8B per value; sparse: 8B value + 4B index.
        if self.x.nnz() == self.n() * self.d() {
            self.x.nnz() * 8
        } else {
            self.x.nnz() * 12
        }
    }

    /// Are all labels and matrix values finite? The serve-tier ingest
    /// gate ([`Scheduler::ingest`](crate::serve::Scheduler::ingest))
    /// refuses batches that fail this — a single NaN arrival would
    /// otherwise poison a whole refit and only be caught downstream by
    /// the publish health gate.
    pub fn is_finite(&self) -> bool {
        if self.y.iter().any(|v| !v.is_finite()) {
            return false;
        }
        for j in 0..self.n() {
            let mut ok = true;
            self.x.for_each_col_entry(j, |_, v| ok &= v.is_finite());
            if !ok {
                return false;
            }
        }
        true
    }
}

impl<M: AppendExamples> Dataset<M> {
    /// Append another dataset's examples in place (labels and cached norms
    /// included). The matrix side shares `other`'s sealed segments
    /// ([`AppendExamples::append_examples`]); only labels/norms are
    /// extended by value.
    pub fn append(&mut self, other: &Dataset<M>) {
        assert_eq!(self.d(), other.d(), "feature dimension mismatch");
        self.x.append_examples(&other.x);
        self.y.extend_from_slice(&other.y);
        self.norms_sq.extend_from_slice(&other.norms_sq);
    }

    /// Functional append: build the successor dataset without touching
    /// this one. Every existing matrix segment is shared by `Arc`, so the
    /// cost is `O(segments + rows added)` for storage plus an `O(n)`
    /// label/norm copy — never an `O(nnz)` clone, no matter how many
    /// snapshots still hold the predecessor. This is the serving-side
    /// ingestion path
    /// ([`crate::serve::Session::partial_fit_rows`]).
    pub fn appended(&self, other: &Dataset<M>) -> Dataset<M> {
        let mut next = self.clone();
        next.append(other);
        next
    }
}

/// Type-erased dataset for the CLI / figure harness boundary.
pub enum AnyDataset {
    Dense(Dataset<DenseMatrix>),
    Sparse(Dataset<CscMatrix>),
}

impl AnyDataset {
    pub fn n(&self) -> usize {
        match self {
            AnyDataset::Dense(ds) => ds.n(),
            AnyDataset::Sparse(ds) => ds.n(),
        }
    }

    pub fn d(&self) -> usize {
        match self {
            AnyDataset::Dense(ds) => ds.d(),
            AnyDataset::Sparse(ds) => ds.d(),
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            AnyDataset::Dense(ds) => ds.x.nnz(),
            AnyDataset::Sparse(ds) => ds.x.nnz(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, AnyDataset::Sparse(_))
    }
}

impl Dataset<DenseMatrix> {
    /// Materialize the selected examples as a new dataset.
    pub fn subset(&self, idx: &[usize]) -> Dataset<DenseMatrix> {
        Dataset::new(self.x.subset(idx), idx.iter().map(|&j| self.y[j]).collect())
    }
}

impl Dataset<CscMatrix> {
    /// Materialize the selected examples as a new dataset.
    pub fn subset(&self, idx: &[usize]) -> Dataset<CscMatrix> {
        Dataset::new(self.x.subset(idx), idx.iter().map(|&j| self.y[j]).collect())
    }
}

impl AnyDataset {
    /// Deterministic train/test split: the examples are i.i.d. by
    /// construction, so an index split is a valid held-out set.
    pub fn split(&self, test_frac: f64, seed: u64) -> (AnyDataset, AnyDataset) {
        let (tr, te) = split_indices(self.n(), test_frac, seed);
        match self {
            AnyDataset::Dense(ds) => (
                AnyDataset::Dense(ds.subset(&tr)),
                AnyDataset::Dense(ds.subset(&te)),
            ),
            AnyDataset::Sparse(ds) => (
                AnyDataset::Sparse(ds.subset(&tr)),
                AnyDataset::Sparse(ds.subset(&te)),
            ),
        }
    }
}

/// Deterministic train/test split by hashing indices (keeps both halves
/// reproducible without materializing a permutation of the data).
pub fn split_indices(n: usize, test_frac: f64, seed: u64) -> (Vec<usize>, Vec<usize>) {
    let mut rng = crate::util::Rng::new(seed);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let test = idx[..n_test].to_vec();
    let train = idx[n_test..].to_vec();
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_caches_norms() {
        let m = DenseMatrix::from_columns(2, &[&[3.0, 4.0], &[1.0, 0.0]]);
        let ds = Dataset::new(m, vec![1.0, -1.0]);
        assert!((ds.norm_sq(0) - 25.0).abs() < 1e-12);
        assert!((ds.norm_sq(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn dataset_rejects_label_mismatch() {
        let m = DenseMatrix::from_columns(2, &[&[1.0, 2.0]]);
        let _ = Dataset::new(m, vec![1.0, -1.0]);
    }

    #[test]
    fn append_dense_examples() {
        let a = DenseMatrix::from_columns(2, &[&[1.0, 2.0], &[3.0, 4.0]]);
        let mut dsa = Dataset::new(a, vec![1.0, -1.0]);
        let b = DenseMatrix::from_columns(2, &[&[5.0, 6.0]]);
        let dsb = Dataset::new(b, vec![1.0]);
        dsa.append(&dsb);
        assert_eq!(dsa.n(), 3);
        assert_eq!(dsa.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(dsa.x.col(2), &[5.0, 6.0]);
        assert!((dsa.norm_sq(2) - 61.0).abs() < 1e-12);
    }

    #[test]
    fn append_sparse_examples() {
        let a = CscMatrix::from_examples(3, &[vec![(0, 1.0)], vec![(2, 2.0)]]);
        let mut dsa = Dataset::new(a, vec![1.0, -1.0]);
        let b = CscMatrix::from_examples(3, &[vec![(1, 3.0), (2, 4.0)]]);
        let dsb = Dataset::new(b, vec![1.0]);
        dsa.append(&dsb);
        assert_eq!((dsa.n(), dsa.x.nnz()), (3, 4));
        let (idx, val) = dsa.x.col(2);
        assert_eq!(idx, &[1, 2]);
        assert_eq!(val, &[3.0, 4.0]);
        assert!((dsa.norm_sq(2) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn is_finite_catches_bad_labels_and_values() {
        let m = DenseMatrix::from_columns(2, &[&[1.0, 2.0], &[3.0, 4.0]]);
        let clean = Dataset::new(m.clone(), vec![1.0, -1.0]);
        assert!(clean.is_finite());

        let mut bad_label = Dataset::new(m, vec![1.0, -1.0]);
        bad_label.y[0] = f64::NAN;
        assert!(!bad_label.is_finite());

        let poisoned = DenseMatrix::from_columns(2, &[&[1.0, f64::INFINITY]]);
        let bad_value = Dataset::new(poisoned, vec![1.0]);
        assert!(!bad_value.is_finite());

        let sparse = CscMatrix::from_examples(3, &[vec![(1, f64::NEG_INFINITY)]]);
        let bad_sparse = Dataset::new(sparse, vec![1.0]);
        assert!(!bad_sparse.is_finite());
    }

    #[test]
    #[should_panic]
    fn append_rejects_dimension_mismatch() {
        let mut a = Dataset::new(DenseMatrix::zeros(2, 1), vec![1.0]);
        let b = Dataset::new(DenseMatrix::zeros(3, 1), vec![1.0]);
        a.append(&b);
    }

    /// The core structural-sharing claim: after an append, the original
    /// columns live in the SAME allocation (no payload copy), the appended
    /// matrix gained exactly the other side's segments, and the source
    /// dataset is untouched.
    #[test]
    fn append_shares_segments_structurally() {
        let a = Dataset::new(
            DenseMatrix::from_columns(2, &[&[1.0, 2.0], &[3.0, 4.0]]),
            vec![1.0, -1.0],
        );
        let b = Dataset::new(DenseMatrix::from_columns(2, &[&[5.0, 6.0]]), vec![1.0]);
        let p_a = a.x.col(0).as_ptr();
        let p_b = b.x.col(0).as_ptr();
        let grown = a.appended(&b);
        assert_eq!(grown.n(), 3);
        assert_eq!(grown.x.num_segments(), 2);
        // both sides' storage is shared, not copied
        assert_eq!(grown.x.col(0).as_ptr(), p_a);
        assert_eq!(grown.x.col(2).as_ptr(), p_b);
        // the predecessor is untouched (snapshots keep serving it)
        assert_eq!((a.n(), a.x.num_segments()), (2, 1));
        assert!(a.x.segment_rc(0) >= 2, "segment must now be shared");

        let sa = Dataset::new(
            CscMatrix::from_examples(3, &[vec![(0, 1.0)], vec![(2, 2.0)]]),
            vec![1.0, -1.0],
        );
        let sb = Dataset::new(CscMatrix::from_examples(3, &[vec![(1, 3.0)]]), vec![1.0]);
        let p_sa = sa.x.col(0).1.as_ptr();
        let grown = sa.appended(&sb);
        assert_eq!(grown.x.num_segments(), 2);
        assert_eq!(grown.x.col(0).1.as_ptr(), p_sa);
        assert!(sa.x.segment_rc(0) >= 2);
    }

    /// A cursor walk across segment boundaries agrees with the per-column
    /// trait path (which re-locates per call).
    #[test]
    fn cursor_matches_per_column_access_across_segments() {
        let mut ds = Dataset::new(
            CscMatrix::from_examples(4, &[vec![(0, 1.0), (3, -2.0)], vec![(1, 0.5)]]),
            vec![1.0, -1.0],
        );
        for k in 0..3 {
            let extra = Dataset::new(
                CscMatrix::from_examples(4, &[vec![(2, 1.0 + k as f64)], vec![(0, -0.25)]]),
                vec![1.0, -1.0],
            );
            ds.append(&extra);
        }
        assert_eq!(ds.x.num_segments(), 4);
        let v = [0.3, -1.2, 2.0, 0.7];
        let mut cur = ds.x.col_cursor();
        // forward then backward visits all agree bit-wise
        let order: Vec<usize> = (0..ds.n()).chain((0..ds.n()).rev()).collect();
        for &j in &order {
            assert_eq!(cur.dot(j, &v).to_bits(), ds.x.dot_col(j, &v).to_bits(), "col {j}");
            assert_eq!(cur.nnz_col(j), ds.x.nnz_col(j));
            let mut a = vec![0.1; 4];
            let mut b = vec![0.1; 4];
            cur.axpy(j, 1.5, &mut a);
            ds.x.axpy_col(j, 1.5, &mut b);
            assert_eq!(a, b);
        }
        // segment geometry is a partition
        let mut end = 0;
        for s in 0..ds.x.num_segments() {
            let r = ds.x.segment_range(s);
            assert_eq!(r.start, end);
            assert!(r.end > r.start);
            for j in r.clone() {
                assert_eq!(ds.x.segment_of(j), s);
            }
            end = r.end;
        }
        assert_eq!(end, ds.n());
    }

    #[test]
    fn split_is_partition() {
        let (tr, te) = split_indices(100, 0.2, 7);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        let mut all: Vec<usize> = tr.iter().chain(te.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_deterministic() {
        let (a, _) = split_indices(50, 0.1, 3);
        let (b, _) = split_indices(50, 0.1, 3);
        assert_eq!(a, b);
    }
}
