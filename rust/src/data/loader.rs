//! Dataset I/O: LIBSVM text format (what criteo-kaggle / HIGGS / epsilon
//! are distributed as) and a fast binary cache so repeated experiment runs
//! skip text parsing (the paper excludes load time from training time; we
//! keep it cheap anyway).

use super::{CscMatrix, Dataset, DenseMatrix};
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Parse a LIBSVM-format text file into a sparse dataset.
///
/// * labels: `+1/-1`, `0/1` (mapped to `±1`) or real values;
/// * indices: 1-based (LIBSVM convention) or 0-based — auto-detected from
///   the minimum index seen;
/// * `d_hint`: optional feature-count override (use when train/test splits
///   must agree on dimensionality).
pub fn load_libsvm(path: &Path, d_hint: Option<usize>) -> Result<Dataset<CscMatrix>> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::with_capacity(1 << 20, f);
    let mut examples: Vec<Vec<(u32, f64)>> = Vec::new();
    let mut y = Vec::new();
    let mut max_idx = 0u32;
    let mut min_idx = u32::MAX;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f64 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("bad label at line {}", lineno + 1))?;
        let mut ex = Vec::new();
        for p in parts {
            let (i, v) = p
                .split_once(':')
                .with_context(|| format!("bad feature '{}' at line {}", p, lineno + 1))?;
            let i: u32 = i.parse().with_context(|| format!("bad index at line {}", lineno + 1))?;
            let v: f64 = v.parse().with_context(|| format!("bad value at line {}", lineno + 1))?;
            max_idx = max_idx.max(i);
            min_idx = min_idx.min(i);
            ex.push((i, v));
        }
        examples.push(ex);
        y.push(label);
    }
    if examples.is_empty() {
        bail!("{}: empty dataset", path.display());
    }
    // 1-based (libsvm convention) unless a 0 index appears.
    let offset = if min_idx == 0 { 0 } else { 1 };
    let d_seen = (max_idx + 1 - offset) as usize;
    let d = d_hint.unwrap_or(d_seen).max(d_seen);
    for ex in &mut examples {
        for e in ex.iter_mut() {
            e.0 -= offset;
        }
        ex.sort_unstable_by_key(|&(i, _)| i);
    }
    let y = normalize_binary_labels(y);
    Ok(Dataset::new(CscMatrix::from_examples(d, &examples), y))
}

/// Map `{0,1}` labels to `{-1,+1}`; leave `±1` or regression targets alone.
fn normalize_binary_labels(y: Vec<f64>) -> Vec<f64> {
    let zero_one = y.iter().all(|&v| v == 0.0 || v == 1.0) && y.iter().any(|&v| v == 0.0);
    if zero_one {
        y.into_iter().map(|v| if v == 0.0 { -1.0 } else { 1.0 }).collect()
    } else {
        y
    }
}

/// Densify a sparse dataset (for dense-path experiments on datasets that
/// are logically dense but distributed as LIBSVM text, e.g. epsilon).
pub fn to_dense(ds: &Dataset<CscMatrix>) -> Dataset<DenseMatrix> {
    use super::DataMatrix;
    let (d, n) = (ds.d(), ds.n());
    let mut data = vec![0.0f64; d * n];
    for j in 0..n {
        ds.x.write_col_dense(j, &mut data[j * d..(j + 1) * d]);
    }
    Dataset::new(DenseMatrix::new(d, n, data), ds.y.clone())
}

const BIN_MAGIC: &[u8; 8] = b"PARLIN01";

/// Write the binary cache: `magic | d | n | nnz | col_ptr | idx | val | y`.
pub fn save_binary(ds: &Dataset<CscMatrix>, path: &Path) -> Result<()> {
    use super::DataMatrix;
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    let (d, n, nnz) = (ds.d() as u64, ds.n() as u64, ds.x.nnz() as u64);
    for v in [d, n, nnz] {
        w.write_all(&v.to_le_bytes())?;
    }
    for j in 0..ds.n() {
        let (idx, val) = ds.x.col(j);
        w.write_all(&(idx.len() as u32).to_le_bytes())?;
        for &i in idx {
            w.write_all(&i.to_le_bytes())?;
        }
        for &v in val {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    for &label in &ds.y {
        w.write_all(&label.to_le_bytes())?;
    }
    Ok(())
}

/// Read the binary cache written by [`save_binary`].
pub fn load_binary(path: &Path) -> Result<Dataset<CscMatrix>> {
    let mut r = BufReader::with_capacity(1 << 20, File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        bail!("{}: not a parlin binary dataset", path.display());
    }
    let mut u64buf = [0u8; 8];
    let mut read_u64 = |r: &mut BufReader<File>| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let d = read_u64(&mut r)? as usize;
    let n = read_u64(&mut r)? as usize;
    let nnz = read_u64(&mut r)? as usize;
    let mut col_ptr = Vec::with_capacity(n + 1);
    let mut idx = Vec::with_capacity(nnz);
    let mut val = Vec::with_capacity(nnz);
    col_ptr.push(0usize);
    let mut u32buf = [0u8; 4];
    let mut f64buf = [0u8; 8];
    for _ in 0..n {
        r.read_exact(&mut u32buf)?;
        let len = u32::from_le_bytes(u32buf) as usize;
        for _ in 0..len {
            r.read_exact(&mut u32buf)?;
            idx.push(u32::from_le_bytes(u32buf));
        }
        for _ in 0..len {
            r.read_exact(&mut f64buf)?;
            val.push(f64::from_le_bytes(f64buf));
        }
        col_ptr.push(idx.len());
    }
    if idx.len() != nnz {
        bail!("{}: truncated payload", path.display());
    }
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        r.read_exact(&mut f64buf)?;
        y.push(f64::from_le_bytes(f64buf));
    }
    Ok(Dataset::new(CscMatrix::new(d, n, col_ptr, idx, val), y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataMatrix;

    fn write_tmp(content: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "parlin_test_{}_{}.libsvm",
            std::process::id(),
            content.len()
        ));
        let mut f = File::create(&p).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        p
    }

    #[test]
    fn parses_one_based_libsvm() {
        let p = write_tmp("+1 1:0.5 3:2.0\n-1 2:1.0\n");
        let ds = load_libsvm(&p, None).unwrap();
        assert_eq!((ds.n(), ds.d()), (2, 3));
        assert_eq!(ds.y, vec![1.0, -1.0]);
        let (idx, val) = ds.x.col(0);
        assert_eq!(idx, &[0, 2]);
        assert_eq!(val, &[0.5, 2.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn parses_zero_based_and_zero_one_labels() {
        let p = write_tmp("1 0:1.0\n0 1:1.0\n");
        let ds = load_libsvm(&p, None).unwrap();
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.d(), 2);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn d_hint_expands() {
        let p = write_tmp("+1 1:1.0\n");
        let ds = load_libsvm(&p, Some(10)).unwrap();
        assert_eq!(ds.d(), 10);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = write_tmp("+1 nonsense\n");
        assert!(load_libsvm(&p, None).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let ds = crate::data::synthetic::sparse_classification(100, 50, 0.1, 7);
        let p = std::env::temp_dir().join(format!("parlin_bin_{}.bin", std::process::id()));
        save_binary(&ds, &p).unwrap();
        let ds2 = load_binary(&p).unwrap();
        assert_eq!(ds.n(), ds2.n());
        assert_eq!(ds.d(), ds2.d());
        assert_eq!(ds.y, ds2.y);
        for j in 0..ds.n() {
            assert_eq!(ds.x.col(j), ds2.x.col(j));
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn densify_matches() {
        let ds = crate::data::synthetic::sparse_classification(20, 10, 0.3, 8);
        let dd = to_dense(&ds);
        for j in 0..ds.n() {
            let v: Vec<f64> = (0..10).map(|i| if i == 3 { 1.0 } else { 0.0 }).collect();
            assert!((ds.x.dot_col(j, &v) - dd.x.dot_col(j, &v)).abs() < 1e-12);
        }
    }
}
