//! Shard-resident interleaved training layout — the paper's cache-line
//! locality and cache-line prefetching optimizations (§4) applied to the
//! *example data* access pattern, not just the model vector.
//!
//! ## Why a second copy of the data
//!
//! The generic [`DataMatrix`] stores sparse examples CSC-style as split
//! `idx`/`val` arrays, and every coordinate step walks an example **twice**
//! (`dot_col` to get the margin, then `axpy_col` to apply the update).
//! That is four stream walks per step over two distinct address streams —
//! the hardware prefetcher has to track both, and the second pass re-issues
//! the same index loads. The paper's measurements (and SySCD's layout
//! redesign) show the remaining per-epoch time on large models is exactly
//! this memory traffic.
//!
//! [`ShardedLayout`] materializes, once per `train()` call (or per serving
//! [`Session`](crate::serve::Session)), a bucket-major **interleaved**
//! encoding of each worker shard:
//!
//! * one [`Entry`] record `(idx: u32, val_bits: u64)` per stored non-zero,
//!   packed per example, examples laid out in exactly the order the bucket
//!   walk visits them — one coordinate step is one forward streaming read
//!   of a single contiguous slice (§4 "cache line locality");
//! * the backing buffer is 64-byte aligned ([`EntryBuf`]), so bucket entry
//!   ranges start on cache-line boundaries and a bucket's stream never
//!   splits a line with its neighbour;
//! * per-bucket entry ranges are indexable, so the *next* bucket of the
//!   shuffled permutation can be software-prefetched while the current one
//!   computes ([`Shard::prefetch_bucket`]) — the shuffled bucket order
//!   defeats the hardware stream detector, but the permutation makes the
//!   target known one step ahead (§4 "cache line prefetching");
//! * shards follow the *static* partitioning boundaries (one shard per
//!   NUMA node for the hierarchical solver, one global shard otherwise).
//!   The paper's **dynamic** re-deal shuffles bucket *assignment* between
//!   workers every epoch — assignments are index lists, so a re-deal is a
//!   pointer swap and never touches the per-bucket encoding. The layout is
//!   rebuilt only when the partition geometry or the dataset itself
//!   changes (e.g. `refit-rows` appends examples).
//!
//! The source matrix itself is a segment list ([`crate::data`]): the
//! encoder walks it through a [`ColCursor`](crate::data::ColCursor), so
//! building a shard from a many-segment dataset costs the same one
//! forward pass, and [`ShardedLayout::append_tail`] consumes exactly the
//! freshly appended tail segments. Note the encoding itself stays one
//! contiguous buffer per shard (bucket streams must not be chunked);
//! segmenting it the same way is a recorded follow-on (ROADMAP).
//!
//! ## When it pays
//!
//! An [`Entry`] costs 16 bytes per stored non-zero. For sparse data that
//! replaces a 12-byte split `(idx, val)` pair that the two-pass walk
//! reads **twice** per step with one forward 16-byte stream — strictly
//! fewer cold bytes plus the fused/prefetched access pattern. For dense
//! data the encoding doubles the cold bytes per value (8 → 16, the index
//! is implicit in a dense column) in exchange for the same fusion and
//! prefetch wins; which effect dominates is bandwidth-dependent, so the
//! `benches/hot_paths.rs` layout ablation measures both and `--layout
//! csc` opts any run out — results are bit-wise identical either way.
//!
//! ## Determinism
//!
//! The interleaved kernels ([`crate::solver::kernel`]) reproduce the exact
//! floating-point reduction order of the `DataMatrix` paths (the same
//! 4-accumulator chains as [`crate::util::dot`]), so training over a
//! `ShardedLayout` is **bit-wise identical** to training over the source
//! matrix — locked in by `rust/tests/pool_equivalence.rs`.

use super::DataMatrix;
use crate::solver::bucket::Buckets;

/// Which data layout the inner training loops stream.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LayoutPolicy {
    /// Walk the source matrix directly (split `idx`/`val` CSC arrays or
    /// the dense column store) — the pre-layout baseline.
    Csc,
    /// Stream the shard-resident interleaved encoding with fused,
    /// prefetching bucket kernels (default).
    #[default]
    Interleaved,
}

/// One interleaved stored non-zero: feature index + value bits in a single
/// 16-byte record, so margin and update passes read **one** stream.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct Entry {
    /// Feature index (the paper's datasets stay under 2³² features).
    pub idx: u32,
    _pad: u32,
    /// `f64::to_bits` of the value — a free bit-cast on both ends.
    pub val_bits: u64,
}

impl Entry {
    #[inline]
    pub fn new(idx: u32, val: f64) -> Self {
        Entry {
            idx,
            _pad: 0,
            val_bits: val.to_bits(),
        }
    }

    #[inline]
    pub fn val(&self) -> f64 {
        f64::from_bits(self.val_bits)
    }
}

/// Entries per 64-byte cache line (16 B each).
const ENTRIES_PER_LINE: usize = 4;

/// A 64-byte-aligned line of four entries — the allocation unit that keeps
/// the whole backing buffer cache-line aligned without custom allocators.
#[repr(C, align(64))]
#[derive(Clone, Copy)]
struct EntryLine(
    // read through `EntryBuf::as_slice`'s pointer cast; written by name
    // only on the append path (`EntryBuf::push`)
    [Entry; ENTRIES_PER_LINE],
);

/// 64-byte-aligned entry buffer. Logical length may be any entry count;
/// the tail of the last line is zero padding that is never addressed.
#[derive(Clone)]
pub struct EntryBuf {
    lines: Vec<EntryLine>,
    len: usize,
}

impl EntryBuf {
    fn zeroed(len: usize) -> Self {
        let line = EntryLine([Entry::new(0, 0.0); ENTRIES_PER_LINE]);
        EntryBuf {
            lines: vec![line; len.div_ceil(ENTRIES_PER_LINE)],
            len,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn as_slice(&self) -> &[Entry] {
        // Safety: `lines` owns `len.div_ceil(4)` properly-initialized
        // `EntryLine`s, each exactly four `Entry`s, so the first `len`
        // entries are initialized and in bounds; alignment 64 ≥ 8.
        unsafe { std::slice::from_raw_parts(self.lines.as_ptr().cast::<Entry>(), self.len) }
    }

    #[inline]
    fn as_mut_slice(&mut self) -> &mut [Entry] {
        // Safety: see `as_slice`; exclusive borrow of `lines`.
        unsafe { std::slice::from_raw_parts_mut(self.lines.as_mut_ptr().cast::<Entry>(), self.len) }
    }

    /// Append one entry, filling the zero-padded tail of the last line
    /// before growing a new one — the streaming-ingestion path, amortized
    /// `O(1)` per entry and alignment-preserving (`lines` only ever holds
    /// whole 64-byte lines).
    #[inline]
    fn push(&mut self, e: Entry) {
        let slot = self.len % ENTRIES_PER_LINE;
        if slot == 0 {
            self.lines
                .push(EntryLine([Entry::new(0, 0.0); ENTRIES_PER_LINE]));
        }
        self.lines.last_mut().expect("line pushed above").0[slot] = e;
        self.len += 1;
    }
}

impl std::fmt::Debug for EntryBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "EntryBuf({} entries, 64B-aligned)", self.len)
    }
}

/// One worker shard: the interleaved encoding of a contiguous global
/// bucket range (a NUMA node's static split, or everything).
#[derive(Clone, Debug)]
pub struct Shard {
    /// Global bucket range `[bucket_lo, bucket_hi)` this shard encodes.
    bucket_lo: usize,
    bucket_hi: usize,
    /// Global example range covered (derived from the bucket range).
    example_lo: usize,
    example_hi: usize,
    /// Entry offset of local example `e`: entries of global example `j`
    /// are `buf[col_ptr[j - example_lo] .. col_ptr[j - example_lo + 1]]`.
    col_ptr: Vec<usize>,
    buf: EntryBuf,
    bucket_size: usize,
    n_total: usize,
}

impl Shard {
    fn build<M: DataMatrix>(x: &M, buckets: &Buckets, bucket_lo: usize, bucket_hi: usize) -> Self {
        let n = x.n();
        let size = buckets.size();
        let example_lo = (bucket_lo * size).min(n);
        let example_hi = (bucket_hi * size).min(n);
        // encode from the source's segment list: a cursor walk visits the
        // columns in global order, so the segment lookup is amortized to
        // one re-seat per segment boundary crossed
        let mut cur = x.col_cursor();
        let total: usize = (example_lo..example_hi).map(|j| cur.nnz_col(j)).sum();
        let mut col_ptr = Vec::with_capacity(example_hi - example_lo + 1);
        col_ptr.push(0usize);
        let mut buf = EntryBuf::zeroed(total);
        let slice = buf.as_mut_slice();
        let mut k = 0usize;
        for j in example_lo..example_hi {
            cur.for_each_entry(j, |i, v| {
                slice[k] = Entry::new(i as u32, v);
                k += 1;
            });
            col_ptr.push(k);
        }
        debug_assert_eq!(k, total);
        Shard {
            bucket_lo,
            bucket_hi,
            example_lo,
            example_hi,
            col_ptr,
            buf,
            bucket_size: size,
            n_total: n,
        }
    }

    /// Global bucket range this shard encodes.
    #[inline]
    pub fn bucket_range(&self) -> std::ops::Range<usize> {
        self.bucket_lo..self.bucket_hi
    }

    /// Global example range this shard encodes.
    #[inline]
    pub fn example_range(&self) -> std::ops::Range<usize> {
        self.example_lo..self.example_hi
    }

    #[inline]
    pub fn covers_bucket(&self, b: usize) -> bool {
        b >= self.bucket_lo && b < self.bucket_hi
    }

    /// Interleaved entries of global example `j` (must be in this shard).
    #[inline]
    pub fn entries(&self, j: usize) -> &[Entry] {
        let local = j - self.example_lo;
        let lo = self.col_ptr[local];
        let hi = self.col_ptr[local + 1];
        &self.buf.as_slice()[lo..hi]
    }

    /// Entry range (into this shard's buffer) of global bucket `b`.
    #[inline]
    pub fn bucket_entry_range(&self, b: usize) -> std::ops::Range<usize> {
        debug_assert!(self.covers_bucket(b));
        let lo = (b * self.bucket_size).min(self.n_total) - self.example_lo;
        let hi = ((b + 1) * self.bucket_size).min(self.n_total) - self.example_lo;
        self.col_ptr[lo]..self.col_ptr[hi]
    }

    /// Software-prefetch the entry stream of global bucket `b` — issued
    /// for the *next* bucket of the shuffled permutation while the current
    /// one computes, because the shuffled bucket order defeats the
    /// hardware stream detector (§4). No-op off x86_64 and for buckets
    /// outside this shard.
    #[inline]
    pub fn prefetch_bucket(&self, b: usize) {
        if !self.covers_bucket(b) {
            return;
        }
        self.prefetch_entries(self.bucket_entry_range(b));
    }

    /// Software-prefetch one example's entry stream — the wild solver's
    /// walk unit (its flat permutation ignores bucket geometry, so this
    /// works against a shard built with any bucket size).
    #[inline]
    pub fn prefetch_example(&self, j: usize) {
        if j < self.example_lo || j >= self.example_hi {
            return;
        }
        let local = j - self.example_lo;
        self.prefetch_entries(self.col_ptr[local]..self.col_ptr[local + 1]);
    }

    #[inline]
    fn prefetch_entries(&self, range: std::ops::Range<usize>) {
        crate::util::prefetch_slice(&self.buf.as_slice()[range]);
    }

    /// Stored entries in this shard.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.buf.len()
    }

    /// Extend this shard's encoding with the examples `x` gained since it
    /// was built (they all sit at the tail, `self.example_hi..x.n()`), and
    /// grow the covered bucket range to `new_bucket_hi`. The entry stream
    /// and `col_ptr` are strictly appended to — existing entries are not
    /// touched — so the cost is `O(entries added)`, not `O(nnz)`. The
    /// walk consumes the freshly appended tail segment(s) directly: the
    /// cursor seats on the first tail segment and never revisits the
    /// already-encoded head.
    fn append_tail<M: DataMatrix>(&mut self, x: &M, new_bucket_hi: usize) {
        debug_assert_eq!(self.example_lo, 0, "tail append targets the global shard");
        let mut cur = x.col_cursor();
        for j in self.example_hi..x.n() {
            cur.for_each_entry(j, |i, v| self.buf.push(Entry::new(i as u32, v)));
            self.col_ptr.push(self.buf.len());
        }
        self.example_hi = x.n();
        self.n_total = x.n();
        self.bucket_hi = new_bucket_hi;
    }
}

/// The shard-resident interleaved layout of one dataset: one [`Shard`] per
/// static partition (per active NUMA node for the hierarchical solver, one
/// global shard otherwise). Built once per `train()`/`Session`; dynamic
/// re-deals of buckets to workers only swap index lists, never entries.
#[derive(Clone, Debug)]
pub struct ShardedLayout {
    shards: Vec<Shard>,
    bucket_size: usize,
    n: usize,
    d: usize,
}

impl ShardedLayout {
    /// One global shard over all buckets — the `seq`/`dom`/`wild` layout
    /// (their dynamic partitioning shares the whole dataset).
    pub fn single<M: DataMatrix>(x: &M, buckets: &Buckets) -> Self {
        ShardedLayout {
            shards: vec![Shard::build(x, buckets, 0, buckets.count())],
            bucket_size: buckets.size(),
            n: x.n(),
            d: x.d(),
        }
    }

    /// One shard per static bucket range — the hierarchical solver's
    /// per-NUMA-node split (`ranges[k]` is node `k`'s range; inactive
    /// nodes pass an empty range and get an empty shard, keeping shard
    /// index == node index).
    pub fn for_nodes<M: DataMatrix>(
        x: &M,
        buckets: &Buckets,
        ranges: &[std::ops::Range<u32>],
    ) -> Self {
        ShardedLayout {
            shards: ranges
                .iter()
                .map(|r| Shard::build(x, buckets, r.start as usize, r.end as usize))
                .collect(),
            bucket_size: buckets.size(),
            n: x.n(),
            d: x.d(),
        }
    }

    #[inline]
    pub fn shard(&self, s: usize) -> &Shard {
        &self.shards[s]
    }

    #[inline]
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    pub fn bucket_size(&self) -> usize {
        self.bucket_size
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Total interleaved entries across shards.
    pub fn nnz(&self) -> usize {
        self.shards.iter().map(|s| s.nnz()).sum()
    }

    /// Does this layout describe the same dataset shape (`n`, `d`, total
    /// stored entries)? A necessary condition for any reuse — a cache
    /// built from a *different* dataset that happened to share `n` would
    /// otherwise be streamed silently against the wrong labels/norms.
    fn same_shape(&self, n: usize, d: usize, nnz: usize) -> bool {
        self.n == n && self.d == d && self.nnz() == nnz
    }

    /// Is this a single-shard layout over exactly this dataset shape with
    /// exactly this bucket geometry? The gate for reusing a
    /// caller-provided layout (`SolverConfig::layout_cache`) in the
    /// bucketed solvers.
    pub fn matches_single(&self, n: usize, d: usize, nnz: usize, bucket_size: usize) -> bool {
        self.shards.len() == 1 && self.bucket_size == bucket_size && self.same_shape(n, d, nnz)
    }

    /// Is this a single-shard layout over exactly this dataset shape (any
    /// bucket geometry)? Sufficient for per-example consumers (the wild
    /// solver, serving predicts).
    pub fn covers_examples(&self, n: usize, d: usize, nnz: usize) -> bool {
        self.shards.len() == 1 && self.same_shape(n, d, nnz)
    }

    /// Is this a per-node layout over exactly this dataset shape, bucket
    /// geometry and static cross-node bucket split? The reuse gate for the
    /// hierarchical solver's `layout_cache`: a serving session caches its
    /// per-node shards keyed on (placement, bucket size) so `Variant::Numa`
    /// refits stop paying `O(nnz)` re-encoding per `train()`.
    pub fn matches_nodes(
        &self,
        n: usize,
        d: usize,
        nnz: usize,
        bucket_size: usize,
        ranges: &[std::ops::Range<u32>],
    ) -> bool {
        self.bucket_size == bucket_size
            && self.same_shape(n, d, nnz)
            && self.shards.len() == ranges.len()
            && self
                .shards
                .iter()
                .zip(ranges)
                .all(|(s, r)| s.bucket_range() == (r.start as usize..r.end as usize))
    }

    /// Incrementally re-encode the tail after `x` grew by appended
    /// examples: freshly ingested rows land *after* every existing one, so
    /// only the last (possibly partial) bucket and the new buckets need
    /// encoding — layout maintenance is `O(rows added)` instead of the
    /// `O(nnz)` full rebuild (ROADMAP "Streaming layout updates"). Only
    /// the single-shard layout supports this (a per-node split moves its
    /// range boundaries when the bucket count grows — rebuild those).
    ///
    /// The result is bit-wise identical to `ShardedLayout::single(&x, …)`
    /// built from scratch — locked in by the `append_tail_*` tests below.
    pub fn append_tail<M: DataMatrix>(&mut self, x: &M) {
        assert_eq!(
            self.shards.len(),
            1,
            "append_tail needs the single-shard layout; per-node splits must rebuild"
        );
        assert_eq!(x.d(), self.d, "appended examples must keep the feature dim");
        assert!(
            x.n() >= self.n,
            "append_tail cannot shrink the example axis ({} -> {})",
            self.n,
            x.n()
        );
        let buckets = Buckets::new(x.n(), self.bucket_size);
        self.shards[0].append_tail(x, buckets.count());
        self.n = x.n();
    }
}

/// The layout one training run streams: borrowed from a caller's cache
/// ([`SolverConfig::layout_cache`](crate::solver::SolverConfig)) when its
/// geometry fits, owned by the run otherwise, absent under
/// [`LayoutPolicy::Csc`]. The single [`RunLayout::resolve`] constructor
/// encodes the "reuse iff it fits, else build" invariant, so solver call
/// sites cannot desynchronize the gate from the build.
pub enum RunLayout<'a> {
    None,
    Cached(&'a ShardedLayout),
    Built(ShardedLayout),
}

impl<'a> RunLayout<'a> {
    pub fn resolve(
        interleaved: bool,
        cache: Option<&'a std::sync::Arc<ShardedLayout>>,
        fits: impl Fn(&ShardedLayout) -> bool,
        build: impl FnOnce() -> ShardedLayout,
    ) -> Self {
        if !interleaved {
            return RunLayout::None;
        }
        match cache.map(|l| l.as_ref()).filter(|l| fits(l)) {
            Some(l) => RunLayout::Cached(l),
            None => RunLayout::Built(build()),
        }
    }

    /// Shard `s`, if a layout is present.
    pub fn shard(&self, s: usize) -> Option<&Shard> {
        match self {
            RunLayout::None => None,
            RunLayout::Cached(l) => Some(l.shard(s)),
            RunLayout::Built(l) => Some(l.shard(s)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CscMatrix, DenseMatrix};

    fn sample_sparse() -> CscMatrix {
        CscMatrix::from_examples(
            5,
            &[
                vec![(0, 1.0), (3, -2.0)],
                vec![],
                vec![(1, 0.5), (2, 4.0), (4, -1.0)],
                vec![(2, 3.0)],
            ],
        )
    }

    #[test]
    fn entry_line_is_one_cache_line() {
        assert_eq!(std::mem::size_of::<Entry>(), 16);
        assert_eq!(std::mem::size_of::<EntryLine>(), 64);
        assert_eq!(std::mem::align_of::<EntryLine>(), 64);
    }

    #[test]
    fn backing_buffer_is_64b_aligned() {
        let m = sample_sparse();
        let buckets = Buckets::new(m.n(), 2);
        let layout = ShardedLayout::single(&m, &buckets);
        let entries = layout.shard(0).entries(0);
        assert_eq!(entries.as_ptr() as usize % 64, 0, "shard stream must start on a line");
    }

    #[test]
    fn single_shard_roundtrips_sparse() {
        let m = sample_sparse();
        let buckets = Buckets::new(m.n(), 2);
        let layout = ShardedLayout::single(&m, &buckets);
        assert_eq!((layout.n(), layout.d(), layout.nnz()), (4, 5, 6));
        let sh = layout.shard(0);
        for j in 0..m.n() {
            let mut want = Vec::new();
            m.for_each_col_entry(j, |i, v| want.push((i as u32, v.to_bits())));
            let got: Vec<(u32, u64)> = sh.entries(j).iter().map(|e| (e.idx, e.val_bits)).collect();
            assert_eq!(got, want, "example {j}");
        }
    }

    #[test]
    fn single_shard_roundtrips_dense() {
        let m = DenseMatrix::from_columns(3, &[&[1.0, 0.0, 2.0], &[-1.0, 4.0, 0.5]]);
        let layout = ShardedLayout::single(&m, &Buckets::new(2, 1));
        let sh = layout.shard(0);
        let e = sh.entries(1);
        assert_eq!(e.len(), 3);
        assert_eq!((e[0].idx, e[0].val()), (0, -1.0));
        assert_eq!((e[2].idx, e[2].val()), (2, 0.5));
    }

    #[test]
    fn node_shards_cover_their_ranges() {
        let m = sample_sparse();
        let buckets = Buckets::new(m.n(), 1); // 4 buckets of 1 example
        let layout = ShardedLayout::for_nodes(&m, &buckets, &[0..2, 2..2, 2..4]);
        assert_eq!(layout.num_shards(), 3);
        assert_eq!(layout.shard(0).example_range(), 0..2);
        assert_eq!(layout.shard(1).example_range(), 2..2); // inactive node
        assert_eq!(layout.shard(2).example_range(), 2..4);
        assert!(layout.shard(2).covers_bucket(3));
        assert!(!layout.shard(2).covers_bucket(1));
        let e = layout.shard(2).entries(3);
        assert_eq!((e[0].idx, e[0].val()), (2, 3.0));
        assert_eq!(layout.shard(0).nnz() + layout.shard(2).nnz(), m.nnz());
    }

    #[test]
    fn bucket_entry_ranges_tile_the_stream() {
        let m = sample_sparse();
        let buckets = Buckets::new(m.n(), 3); // buckets: [0..3), [3..4)
        let layout = ShardedLayout::single(&m, &buckets);
        let sh = layout.shard(0);
        assert_eq!(sh.bucket_entry_range(0), 0..5);
        assert_eq!(sh.bucket_entry_range(1), 5..6);
        sh.prefetch_bucket(0); // smoke: must not fault
        sh.prefetch_bucket(7); // out of range: no-op
    }

    #[test]
    fn run_layout_reuses_only_a_fitting_cache() {
        let m = sample_sparse();
        let cache = std::sync::Arc::new(ShardedLayout::single(&m, &Buckets::new(m.n(), 2)));
        let r = RunLayout::resolve(true, Some(&cache), |l| l.matches_single(4, 5, 6, 2), || {
            unreachable!("fitting cache must not trigger a build")
        });
        assert!(matches!(r, RunLayout::Cached(_)));
        assert!(r.shard(0).is_some());
        for miss in [
            (4usize, 5usize, 6usize, 8usize), // wrong bucket geometry
            (5, 5, 6, 2),                     // wrong n (different dataset)
            (4, 7, 6, 2),                     // wrong d
            (4, 5, 9, 2),                     // wrong nnz
        ] {
            let r = RunLayout::resolve(
                true,
                Some(&cache),
                |l| l.matches_single(miss.0, miss.1, miss.2, miss.3),
                || ShardedLayout::single(&m, &Buckets::new(m.n(), 8)),
            );
            assert!(matches!(r, RunLayout::Built(_)), "{miss:?} must rebuild");
        }
        let r = RunLayout::resolve(false, Some(&cache), |_| true, || {
            unreachable!("Csc runs never build a layout")
        });
        assert!(matches!(r, RunLayout::None));
        assert!(r.shard(0).is_none());
    }

    /// Bit-wise equality of two single-shard layouts over the same matrix:
    /// every example's `(idx, val_bits)` stream, every bucket's entry
    /// range, and the shape metadata must agree exactly.
    fn assert_layouts_bitwise_eq<M: DataMatrix>(a: &ShardedLayout, b: &ShardedLayout, x: &M) {
        assert_eq!((a.n(), a.d(), a.nnz()), (b.n(), b.d(), b.nnz()));
        assert_eq!(a.bucket_size(), b.bucket_size());
        assert_eq!(a.num_shards(), b.num_shards());
        let (sa, sb) = (a.shard(0), b.shard(0));
        assert_eq!(sa.bucket_range(), sb.bucket_range());
        assert_eq!(sa.example_range(), sb.example_range());
        for j in 0..x.n() {
            let ea: Vec<(u32, u64)> = sa.entries(j).iter().map(|e| (e.idx, e.val_bits)).collect();
            let eb: Vec<(u32, u64)> = sb.entries(j).iter().map(|e| (e.idx, e.val_bits)).collect();
            assert_eq!(ea, eb, "example {j} diverged");
        }
        for bkt in 0..Buckets::new(x.n(), a.bucket_size()).count() {
            assert_eq!(
                sa.bucket_entry_range(bkt),
                sb.bucket_entry_range(bkt),
                "bucket {bkt} entry range diverged"
            );
        }
    }

    #[test]
    fn append_tail_matches_full_rebuild_sparse() {
        let mut m = sample_sparse(); // 4 examples, bucket size 3 → partial tail bucket
        let mut incr = ShardedLayout::single(&m, &Buckets::new(m.n(), 3));
        // two successive appends: one that fills out the partial tail
        // bucket + line, one that adds whole new buckets
        for batch in [
            vec![vec![(0u32, 7.0f64)], vec![(4, -3.5), (1, 0.75)]],
            vec![vec![], vec![(2, 1.0), (3, 2.0), (0, -9.0)], vec![(4, 0.5)]],
        ] {
            let grown = {
                let mut ex: Vec<Vec<(u32, f64)>> = (0..m.n())
                    .map(|j| {
                        let mut col = Vec::new();
                        m.for_each_col_entry(j, |i, v| col.push((i as u32, v)));
                        col
                    })
                    .collect();
                ex.extend(batch.iter().cloned());
                CscMatrix::from_examples(5, &ex)
            };
            m = grown;
            incr.append_tail(&m);
            let rebuilt = ShardedLayout::single(&m, &Buckets::new(m.n(), 3));
            assert_layouts_bitwise_eq(&incr, &rebuilt, &m);
        }
        assert_eq!(incr.n(), 9);
    }

    #[test]
    fn append_tail_matches_full_rebuild_dense() {
        let mut cols: Vec<Vec<f64>> = (0..3)
            .map(|j| (0..5).map(|i| (i * 3 + j) as f64 * 0.25 - 1.0).collect())
            .collect();
        let refs: Vec<&[f64]> = cols.iter().map(|c| c.as_slice()).collect();
        let m = DenseMatrix::from_columns(5, &refs);
        let mut incr = ShardedLayout::single(&m, &Buckets::new(m.n(), 2));
        cols.push(vec![0.5, -0.5, 1.5, -1.5, 2.5]);
        cols.push(vec![9.0, 8.0, 7.0, 6.0, 5.0]);
        let refs: Vec<&[f64]> = cols.iter().map(|c| c.as_slice()).collect();
        let grown = DenseMatrix::from_columns(5, &refs);
        incr.append_tail(&grown);
        let rebuilt = ShardedLayout::single(&grown, &Buckets::new(grown.n(), 2));
        assert_layouts_bitwise_eq(&incr, &rebuilt, &grown);
        // the appended stream stays 64-byte aligned at its head
        assert_eq!(incr.shard(0).entries(0).as_ptr() as usize % 64, 0);
    }

    #[test]
    fn append_tail_from_empty_matches_rebuild() {
        let empty = CscMatrix::from_examples(5, &[]);
        let mut incr = ShardedLayout::single(&empty, &Buckets::new(0, 2));
        let m = sample_sparse();
        incr.append_tail(&m);
        let rebuilt = ShardedLayout::single(&m, &Buckets::new(m.n(), 2));
        assert_layouts_bitwise_eq(&incr, &rebuilt, &m);
    }

    #[test]
    #[should_panic]
    fn append_tail_rejects_node_split_layouts() {
        let m = sample_sparse();
        let buckets = Buckets::new(m.n(), 1);
        let mut layout = ShardedLayout::for_nodes(&m, &buckets, &[0..2, 2..4]);
        layout.append_tail(&m);
    }

    #[test]
    fn matches_nodes_gates_on_split_shape_and_geometry() {
        let m = sample_sparse();
        let buckets = Buckets::new(m.n(), 1); // 4 buckets
        let ranges = [0u32..2, 2..2, 2..4];
        let layout = ShardedLayout::for_nodes(&m, &buckets, &ranges);
        assert!(layout.matches_nodes(4, 5, 6, 1, &ranges));
        // any drifted key must miss
        assert!(!layout.matches_nodes(5, 5, 6, 1, &ranges), "wrong n");
        assert!(!layout.matches_nodes(4, 7, 6, 1, &ranges), "wrong d");
        assert!(!layout.matches_nodes(4, 5, 9, 1, &ranges), "wrong nnz");
        assert!(!layout.matches_nodes(4, 5, 6, 2, &ranges), "wrong bucket size");
        assert!(!layout.matches_nodes(4, 5, 6, 1, &ranges[..2]), "wrong node count");
        let shifted = [0u32..3, 3..3, 3..4];
        assert!(!layout.matches_nodes(4, 5, 6, 1, &shifted), "wrong split");
        // a single-shard layout never satisfies a multi-node key
        let single = ShardedLayout::single(&m, &buckets);
        assert!(!single.matches_nodes(4, 5, 6, 1, &ranges));
        assert!(single.matches_nodes(4, 5, 6, 1, &[0u32..4]));
    }

    #[test]
    fn empty_dataset_ok() {
        let m = CscMatrix::from_examples(3, &[]);
        let layout = ShardedLayout::single(&m, &Buckets::new(0, 4));
        assert_eq!(layout.nnz(), 0);
        assert_eq!(layout.shard(0).example_range(), 0..0);
    }
}
