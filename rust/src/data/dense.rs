//! Column-major dense matrix, stored as a segment list: example `j`
//! occupies one contiguous `d`-length slice inside the immutable
//! [`DenseSegment`] that owns it, so one SDCA step streams exactly one
//! column — the access pattern the paper's prefetching argument relies
//! on. A freshly loaded matrix is a single segment; appends seal the
//! arriving columns into a new tail segment and share every existing one
//! by `Arc` (see the [`crate::data`] module docs for the segment model).

use super::{AppendExamples, DataMatrix};
use crate::util;
use std::sync::Arc;

/// One immutable chunk of the example axis: a column-major block of
/// consecutive examples, sealed at construction and shared by `Arc`
/// between dataset versions.
#[derive(Debug)]
pub struct DenseSegment {
    d: usize,
    n: usize,
    /// Column-major payload, `data.len() == d·n`.
    data: Vec<f64>,
}

impl DenseSegment {
    /// Local example `local` as a slice.
    #[inline]
    fn col(&self, local: usize) -> &[f64] {
        &self.data[local * self.d..(local + 1) * self.d]
    }
}

/// Column-major dense matrix over an ordered list of immutable
/// [`DenseSegment`] chunks. Single-segment after a bulk load (no lookup
/// cost on the fast path); one extra segment per appended batch, all
/// existing segments shared with prior dataset versions.
#[derive(Clone, Debug)]
pub struct DenseMatrix {
    d: usize,
    n: usize,
    segs: Vec<Arc<DenseSegment>>,
    /// `seg_start[s]` = first global example of segment `s`, plus one
    /// trailing entry equal to `n` (`seg_start.len() == segs.len() + 1`).
    seg_start: Vec<usize>,
}

impl DenseMatrix {
    /// Build from raw column-major storage (`data.len() == d·n`) — one
    /// sealed segment.
    pub fn new(d: usize, n: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), d * n, "dense payload must be d·n");
        let mut m = DenseMatrix {
            d,
            n: 0,
            segs: Vec::new(),
            seg_start: vec![0],
        };
        m.push_segment(Arc::new(DenseSegment { d, n, data }));
        m
    }

    /// Build from explicit column slices (test helper).
    pub fn from_columns(d: usize, cols: &[&[f64]]) -> Self {
        let mut data = Vec::with_capacity(d * cols.len());
        for c in cols {
            assert_eq!(c.len(), d);
            data.extend_from_slice(c);
        }
        DenseMatrix::new(d, cols.len(), data)
    }

    /// Zero matrix with shape `(d, n)`.
    pub fn zeros(d: usize, n: usize) -> Self {
        DenseMatrix::new(d, n, vec![0.0; d * n])
    }

    /// Attach a sealed segment to the tail (empty segments are skipped so
    /// `segment_range` stays non-empty for every listed segment).
    fn push_segment(&mut self, seg: Arc<DenseSegment>) {
        debug_assert_eq!(seg.d, self.d, "segment feature dim mismatch");
        if seg.n == 0 {
            return;
        }
        self.n += seg.n;
        self.seg_start.push(self.n);
        self.segs.push(seg);
    }

    /// `(segment, local example)` of global example `j`.
    #[inline]
    fn locate(&self, j: usize) -> (usize, usize) {
        // fast path: the monolithic (single bulk load) case
        if self.segs.len() == 1 {
            return (0, j);
        }
        let s = self.seg_start.partition_point(|&lo| lo <= j) - 1;
        (s, j - self.seg_start[s])
    }

    /// Example `j` as a slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        let (s, local) = self.locate(j);
        self.segs[s].col(local)
    }

    /// Strong reference count of segment `s`'s backing `Arc` — the
    /// clone-count diagnostic the structural-sharing tests assert on.
    pub fn segment_rc(&self, s: usize) -> usize {
        Arc::strong_count(&self.segs[s])
    }

    /// Copy the selected examples into a new (single-segment) matrix
    /// (train/test splits).
    pub fn subset(&self, idx: &[usize]) -> DenseMatrix {
        let mut data = Vec::with_capacity(idx.len() * self.d);
        for &j in idx {
            data.extend_from_slice(self.col(j));
        }
        DenseMatrix::new(self.d, idx.len(), data)
    }

    /// Gather a row-major `(rows.len(), d)` tile of the selected examples —
    /// the shape the AOT matvec artifact consumes.
    pub fn gather_rows_major(&self, rows: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), rows.len() * self.d);
        for (r, &j) in rows.iter().enumerate() {
            out[r * self.d..(r + 1) * self.d].copy_from_slice(self.col(j));
        }
    }
}

impl AppendExamples for DenseMatrix {
    fn append_examples(&mut self, other: &Self) {
        assert_eq!(self.d, other.d, "feature dimension mismatch");
        for seg in &other.segs {
            self.push_segment(Arc::clone(seg));
        }
    }
}

impl DataMatrix for DenseMatrix {
    #[inline]
    fn n(&self) -> usize {
        self.n
    }

    #[inline]
    fn d(&self) -> usize {
        self.d
    }

    #[inline]
    fn nnz(&self) -> usize {
        self.d * self.n
    }

    #[inline]
    fn nnz_col(&self, _j: usize) -> usize {
        self.d
    }

    #[inline]
    fn norm_sq_col(&self, j: usize) -> f64 {
        util::norm_sq(self.col(j))
    }

    fn write_col_dense(&self, j: usize, out: &mut [f64]) {
        out[..self.d].copy_from_slice(self.col(j));
        for x in &mut out[self.d..] {
            *x = 0.0;
        }
    }

    fn for_each_col_index(&self, _j: usize, mut f: impl FnMut(usize)) {
        for i in 0..self.d {
            f(i);
        }
    }

    #[inline]
    fn num_segments(&self) -> usize {
        self.segs.len()
    }

    #[inline]
    fn segment_of(&self, j: usize) -> usize {
        self.locate(j).0
    }

    #[inline]
    fn segment_range(&self, s: usize) -> std::ops::Range<usize> {
        self.seg_start[s]..self.seg_start[s + 1]
    }

    #[inline]
    fn dot_col_in(&self, s: usize, j: usize, v: &[f64]) -> f64 {
        util::dot(self.segs[s].col(j - self.seg_start[s]), v)
    }

    #[inline]
    fn axpy_col_in(&self, s: usize, j: usize, scale: f64, v: &mut [f64]) {
        util::axpy(scale, self.segs[s].col(j - self.seg_start[s]), v);
    }

    #[inline]
    fn nnz_col_in(&self, _s: usize, _j: usize) -> usize {
        self.d
    }

    fn for_each_col_entry_in(&self, s: usize, j: usize, mut f: impl FnMut(usize, f64)) {
        for (i, &x) in self.segs[s].col(j - self.seg_start[s]).iter().enumerate() {
            f(i, x);
        }
    }

    fn dot_col_atomic_in(&self, s: usize, j: usize, v: &[crate::util::PaddedAtomicF64]) -> f64 {
        let col = self.segs[s].col(j - self.seg_start[s]);
        let mut sum = 0.0;
        for (x, vi) in col.iter().zip(v.iter()) {
            sum += x * vi.load();
        }
        sum
    }

    fn axpy_col_wild_in(&self, s: usize, j: usize, scale: f64, v: &[crate::util::PaddedAtomicF64]) {
        let col = self.segs[s].col(j - self.seg_start[s]);
        for (x, vi) in col.iter().zip(v.iter()) {
            vi.add_wild(scale * x);
        }
    }

    /// Hint the hardware prefetcher at the column range `j_lo..j_hi`
    /// (the *next* bucket while the current one is being processed —
    /// §3's "CPU prefetching efficiency" made explicit). Clamped to the
    /// segment containing `j_lo`: a range that crosses a segment
    /// boundary prefetches its head, which is all a hint needs. No-op on
    /// non-x86 targets (see [`util::prefetch_slice`]).
    #[inline]
    fn prefetch_cols(&self, j_lo: usize, j_hi: usize) {
        if j_lo >= self.n || j_hi <= j_lo {
            return;
        }
        let (s, local) = self.locate(j_lo);
        let seg = &self.segs[s];
        let hi_local = (j_hi.min(self.seg_start[s] + seg.n) - self.seg_start[s]).max(local);
        util::prefetch_slice(&seg.data[local * self.d..hi_local * self.d]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DenseMatrix {
        DenseMatrix::from_columns(3, &[&[1.0, 2.0, 3.0], &[0.0, -1.0, 0.5]])
    }

    #[test]
    fn shape_and_cols() {
        let m = sample();
        assert_eq!((m.d(), m.n(), m.nnz()), (3, 2, 6));
        assert_eq!(m.col(1), &[0.0, -1.0, 0.5]);
        assert_eq!(m.num_segments(), 1);
        assert_eq!(m.segment_range(0), 0..2);
    }

    #[test]
    fn dot_and_axpy() {
        let m = sample();
        let v = [1.0, 1.0, 2.0];
        assert!((m.dot_col(0, &v) - 9.0).abs() < 1e-12);
        let mut w = [0.0; 3];
        m.axpy_col(1, 2.0, &mut w);
        assert_eq!(w, [0.0, -2.0, 1.0]);
    }

    #[test]
    fn norms() {
        let m = sample();
        assert!((m.norm_sq_col(0) - 14.0).abs() < 1e-12);
    }

    #[test]
    fn gather_tile() {
        let m = sample();
        let mut out = vec![0.0; 6];
        m.gather_rows_major(&[1, 0], &mut out);
        assert_eq!(out, vec![0.0, -1.0, 0.5, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn write_col_dense_pads() {
        let m = sample();
        let mut out = vec![9.0; 5];
        m.write_col_dense(0, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn append_pushes_shared_tail_segment() {
        let mut m = sample();
        let p0 = m.col(0).as_ptr();
        let tail = DenseMatrix::from_columns(3, &[&[7.0, 8.0, 9.0]]);
        let p_tail = tail.col(0).as_ptr();
        m.append_examples(&tail);
        assert_eq!((m.n(), m.num_segments()), (3, 2));
        assert_eq!(m.col(2), &[7.0, 8.0, 9.0]);
        // structural sharing: both allocations are reused, not copied
        assert_eq!(m.col(0).as_ptr(), p0);
        assert_eq!(m.col(2).as_ptr(), p_tail);
        assert_eq!(m.segment_of(1), 0);
        assert_eq!(m.segment_of(2), 1);
        assert_eq!(m.segment_range(1), 2..3);
        // column ops cross the boundary transparently
        let v = [1.0, 1.0, 1.0];
        assert!((m.dot_col(2, &v) - 24.0).abs() < 1e-12);
        // appending an empty matrix adds no segment
        m.append_examples(&DenseMatrix::zeros(3, 0));
        assert_eq!((m.n(), m.num_segments()), (3, 2));
    }

    #[test]
    fn prefetch_clamps_to_segment() {
        let mut m = sample();
        m.append_examples(&sample());
        m.prefetch_cols(1, 4); // crosses the boundary: must not fault
        m.prefetch_cols(3, 3); // empty range: no-op
        m.prefetch_cols(9, 12); // out of range: no-op
    }

    #[test]
    #[should_panic]
    fn new_rejects_bad_len() {
        let _ = DenseMatrix::new(3, 2, vec![0.0; 5]);
    }
}
