//! Column-major dense matrix: example `j` occupies the contiguous slice
//! `data[j·d .. (j+1)·d]`, so one SDCA step streams exactly one column —
//! the access pattern the paper's prefetching argument relies on.

use super::{AppendExamples, DataMatrix};
use crate::util;

#[derive(Clone, Debug)]
pub struct DenseMatrix {
    d: usize,
    n: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Build from raw column-major storage (`data.len() == d·n`).
    pub fn new(d: usize, n: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), d * n, "dense payload must be d·n");
        DenseMatrix { d, n, data }
    }

    /// Build from explicit column slices (test helper).
    pub fn from_columns(d: usize, cols: &[&[f64]]) -> Self {
        let mut data = Vec::with_capacity(d * cols.len());
        for c in cols {
            assert_eq!(c.len(), d);
            data.extend_from_slice(c);
        }
        DenseMatrix {
            d,
            n: cols.len(),
            data,
        }
    }

    /// Zero matrix with shape `(d, n)`.
    pub fn zeros(d: usize, n: usize) -> Self {
        DenseMatrix {
            d,
            n,
            data: vec![0.0; d * n],
        }
    }

    /// Example `j` as a slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.d..(j + 1) * self.d]
    }

    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.d..(j + 1) * self.d]
    }

    /// Raw payload (runtime tiling uses this to feed PJRT buffers).
    pub fn raw(&self) -> &[f64] {
        &self.data
    }

    /// Hint the hardware prefetcher at the column range `j_lo..j_hi`
    /// (the *next* bucket while the current one is being processed —
    /// §3's "CPU prefetching efficiency" made explicit). No-op on
    /// non-x86 targets (see [`util::prefetch_slice`]).
    #[inline]
    fn prefetch_cols_impl(&self, j_lo: usize, j_hi: usize) {
        let lo = j_lo * self.d;
        let hi = (j_hi * self.d).min(self.data.len());
        util::prefetch_slice(&self.data[lo..hi]);
    }

    /// Copy the selected examples into a new matrix (train/test splits).
    pub fn subset(&self, idx: &[usize]) -> DenseMatrix {
        let mut data = Vec::with_capacity(idx.len() * self.d);
        for &j in idx {
            data.extend_from_slice(self.col(j));
        }
        DenseMatrix::new(self.d, idx.len(), data)
    }

    /// Gather a row-major `(rows.len(), d)` tile of the selected examples —
    /// the shape the AOT matvec artifact consumes.
    pub fn gather_rows_major(&self, rows: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), rows.len() * self.d);
        for (r, &j) in rows.iter().enumerate() {
            out[r * self.d..(r + 1) * self.d].copy_from_slice(self.col(j));
        }
    }
}

impl AppendExamples for DenseMatrix {
    fn append_examples(&mut self, other: &Self) {
        assert_eq!(self.d, other.d, "feature dimension mismatch");
        self.data.extend_from_slice(&other.data);
        self.n += other.n;
    }
}

impl DataMatrix for DenseMatrix {
    #[inline]
    fn n(&self) -> usize {
        self.n
    }

    #[inline]
    fn d(&self) -> usize {
        self.d
    }

    #[inline]
    fn nnz(&self) -> usize {
        self.d * self.n
    }

    #[inline]
    fn nnz_col(&self, _j: usize) -> usize {
        self.d
    }

    #[inline]
    fn dot_col(&self, j: usize, v: &[f64]) -> f64 {
        util::dot(self.col(j), v)
    }

    #[inline]
    fn axpy_col(&self, j: usize, scale: f64, v: &mut [f64]) {
        util::axpy(scale, self.col(j), v);
    }

    #[inline]
    fn norm_sq_col(&self, j: usize) -> f64 {
        util::norm_sq(self.col(j))
    }

    fn write_col_dense(&self, j: usize, out: &mut [f64]) {
        out[..self.d].copy_from_slice(self.col(j));
        for x in &mut out[self.d..] {
            *x = 0.0;
        }
    }

    #[inline]
    fn prefetch_cols(&self, j_lo: usize, j_hi: usize) {
        self.prefetch_cols_impl(j_lo, j_hi);
    }

    fn for_each_col_index(&self, _j: usize, mut f: impl FnMut(usize)) {
        for i in 0..self.d {
            f(i);
        }
    }

    fn for_each_col_entry(&self, j: usize, mut f: impl FnMut(usize, f64)) {
        for (i, &x) in self.col(j).iter().enumerate() {
            f(i, x);
        }
    }

    fn dot_col_atomic(&self, j: usize, v: &[crate::util::PaddedAtomicF64]) -> f64 {
        let col = self.col(j);
        let mut s = 0.0;
        for (x, vi) in col.iter().zip(v.iter()) {
            s += x * vi.load();
        }
        s
    }

    fn axpy_col_wild(&self, j: usize, scale: f64, v: &[crate::util::PaddedAtomicF64]) {
        let col = self.col(j);
        for (x, vi) in col.iter().zip(v.iter()) {
            vi.add_wild(scale * x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DenseMatrix {
        DenseMatrix::from_columns(3, &[&[1.0, 2.0, 3.0], &[0.0, -1.0, 0.5]])
    }

    #[test]
    fn shape_and_cols() {
        let m = sample();
        assert_eq!((m.d(), m.n(), m.nnz()), (3, 2, 6));
        assert_eq!(m.col(1), &[0.0, -1.0, 0.5]);
    }

    #[test]
    fn dot_and_axpy() {
        let m = sample();
        let v = [1.0, 1.0, 2.0];
        assert!((m.dot_col(0, &v) - 9.0).abs() < 1e-12);
        let mut w = [0.0; 3];
        m.axpy_col(1, 2.0, &mut w);
        assert_eq!(w, [0.0, -2.0, 1.0]);
    }

    #[test]
    fn norms() {
        let m = sample();
        assert!((m.norm_sq_col(0) - 14.0).abs() < 1e-12);
    }

    #[test]
    fn gather_tile() {
        let m = sample();
        let mut out = vec![0.0; 6];
        m.gather_rows_major(&[1, 0], &mut out);
        assert_eq!(out, vec![0.0, -1.0, 0.5, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn write_col_dense_pads() {
        let m = sample();
        let mut out = vec![9.0; 5];
        m.write_col_dense(0, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn new_rejects_bad_len() {
        let _ = DenseMatrix::new(3, 2, vec![0.0; 5]);
    }
}
