//! Compressed-sparse-column matrix (examples are columns, criteo-style),
//! stored as a segment list: each immutable [`CscSegment`] holds the CSC
//! arrays of a contiguous run of examples, sealed at construction and
//! shared by `Arc` across dataset versions (see the [`crate::data`]
//! module docs for the segment model).
//!
//! Feature indices are `u32` (the paper's datasets stay under 2³² features)
//! which halves index bandwidth vs `usize` — per-epoch time on sparse data
//! is dominated by streaming `(index, value)` pairs.

use super::{AppendExamples, DataMatrix};
use std::sync::Arc;

/// One immutable CSC chunk of the example axis.
#[derive(Debug)]
pub struct CscSegment {
    d: usize,
    n: usize,
    /// `col_ptr[l]..col_ptr[l+1]` bounds local example `l`'s entries.
    col_ptr: Vec<usize>,
    idx: Vec<u32>,
    val: Vec<f64>,
}

impl CscSegment {
    /// `(indices, values)` of local example `local`.
    #[inline]
    fn col(&self, local: usize) -> (&[u32], &[f64]) {
        let lo = self.col_ptr[local];
        let hi = self.col_ptr[local + 1];
        (&self.idx[lo..hi], &self.val[lo..hi])
    }

    #[inline]
    fn nnz_col(&self, local: usize) -> usize {
        self.col_ptr[local + 1] - self.col_ptr[local]
    }
}

/// CSC matrix over an ordered list of immutable [`CscSegment`] chunks.
/// Single-segment after a bulk load; one extra segment per appended
/// batch, all existing segments shared with prior dataset versions.
#[derive(Clone, Debug)]
pub struct CscMatrix {
    d: usize,
    n: usize,
    nnz: usize,
    segs: Vec<Arc<CscSegment>>,
    /// `seg_start[s]` = first global example of segment `s`, plus one
    /// trailing entry equal to `n` (`seg_start.len() == segs.len() + 1`).
    seg_start: Vec<usize>,
}

impl CscMatrix {
    /// Build from raw CSC arrays — one sealed segment.
    pub fn new(d: usize, n: usize, col_ptr: Vec<usize>, idx: Vec<u32>, val: Vec<f64>) -> Self {
        assert_eq!(col_ptr.len(), n + 1);
        assert_eq!(*col_ptr.last().unwrap(), idx.len());
        assert_eq!(idx.len(), val.len());
        debug_assert!(idx.iter().all(|&i| (i as usize) < d));
        let mut m = CscMatrix {
            d,
            n: 0,
            nnz: 0,
            segs: Vec::new(),
            seg_start: vec![0],
        };
        m.push_segment(Arc::new(CscSegment {
            d,
            n,
            col_ptr,
            idx,
            val,
        }));
        m
    }

    /// Build from per-example `(feature, value)` lists.
    pub fn from_examples(d: usize, examples: &[Vec<(u32, f64)>]) -> Self {
        let n = examples.len();
        let nnz: usize = examples.iter().map(|e| e.len()).sum();
        let mut col_ptr = Vec::with_capacity(n + 1);
        let mut idx = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        col_ptr.push(0);
        for ex in examples {
            for &(i, v) in ex {
                assert!((i as usize) < d, "feature index {i} out of range (d={d})");
                idx.push(i);
                val.push(v);
            }
            col_ptr.push(idx.len());
        }
        CscMatrix::new(d, n, col_ptr, idx, val)
    }

    /// Attach a sealed segment to the tail (empty segments are skipped so
    /// `segment_range` stays non-empty for every listed segment).
    fn push_segment(&mut self, seg: Arc<CscSegment>) {
        debug_assert_eq!(seg.d, self.d, "segment feature dim mismatch");
        if seg.n == 0 {
            return;
        }
        self.n += seg.n;
        self.nnz += seg.idx.len();
        self.seg_start.push(self.n);
        self.segs.push(seg);
    }

    /// `(segment, local example)` of global example `j`.
    #[inline]
    fn locate(&self, j: usize) -> (usize, usize) {
        // fast path: the monolithic (single bulk load) case
        if self.segs.len() == 1 {
            return (0, j);
        }
        let s = self.seg_start.partition_point(|&lo| lo <= j) - 1;
        (s, j - self.seg_start[s])
    }

    /// `(indices, values)` of example `j`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let (s, local) = self.locate(j);
        self.segs[s].col(local)
    }

    /// Strong reference count of segment `s`'s backing `Arc` — the
    /// clone-count diagnostic the structural-sharing tests assert on.
    pub fn segment_rc(&self, s: usize) -> usize {
        Arc::strong_count(&self.segs[s])
    }

    /// Copy the selected examples into a new (single-segment) matrix
    /// (train/test splits). Output vectors are pre-sized to the exact
    /// selected nnz — growing them by push caused repeated reallocs (and
    /// full copies) on large shards. Each selected column is located
    /// exactly once (the slices are kept for the copy pass); a cursor
    /// would not help here because split index lists are shuffled, so
    /// consecutive visits rarely share a segment.
    pub fn subset(&self, idx: &[usize]) -> CscMatrix {
        let cols: Vec<(&[u32], &[f64])> = idx.iter().map(|&j| self.col(j)).collect();
        let total: usize = cols.iter().map(|(ci, _)| ci.len()).sum();
        let mut col_ptr = Vec::with_capacity(idx.len() + 1);
        let mut new_idx = Vec::with_capacity(total);
        let mut new_val = Vec::with_capacity(total);
        col_ptr.push(0);
        for (ci, cv) in cols {
            new_idx.extend_from_slice(ci);
            new_val.extend_from_slice(cv);
            col_ptr.push(new_idx.len());
        }
        CscMatrix::new(self.d, idx.len(), col_ptr, new_idx, new_val)
    }

    /// Average non-zeros per example.
    pub fn avg_nnz(&self) -> f64 {
        self.nnz() as f64 / self.n as f64
    }
}

impl AppendExamples for CscMatrix {
    fn append_examples(&mut self, other: &Self) {
        assert_eq!(self.d, other.d, "feature dimension mismatch");
        for seg in &other.segs {
            self.push_segment(Arc::clone(seg));
        }
    }
}

impl DataMatrix for CscMatrix {
    #[inline]
    fn n(&self) -> usize {
        self.n
    }

    #[inline]
    fn d(&self) -> usize {
        self.d
    }

    #[inline]
    fn nnz(&self) -> usize {
        self.nnz
    }

    #[inline]
    fn nnz_col(&self, j: usize) -> usize {
        let (s, local) = self.locate(j);
        self.segs[s].nnz_col(local)
    }

    #[inline]
    fn norm_sq_col(&self, j: usize) -> f64 {
        let (_, val) = self.col(j);
        val.iter().map(|x| x * x).sum()
    }

    fn write_col_dense(&self, j: usize, out: &mut [f64]) {
        for x in out.iter_mut() {
            *x = 0.0;
        }
        let (idx, val) = self.col(j);
        for (&i, &x) in idx.iter().zip(val.iter()) {
            out[i as usize] = x;
        }
    }

    fn for_each_col_index(&self, j: usize, mut f: impl FnMut(usize)) {
        let (idx, _) = self.col(j);
        for &i in idx {
            f(i as usize);
        }
    }

    #[inline]
    fn num_segments(&self) -> usize {
        self.segs.len()
    }

    #[inline]
    fn segment_of(&self, j: usize) -> usize {
        self.locate(j).0
    }

    #[inline]
    fn segment_range(&self, s: usize) -> std::ops::Range<usize> {
        self.seg_start[s]..self.seg_start[s + 1]
    }

    #[inline]
    fn dot_col_in(&self, s: usize, j: usize, v: &[f64]) -> f64 {
        // The shared 4-chain reduction (`util::dot4_by`): independent
        // chains keep the gather pipeline full, and the sparse, dense and
        // interleaved dot paths stay bit-wise identical by construction.
        let (idx, val) = self.segs[s].col(j - self.seg_start[s]);
        crate::util::dot4_by(idx.len(), |k| (val[k], v[idx[k] as usize]))
    }

    #[inline]
    fn axpy_col_in(&self, s: usize, j: usize, scale: f64, v: &mut [f64]) {
        let (idx, val) = self.segs[s].col(j - self.seg_start[s]);
        for (&i, &x) in idx.iter().zip(val.iter()) {
            v[i as usize] += scale * x;
        }
    }

    #[inline]
    fn nnz_col_in(&self, s: usize, j: usize) -> usize {
        self.segs[s].nnz_col(j - self.seg_start[s])
    }

    fn for_each_col_entry_in(&self, s: usize, j: usize, mut f: impl FnMut(usize, f64)) {
        let (idx, val) = self.segs[s].col(j - self.seg_start[s]);
        for (&i, &x) in idx.iter().zip(val.iter()) {
            f(i as usize, x);
        }
    }

    fn dot_col_atomic_in(&self, s: usize, j: usize, v: &[crate::util::PaddedAtomicF64]) -> f64 {
        let (idx, val) = self.segs[s].col(j - self.seg_start[s]);
        let mut sum = 0.0;
        for (&i, &x) in idx.iter().zip(val.iter()) {
            sum += x * v[i as usize].load();
        }
        sum
    }

    fn axpy_col_wild_in(&self, s: usize, j: usize, scale: f64, v: &[crate::util::PaddedAtomicF64]) {
        let (idx, val) = self.segs[s].col(j - self.seg_start[s]);
        for (&i, &x) in idx.iter().zip(val.iter()) {
            v[i as usize].add_wild(scale * x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CscMatrix {
        // d=4, two examples: x0 = (0:1.0, 2:2.0), x1 = (1:-1.0, 3:0.5)
        CscMatrix::from_examples(4, &[vec![(0, 1.0), (2, 2.0)], vec![(1, -1.0), (3, 0.5)]])
    }

    #[test]
    fn shape() {
        let m = sample();
        assert_eq!((m.d(), m.n(), m.nnz()), (4, 2, 4));
        assert_eq!(m.nnz_col(0), 2);
        assert!((m.avg_nnz() - 2.0).abs() < 1e-12);
        assert_eq!(m.num_segments(), 1);
    }

    #[test]
    fn dot_and_axpy() {
        let m = sample();
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((m.dot_col(0, &v) - 7.0).abs() < 1e-12);
        assert!((m.dot_col(1, &v) - 0.0).abs() < 1e-12);
        let mut w = [0.0; 4];
        m.axpy_col(1, 2.0, &mut w);
        assert_eq!(w, [0.0, -2.0, 0.0, 1.0]);
    }

    #[test]
    fn norms_and_densify() {
        let m = sample();
        assert!((m.norm_sq_col(0) - 5.0).abs() < 1e-12);
        let mut out = vec![7.0; 4];
        m.write_col_dense(1, &mut out);
        assert_eq!(out, vec![0.0, -1.0, 0.0, 0.5]);
    }

    #[test]
    fn append_pushes_shared_tail_segment() {
        let mut m = sample();
        let p0 = m.col(0).1.as_ptr();
        let tail = CscMatrix::from_examples(4, &[vec![(0, 9.0)], vec![]]);
        m.append_examples(&tail);
        assert_eq!((m.n(), m.nnz(), m.num_segments()), (4, 5, 2));
        // structural sharing of the original payload
        assert_eq!(m.col(0).1.as_ptr(), p0);
        // cross-boundary access
        let (idx, val) = m.col(2);
        assert_eq!((idx, val), (&[0u32][..], &[9.0][..]));
        assert_eq!(m.nnz_col(3), 0);
        assert_eq!(m.segment_of(3), 1);
        // appending an empty matrix adds no segment
        m.append_examples(&CscMatrix::from_examples(4, &[]));
        assert_eq!(m.num_segments(), 2);
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range_feature() {
        let _ = CscMatrix::from_examples(2, &[vec![(5, 1.0)]]);
    }

    #[test]
    fn empty_columns_ok() {
        let m = CscMatrix::from_examples(3, &[vec![], vec![(1, 2.0)], vec![]]);
        assert_eq!(m.nnz_col(0), 0);
        assert_eq!(m.dot_col(0, &[1.0, 1.0, 1.0]), 0.0);
        assert_eq!(m.norm_sq_col(2), 0.0);
    }
}
