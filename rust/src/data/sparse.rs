//! Compressed-sparse-column matrix (examples are columns, criteo-style).
//!
//! Feature indices are `u32` (the paper's datasets stay under 2³² features)
//! which halves index bandwidth vs `usize` — per-epoch time on sparse data
//! is dominated by streaming `(index, value)` pairs.

use super::{AppendExamples, DataMatrix};

#[derive(Clone, Debug)]
pub struct CscMatrix {
    d: usize,
    n: usize,
    /// `col_ptr[j]..col_ptr[j+1]` bounds example `j`'s entries.
    col_ptr: Vec<usize>,
    idx: Vec<u32>,
    val: Vec<f64>,
}

impl CscMatrix {
    pub fn new(d: usize, n: usize, col_ptr: Vec<usize>, idx: Vec<u32>, val: Vec<f64>) -> Self {
        assert_eq!(col_ptr.len(), n + 1);
        assert_eq!(*col_ptr.last().unwrap(), idx.len());
        assert_eq!(idx.len(), val.len());
        debug_assert!(idx.iter().all(|&i| (i as usize) < d));
        CscMatrix {
            d,
            n,
            col_ptr,
            idx,
            val,
        }
    }

    /// Build from per-example `(feature, value)` lists.
    pub fn from_examples(d: usize, examples: &[Vec<(u32, f64)>]) -> Self {
        let n = examples.len();
        let nnz: usize = examples.iter().map(|e| e.len()).sum();
        let mut col_ptr = Vec::with_capacity(n + 1);
        let mut idx = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        col_ptr.push(0);
        for ex in examples {
            for &(i, v) in ex {
                assert!((i as usize) < d, "feature index {i} out of range (d={d})");
                idx.push(i);
                val.push(v);
            }
            col_ptr.push(idx.len());
        }
        CscMatrix {
            d,
            n,
            col_ptr,
            idx,
            val,
        }
    }

    /// `(indices, values)` of example `j`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let lo = self.col_ptr[j];
        let hi = self.col_ptr[j + 1];
        (&self.idx[lo..hi], &self.val[lo..hi])
    }

    /// Copy the selected examples into a new matrix (train/test splits).
    /// Output vectors are pre-sized to the exact selected nnz — growing
    /// them by push caused repeated reallocs (and full copies) on large
    /// shards.
    pub fn subset(&self, idx: &[usize]) -> CscMatrix {
        let total: usize = idx.iter().map(|&j| self.nnz_col(j)).sum();
        let mut col_ptr = Vec::with_capacity(idx.len() + 1);
        let mut new_idx = Vec::with_capacity(total);
        let mut new_val = Vec::with_capacity(total);
        col_ptr.push(0);
        for &j in idx {
            let (ci, cv) = self.col(j);
            new_idx.extend_from_slice(ci);
            new_val.extend_from_slice(cv);
            col_ptr.push(new_idx.len());
        }
        CscMatrix::new(self.d, idx.len(), col_ptr, new_idx, new_val)
    }

    /// Average non-zeros per example.
    pub fn avg_nnz(&self) -> f64 {
        self.nnz() as f64 / self.n as f64
    }
}

impl AppendExamples for CscMatrix {
    fn append_examples(&mut self, other: &Self) {
        assert_eq!(self.d, other.d, "feature dimension mismatch");
        let base = *self.col_ptr.last().unwrap();
        self.col_ptr
            .extend(other.col_ptr.iter().skip(1).map(|&p| base + p));
        self.idx.extend_from_slice(&other.idx);
        self.val.extend_from_slice(&other.val);
        self.n += other.n;
    }
}

impl DataMatrix for CscMatrix {
    #[inline]
    fn n(&self) -> usize {
        self.n
    }

    #[inline]
    fn d(&self) -> usize {
        self.d
    }

    #[inline]
    fn nnz(&self) -> usize {
        self.val.len()
    }

    #[inline]
    fn nnz_col(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    #[inline]
    fn dot_col(&self, j: usize, v: &[f64]) -> f64 {
        // The shared 4-chain reduction (`util::dot4_by`): independent
        // chains keep the gather pipeline full, and the sparse, dense and
        // interleaved dot paths stay bit-wise identical by construction.
        let (idx, val) = self.col(j);
        crate::util::dot4_by(idx.len(), |k| (val[k], v[idx[k] as usize]))
    }

    #[inline]
    fn axpy_col(&self, j: usize, scale: f64, v: &mut [f64]) {
        let (idx, val) = self.col(j);
        for (&i, &x) in idx.iter().zip(val.iter()) {
            v[i as usize] += scale * x;
        }
    }

    #[inline]
    fn norm_sq_col(&self, j: usize) -> f64 {
        let (_, val) = self.col(j);
        val.iter().map(|x| x * x).sum()
    }

    fn write_col_dense(&self, j: usize, out: &mut [f64]) {
        for x in out.iter_mut() {
            *x = 0.0;
        }
        let (idx, val) = self.col(j);
        for (&i, &x) in idx.iter().zip(val.iter()) {
            out[i as usize] = x;
        }
    }

    fn for_each_col_index(&self, j: usize, mut f: impl FnMut(usize)) {
        let (idx, _) = self.col(j);
        for &i in idx {
            f(i as usize);
        }
    }

    fn for_each_col_entry(&self, j: usize, mut f: impl FnMut(usize, f64)) {
        let (idx, val) = self.col(j);
        for (&i, &x) in idx.iter().zip(val.iter()) {
            f(i as usize, x);
        }
    }

    fn dot_col_atomic(&self, j: usize, v: &[crate::util::PaddedAtomicF64]) -> f64 {
        let (idx, val) = self.col(j);
        let mut s = 0.0;
        for (&i, &x) in idx.iter().zip(val.iter()) {
            s += x * v[i as usize].load();
        }
        s
    }

    fn axpy_col_wild(&self, j: usize, scale: f64, v: &[crate::util::PaddedAtomicF64]) {
        let (idx, val) = self.col(j);
        for (&i, &x) in idx.iter().zip(val.iter()) {
            v[i as usize].add_wild(scale * x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CscMatrix {
        // d=4, two examples: x0 = (0:1.0, 2:2.0), x1 = (1:-1.0, 3:0.5)
        CscMatrix::from_examples(4, &[vec![(0, 1.0), (2, 2.0)], vec![(1, -1.0), (3, 0.5)]])
    }

    #[test]
    fn shape() {
        let m = sample();
        assert_eq!((m.d(), m.n(), m.nnz()), (4, 2, 4));
        assert_eq!(m.nnz_col(0), 2);
        assert!((m.avg_nnz() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn dot_and_axpy() {
        let m = sample();
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((m.dot_col(0, &v) - 7.0).abs() < 1e-12);
        assert!((m.dot_col(1, &v) - 0.0).abs() < 1e-12);
        let mut w = [0.0; 4];
        m.axpy_col(1, 2.0, &mut w);
        assert_eq!(w, [0.0, -2.0, 0.0, 1.0]);
    }

    #[test]
    fn norms_and_densify() {
        let m = sample();
        assert!((m.norm_sq_col(0) - 5.0).abs() < 1e-12);
        let mut out = vec![7.0; 4];
        m.write_col_dense(1, &mut out);
        assert_eq!(out, vec![0.0, -1.0, 0.0, 0.5]);
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range_feature() {
        let _ = CscMatrix::from_examples(2, &[vec![(5, 1.0)]]);
    }

    #[test]
    fn empty_columns_ok() {
        let m = CscMatrix::from_examples(3, &[vec![], vec![(1, 2.0)], vec![]]);
        assert_eq!(m.nnz_col(0), 0);
        assert_eq!(m.dot_col(0, &[1.0, 1.0, 1.0]), 0.0);
        assert_eq!(m.norm_sq_col(2), 0.0);
    }
}
