//! Concurrent request scheduler: readers run in parallel against
//! versioned snapshots, writers serialize and publish atomically.
//!
//! A bare [`Session`] admits one request at a time, so the resident pool
//! idles between refits even though predicts are read-only. The
//! [`Scheduler`] puts a reader/writer split in front of the session:
//!
//! * **Readers** ([`Scheduler::predict`]) — any number run concurrently.
//!   A reader grabs the current [`ModelSnapshot`] (one brief mutex lock
//!   to clone two `Arc`s — never held across any compute) and serves the
//!   request entirely from that immutable version. Readers never take the
//!   writer lock, so they never wait for a refit to finish; a predict
//!   storm keeps flowing while a refit trains in the background.
//! * **Writers** ([`Scheduler::ingest`]-triggered refits,
//!   [`Scheduler::refit_lambda`], [`Scheduler::retrain`]) — serialized on
//!   the session mutex. A writer mutates only the session's private state
//!   and, on completion, publishes a brand-new snapshot by swapping the
//!   `Arc` — version `k+1` becomes visible to the next reader in one
//!   pointer store while version `k` keeps serving everyone who already
//!   holds it.
//!
//! ## Determinism of concurrent reads
//!
//! Every predict is bit-wise identical to a *sequential* predict against
//! the snapshot version it was served from, regardless of how many
//! readers and writers are in flight:
//!
//! 1. a snapshot is immutable after construction and `Arc`-shared — a
//!    writer producing `k+1` builds new state off to the side (a fresh
//!    weight vector, and a successor dataset that *shares* version `k`'s
//!    sealed segments while adding its own tail — clone-free appends, see
//!    [`crate::data`]), so no bytes a version-`k` reader can reach are
//!    ever written again; a torn or mixed-version read is impossible by
//!    construction, not by locking discipline;
//! 2. each margin `z_j = ⟨x_j, w⟩` is a pure function of that frozen
//!    snapshot, computed by the same kernel
//!    ([`kernel::dot_entries`](crate::solver::kernel::dot_entries) /
//!    `dot_col`) whether the request runs sequentially
//!    ([`ModelSnapshot::predict`]) or as pool shards
//!    ([`ModelSnapshot::predict_on`] — disjoint contiguous shards, merged
//!    in job order), so *where* and *when* a reader runs cannot change a
//!    single bit;
//! 3. writers publish whole versions atomically (one `Arc` store under
//!    the publish mutex) and never in place — a reader observes either
//!    all of version `k` or all of `k+1`.
//!
//! `rust/tests/scheduler.rs` locks this in: predicts racing a live
//! writer are replayed sequentially against their version's retained
//! snapshot and compared bit-for-bit.
//!
//! Reader shards and writer merge-rounds share the same resident
//! [`WorkerPool`] (its per-worker queues accept dispatch from any number
//! of in-flight requests); they interleave at job granularity, which
//! affects latency only — never results.
//!
//! ## Streaming ingestion
//!
//! [`Scheduler::ingest`] appends rows to a staging buffer and returns —
//! arrivals do not block on training, and staging is itself a segment
//! append (each burst's matrix is attached by `Arc`, not copied). A
//! background refit (one dedicated writer thread; never more than one in
//! flight) drains the buffer into
//! [`Session::partial_fit_rows`] — which appends the staged segments to
//! the resident dataset clone-free, whatever snapshots are outstanding —
//! when either threshold trips:
//! `refit_rows_threshold` staged rows, or the oldest staged row waiting
//! `refit_staleness_s` seconds. Until the refit lands, readers keep
//! serving the previous snapshot; [`Scheduler::flush`] forces a
//! synchronous drain (shutdown, tests).
//!
//! ## Admission control
//!
//! Past saturation an open-loop arrival stream would otherwise queue
//! readers without bound. [`SchedulerConfig::max_pending`] caps the
//! readers in flight: [`Scheduler::try_predict`] reserves a pending slot
//! before serving and returns [`PredictAdmission::Rejected`] — counted in
//! [`SchedReport::rejected_predicts`], never silently dropped — when the
//! budget is full. Admission decides only *whether* a request runs, never
//! what it computes, so every served predict keeps the bit-wise
//! determinism contract above. [`Scheduler::predict`] stays
//! unconditional (closed-loop drivers and tests want every request
//! served) but maintains the same pending gauge.

use crate::data::{AppendExamples, Dataset};
use crate::glm::GapReport;
use crate::obs::{self, EventKind};
use crate::serve::session::{RefitReport, Session};
use crate::serve::snapshot::ModelSnapshot;
use crate::solver::{PoolStats, QueueDelayReport, WorkerPool};
use crate::util::Percentiles;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Streaming-ingestion thresholds (the serve CLI's `--refit-rows-threshold`
/// and `--refit-staleness`). Validated in [`Scheduler::new`]: both must be
/// positive (and the staleness finite) — a zero row threshold would refit
/// on every arrival and an infinite staleness would never drain a
/// below-threshold buffer.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Staged rows that trigger a background refit.
    pub refit_rows_threshold: usize,
    /// Seconds the oldest staged row may wait before a refit is forced.
    ///
    /// The deadline is checked on the request path (every `ingest` and
    /// `predict`), not by a timer: a completely idle scheduler holds
    /// below-threshold rows until the next request or `flush` arrives.
    /// Under any ongoing traffic the bound behaves as stated.
    pub refit_staleness_s: f64,
    /// Bounded pending-reader budget for [`Scheduler::try_predict`]
    /// (the serve CLI's `--max-pending`): `None` (default) admits every
    /// reader; `Some(k)` sheds arrivals once `k` readers are in flight.
    /// Validated in [`Scheduler::new`]: `Some(0)` would shed everything.
    pub max_pending: Option<usize>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            refit_rows_threshold: 64,
            refit_staleness_s: 0.25,
            max_pending: None,
        }
    }
}

/// What one scheduled predict observed.
#[derive(Clone, Debug)]
pub struct PredictOutcome {
    /// Snapshot version this request was served from.
    pub version: u64,
    pub margins: Vec<f64>,
    /// Age of the served snapshot when the request started.
    pub snapshot_age_s: f64,
    /// Was a background refit in flight while this predict ran? (The
    /// overlap the scheduler exists to create.)
    pub overlapped_refit: bool,
}

/// Outcome of an admission-controlled [`Scheduler::try_predict`]: served
/// like any other read, or explicitly shed because the pending-reader
/// budget ([`SchedulerConfig::max_pending`]) was full. A rejection is
/// counted in [`SchedReport::rejected_predicts`] — load shedding is
/// always visible, never a silent drop.
#[derive(Clone, Debug)]
pub enum PredictAdmission {
    /// Admitted and served — bit-wise identical to an unconditional
    /// [`Scheduler::predict`] against the same snapshot version.
    Served(PredictOutcome),
    /// Shed at the door: the budget was exhausted by in-flight readers.
    Rejected {
        /// Readers in flight when this request was turned away.
        pending: usize,
    },
}

impl PredictAdmission {
    /// The outcome, if admitted.
    pub fn served(self) -> Option<PredictOutcome> {
        match self {
            PredictAdmission::Served(out) => Some(out),
            PredictAdmission::Rejected { .. } => None,
        }
    }

    pub fn is_rejected(&self) -> bool {
        matches!(self, PredictAdmission::Rejected { .. })
    }
}

/// Predict latencies of one snapshot version.
#[derive(Clone, Debug)]
pub struct VersionLatencies {
    pub version: u64,
    pub predict_s: Vec<f64>,
}

/// Aggregated scheduler metrics: per-version latency distributions plus
/// the snapshot-age distribution across every served predict.
#[derive(Clone, Debug, Default)]
pub struct SchedReport {
    /// Ascending by version.
    pub per_version: Vec<VersionLatencies>,
    /// Snapshot age observed by each predict, in arrival order.
    pub snapshot_age_s: Vec<f64>,
    pub predicts: u64,
    pub predicted_examples: u64,
    /// Predicts that ran while a background refit was in flight.
    pub overlapped_predicts: u64,
    pub ingested_rows: u64,
    /// Versions published after the initial one (refits + retrains).
    pub publishes: u64,
    /// Staging-buffer drains executed (background writer refits plus a
    /// foreground [`Scheduler::flush`] that found rows waiting).
    pub staged_drains: u64,
    /// Predicts shed by admission control ([`Scheduler::try_predict`]
    /// against a full [`SchedulerConfig::max_pending`] budget).
    pub rejected_predicts: u64,
    /// Per-class pool queue delay over the driven window (enqueue→start
    /// of reader predict shards vs writer refit rounds). Stamped by the
    /// closed- and open-loop drivers; zero for a bare `report()` call.
    pub queue_delay: QueueDelayReport,
    /// Filled by the closed-loop driver.
    pub total_wall_s: f64,
    /// Frozen [`obs::registry`] view, stamped by the storm driver
    /// ([`drive_concurrent`](crate::serve::drive_concurrent)); empty for a
    /// bare `report()` call.
    pub metrics: obs::MetricsSnapshot,
}

impl SchedReport {
    /// Human-readable per-version p50/p99 + snapshot-age table.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for v in &self.per_version {
            let lat = Percentiles::of(&v.predict_s);
            s.push_str(&format!(
                "  version {:>3}: {:>6} predicts  p50 {:>9.3} ms  p99 {:>9.3} ms\n",
                v.version,
                v.predict_s.len(),
                lat.p50() * 1e3,
                lat.p99() * 1e3,
            ));
        }
        if !self.snapshot_age_s.is_empty() {
            let ages = Percentiles::of(&self.snapshot_age_s);
            s.push_str(&format!(
                "  snapshot age: p50 {:>8.1} ms  p99 {:>8.1} ms  max {:>8.1} ms\n",
                ages.p50() * 1e3,
                ages.p99() * 1e3,
                ages.max() * 1e3,
            ));
        }
        s.push_str(&format!(
            "  {} predicts ({} overlapped an in-flight refit, {} shed), {} rows ingested, \
             {} versions published ({} staged drains)\n",
            self.predicts,
            self.overlapped_predicts,
            self.rejected_predicts,
            self.ingested_rows,
            self.publishes,
            self.staged_drains,
        ));
        if self.queue_delay.reader.jobs + self.queue_delay.writer.jobs > 0 {
            s.push_str(&self.queue_delay.summary_line());
        }
        if self.total_wall_s > 0.0 {
            s.push_str(&format!(
                "  wall {:.3}s  ({:.1} predicts/s)\n",
                self.total_wall_s,
                self.predicts as f64 / self.total_wall_s.max(1e-9)
            ));
        }
        s
    }
}

/// The staging buffer of the streaming-ingestion path: arrivals append
/// here (cheap, never blocks on training) until a threshold trips.
struct Staging<M: AppendExamples> {
    rows: Option<Dataset<M>>,
    /// When the oldest currently-staged row arrived.
    since: Option<Instant>,
}

impl<M: AppendExamples> Staging<M> {
    fn staged(&self) -> usize {
        self.rows.as_ref().map(|d| d.n()).unwrap_or(0)
    }
}

/// The published read state: the current snapshot plus the pool readers
/// shard on. Locked only to clone/swap the `Arc`s — never across compute.
struct Published<M: AppendExamples> {
    snap: Arc<ModelSnapshot<M>>,
    pool: Arc<WorkerPool>,
}

#[derive(Default)]
struct SchedMetrics {
    per_version: BTreeMap<u64, Vec<f64>>,
    ages: Vec<f64>,
    predicts: u64,
    predicted_examples: u64,
    overlapped: u64,
    ingested_rows: u64,
    publishes: u64,
    staged_drains: u64,
    rejected: u64,
}

struct Shared<M: AppendExamples> {
    cfg: SchedulerConfig,
    /// Writer state. Writers (refits, retrains) serialize here; readers
    /// never touch it.
    session: Mutex<Session<M>>,
    published: Mutex<Published<M>>,
    staging: Mutex<Staging<M>>,
    /// Mirror of `staging`'s row count, maintained under the staging lock
    /// but readable without it — the predict hot path polls "anything
    /// staged?" on every request, and an atomic load keeps that poll off
    /// the lock (readers must not serialize on a third mutex to check an
    /// almost-always-false condition).
    staged_count: AtomicUsize,
    /// Mirror of the published snapshot's example count, maintained in
    /// `publish` — the storm readers poll `current_n` before every
    /// request, and an atomic load keeps that poll off the publish lock
    /// (which each predict must already take once).
    published_n: AtomicUsize,
    /// At most one background refit in flight (CAS-guarded).
    refit_running: AtomicBool,
    refit_handle: Mutex<Option<JoinHandle<()>>>,
    /// Readers currently in flight (admitted, not yet completed) — the
    /// gauge [`SchedulerConfig::max_pending`] admission checks against.
    pending_readers: AtomicUsize,
    metrics: Mutex<SchedMetrics>,
}

/// Decrements the pending-reader gauge on drop, so an admitted slot is
/// released even if the predict compute panics.
struct PendingSlot<'a>(&'a AtomicUsize);

impl Drop for PendingSlot<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

impl<M: AppendExamples + Send> Shared<M> {
    /// Atomically remove everything staged (resetting the fast-path
    /// counter with it).
    fn take_batch(&self) -> Option<Dataset<M>> {
        let mut g = self.staging.lock().unwrap();
        self.staged_count.store(0, Ordering::Relaxed);
        g.since = None;
        g.rows.take()
    }

    /// Drain the staging buffer into a warm refit and publish the result
    /// — the one drain sequence, shared by the background writer thread
    /// and the foreground [`Scheduler::flush`]. The session lock is held
    /// for the whole training request; readers are unaffected (they hold
    /// snapshots), other writers queue behind the lock.
    fn run_staged_refit(&self) -> Option<RefitReport> {
        let mut sess = self.session.lock().unwrap();
        let batch = self.take_batch()?;
        obs::emit(EventKind::IngestDrain, obs::CLASS_WRITER, 0, batch.n() as u64);
        obs::registry().counter("sched.staged_drains").inc();
        let report = sess.partial_fit_rows(&batch);
        self.metrics.lock().unwrap().staged_drains += 1;
        self.publish(&sess, report.kind);
        Some(report)
    }

    /// Install the session's current model as the next snapshot version.
    /// One `Arc` swap under the publish lock: readers that already cloned
    /// version `k` keep it; the next reader gets `k+1` whole.
    fn publish(&self, sess: &Session<M>, kind: &'static str) -> u64 {
        let mut g = self.published.lock().unwrap();
        let version = g.snap.version() + 1;
        g.snap = Arc::new(sess.snapshot(version, kind));
        g.pool = sess.pool_arc();
        self.published_n.store(g.snap.n(), Ordering::Relaxed);
        drop(g);
        self.metrics.lock().unwrap().publishes += 1;
        obs::emit(EventKind::SnapshotPublish, obs::CLASS_WRITER, 0, version);
        obs::registry().counter("sched.publishes").inc();
        version
    }

    /// Wait out any in-flight background writer — including one whose
    /// spawner has CAS'd `refit_running` but not yet stored the handle
    /// (the `None` + flag-still-set window). Shared by [`Scheduler::flush`]
    /// and the `Drop` impl so the subtle loop exists exactly once.
    fn join_background_writer(&self) {
        loop {
            let prev = self.refit_handle.lock().unwrap().take();
            match prev {
                Some(h) => {
                    let _ = h.join();
                }
                None => {
                    if !self.refit_running.load(Ordering::SeqCst) {
                        break;
                    }
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// Reader/writer scheduler over one resident [`Session`] — see the module
/// docs for the concurrency and determinism contract.
pub struct Scheduler<M: AppendExamples + Send + 'static> {
    shared: Arc<Shared<M>>,
}

impl<M: AppendExamples + Send + 'static> Scheduler<M> {
    /// Wrap a trained session and publish its model as snapshot version 0.
    ///
    /// Panics on a non-positive rows threshold, a non-finite /
    /// non-positive staleness, or a zero pending budget (the same
    /// loud-at-the-door treatment `refit-lambda` gets): a zero threshold
    /// would refit per arrival, a bad staleness would either spin or
    /// never drain, and a zero budget would shed every request.
    pub fn new(session: Session<M>, cfg: SchedulerConfig) -> Self {
        assert!(
            cfg.refit_rows_threshold >= 1,
            "refit rows threshold must be >= 1, got {}",
            cfg.refit_rows_threshold
        );
        assert!(
            cfg.refit_staleness_s.is_finite() && cfg.refit_staleness_s > 0.0,
            "refit staleness must be finite and positive, got {}",
            cfg.refit_staleness_s
        );
        if let Some(budget) = cfg.max_pending {
            assert!(budget >= 1, "max pending readers must be >= 1, got 0");
        }
        let snap = Arc::new(session.snapshot(0, "initial-train"));
        let pool = session.pool_arc();
        let published_n = AtomicUsize::new(snap.n());
        Scheduler {
            shared: Arc::new(Shared {
                cfg,
                session: Mutex::new(session),
                published: Mutex::new(Published { snap, pool }),
                staging: Mutex::new(Staging {
                    rows: None,
                    since: None,
                }),
                staged_count: AtomicUsize::new(0),
                published_n,
                refit_running: AtomicBool::new(false),
                refit_handle: Mutex::new(None),
                pending_readers: AtomicUsize::new(0),
                metrics: Mutex::new(SchedMetrics::default()),
            }),
        }
    }

    /// The currently published snapshot (cheap: two `Arc` clones).
    /// Holding the returned `Arc` pins that version — it stays fully
    /// servable no matter how many writers publish after it.
    pub fn snapshot(&self) -> Arc<ModelSnapshot<M>> {
        self.shared.published.lock().unwrap().snap.clone()
    }

    /// Version of the currently published snapshot.
    pub fn version(&self) -> u64 {
        self.snapshot().version()
    }

    /// Example count of the current snapshot (one atomic load — no lock,
    /// the storm readers poll this before every request). Datasets only
    /// grow, so an index below this stays valid against every later
    /// version too.
    pub fn current_n(&self) -> usize {
        self.shared.published_n.load(Ordering::Relaxed)
    }

    pub fn d(&self) -> usize {
        self.snapshot().d()
    }

    pub fn avg_nnz(&self) -> f64 {
        self.snapshot().avg_nnz()
    }

    /// Serve a read-only predict concurrently: grab the current snapshot,
    /// compute sharded margins on the resident pool, record per-version
    /// latency + snapshot age. Never takes the writer lock. Also gives
    /// the ingestion thresholds a chance to fire (a storm keeps staleness
    /// honest even when the append stream pauses). Always admitted; the
    /// pending gauge is maintained so concurrent [`try_predict`]
    /// (admission-controlled) callers see these readers too.
    ///
    /// [`try_predict`]: Scheduler::try_predict
    pub fn predict(&self, idx: &[usize]) -> PredictOutcome {
        self.shared.pending_readers.fetch_add(1, Ordering::SeqCst);
        let _slot = PendingSlot(&self.shared.pending_readers);
        self.serve_predict(idx)
    }

    /// Admission-controlled predict: reserve one of the
    /// [`SchedulerConfig::max_pending`] pending-reader slots and serve, or
    /// shed the request explicitly ([`PredictAdmission::Rejected`], which
    /// is counted in [`SchedReport::rejected_predicts`]). With an
    /// unbounded budget (`max_pending: None`) every request is admitted.
    /// The slot is held for the request's whole lifetime — a reader
    /// blocked on a busy pool keeps its slot, which is exactly what makes
    /// the budget a backpressure bound past saturation.
    pub fn try_predict(&self, idx: &[usize]) -> PredictAdmission {
        let gauge = &self.shared.pending_readers;
        let mut current = gauge.load(Ordering::SeqCst);
        loop {
            if self.shared.cfg.max_pending.is_some_and(|cap| current >= cap) {
                self.shared.metrics.lock().unwrap().rejected += 1;
                obs::emit(EventKind::AdmissionReject, obs::CLASS_READER, 0, current as u64);
                obs::registry().counter("sched.rejected").inc();
                return PredictAdmission::Rejected { pending: current };
            }
            match gauge.compare_exchange(current, current + 1, Ordering::SeqCst, Ordering::SeqCst)
            {
                Ok(_) => break,
                Err(now) => current = now,
            }
        }
        let _slot = PendingSlot(gauge);
        PredictAdmission::Served(self.serve_predict(idx))
    }

    /// Readers currently in flight (diagnostics + admission tests).
    pub fn pending_readers(&self) -> usize {
        self.shared.pending_readers.load(Ordering::SeqCst)
    }

    /// The one serve path behind [`Scheduler::predict`] and
    /// [`Scheduler::try_predict`] — admission decides only whether this
    /// runs, so both entry points are bit-wise identical per version.
    fn serve_predict(&self, idx: &[usize]) -> PredictOutcome {
        let (snap, pool) = {
            let g = self.shared.published.lock().unwrap();
            (g.snap.clone(), g.pool.clone())
        };
        let overlapped_at_start = self.shared.refit_running.load(Ordering::Relaxed);
        let age = snap.age_s();
        let t = crate::util::Timer::start();
        let margins = snap.predict_on(&pool, idx);
        let dt = t.elapsed_s();
        let overlapped = overlapped_at_start || self.shared.refit_running.load(Ordering::Relaxed);
        {
            let mut m = self.shared.metrics.lock().unwrap();
            m.per_version.entry(snap.version()).or_default().push(dt);
            m.ages.push(age);
            m.predicts += 1;
            m.predicted_examples += idx.len() as u64;
            if overlapped {
                m.overlapped += 1;
            }
        }
        self.maybe_spawn_refit();
        PredictOutcome {
            version: snap.version(),
            margins,
            snapshot_age_s: age,
            overlapped_refit: overlapped,
        }
    }

    /// Stream freshly arrived examples into the staging buffer (cheap —
    /// no training on this path) and kick a background refit if a
    /// threshold tripped. Readers keep serving the previous snapshot
    /// until the refit publishes.
    pub fn ingest(&self, rows: Dataset<M>) {
        assert_eq!(rows.d(), self.d(), "ingested rows must match d");
        let k = rows.n();
        {
            let mut g = self.shared.staging.lock().unwrap();
            match g.rows.take() {
                Some(mut acc) => {
                    acc.append(&rows);
                    g.rows = Some(acc);
                }
                None => {
                    g.since = Some(Instant::now());
                    g.rows = Some(rows);
                }
            }
            self.shared.staged_count.store(g.staged(), Ordering::Relaxed);
        }
        self.shared.metrics.lock().unwrap().ingested_rows += k as u64;
        self.maybe_spawn_refit();
    }

    /// Rows currently staged (not yet absorbed by a refit).
    pub fn staged_rows(&self) -> usize {
        self.shared.staged_count.load(Ordering::Relaxed)
    }

    /// Has the staging buffer tripped a refit threshold? The empty-buffer
    /// case — the predict hot path's poll — is answered by one atomic
    /// load; the staging lock is taken only while rows are actually
    /// waiting (a bounded window: a due refit soon drains them to zero).
    pub fn refit_due(&self) -> bool {
        if self.shared.staged_count.load(Ordering::Relaxed) == 0 {
            return false;
        }
        let g = self.shared.staging.lock().unwrap();
        let staged = g.staged();
        staged >= self.shared.cfg.refit_rows_threshold
            || (staged > 0
                && g.since
                    .map(|s| s.elapsed().as_secs_f64() >= self.shared.cfg.refit_staleness_s)
                    .unwrap_or(false))
    }

    /// Spawn the background writer if a threshold tripped and none is in
    /// flight. Returns whether a refit was started.
    fn maybe_spawn_refit(&self) -> bool {
        if !self.refit_due() {
            return false;
        }
        if self.shared.refit_running.swap(true, Ordering::SeqCst) {
            return false; // one background writer at a time
        }
        // the handle slot is held across reap → spawn → store so a slow
        // spawner can never clobber (and thereby detach) a newer writer's
        // handle — whoever joins the stored handle joins the latest writer
        let mut slot = self.shared.refit_handle.lock().unwrap();
        if let Some(h) = slot.take() {
            // previous writer already cleared refit_running, so it has
            // finished its work; the join is a formality
            let _ = h.join();
        }
        let shared = Arc::clone(&self.shared);
        let handle = std::thread::Builder::new()
            .name("parlin-sched-refit".to_string())
            .spawn(move || {
                // clear the in-flight flag even if the refit panics (e.g.
                // a poisoned session lock) — a stuck `true` would disable
                // background refits forever and leave flush() spinning
                struct Reset<'a>(&'a AtomicBool);
                impl Drop for Reset<'_> {
                    fn drop(&mut self) {
                        self.0.store(false, Ordering::SeqCst);
                    }
                }
                let _reset = Reset(&shared.refit_running);
                let _ = shared.run_staged_refit();
            })
            .expect("spawn background refit writer");
        *slot = Some(handle);
        true
    }

    /// Foreground writer: change λ and warm-refit, then publish.
    /// Serializes with every other writer on the session lock.
    pub fn refit_lambda(&self, lambda: f64) -> RefitReport {
        let mut sess = self.shared.session.lock().unwrap();
        let r = sess.partial_fit_lambda(lambda);
        self.shared.publish(&sess, r.kind);
        r
    }

    /// Foreground writer: cold retrain with the session's current config,
    /// then publish.
    pub fn retrain(&self) -> RefitReport {
        let mut sess = self.shared.session.lock().unwrap();
        let r = sess.retrain_same();
        self.shared.publish(&sess, r.kind);
        r
    }

    /// Wait out any in-flight background refit, then synchronously drain
    /// whatever is still staged (ignoring thresholds). Returns the drain
    /// refit's report, if rows were staged.
    pub fn flush(&self) -> Option<RefitReport> {
        self.shared.join_background_writer();
        self.shared.run_staged_refit()
    }

    /// Snapshot of the accumulated metrics (per-version latencies,
    /// snapshot ages, overlap counters). `total_wall_s` is left 0 — the
    /// closed-loop driver stamps it.
    pub fn report(&self) -> SchedReport {
        let m = self.shared.metrics.lock().unwrap();
        SchedReport {
            per_version: m
                .per_version
                .iter()
                .map(|(&version, lat)| VersionLatencies {
                    version,
                    predict_s: lat.clone(),
                })
                .collect(),
            snapshot_age_s: m.ages.clone(),
            predicts: m.predicts,
            predicted_examples: m.predicted_examples,
            overlapped_predicts: m.overlapped,
            ingested_rows: m.ingested_rows,
            publishes: m.publishes,
            staged_drains: m.staged_drains,
            rejected_predicts: m.rejected,
            queue_delay: QueueDelayReport::default(),
            total_wall_s: 0.0,
            metrics: obs::MetricsSnapshot::default(),
        }
    }

    /// Busy-time census of the resident pool (locks the writer state
    /// briefly; diagnostics only).
    pub fn pool_stats(&self) -> PoolStats {
        self.shared.session.lock().unwrap().pool_stats()
    }

    /// Duality gap of the model the *writer* currently holds (may be one
    /// publish ahead of the read side; diagnostics only).
    pub fn gap(&self) -> GapReport {
        self.shared.session.lock().unwrap().gap()
    }
}

impl<M: AppendExamples + Send + 'static> Drop for Scheduler<M> {
    fn drop(&mut self) {
        // deterministic shutdown: reap the background writer so dropping
        // the scheduler leaves no transient thread behind (the pool's
        // workers are joined by the session drop right after)
        self.shared.join_background_writer();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::glm::Objective;
    use crate::solver::{SolverConfig, Variant};
    use crate::sysinfo::Topology;

    fn session(n: usize, seed: u64) -> Session<crate::data::DenseMatrix> {
        let ds = synthetic::dense_classification(n, 6, seed);
        let cfg = SolverConfig::new(Objective::Logistic {
            lambda: 1.0 / n as f64,
        })
        .with_variant(Variant::Domesticated)
        .with_threads(2)
        .with_topology(Topology::flat(2))
        .with_tol(1e-3)
        .with_max_epochs(200);
        Session::new(ds, cfg)
    }

    #[test]
    fn publishes_version_zero_and_serves_it() {
        let sched = Scheduler::new(session(120, 71), SchedulerConfig::default());
        assert_eq!(sched.version(), 0);
        let snap = sched.snapshot();
        let out = sched.predict(&[0, 7, 119]);
        assert_eq!(out.version, 0);
        assert_eq!(out.margins, snap.predict(&[0, 7, 119]));
        assert!(!out.overlapped_refit);
        let report = sched.report();
        assert_eq!((report.predicts, report.publishes), (1, 0));
        assert_eq!(report.per_version.len(), 1);
    }

    #[test]
    fn row_threshold_triggers_background_refit() {
        let sched = Scheduler::new(
            session(120, 72),
            SchedulerConfig {
                refit_rows_threshold: 10,
                refit_staleness_s: 1e6, // rows, not time, must trip this
                max_pending: None,
            },
        );
        sched.ingest(synthetic::dense_classification(4, 6, 73));
        assert!(!sched.refit_due(), "4 staged rows are below the threshold");
        assert_eq!(sched.version(), 0);
        sched.ingest(synthetic::dense_classification(6, 6, 74));
        // the threshold tripped inside ingest; wait for the background
        // writer to publish
        for _ in 0..2000 {
            if sched.version() > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(sched.version(), 1, "background refit must publish v1");
        assert_eq!(sched.current_n(), 130);
        assert_eq!(sched.staged_rows(), 0);
        let report = sched.report();
        assert_eq!(report.ingested_rows, 10);
        assert_eq!(report.staged_drains, 1);
    }

    #[test]
    fn staleness_threshold_trips_via_reads() {
        let sched = Scheduler::new(
            session(100, 75),
            SchedulerConfig {
                refit_rows_threshold: 1_000_000, // time, not rows, must trip
                refit_staleness_s: 0.02,
                max_pending: None,
            },
        );
        sched.ingest(synthetic::dense_classification(3, 6, 76));
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(sched.refit_due(), "staged rows outlived the staleness budget");
        let _ = sched.predict(&[0, 1]); // a read is enough to kick the writer
        for _ in 0..2000 {
            if sched.version() > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(sched.version(), 1);
        assert_eq!(sched.current_n(), 103);
    }

    #[test]
    fn flush_drains_below_threshold_rows() {
        let sched = Scheduler::new(
            session(100, 77),
            SchedulerConfig {
                refit_rows_threshold: 1_000_000,
                refit_staleness_s: 1e6,
                max_pending: None,
            },
        );
        sched.ingest(synthetic::dense_classification(5, 6, 78));
        assert_eq!(sched.version(), 0);
        let r = sched.flush().expect("staged rows must force a drain refit");
        assert_eq!(r.kind, "refit-rows");
        assert_eq!((sched.version(), sched.current_n()), (1, 105));
        assert!(sched.flush().is_none(), "nothing staged, nothing to drain");
    }

    #[test]
    fn foreground_writers_publish_in_sequence() {
        let sched = Scheduler::new(session(110, 79), SchedulerConfig::default());
        let r1 = sched.refit_lambda(0.02);
        assert_eq!((r1.kind, sched.version()), ("refit-lambda", 1));
        let r2 = sched.retrain();
        assert_eq!((r2.kind, sched.version()), ("retrain", 2));
        // the published snapshot serves the post-retrain weights
        let snap = sched.snapshot();
        assert_eq!(snap.produced_by(), "retrain");
        let out = sched.predict(&[1, 2, 3]);
        assert_eq!(out.version, 2);
        assert_eq!(out.margins, snap.predict(&[1, 2, 3]));
    }

    #[test]
    #[should_panic]
    fn rejects_zero_rows_threshold() {
        let _ = Scheduler::new(
            session(60, 80),
            SchedulerConfig {
                refit_rows_threshold: 0,
                refit_staleness_s: 1.0,
                max_pending: None,
            },
        );
    }

    #[test]
    #[should_panic]
    fn rejects_nonfinite_staleness() {
        let _ = Scheduler::new(
            session(60, 81),
            SchedulerConfig {
                refit_rows_threshold: 8,
                refit_staleness_s: f64::INFINITY,
                max_pending: None,
            },
        );
    }

    #[test]
    #[should_panic]
    fn rejects_zero_max_pending() {
        let _ = Scheduler::new(
            session(60, 82),
            SchedulerConfig {
                refit_rows_threshold: 8,
                refit_staleness_s: 1.0,
                max_pending: Some(0),
            },
        );
    }

    #[test]
    fn try_predict_admits_within_budget_and_matches_predict() {
        let sched = Scheduler::new(
            session(90, 83),
            SchedulerConfig {
                refit_rows_threshold: 1_000_000,
                refit_staleness_s: 1e6,
                max_pending: Some(4),
            },
        );
        let idx = [0usize, 3, 89];
        let out = sched
            .try_predict(&idx)
            .served()
            .expect("an idle scheduler must admit within the budget");
        // admission changes only whether a request runs, never its bits
        assert_eq!(out.margins, sched.predict(&idx).margins);
        assert_eq!(sched.pending_readers(), 0, "slots released after serving");
        let report = sched.report();
        assert_eq!(report.rejected_predicts, 0);
        assert_eq!(report.predicts, 2);
    }

    #[test]
    fn unbounded_budget_never_sheds() {
        let sched = Scheduler::new(session(80, 84), SchedulerConfig::default());
        for k in 0..10usize {
            assert!(
                !sched.try_predict(&[k % 80]).is_rejected(),
                "max_pending: None must admit every request"
            );
        }
        assert_eq!(sched.report().rejected_predicts, 0);
    }
}
