//! Concurrent request scheduler: readers run in parallel against
//! versioned snapshots, writers serialize and publish atomically.
//!
//! A bare [`Session`] admits one request at a time, so the resident pool
//! idles between refits even though predicts are read-only. The
//! [`Scheduler`] puts a reader/writer split in front of the session:
//!
//! * **Readers** ([`Scheduler::predict`]) — any number run concurrently.
//!   A reader grabs the current [`ModelSnapshot`] (one brief mutex lock
//!   to clone two `Arc`s — never held across any compute) and serves the
//!   request entirely from that immutable version. Readers never take the
//!   writer lock, so they never wait for a refit to finish; a predict
//!   storm keeps flowing while a refit trains in the background.
//! * **Writers** ([`Scheduler::ingest`]-triggered refits,
//!   [`Scheduler::refit_lambda`], [`Scheduler::retrain`]) — serialized on
//!   the session mutex. A writer mutates only the session's private state
//!   and, on completion, publishes a brand-new snapshot by swapping the
//!   `Arc` — version `k+1` becomes visible to the next reader in one
//!   pointer store while version `k` keeps serving everyone who already
//!   holds it.
//!
//! ## Determinism of concurrent reads
//!
//! Every predict is bit-wise identical to a *sequential* predict against
//! the snapshot version it was served from, regardless of how many
//! readers and writers are in flight:
//!
//! 1. a snapshot is immutable after construction and `Arc`-shared — a
//!    writer producing `k+1` builds new state off to the side (a fresh
//!    weight vector, and a successor dataset that *shares* version `k`'s
//!    sealed segments while adding its own tail — clone-free appends, see
//!    [`crate::data`]), so no bytes a version-`k` reader can reach are
//!    ever written again; a torn or mixed-version read is impossible by
//!    construction, not by locking discipline;
//! 2. each margin `z_j = ⟨x_j, w⟩` is a pure function of that frozen
//!    snapshot, computed by the same kernel
//!    ([`kernel::dot_entries`](crate::solver::kernel::dot_entries) /
//!    `dot_col`) whether the request runs sequentially
//!    ([`ModelSnapshot::predict`]) or as pool shards
//!    ([`ModelSnapshot::predict_on`] — disjoint contiguous shards, merged
//!    in job order), so *where* and *when* a reader runs cannot change a
//!    single bit;
//! 3. writers publish whole versions atomically (one `Arc` store under
//!    the publish mutex) and never in place — a reader observes either
//!    all of version `k` or all of `k+1`.
//!
//! `rust/tests/scheduler.rs` locks this in: predicts racing a live
//! writer are replayed sequentially against their version's retained
//! snapshot and compared bit-for-bit.
//!
//! Reader shards and writer merge-rounds share the same resident
//! [`WorkerPool`] (its per-worker queues accept dispatch from any number
//! of in-flight requests); they interleave at job granularity, which
//! affects latency only — never results.
//!
//! ## Streaming ingestion
//!
//! [`Scheduler::ingest`] appends rows to a staging buffer and returns —
//! arrivals do not block on training, and staging is itself a segment
//! append (each burst's matrix is attached by `Arc`, not copied). A
//! background refit (one dedicated writer thread; never more than one in
//! flight) drains the buffer into
//! [`Session::partial_fit_rows`] — which appends the staged segments to
//! the resident dataset clone-free, whatever snapshots are outstanding —
//! when either threshold trips:
//! `refit_rows_threshold` staged rows, or the oldest staged row waiting
//! `refit_staleness_s` seconds. Until the refit lands, readers keep
//! serving the previous snapshot; [`Scheduler::flush`] forces a
//! synchronous drain (shutdown, tests).
//!
//! ## Admission control
//!
//! Past saturation an open-loop arrival stream would otherwise queue
//! readers without bound. [`SchedulerConfig::max_pending`] caps the
//! readers in flight: [`Scheduler::try_predict`] reserves a pending slot
//! before serving and returns [`PredictAdmission::Rejected`] — counted in
//! [`SchedReport::rejected_predicts`], never silently dropped — when the
//! budget is full. Admission decides only *whether* a request runs, never
//! what it computes, so every served predict keeps the bit-wise
//! determinism contract above. [`Scheduler::predict`] stays
//! unconditional (closed-loop drivers and tests want every request
//! served) but maintains the same pending gauge.
//!
//! ## Fault containment and self-healing
//!
//! The serve path assumes writers *will* fail — a panicking solver, a
//! refit that trains to NaN, a drain thread that dies — and contains each
//! failure to the request that caused it (see `docs/ROBUSTNESS.md`):
//!
//! * Writer failures are **outcomes**, not panics: every writer entry
//!   point returns `Result<RefitReport, ServeError>`, and the session has
//!   already rolled back to its last-known-good state when an `Err` comes
//!   out. A failed writer never poisons the session mutex (and every
//!   scheduler lock recovers from poisoning via
//!   [`lock_recover`](crate::util::lock_recover) anyway).
//! * The drain retries with exponential backoff
//!   ([`SchedulerConfig::drain_max_retries`]); a batch that still fails is
//!   **quarantined** to a bounded dead-letter buffer
//!   ([`SchedulerConfig::dead_letter_rows`]) so one poisoned batch cannot
//!   wedge the staging pipeline forever.
//! * A dead background drain thread is detected (its panic-guard flags
//!   it) and respawned by the next request that finds work; a *stuck*
//!   drain is flagged by a heartbeat watchdog
//!   ([`SchedulerConfig::drain_stall_s`]), reported as degraded, **and
//!   force-recovered**: the watchdog trips the session's
//!   [`CancelToken`], so the stuck refit aborts at its next epoch
//!   checkpoint with a typed [`ServeError::Cancelled`] and the session
//!   rolls back to the last-known-good model. An OS thread is never
//!   killed — the solver cancels itself cooperatively.
//! * Every report carries a [`ServeHealth`]: `Healthy` after a
//!   successful publish, `Degraded { reason }` while the most recent
//!   writer failed or the drain is dead/stalled. `parlin serve` exits
//!   nonzero unless the final state is `Healthy`.

use crate::data::{AppendExamples, Dataset};
use crate::fault::{self, FaultSite};
use crate::glm::GapReport;
use crate::obs::{self, EventKind};
use crate::serve::error::{ServeError, ServeHealth};
use crate::serve::session::{RefitReport, Session};
use crate::serve::snapshot::ModelSnapshot;
use crate::solver::{CancelToken, PoolStats, QueueDelayReport, WorkerPool};
use crate::util::{lock_recover, Percentiles};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Streaming-ingestion thresholds (the serve CLI's `--refit-rows-threshold`
/// and `--refit-staleness`) plus the robustness knobs (`--drain-retries`,
/// `--drain-stall`, `--dead-letter-rows`). Validated in [`Scheduler::new`]:
/// thresholds must be positive (and the staleness/stall budgets finite) — a
/// zero row threshold would refit on every arrival and an infinite
/// staleness would never drain a below-threshold buffer.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Staged rows that trigger a background refit.
    pub refit_rows_threshold: usize,
    /// Seconds the oldest staged row may wait before a refit is forced.
    ///
    /// The deadline is checked on the request path (every `ingest` and
    /// `predict`), not by a timer: a completely idle scheduler holds
    /// below-threshold rows until the next request or `flush` arrives.
    /// Under any ongoing traffic the bound behaves as stated.
    pub refit_staleness_s: f64,
    /// Bounded pending-reader budget for [`Scheduler::try_predict`]
    /// (the serve CLI's `--max-pending`): `None` (default) admits every
    /// reader; `Some(k)` sheds arrivals once `k` readers are in flight.
    /// Validated in [`Scheduler::new`]: `Some(0)` would shed everything.
    pub max_pending: Option<usize>,
    /// Extra attempts a drain gets after its first refit fails (each
    /// preceded by an exponential backoff: 10 ms, 20 ms, … capped at
    /// 200 ms). `0` quarantines on the first failure. Transient failures
    /// (an injected single-shot fault, a racing allocator hiccup) recover
    /// without losing the batch; persistent ones hit the dead letter.
    pub drain_max_retries: usize,
    /// Heartbeat-staleness budget for the drain watchdog, in seconds: a
    /// drain attempt whose heartbeat is older than this is flagged as
    /// stalled and the scheduler reports `Degraded`. Must be finite and
    /// positive.
    pub drain_stall_s: f64,
    /// Row capacity of the dead-letter buffer holding quarantined batches
    /// (oldest whole batches are evicted past the cap, never a partial
    /// batch; the newest batch is always kept). Must be >= 1.
    pub dead_letter_rows: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            refit_rows_threshold: 64,
            refit_staleness_s: 0.25,
            max_pending: None,
            drain_max_retries: 2,
            drain_stall_s: 30.0,
            dead_letter_rows: 1024,
        }
    }
}

/// What one scheduled predict observed.
#[derive(Clone, Debug)]
pub struct PredictOutcome {
    /// Snapshot version this request was served from.
    pub version: u64,
    pub margins: Vec<f64>,
    /// Age of the served snapshot when the request started.
    pub snapshot_age_s: f64,
    /// Was a background refit in flight while this predict ran? (The
    /// overlap the scheduler exists to create.)
    pub overlapped_refit: bool,
}

/// Outcome of an admission-controlled [`Scheduler::try_predict`]: served
/// like any other read, or explicitly shed because the pending-reader
/// budget ([`SchedulerConfig::max_pending`]) was full. A rejection is
/// counted in [`SchedReport::rejected_predicts`] — load shedding is
/// always visible, never a silent drop.
#[derive(Clone, Debug)]
pub enum PredictAdmission {
    /// Admitted and served — bit-wise identical to an unconditional
    /// [`Scheduler::predict`] against the same snapshot version.
    Served(PredictOutcome),
    /// Shed at the door: the budget was exhausted by in-flight readers.
    Rejected {
        /// Readers in flight when this request was turned away.
        pending: usize,
    },
}

impl PredictAdmission {
    /// The outcome, if admitted.
    pub fn served(self) -> Option<PredictOutcome> {
        match self {
            PredictAdmission::Served(out) => Some(out),
            PredictAdmission::Rejected { .. } => None,
        }
    }

    pub fn is_rejected(&self) -> bool {
        matches!(self, PredictAdmission::Rejected { .. })
    }
}

/// Predict latencies of one snapshot version.
#[derive(Clone, Debug)]
pub struct VersionLatencies {
    pub version: u64,
    pub predict_s: Vec<f64>,
}

/// Aggregated scheduler metrics: per-version latency distributions plus
/// the snapshot-age distribution across every served predict.
#[derive(Clone, Debug, Default)]
pub struct SchedReport {
    /// Ascending by version.
    pub per_version: Vec<VersionLatencies>,
    /// Snapshot age observed by each predict, in arrival order.
    pub snapshot_age_s: Vec<f64>,
    pub predicts: u64,
    pub predicted_examples: u64,
    /// Predicts that ran while a background refit was in flight.
    pub overlapped_predicts: u64,
    pub ingested_rows: u64,
    /// Versions published after the initial one (refits + retrains).
    pub publishes: u64,
    /// Staging-buffer drains executed (background writer refits plus a
    /// foreground [`Scheduler::flush`] that found rows waiting).
    pub staged_drains: u64,
    /// Predicts shed by admission control ([`Scheduler::try_predict`]
    /// against a full [`SchedulerConfig::max_pending`] budget).
    pub rejected_predicts: u64,
    /// Writer attempts that failed and were rolled back to the
    /// last-known-good model (the published version never changed).
    pub rollbacks: u64,
    /// Publishes refused by the health gate (the refit finished but its
    /// model was non-finite). A subset of `rollbacks`.
    pub publish_rejected: u64,
    /// Rows quarantined to the dead-letter buffer after a drain exhausted
    /// its retries.
    pub quarantined_rows: u64,
    /// Rows refused at [`Scheduler::ingest`] for carrying non-finite
    /// values (never staged, never counted in `ingested_rows`).
    pub ingest_rejected_rows: u64,
    /// Backoff retries taken by drain refits after a failed attempt.
    pub drain_retries: u64,
    /// Times the background drain thread died (its panic-guard fired).
    pub drain_deaths: u64,
    /// Times a dead drain thread was respawned by a later request.
    pub drain_respawns: u64,
    /// Times the watchdog flagged a stuck drain (heartbeat older than
    /// [`SchedulerConfig::drain_stall_s`]).
    pub drain_stalls: u64,
    /// Health at report time: `Healthy` after a successful publish,
    /// `Degraded` while the most recent writer failed or the drain is
    /// dead/stalled.
    pub health: ServeHealth,
    /// Per-class pool queue delay over the driven window (enqueue→start
    /// of reader predict shards vs writer refit rounds). Stamped by the
    /// closed- and open-loop drivers; zero for a bare `report()` call.
    pub queue_delay: QueueDelayReport,
    /// Filled by the closed-loop driver.
    pub total_wall_s: f64,
    /// Frozen [`obs::registry`] view, stamped by the storm driver
    /// ([`drive_concurrent`](crate::serve::drive_concurrent)); empty for a
    /// bare `report()` call.
    pub metrics: obs::MetricsSnapshot,
}

impl SchedReport {
    /// Human-readable per-version p50/p99 + snapshot-age table.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for v in &self.per_version {
            let lat = Percentiles::of(&v.predict_s);
            s.push_str(&format!(
                "  version {:>3}: {:>6} predicts  p50 {:>9.3} ms  p99 {:>9.3} ms\n",
                v.version,
                v.predict_s.len(),
                lat.p50() * 1e3,
                lat.p99() * 1e3,
            ));
        }
        if !self.snapshot_age_s.is_empty() {
            let ages = Percentiles::of(&self.snapshot_age_s);
            s.push_str(&format!(
                "  snapshot age: p50 {:>8.1} ms  p99 {:>8.1} ms  max {:>8.1} ms\n",
                ages.p50() * 1e3,
                ages.p99() * 1e3,
                ages.max() * 1e3,
            ));
        }
        s.push_str(&format!(
            "  {} predicts ({} overlapped an in-flight refit, {} shed), {} rows ingested, \
             {} versions published ({} staged drains)\n",
            self.predicts,
            self.overlapped_predicts,
            self.rejected_predicts,
            self.ingested_rows,
            self.publishes,
            self.staged_drains,
        ));
        let fault_total = self.rollbacks
            + self.publish_rejected
            + self.quarantined_rows
            + self.ingest_rejected_rows
            + self.drain_retries
            + self.drain_deaths
            + self.drain_respawns
            + self.drain_stalls;
        if fault_total > 0 {
            s.push_str(&format!(
                "  faults: {} rollbacks ({} publish-rejected), {} rows quarantined, \
                 {} rows rejected at ingest, drain retries {} / deaths {} / respawns {} / stalls {}\n",
                self.rollbacks,
                self.publish_rejected,
                self.quarantined_rows,
                self.ingest_rejected_rows,
                self.drain_retries,
                self.drain_deaths,
                self.drain_respawns,
                self.drain_stalls,
            ));
        }
        s.push_str(&format!("  health: {}\n", self.health));
        if self.queue_delay.reader.jobs + self.queue_delay.writer.jobs > 0 {
            s.push_str(&self.queue_delay.summary_line());
        }
        if self.total_wall_s > 0.0 {
            s.push_str(&format!(
                "  wall {:.3}s  ({:.1} predicts/s)\n",
                self.total_wall_s,
                self.predicts as f64 / self.total_wall_s.max(1e-9)
            ));
        }
        s
    }
}

/// The staging buffer of the streaming-ingestion path: arrivals append
/// here (cheap, never blocks on training) until a threshold trips.
struct Staging<M: AppendExamples> {
    rows: Option<Dataset<M>>,
    /// When the oldest currently-staged row arrived.
    since: Option<Instant>,
}

impl<M: AppendExamples> Staging<M> {
    fn staged(&self) -> usize {
        self.rows.as_ref().map(|d| d.n()).unwrap_or(0)
    }
}

/// Bounded quarantine for batches a drain could not absorb: the refit
/// failed every retry, so the rows are parked here — visible for
/// inspection ([`Scheduler::dead_letter`]), never re-staged — instead of
/// wedging the staging pipeline by failing forever. Capacity is
/// row-counted; past it the *oldest whole batches* are evicted (the
/// newest batch always stays, even if it alone exceeds the cap) and the
/// evicted rows are counted in `dropped_rows`.
struct DeadLetter<M: AppendExamples> {
    batches: VecDeque<Dataset<M>>,
    rows: usize,
    cap_rows: usize,
    dropped_rows: u64,
}

impl<M: AppendExamples> DeadLetter<M> {
    fn new(cap_rows: usize) -> Self {
        DeadLetter {
            batches: VecDeque::new(),
            rows: 0,
            cap_rows,
            dropped_rows: 0,
        }
    }

    fn push(&mut self, batch: Dataset<M>) {
        self.rows += batch.n();
        self.batches.push_back(batch);
        while self.rows > self.cap_rows && self.batches.len() > 1 {
            if let Some(old) = self.batches.pop_front() {
                self.rows -= old.n();
                self.dropped_rows += old.n() as u64;
            }
        }
    }
}

/// The published read state: the current snapshot plus the pool readers
/// shard on. Locked only to clone/swap the `Arc`s — never across compute.
struct Published<M: AppendExamples> {
    snap: Arc<ModelSnapshot<M>>,
    pool: Arc<WorkerPool>,
}

#[derive(Default)]
struct SchedMetrics {
    per_version: BTreeMap<u64, Vec<f64>>,
    ages: Vec<f64>,
    predicts: u64,
    predicted_examples: u64,
    overlapped: u64,
    ingested_rows: u64,
    publishes: u64,
    staged_drains: u64,
    rejected: u64,
    rollbacks: u64,
    publish_rejected: u64,
    quarantined_rows: u64,
    ingest_rejected_rows: u64,
    drain_retries: u64,
    drain_deaths: u64,
    drain_respawns: u64,
    drain_stalls: u64,
}

struct Shared<M: AppendExamples> {
    cfg: SchedulerConfig,
    /// Writer state. Writers (refits, retrains) serialize here; readers
    /// never touch it.
    session: Mutex<Session<M>>,
    published: Mutex<Published<M>>,
    staging: Mutex<Staging<M>>,
    /// Mirror of `staging`'s row count, maintained under the staging lock
    /// but readable without it — the predict hot path polls "anything
    /// staged?" on every request, and an atomic load keeps that poll off
    /// the lock (readers must not serialize on a third mutex to check an
    /// almost-always-false condition).
    staged_count: AtomicUsize,
    /// Mirror of the published snapshot's example count, maintained in
    /// `publish` — the storm readers poll `current_n` before every
    /// request, and an atomic load keeps that poll off the publish lock
    /// (which each predict must already take once).
    published_n: AtomicUsize,
    /// At most one background refit in flight (CAS-guarded).
    refit_running: AtomicBool,
    refit_handle: Mutex<Option<JoinHandle<()>>>,
    /// Readers currently in flight (admitted, not yet completed) — the
    /// gauge [`SchedulerConfig::max_pending`] admission checks against.
    pending_readers: AtomicUsize,
    /// Quarantined batches (drains that exhausted their retries).
    dead_letter: Mutex<DeadLetter<M>>,
    /// Current serve-tier health, stamped by every writer outcome.
    health: Mutex<ServeHealth>,
    /// `obs::now_ns()` stamped at the start of each drain attempt, `0`
    /// while no drain is working — the watchdog's liveness signal. A
    /// foreground `flush` stamps and clears it through the same drain
    /// path.
    drain_heartbeat_ns: AtomicU64,
    /// Set by the drain thread's panic-guard when the thread dies; the
    /// next spawner swaps it back off and counts a respawn.
    drain_died: AtomicBool,
    /// Latches the stall diagnosis so the watchdog warns once per stuck
    /// drain, not once per predict.
    stall_flagged: AtomicBool,
    /// The session's cooperative cancellation token (cloned out at
    /// construction, before the session goes behind its mutex) — the
    /// watchdog trips it to force-recover a stuck drain *without* taking
    /// the session lock the stuck refit is holding.
    cancel: CancelToken,
    metrics: Mutex<SchedMetrics>,
}

/// Decrements the pending-reader gauge on drop, so an admitted slot is
/// released even if the predict compute panics.
struct PendingSlot<'a>(&'a AtomicUsize);

impl Drop for PendingSlot<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Panic-guard of the background drain thread: always clears the
/// heartbeat and the in-flight flag (a stuck `true` would disable
/// background refits forever and leave `flush()` spinning); when the
/// thread is actually dying of a panic it additionally flags the death
/// so the next request respawns the drain, and degrades health so the
/// outage is visible until the respawned drain publishes.
struct DrainGuard<'a, M: AppendExamples> {
    shared: &'a Shared<M>,
}

impl<M: AppendExamples> Drop for DrainGuard<'_, M> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.shared.drain_died.store(true, Ordering::SeqCst);
            lock_recover(&self.shared.metrics).drain_deaths += 1;
            obs::registry().counter("sched.drain_deaths").inc();
            *lock_recover(&self.shared.health) =
                ServeHealth::degraded("background drain thread died");
            crate::obs::flight::trip("background drain thread died");
            crate::diag!(
                Warn,
                "background drain thread died; the next request that finds staged rows respawns it"
            );
        }
        self.shared.drain_heartbeat_ns.store(0, Ordering::Relaxed);
        self.shared.refit_running.store(false, Ordering::SeqCst);
    }
}

impl<M: AppendExamples + Send> Shared<M> {
    /// Atomically remove everything staged (resetting the fast-path
    /// counter with it).
    fn take_batch(&self) -> Option<Dataset<M>> {
        let mut g = lock_recover(&self.staging);
        self.staged_count.store(0, Ordering::Relaxed);
        g.since = None;
        g.rows.take()
    }

    /// Drain the staging buffer into a warm refit and publish the result
    /// — the one drain sequence, shared by the background writer thread
    /// and the foreground [`Scheduler::flush`]. The session lock is held
    /// for the whole training request; readers are unaffected (they hold
    /// snapshots), other writers queue behind the lock.
    ///
    /// A failed refit (the session has already rolled back) is retried
    /// with exponential backoff up to
    /// [`SchedulerConfig::drain_max_retries`] extra attempts; a batch
    /// that fails them all is quarantined to the dead-letter buffer and
    /// the failure is returned — `Some(Err(_))` means "rows were staged
    /// and could not be absorbed", never a lost batch.
    fn drain_staged(&self) -> Option<Result<RefitReport, ServeError>> {
        let mut sess = lock_recover(&self.session);
        let batch = self.take_batch()?;
        obs::emit(EventKind::IngestDrain, obs::CLASS_WRITER, 0, batch.n() as u64);
        obs::registry().counter("sched.staged_drains").inc();
        lock_recover(&self.metrics).staged_drains += 1;
        let mut last_err: Option<ServeError> = None;
        for attempt in 0..=self.cfg.drain_max_retries {
            if attempt > 0 {
                lock_recover(&self.metrics).drain_retries += 1;
                obs::registry().counter("sched.drain_retries").inc();
                std::thread::sleep(Duration::from_millis((10u64 << (attempt - 1)).min(200)));
            }
            // every attempt starts with a clean cancellation token: a
            // watchdog that force-cancelled a previous stuck attempt must
            // not abort this fresh one at its first epoch checkpoint
            self.cancel.reset();
            self.drain_heartbeat_ns.store(obs::now_ns().max(1), Ordering::Relaxed);
            match sess.partial_fit_rows(&batch) {
                Ok(report) => {
                    self.publish(&sess, report.kind);
                    *lock_recover(&self.health) = ServeHealth::Healthy;
                    self.stall_flagged.store(false, Ordering::SeqCst);
                    self.drain_heartbeat_ns.store(0, Ordering::Relaxed);
                    return Some(Ok(report));
                }
                Err(err) => {
                    self.note_rollback(&err);
                    last_err = Some(err);
                }
            }
        }
        let err = last_err.expect("drain loop runs at least one attempt");
        let quarantined = batch.n();
        lock_recover(&self.dead_letter).push(batch);
        lock_recover(&self.metrics).quarantined_rows += quarantined as u64;
        obs::registry()
            .counter("sched.quarantined_rows")
            .add(quarantined as u64);
        crate::diag!(
            Warn,
            "drain refit failed {} attempt(s); quarantined {} rows to the dead letter: {}",
            self.cfg.drain_max_retries + 1,
            quarantined,
            err
        );
        *lock_recover(&self.health) = ServeHealth::degraded(format!("drain failed: {err}"));
        crate::obs::flight::trip("drain retries exhausted");
        self.drain_heartbeat_ns.store(0, Ordering::Relaxed);
        Some(Err(err))
    }

    /// Record a writer attempt that failed and was rolled back: the
    /// published version is retained (readers never saw anything), the
    /// rollback counters tick, and a `snapshot_rollback` trace event
    /// carries the version that kept serving. A health-gate refusal
    /// ([`ServeError::NonFinite`]) additionally counts as a rejected
    /// publish.
    fn note_rollback(&self, err: &ServeError) {
        let version = lock_recover(&self.published).snap.version();
        {
            let mut m = lock_recover(&self.metrics);
            m.rollbacks += 1;
            if matches!(err, ServeError::NonFinite { .. }) {
                m.publish_rejected += 1;
            }
        }
        obs::registry().counter("sched.rollbacks").inc();
        if matches!(err, ServeError::NonFinite { .. }) {
            obs::registry().counter("sched.publish_rejected").inc();
        }
        obs::emit(EventKind::SnapshotRollback, obs::CLASS_WRITER, 0, version);
        // the emit above runs on this same thread, so the rollback event
        // is already in its ring when the flight dump drains it
        crate::obs::flight::trip("snapshot_rollback");
        crate::diag!(Warn, "writer rolled back, v{} keeps serving: {}", version, err);
    }

    /// Shared tail of the foreground writers ([`Scheduler::refit_lambda`],
    /// [`Scheduler::retrain`]): publish on success, account the rollback
    /// and degrade on failure.
    fn finish_foreground(
        &self,
        sess: &Session<M>,
        r: Result<RefitReport, ServeError>,
    ) -> Result<RefitReport, ServeError> {
        match r {
            Ok(report) => {
                self.publish(sess, report.kind);
                *lock_recover(&self.health) = ServeHealth::Healthy;
                Ok(report)
            }
            Err(err) => {
                self.note_rollback(&err);
                *lock_recover(&self.health) = ServeHealth::degraded(err.to_string());
                crate::obs::flight::trip("foreground writer failed");
                Err(err)
            }
        }
    }

    /// Install the session's current model as the next snapshot version.
    /// One `Arc` swap under the publish lock: readers that already cloned
    /// version `k` keep it; the next reader gets `k+1` whole.
    fn publish(&self, sess: &Session<M>, kind: &'static str) -> u64 {
        let mut g = lock_recover(&self.published);
        let version = g.snap.version() + 1;
        g.snap = Arc::new(sess.snapshot(version, kind));
        g.pool = sess.pool_arc();
        self.published_n.store(g.snap.n(), Ordering::Relaxed);
        drop(g);
        lock_recover(&self.metrics).publishes += 1;
        obs::emit(EventKind::SnapshotPublish, obs::CLASS_WRITER, 0, version);
        obs::registry().counter("sched.publishes").inc();
        version
    }

    /// Wait out any in-flight background writer — including one whose
    /// spawner has CAS'd `refit_running` but not yet stored the handle
    /// (the `None` + flag-still-set window). Shared by [`Scheduler::flush`]
    /// and the `Drop` impl so the subtle loop exists exactly once.
    fn join_background_writer(&self) {
        loop {
            let prev = lock_recover(&self.refit_handle).take();
            match prev {
                Some(h) => {
                    let _ = h.join();
                }
                None => {
                    if !self.refit_running.load(Ordering::SeqCst) {
                        break;
                    }
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// Reader/writer scheduler over one resident [`Session`] — see the module
/// docs for the concurrency and determinism contract.
pub struct Scheduler<M: AppendExamples + Send + 'static> {
    shared: Arc<Shared<M>>,
}

impl<M: AppendExamples + Send + 'static> Scheduler<M> {
    /// Wrap a trained session and publish its model as snapshot version 0.
    ///
    /// Panics on a non-positive rows threshold, a non-finite /
    /// non-positive staleness or stall budget, a zero pending budget, or
    /// a zero dead-letter capacity (the same loud-at-the-door treatment
    /// `refit-lambda` gets): a zero threshold would refit per arrival, a
    /// bad staleness would either spin or never drain, and a zero budget
    /// would shed every request.
    pub fn new(session: Session<M>, cfg: SchedulerConfig) -> Self {
        assert!(
            cfg.refit_rows_threshold >= 1,
            "refit rows threshold must be >= 1, got {}",
            cfg.refit_rows_threshold
        );
        assert!(
            cfg.refit_staleness_s.is_finite() && cfg.refit_staleness_s > 0.0,
            "refit staleness must be finite and positive, got {}",
            cfg.refit_staleness_s
        );
        if let Some(budget) = cfg.max_pending {
            assert!(budget >= 1, "max pending readers must be >= 1, got 0");
        }
        assert!(
            cfg.drain_stall_s.is_finite() && cfg.drain_stall_s > 0.0,
            "drain stall budget must be finite and positive, got {}",
            cfg.drain_stall_s
        );
        assert!(
            cfg.dead_letter_rows >= 1,
            "dead letter capacity must be >= 1 row, got 0"
        );
        let snap = Arc::new(session.snapshot(0, "initial-train"));
        let pool = session.pool_arc();
        let cancel = session.cancel_token();
        let published_n = AtomicUsize::new(snap.n());
        let dead_letter = Mutex::new(DeadLetter::new(cfg.dead_letter_rows));
        Scheduler {
            shared: Arc::new(Shared {
                cfg,
                session: Mutex::new(session),
                published: Mutex::new(Published { snap, pool }),
                staging: Mutex::new(Staging {
                    rows: None,
                    since: None,
                }),
                staged_count: AtomicUsize::new(0),
                published_n,
                refit_running: AtomicBool::new(false),
                refit_handle: Mutex::new(None),
                pending_readers: AtomicUsize::new(0),
                dead_letter,
                health: Mutex::new(ServeHealth::Healthy),
                drain_heartbeat_ns: AtomicU64::new(0),
                drain_died: AtomicBool::new(false),
                stall_flagged: AtomicBool::new(false),
                cancel,
                metrics: Mutex::new(SchedMetrics::default()),
            }),
        }
    }

    /// The currently published snapshot (cheap: two `Arc` clones).
    /// Holding the returned `Arc` pins that version — it stays fully
    /// servable no matter how many writers publish after it.
    pub fn snapshot(&self) -> Arc<ModelSnapshot<M>> {
        lock_recover(&self.shared.published).snap.clone()
    }

    /// Version of the currently published snapshot.
    pub fn version(&self) -> u64 {
        self.snapshot().version()
    }

    /// Example count of the current snapshot (one atomic load — no lock,
    /// the storm readers poll this before every request). Datasets only
    /// grow, so an index below this stays valid against every later
    /// version too.
    pub fn current_n(&self) -> usize {
        self.shared.published_n.load(Ordering::Relaxed)
    }

    pub fn d(&self) -> usize {
        self.snapshot().d()
    }

    pub fn avg_nnz(&self) -> f64 {
        self.snapshot().avg_nnz()
    }

    /// Current serve-tier health: `Healthy` after a successful publish,
    /// `Degraded { reason }` while the most recent writer failed or the
    /// background drain is dead/stalled. Readers serve the last published
    /// version in either state.
    pub fn health(&self) -> ServeHealth {
        lock_recover(&self.shared.health).clone()
    }

    /// Rows currently held in the dead-letter buffer (quarantined by
    /// drains that exhausted their retries).
    pub fn dead_letter_rows(&self) -> usize {
        lock_recover(&self.shared.dead_letter).rows
    }

    /// The quarantined batches themselves (cloned; diagnostics and
    /// offline triage — the scheduler never re-stages them).
    pub fn dead_letter(&self) -> Vec<Dataset<M>> {
        lock_recover(&self.shared.dead_letter)
            .batches
            .iter()
            .cloned()
            .collect()
    }

    /// Serve a read-only predict concurrently: grab the current snapshot,
    /// compute sharded margins on the resident pool, record per-version
    /// latency + snapshot age. Never takes the writer lock. Also gives
    /// the ingestion thresholds a chance to fire (a storm keeps staleness
    /// honest even when the append stream pauses). Always admitted; the
    /// pending gauge is maintained so concurrent [`try_predict`]
    /// (admission-controlled) callers see these readers too.
    ///
    /// [`try_predict`]: Scheduler::try_predict
    pub fn predict(&self, idx: &[usize]) -> PredictOutcome {
        self.shared.pending_readers.fetch_add(1, Ordering::SeqCst);
        let _slot = PendingSlot(&self.shared.pending_readers);
        self.serve_predict(idx)
    }

    /// Admission-controlled predict: reserve one of the
    /// [`SchedulerConfig::max_pending`] pending-reader slots and serve, or
    /// shed the request explicitly ([`PredictAdmission::Rejected`], which
    /// is counted in [`SchedReport::rejected_predicts`]). With an
    /// unbounded budget (`max_pending: None`) every request is admitted.
    /// The slot is held for the request's whole lifetime — a reader
    /// blocked on a busy pool keeps its slot, which is exactly what makes
    /// the budget a backpressure bound past saturation.
    pub fn try_predict(&self, idx: &[usize]) -> PredictAdmission {
        let gauge = &self.shared.pending_readers;
        let mut current = gauge.load(Ordering::SeqCst);
        loop {
            if self.shared.cfg.max_pending.is_some_and(|cap| current >= cap) {
                lock_recover(&self.shared.metrics).rejected += 1;
                obs::emit(EventKind::AdmissionReject, obs::CLASS_READER, 0, current as u64);
                obs::registry().counter("sched.rejected").inc();
                return PredictAdmission::Rejected { pending: current };
            }
            match gauge.compare_exchange(current, current + 1, Ordering::SeqCst, Ordering::SeqCst)
            {
                Ok(_) => break,
                Err(now) => current = now,
            }
        }
        let _slot = PendingSlot(gauge);
        PredictAdmission::Served(self.serve_predict(idx))
    }

    /// Readers currently in flight (diagnostics + admission tests).
    pub fn pending_readers(&self) -> usize {
        self.shared.pending_readers.load(Ordering::SeqCst)
    }

    /// The one serve path behind [`Scheduler::predict`] and
    /// [`Scheduler::try_predict`] — admission decides only whether this
    /// runs, so both entry points are bit-wise identical per version.
    fn serve_predict(&self, idx: &[usize]) -> PredictOutcome {
        let (snap, pool) = {
            let g = lock_recover(&self.shared.published);
            (g.snap.clone(), g.pool.clone())
        };
        let overlapped_at_start = self.shared.refit_running.load(Ordering::Relaxed);
        let age = snap.age_s();
        let t = crate::util::Timer::start();
        let margins = snap.predict_on(&pool, idx);
        let dt = t.elapsed_s();
        let overlapped = overlapped_at_start || self.shared.refit_running.load(Ordering::Relaxed);
        {
            let mut m = lock_recover(&self.shared.metrics);
            m.per_version.entry(snap.version()).or_default().push(dt);
            m.ages.push(age);
            m.predicts += 1;
            m.predicted_examples += idx.len() as u64;
            if overlapped {
                m.overlapped += 1;
            }
        }
        self.maybe_spawn_refit();
        PredictOutcome {
            version: snap.version(),
            margins,
            snapshot_age_s: age,
            overlapped_refit: overlapped,
        }
    }

    /// Stream freshly arrived examples into the staging buffer (cheap —
    /// no training on this path) and kick a background refit if a
    /// threshold tripped. Readers keep serving the previous snapshot
    /// until the refit publishes.
    ///
    /// Rows carrying non-finite values are refused at the door — counted
    /// in [`SchedReport::ingest_rejected_rows`], never staged — so a
    /// poisoned arrival cannot reach training at all (defense in depth:
    /// the publish health gate would also catch the NaN model such rows
    /// could produce).
    pub fn ingest(&self, rows: Dataset<M>) {
        assert_eq!(rows.d(), self.d(), "ingested rows must match d");
        let k = rows.n();
        if !rows.is_finite() {
            lock_recover(&self.shared.metrics).ingest_rejected_rows += k as u64;
            obs::registry()
                .counter("sched.ingest_rejected_rows")
                .add(k as u64);
            crate::diag!(Warn, "rejected {}-row ingest batch: non-finite values", k);
            return;
        }
        {
            let mut g = lock_recover(&self.shared.staging);
            match g.rows.take() {
                Some(mut acc) => {
                    acc.append(&rows);
                    g.rows = Some(acc);
                }
                None => {
                    g.since = Some(Instant::now());
                    g.rows = Some(rows);
                }
            }
            self.shared.staged_count.store(g.staged(), Ordering::Relaxed);
        }
        lock_recover(&self.shared.metrics).ingested_rows += k as u64;
        self.maybe_spawn_refit();
    }

    /// Rows currently staged (not yet absorbed by a refit).
    pub fn staged_rows(&self) -> usize {
        self.shared.staged_count.load(Ordering::Relaxed)
    }

    /// Has the staging buffer tripped a refit threshold? The empty-buffer
    /// case — the predict hot path's poll — is answered by one atomic
    /// load; the staging lock is taken only while rows are actually
    /// waiting (a bounded window: a due refit soon drains them to zero).
    pub fn refit_due(&self) -> bool {
        if self.shared.staged_count.load(Ordering::Relaxed) == 0 {
            return false;
        }
        let g = lock_recover(&self.shared.staging);
        let staged = g.staged();
        staged >= self.shared.cfg.refit_rows_threshold
            || (staged > 0
                && g.since
                    .map(|s| s.elapsed().as_secs_f64() >= self.shared.cfg.refit_staleness_s)
                    .unwrap_or(false))
    }

    /// Watchdog half of the self-healing drain: a live drain attempt
    /// stamps `drain_heartbeat_ns`; if that stamp grows older than
    /// [`SchedulerConfig::drain_stall_s`] the drain is stuck inside a
    /// refit (not dead — death clears the heartbeat via its panic-guard).
    /// An OS thread cannot be killed safely, so a stuck drain is flagged
    /// — counted, warned, health degraded — exactly once per incident,
    /// **and force-recovered**: the watchdog trips the session's
    /// [`CancelToken`], the solver unwinds at its next once-per-epoch
    /// checkpoint, and [`Session::guarded`] rolls back to the
    /// last-known-good model with a typed [`ServeError::Cancelled`]. The
    /// drain's retry loop resets the token before each fresh attempt.
    fn check_drain_watchdog(&self) {
        let hb = self.shared.drain_heartbeat_ns.load(Ordering::Relaxed);
        if hb == 0 {
            return;
        }
        let age_s = obs::now_ns().saturating_sub(hb) as f64 / 1e9;
        if age_s < self.shared.cfg.drain_stall_s {
            return;
        }
        if !self.shared.stall_flagged.swap(true, Ordering::SeqCst) {
            lock_recover(&self.shared.metrics).drain_stalls += 1;
            obs::registry().counter("sched.drain_stalls").inc();
            self.shared.cancel.cancel();
            obs::registry().counter("sched.drain_cancels").inc();
            *lock_recover(&self.shared.health) = ServeHealth::degraded(format!(
                "background drain stalled ({age_s:.1}s since last heartbeat)"
            ));
            crate::obs::flight::trip("drain watchdog stall");
            crate::diag!(
                Warn,
                "background drain heartbeat is {:.1}s old (budget {}s) — flagging a stall \
                 and cancelling the stuck refit at its next epoch checkpoint",
                age_s,
                self.shared.cfg.drain_stall_s
            );
        }
    }

    /// Spawn the background writer if a threshold tripped and none is in
    /// flight. Returns whether a refit was started. Also runs the stall
    /// watchdog and, when the previous drain thread died, counts the
    /// respawn — this is the "self-healing" half: any later request that
    /// finds staged work brings the drain back.
    fn maybe_spawn_refit(&self) -> bool {
        self.check_drain_watchdog();
        if !self.refit_due() {
            return false;
        }
        if self.shared.refit_running.swap(true, Ordering::SeqCst) {
            return false; // one background writer at a time
        }
        if self.shared.drain_died.swap(false, Ordering::SeqCst) {
            lock_recover(&self.shared.metrics).drain_respawns += 1;
            obs::registry().counter("sched.drain_respawns").inc();
            self.shared.stall_flagged.store(false, Ordering::SeqCst);
            crate::diag!(Info, "respawning background drain thread after a death");
        }
        // the handle slot is held across reap → spawn → store so a slow
        // spawner can never clobber (and thereby detach) a newer writer's
        // handle — whoever joins the stored handle joins the latest writer
        let mut slot = lock_recover(&self.shared.refit_handle);
        if let Some(h) = slot.take() {
            // previous writer already cleared refit_running, so it has
            // finished its work; the join is a formality
            let _ = h.join();
        }
        let shared = Arc::clone(&self.shared);
        let handle = std::thread::Builder::new()
            .name("parlin-sched-refit".to_string())
            .spawn(move || {
                let _guard = DrainGuard { shared: &shared };
                shared
                    .drain_heartbeat_ns
                    .store(obs::now_ns().max(1), Ordering::Relaxed);
                fault::poke(FaultSite::Drain);
                let _ = shared.drain_staged();
            })
            .expect("spawn background refit writer");
        *slot = Some(handle);
        true
    }

    /// Foreground writer: change λ and warm-refit, then publish. An
    /// invalid λ or a contained failure comes back as `Err` — the session
    /// has already rolled back and the published version keeps serving.
    /// Serializes with every other writer on the session lock.
    pub fn refit_lambda(&self, lambda: f64) -> Result<RefitReport, ServeError> {
        let mut sess = lock_recover(&self.shared.session);
        let r = sess.partial_fit_lambda(lambda);
        self.shared.finish_foreground(&sess, r)
    }

    /// Foreground writer: cold retrain with the session's current config,
    /// then publish. A contained failure comes back as `Err` — the
    /// session has already rolled back and the published version keeps
    /// serving.
    pub fn retrain(&self) -> Result<RefitReport, ServeError> {
        let mut sess = lock_recover(&self.shared.session);
        let r = sess.retrain_same();
        self.shared.finish_foreground(&sess, r)
    }

    /// Wait out any in-flight background refit, then synchronously drain
    /// whatever is still staged (ignoring thresholds). `None` when
    /// nothing was staged; `Some(Err(_))` when staged rows could not be
    /// absorbed (they are quarantined in the dead letter).
    pub fn flush(&self) -> Option<Result<RefitReport, ServeError>> {
        self.shared.join_background_writer();
        self.shared.drain_staged()
    }

    /// Snapshot of the accumulated metrics (per-version latencies,
    /// snapshot ages, overlap counters, fault/recovery counters, health).
    /// `total_wall_s` is left 0 — the closed-loop driver stamps it.
    pub fn report(&self) -> SchedReport {
        // health is read before the metrics lock — never hold two guards
        let health = self.health();
        let m = lock_recover(&self.shared.metrics);
        SchedReport {
            per_version: m
                .per_version
                .iter()
                .map(|(&version, lat)| VersionLatencies {
                    version,
                    predict_s: lat.clone(),
                })
                .collect(),
            snapshot_age_s: m.ages.clone(),
            predicts: m.predicts,
            predicted_examples: m.predicted_examples,
            overlapped_predicts: m.overlapped,
            ingested_rows: m.ingested_rows,
            publishes: m.publishes,
            staged_drains: m.staged_drains,
            rejected_predicts: m.rejected,
            rollbacks: m.rollbacks,
            publish_rejected: m.publish_rejected,
            quarantined_rows: m.quarantined_rows,
            ingest_rejected_rows: m.ingest_rejected_rows,
            drain_retries: m.drain_retries,
            drain_deaths: m.drain_deaths,
            drain_respawns: m.drain_respawns,
            drain_stalls: m.drain_stalls,
            health,
            queue_delay: QueueDelayReport::default(),
            total_wall_s: 0.0,
            metrics: obs::MetricsSnapshot::default(),
        }
    }

    /// Busy-time census of the resident pool (locks the writer state
    /// briefly; diagnostics only).
    pub fn pool_stats(&self) -> PoolStats {
        lock_recover(&self.shared.session).pool_stats()
    }

    /// Duality gap of the model the *writer* currently holds (may be one
    /// publish ahead of the read side; diagnostics only).
    pub fn gap(&self) -> GapReport {
        lock_recover(&self.shared.session).gap()
    }
}

impl<M: AppendExamples + Send + 'static> Drop for Scheduler<M> {
    fn drop(&mut self) {
        // deterministic shutdown: reap the background writer so dropping
        // the scheduler leaves no transient thread behind (the pool's
        // workers are joined by the session drop right after)
        self.shared.join_background_writer();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::glm::Objective;
    use crate::solver::{SolverConfig, Variant};
    use crate::sysinfo::Topology;

    fn session(n: usize, seed: u64) -> Session<crate::data::DenseMatrix> {
        let ds = synthetic::dense_classification(n, 6, seed);
        let cfg = SolverConfig::new(Objective::Logistic {
            lambda: 1.0 / n as f64,
        })
        .with_variant(Variant::Domesticated)
        .with_threads(2)
        .with_topology(Topology::flat(2))
        .with_tol(1e-3)
        .with_max_epochs(200);
        Session::new(ds, cfg)
    }

    #[test]
    fn publishes_version_zero_and_serves_it() {
        let sched = Scheduler::new(session(120, 71), SchedulerConfig::default());
        assert_eq!(sched.version(), 0);
        let snap = sched.snapshot();
        let out = sched.predict(&[0, 7, 119]);
        assert_eq!(out.version, 0);
        assert_eq!(out.margins, snap.predict(&[0, 7, 119]));
        assert!(!out.overlapped_refit);
        let report = sched.report();
        assert_eq!((report.predicts, report.publishes), (1, 0));
        assert_eq!(report.per_version.len(), 1);
        assert!(report.health.is_healthy());
    }

    #[test]
    fn row_threshold_triggers_background_refit() {
        let sched = Scheduler::new(
            session(120, 72),
            SchedulerConfig {
                refit_rows_threshold: 10,
                refit_staleness_s: 1e6, // rows, not time, must trip this
                ..SchedulerConfig::default()
            },
        );
        sched.ingest(synthetic::dense_classification(4, 6, 73));
        assert!(!sched.refit_due(), "4 staged rows are below the threshold");
        assert_eq!(sched.version(), 0);
        sched.ingest(synthetic::dense_classification(6, 6, 74));
        // the threshold tripped inside ingest; wait for the background
        // writer to publish
        for _ in 0..2000 {
            if sched.version() > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(sched.version(), 1, "background refit must publish v1");
        assert_eq!(sched.current_n(), 130);
        assert_eq!(sched.staged_rows(), 0);
        let report = sched.report();
        assert_eq!(report.ingested_rows, 10);
        assert_eq!(report.staged_drains, 1);
    }

    #[test]
    fn staleness_threshold_trips_via_reads() {
        let sched = Scheduler::new(
            session(100, 75),
            SchedulerConfig {
                refit_rows_threshold: 1_000_000, // time, not rows, must trip
                refit_staleness_s: 0.02,
                ..SchedulerConfig::default()
            },
        );
        sched.ingest(synthetic::dense_classification(3, 6, 76));
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(sched.refit_due(), "staged rows outlived the staleness budget");
        let _ = sched.predict(&[0, 1]); // a read is enough to kick the writer
        for _ in 0..2000 {
            if sched.version() > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(sched.version(), 1);
        assert_eq!(sched.current_n(), 103);
    }

    #[test]
    fn flush_drains_below_threshold_rows() {
        let sched = Scheduler::new(
            session(100, 77),
            SchedulerConfig {
                refit_rows_threshold: 1_000_000,
                refit_staleness_s: 1e6,
                ..SchedulerConfig::default()
            },
        );
        sched.ingest(synthetic::dense_classification(5, 6, 78));
        assert_eq!(sched.version(), 0);
        let r = sched
            .flush()
            .expect("staged rows must force a drain refit")
            .expect("a clean drain refit must succeed");
        assert_eq!(r.kind, "refit-rows");
        assert_eq!((sched.version(), sched.current_n()), (1, 105));
        assert!(sched.flush().is_none(), "nothing staged, nothing to drain");
    }

    #[test]
    fn foreground_writers_publish_in_sequence() {
        let sched = Scheduler::new(session(110, 79), SchedulerConfig::default());
        let r1 = sched.refit_lambda(0.02).expect("clean λ refit");
        assert_eq!((r1.kind, sched.version()), ("refit-lambda", 1));
        let r2 = sched.retrain().expect("clean retrain");
        assert_eq!((r2.kind, sched.version()), ("retrain", 2));
        // the published snapshot serves the post-retrain weights
        let snap = sched.snapshot();
        assert_eq!(snap.produced_by(), "retrain");
        let out = sched.predict(&[1, 2, 3]);
        assert_eq!(out.version, 2);
        assert_eq!(out.margins, snap.predict(&[1, 2, 3]));
        assert!(sched.health().is_healthy());
    }

    #[test]
    fn invalid_lambda_degrades_health_without_publishing() {
        let sched = Scheduler::new(session(90, 95), SchedulerConfig::default());
        let err = sched.refit_lambda(-1.0).expect_err("λ <= 0 must be refused");
        assert_eq!(err, ServeError::InvalidLambda { lambda: -1.0 });
        assert_eq!(sched.version(), 0, "a refused writer publishes nothing");
        assert!(!sched.health().is_healthy());
        let report = sched.report();
        assert_eq!(report.rollbacks, 1);
        // a later clean writer restores health
        sched.refit_lambda(0.02).expect("clean λ refit");
        assert!(sched.health().is_healthy());
        assert_eq!(sched.version(), 1);
    }

    #[test]
    fn dead_letter_keeps_newest_batches_within_cap() {
        let mut dl = DeadLetter::<crate::data::DenseMatrix>::new(10);
        dl.push(synthetic::dense_classification(6, 4, 1));
        dl.push(synthetic::dense_classification(6, 4, 2));
        // 12 rows > cap 10: the oldest batch is evicted
        assert_eq!(dl.rows, 6);
        assert_eq!(dl.batches.len(), 1);
        assert_eq!(dl.dropped_rows, 6);
        // a single over-cap batch is kept anyway (never drop the newest)
        dl.push(synthetic::dense_classification(25, 4, 3));
        assert_eq!(dl.rows, 25);
        assert_eq!(dl.batches.len(), 1);
        assert_eq!(dl.dropped_rows, 12);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_rows_threshold() {
        let _ = Scheduler::new(
            session(60, 80),
            SchedulerConfig {
                refit_rows_threshold: 0,
                refit_staleness_s: 1.0,
                ..SchedulerConfig::default()
            },
        );
    }

    #[test]
    #[should_panic]
    fn rejects_nonfinite_staleness() {
        let _ = Scheduler::new(
            session(60, 81),
            SchedulerConfig {
                refit_rows_threshold: 8,
                refit_staleness_s: f64::INFINITY,
                ..SchedulerConfig::default()
            },
        );
    }

    #[test]
    #[should_panic]
    fn rejects_zero_max_pending() {
        let _ = Scheduler::new(
            session(60, 82),
            SchedulerConfig {
                refit_rows_threshold: 8,
                refit_staleness_s: 1.0,
                max_pending: Some(0),
                ..SchedulerConfig::default()
            },
        );
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_drain_stall() {
        let _ = Scheduler::new(
            session(60, 96),
            SchedulerConfig {
                drain_stall_s: 0.0,
                ..SchedulerConfig::default()
            },
        );
    }

    #[test]
    #[should_panic]
    fn rejects_zero_dead_letter_capacity() {
        let _ = Scheduler::new(
            session(60, 97),
            SchedulerConfig {
                dead_letter_rows: 0,
                ..SchedulerConfig::default()
            },
        );
    }

    /// The watchdog's force-recovery lever: a heartbeat older than the
    /// stall budget trips the session's cancel token (so the stuck refit
    /// will abort at its next epoch checkpoint), degrades health, and
    /// latches — the second trip does not double-count.
    #[test]
    fn watchdog_flags_stall_and_cancels_the_session_token() {
        let sched = Scheduler::new(
            session(80, 98),
            SchedulerConfig {
                drain_stall_s: 0.001,
                ..SchedulerConfig::default()
            },
        );
        assert!(!sched.shared.cancel.is_cancelled());
        // simulate a drain attempt whose heartbeat went stale long ago
        sched.shared.drain_heartbeat_ns.store(1, Ordering::Relaxed);
        sched.check_drain_watchdog();
        assert!(sched.shared.cancel.is_cancelled(), "watchdog must trip the token");
        assert!(!sched.health().is_healthy());
        assert_eq!(sched.report().drain_stalls, 1);
        // latched: a second check neither warns nor counts again
        sched.check_drain_watchdog();
        assert_eq!(sched.report().drain_stalls, 1);
        // a fresh drain attempt resets the token and recovers end-to-end
        sched.shared.drain_heartbeat_ns.store(0, Ordering::Relaxed);
        sched.ingest(synthetic::dense_classification(5, 6, 99));
        let r = sched
            .flush()
            .expect("staged rows must drain")
            .expect("the post-stall drain must succeed");
        assert_eq!(r.kind, "refit-rows");
        assert!(!sched.shared.cancel.is_cancelled(), "attempt start reset the token");
        assert!(sched.health().is_healthy());
    }

    #[test]
    fn try_predict_admits_within_budget_and_matches_predict() {
        let sched = Scheduler::new(
            session(90, 83),
            SchedulerConfig {
                refit_rows_threshold: 1_000_000,
                refit_staleness_s: 1e6,
                max_pending: Some(4),
                ..SchedulerConfig::default()
            },
        );
        let idx = [0usize, 3, 89];
        let out = sched
            .try_predict(&idx)
            .served()
            .expect("an idle scheduler must admit within the budget");
        // admission changes only whether a request runs, never its bits
        assert_eq!(out.margins, sched.predict(&idx).margins);
        assert_eq!(sched.pending_readers(), 0, "slots released after serving");
        let report = sched.report();
        assert_eq!(report.rejected_predicts, 0);
        assert_eq!(report.predicts, 2);
    }

    #[test]
    fn unbounded_budget_never_sheds() {
        let sched = Scheduler::new(session(80, 84), SchedulerConfig::default());
        for k in 0..10usize {
            assert!(
                !sched.try_predict(&[k % 80]).is_rejected(),
                "max_pending: None must admit every request"
            );
        }
        assert_eq!(sched.report().rejected_predicts, 0);
    }
}
