//! Pool-resident serving: the trained model as a long-lived service.
//!
//! The paper builds a training system tuned to the machine (bucketed,
//! dynamically partitioned, NUMA-hierarchical SDCA); SySCD-style systems
//! show the same design carrying over to a resident, reusable runtime.
//! This module is that runtime: a [`Session`] owns
//!
//! * one `Arc<`[`WorkerPool`](crate::solver::WorkerPool)`>` — the
//!   persistent NUMA-aware workers, spawned **once** and reused by every
//!   request the session ever serves (training dispatch reaches them via
//!   [`ExecPolicy::Shared`](crate::solver::ExecPolicy)),
//! * the dataset — a segment-chunked [`Dataset`](crate::data::Dataset):
//!   `refit-rows` requests grow it by sealing the arrivals into a new
//!   tail segment and sharing every existing segment with outstanding
//!   snapshots (clone-free appends; see [`crate::data`] and
//!   `docs/ARCHITECTURE.md`),
//! * the current trained [`ModelState`](crate::glm::ModelState) and its
//!   cached primal weights.
//!
//! Three request kinds run over the pool's bucket queues:
//!
//! | request                  | entry point                       | start     |
//! |--------------------------|-----------------------------------|-----------|
//! | `predict(batch)`         | [`Session::predict`]              | —         |
//! | `partial_fit(rows \| λ)` | [`Session::partial_fit_rows`] / [`Session::partial_fit_lambda`] | warm      |
//! | `retrain(cfg)`           | [`Session::retrain`]              | cold      |
//!
//! A bare session admits one request at a time. The concurrent front end
//! ([`scheduler`]) layers a reader/writer split on top: any number of
//! predicts run in parallel against immutable, versioned
//! [`ModelSnapshot`]s ([`snapshot`]) while refit/retrain writers
//! serialize and publish new versions atomically; streaming ingestion
//! ([`Scheduler::ingest`]) stages arrivals and refits in the background
//! on row-count/staleness thresholds. See the determinism argument in
//! [`scheduler`]'s module docs; all three determinism arguments of this
//! codebase (job-order merge, layout bit-equality, immutable versioned
//! snapshots) are collected in `docs/ARCHITECTURE.md`.
//!
//! Load is applied by the drivers in [`request`]: closed loop
//! ([`request::drive`], [`request::drive_concurrent`]) or **open loop**
//! ([`request::drive_open_loop`]) — a seeded arrival schedule pushed at
//! the scheduler independent of service times, with latency measured from
//! each request's *scheduled* arrival and overload shed explicitly via
//! [`Scheduler::try_predict`] admission control.
//!
//! ## Determinism of sharded predict
//!
//! [`Session::predict`] splits a request batch into one contiguous shard
//! per resident worker and tags shard `s` with worker `s`'s NUMA node, so
//! each shard's column reads stay on the node that would own those rows
//! under the hierarchical solver's static example split. The result is
//! still bit-wise equal to the sequential batch path
//! ([`glm::model::margins`](crate::glm::model::margins)) because:
//!
//! 1. each margin `z_j = ⟨x_j, w⟩` is a pure function of a read-only
//!    dataset column and the frozen weight vector — predict shards share
//!    no mutable state, so *where* a shard runs cannot change any value;
//! 2. shards are disjoint, contiguous sub-slices of the request batch, and
//!    [`WorkerPool::run_tagged`](crate::solver::WorkerPool::run_tagged)
//!    returns results **in job order** — concatenating them reproduces the
//!    batch order exactly, independent of worker count, node layout or
//!    scheduling.
//!
//! `rust/tests/serving.rs` locks this in against `glm::model::margins`.
//!
//! ## Warm-start refit
//!
//! `partial_fit` re-enters the solver from the session's current state
//! instead of `α = 0`: appended examples get `α = 0` entries
//! ([`ModelState::extended`](crate::glm::ModelState::extended)), `v` is
//! rebuilt exactly from `α`, and the solver's convergence monitor is
//! seeded with the warm state so an (almost) converged refit stops after
//! one epoch. The same resident pool executes the refit — no worker is
//! spawned or torn down on the request path.

pub mod error;
pub mod request;
pub mod scheduler;
pub mod session;
pub mod snapshot;

pub use error::{ServeError, ServeHealth};
pub use request::{
    arrival_schedule, drive, drive_concurrent, drive_open_loop, parse_script, synthetic_mix,
    Arrival, ArrivalKind, ArrivalProcess, OpenLoopConfig, OpenLoopKindStats, OpenLoopOutcome,
    OpenLoopReport, Request, ServeReport, StormConfig, SynthRows,
};
pub use scheduler::{
    PredictAdmission, PredictOutcome, SchedReport, Scheduler, SchedulerConfig, VersionLatencies,
};
pub use session::{RefitReport, Session, SessionStats};
pub use snapshot::ModelSnapshot;
