//! Serving requests: the wire-level model of `parlin serve` — a parsed
//! request script or a deterministic synthetic mix — plus three drivers:
//!
//! * [`drive`] — closed loop, one request at a time against a [`Session`];
//! * [`drive_concurrent`] — closed loop per reader: a predict storm on
//!   reader threads against a [`Scheduler`](crate::serve::Scheduler)
//!   while an append stream triggers background refits;
//! * [`drive_open_loop`] — **open loop**: arrivals follow a seeded
//!   Poisson (or fixed-rate) schedule generated up front, independent of
//!   service times, and every latency is measured from the request's
//!   *scheduled* arrival. A closed loop can never see queueing delay
//!   (the next request politely waits for the previous one); the open
//!   loop is what exposes the saturation knee and makes admission
//!   control ([`Scheduler::try_predict`]) meaningful.
//!
//! All three stamp a per-class pool [`QueueDelayReport`] so closed- and
//! open-loop runs report the same scheduled-vs-dispatch queue-delay
//! signal.

use crate::data::{synthetic, AppendExamples, CscMatrix, Dataset, DenseMatrix};
use crate::obs;
use crate::serve::error::ServeHealth;
use crate::serve::scheduler::{PredictAdmission, SchedReport, Scheduler};
use crate::serve::session::Session;
use crate::solver::QueueDelayReport;
use crate::util::{Percentiles, Rng, Timer};
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One serving request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Margins for `batch` examples (the driver picks a deterministic
    /// rotating window over the resident dataset).
    Predict { batch: usize },
    /// Append `rows` freshly generated examples and warm-start refit.
    RefitRows { rows: usize },
    /// Change the regularization strength and warm-start refit.
    RefitLambda { lambda: f64 },
    /// Cold retrain with the session's current configuration.
    Retrain,
}

/// Parse a request script: one request per line, `#` comments, blank
/// lines ignored.
///
/// ```text
/// predict 256        # margins for 256 examples
/// refit-rows 50      # append 50 rows, warm refit
/// refit-lambda 1e-3  # change λ, warm refit
/// retrain            # cold retrain
/// ```
pub fn parse_script(text: &str) -> Result<Vec<Request>> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let lineno = i + 1;
        let mut parts = line.split_whitespace();
        let verb = parts.next().unwrap_or("");
        let arg = parts.next();
        if parts.next().is_some() {
            bail!("line {lineno}: too many fields in '{line}'");
        }
        let req = match (verb, arg) {
            ("predict", Some(k)) => Request::Predict {
                batch: k
                    .parse()
                    .map_err(|e| anyhow!("line {lineno}: predict batch '{k}': {e}"))?,
            },
            ("refit-rows", Some(k)) => Request::RefitRows {
                rows: k
                    .parse()
                    .map_err(|e| anyhow!("line {lineno}: refit-rows count '{k}': {e}"))?,
            },
            ("refit-lambda", Some(l)) => {
                let lambda: f64 = l
                    .parse()
                    .map_err(|e| anyhow!("line {lineno}: refit-lambda value '{l}': {e}"))?;
                if !lambda.is_finite() || lambda <= 0.0 {
                    bail!("line {lineno}: refit-lambda must be finite and positive, got '{l}'");
                }
                Request::RefitLambda { lambda }
            }
            ("retrain", None) => Request::Retrain,
            _ => bail!(
                "line {lineno}: unknown request '{line}' \
                 (expected: predict K | refit-rows K | refit-lambda X | retrain)"
            ),
        };
        out.push(req);
    }
    Ok(out)
}

/// Deterministic synthetic request mix: ~90% predicts, ~8% row refits,
/// ~2% λ refits — the serving workload of `parlin serve --requests
/// synthetic` and `benches/serving.rs`.
pub fn synthetic_mix(
    count: usize,
    predict_batch: usize,
    refit_rows: usize,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let r = rng.next_f64();
            if r < 0.90 {
                Request::Predict {
                    batch: predict_batch,
                }
            } else if r < 0.98 {
                Request::RefitRows { rows: refit_rows }
            } else {
                Request::RefitLambda {
                    lambda: 10f64.powf(-2.0 - 2.0 * rng.next_f64()),
                }
            }
        })
        .collect()
}

/// Generate fresh labelled examples shaped like the session's dataset —
/// the data source behind synthetic `refit-rows` requests.
pub trait SynthRows: AppendExamples {
    fn synth_rows(d: usize, avg_nnz: f64, k: usize, seed: u64) -> Dataset<Self>;
}

impl SynthRows for DenseMatrix {
    fn synth_rows(d: usize, _avg_nnz: f64, k: usize, seed: u64) -> Dataset<DenseMatrix> {
        synthetic::dense_classification(k, d, seed)
    }
}

impl SynthRows for CscMatrix {
    fn synth_rows(d: usize, avg_nnz: f64, k: usize, seed: u64) -> Dataset<CscMatrix> {
        let density = (avg_nnz / d as f64).clamp(1.0 / d as f64, 1.0);
        synthetic::sparse_classification(k, d, density, seed)
    }
}

/// Latency log of one closed-loop run (seconds per request, by kind).
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub predict_s: Vec<f64>,
    pub refit_s: Vec<f64>,
    pub retrain_s: Vec<f64>,
    pub total_wall_s: f64,
    /// Solver epochs consumed by warm `refit-*` requests.
    pub refit_epochs: u64,
    /// Solver epochs consumed by cold `retrain` requests.
    pub retrain_epochs: u64,
    /// Per-class pool queue delay across the run (enqueue→start of reader
    /// predict shards vs writer refit rounds) — the queueing that a
    /// closed-loop latency log alone cannot see.
    pub queue_delay: QueueDelayReport,
    /// Writer requests that failed and were rolled back to the session's
    /// last-known-good model (the session kept serving throughout).
    pub failed_refits: u64,
    /// Health after the final request: `Healthy` iff the most recent
    /// writer succeeded (or none ran).
    pub health: ServeHealth,
    /// Frozen [`obs::registry`] view as of the end of the run — counters,
    /// gauges and histogram summaries across pool, solver and scheduler.
    pub metrics: obs::MetricsSnapshot,
}

impl ServeReport {
    pub fn requests(&self) -> usize {
        self.predict_s.len() + self.refit_s.len() + self.retrain_s.len()
    }

    /// Human-readable per-kind p50/p99 latency + throughput table.
    pub fn summary(&self) -> String {
        fn line(name: &str, xs: &[f64]) -> String {
            if xs.is_empty() {
                return format!("  {name:<8} {:>6} reqs\n", 0);
            }
            let p = Percentiles::of(xs);
            format!(
                "  {name:<8} {:>6} reqs  p50 {:>9.3} ms  p99 {:>9.3} ms\n",
                xs.len(),
                p.p50() * 1e3,
                p.p99() * 1e3
            )
        }
        let mut s = String::new();
        s.push_str(&line("predict", &self.predict_s));
        s.push_str(&line("refit", &self.refit_s));
        s.push_str(&line("retrain", &self.retrain_s));
        s.push_str(&format!(
            "  total    {:>6} reqs in {:.3}s  ({:.1} req/s)\n",
            self.requests(),
            self.total_wall_s,
            self.requests() as f64 / self.total_wall_s.max(1e-9)
        ));
        if self.failed_refits > 0 {
            s.push_str(&format!(
                "  faults: {} writer request(s) failed and rolled back\n",
                self.failed_refits
            ));
        }
        s.push_str(&format!("  health: {}\n", self.health));
        if self.queue_delay.reader.jobs + self.queue_delay.writer.jobs > 0 {
            s.push_str(&self.queue_delay.summary_line());
        }
        s
    }
}

/// Replay `reqs` against the session, closed-loop (next request issues
/// when the previous one completes), recording per-request latency. A
/// writer request that fails is contained by the session (rolled back to
/// last-known-good) and counted in [`ServeReport::failed_refits`]; the
/// run keeps going — one poisoned request must not abort the replay.
pub fn drive<M: SynthRows>(sess: &mut Session<M>, reqs: &[Request], seed: u64) -> ServeReport {
    let mut report = ServeReport::default();
    let delay_mark = QueueDelayReport::from_stats(&sess.pool_stats());
    let total = Timer::start();
    let mut cursor = 0usize; // rotating predict window over the dataset
    let mut row_seed = seed;
    for req in reqs {
        match req {
            Request::Predict { batch } => {
                let n = sess.n();
                let idx: Vec<usize> = (0..*batch).map(|k| (cursor + k) % n).collect();
                cursor = (cursor + batch) % n;
                let t = Timer::start();
                let margins = sess.predict(&idx);
                report.predict_s.push(t.elapsed_s());
                std::hint::black_box(margins);
            }
            Request::RefitRows { rows } => {
                row_seed = row_seed.wrapping_add(1);
                let fresh = M::synth_rows(sess.d(), sess.avg_nnz(), (*rows).max(1), row_seed);
                let t = Timer::start();
                match sess.partial_fit_rows(&fresh) {
                    Ok(r) => {
                        report.refit_s.push(t.elapsed_s());
                        report.refit_epochs += r.epochs as u64;
                        report.health = ServeHealth::Healthy;
                    }
                    Err(err) => {
                        report.failed_refits += 1;
                        report.health = ServeHealth::degraded(err.to_string());
                        crate::diag!(Warn, "refit-rows request failed (contained): {}", err);
                    }
                }
            }
            Request::RefitLambda { lambda } => {
                let t = Timer::start();
                match sess.partial_fit_lambda(*lambda) {
                    Ok(r) => {
                        report.refit_s.push(t.elapsed_s());
                        report.refit_epochs += r.epochs as u64;
                        report.health = ServeHealth::Healthy;
                    }
                    Err(err) => {
                        report.failed_refits += 1;
                        report.health = ServeHealth::degraded(err.to_string());
                        crate::diag!(Warn, "refit-lambda request failed (contained): {}", err);
                    }
                }
            }
            Request::Retrain => {
                let t = Timer::start();
                match sess.retrain_same() {
                    Ok(r) => {
                        report.retrain_s.push(t.elapsed_s());
                        report.retrain_epochs += r.epochs as u64;
                        report.health = ServeHealth::Healthy;
                    }
                    Err(err) => {
                        report.failed_refits += 1;
                        report.health = ServeHealth::degraded(err.to_string());
                        crate::diag!(Warn, "retrain request failed (contained): {}", err);
                    }
                }
            }
        }
    }
    report.total_wall_s = total.elapsed_s();
    report.queue_delay = QueueDelayReport::from_stats(&sess.pool_stats()).since(&delay_mark);
    report.metrics = obs::registry().snapshot();
    report
}

/// Shape of one concurrent closed-loop run: a predict storm spread over
/// `readers` threads, interleaved with `appends` ingestion bursts paced
/// across the storm (the `parlin serve --concurrency N` workload and the
/// serving bench's overlap demonstration).
#[derive(Clone, Debug)]
pub struct StormConfig {
    /// Concurrent reader threads (`--concurrency`).
    pub readers: usize,
    /// Total predict requests across all readers.
    pub predicts: usize,
    /// Examples per predict request.
    pub predict_batch: usize,
    /// Ingestion bursts issued while the storm runs.
    pub appends: usize,
    /// Freshly generated examples per burst.
    pub rows_per_append: usize,
}

/// Run a predict storm against the scheduler from `readers` threads while
/// the driver thread streams `appends` ingestion bursts, paced evenly
/// across the storm so background refits genuinely overlap reads. Closed
/// loop per reader (a reader issues its next predict when the previous
/// one returns). Ends with a [`Scheduler::flush`] so every ingested row
/// is absorbed, then returns the scheduler's per-version report with the
/// wall clock stamped.
pub fn drive_concurrent<M>(sched: &Scheduler<M>, storm: &StormConfig, seed: u64) -> SchedReport
where
    M: SynthRows + Send + 'static,
{
    assert!(storm.readers >= 1, "storm needs at least one reader");
    let delay_mark = QueueDelayReport::from_stats(&sched.pool_stats());
    let total = Timer::start();
    let issued = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for reader in 0..storm.readers {
            let issued = &issued;
            scope.spawn(move || {
                loop {
                    let k = issued.fetch_add(1, Ordering::Relaxed);
                    if k >= storm.predicts {
                        break;
                    }
                    // rotating window over the dataset as of the *current*
                    // snapshot; datasets only grow, so the indices stay
                    // valid for whichever version actually serves them
                    let n = sched.current_n();
                    let idx: Vec<usize> = (0..storm.predict_batch)
                        .map(|i| (k * 131 + i * 7 + reader) % n)
                        .collect();
                    let out = sched.predict(&idx);
                    std::hint::black_box(out.margins);
                }
            });
        }
        // the append stream, paced so each burst lands mid-storm instead
        // of front-loading the whole stream before the readers start
        let gap = (storm.predicts / (storm.appends + 1)).max(1);
        let mut row_seed = seed;
        for burst in 0..storm.appends {
            // capped at the storm size so a burst count larger than the
            // storm cannot wait for progress that will never come
            let due = ((burst + 1) * gap).min(storm.predicts);
            // parked waiting, not a spin: the pacer must not burn a core
            // the readers need (that would skew the very latencies this
            // driver reports). The wait is also bounded so a storm whose
            // readers all died (a panicking assert) stops pacing and lets
            // the scope join surface the panic instead of hanging.
            let mut waited_ms = 0u32;
            while issued.load(Ordering::Relaxed) < due {
                std::thread::sleep(std::time::Duration::from_millis(1));
                waited_ms += 1;
                if waited_ms > 30_000 {
                    break;
                }
            }
            row_seed = row_seed.wrapping_add(1);
            let fresh = M::synth_rows(
                sched.d(),
                sched.avg_nnz(),
                storm.rows_per_append.max(1),
                row_seed,
            );
            sched.ingest(fresh);
        }
    });
    // a failed final drain is already accounted (rollbacks, quarantine,
    // health) by the scheduler — the report below carries it
    let _ = sched.flush();
    let mut report = sched.report();
    report.total_wall_s = total.elapsed_s();
    report.queue_delay = QueueDelayReport::from_stats(&sched.pool_stats()).since(&delay_mark);
    report.metrics = obs::registry().snapshot();
    report
}

/// Inter-arrival law of the open-loop schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalProcess {
    /// Exponential inter-arrival gaps (a Poisson process at `rate_per_s`)
    /// — the standard open-loop load model; bursts happen by design.
    Poisson,
    /// Constant gaps of exactly `1 / rate_per_s` — a pathological,
    /// burst-free baseline useful for isolating service-time effects.
    Fixed,
}

/// What kind of request an arrival issues.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalKind {
    Predict,
    Ingest,
}

/// One pre-scheduled arrival: its offset from the run start and its kind.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    /// Scheduled arrival time, seconds after the run starts. Latencies
    /// are measured from here — not from dispatch — so time spent waiting
    /// for a free dispatcher or a pool worker is *in* the number.
    pub at_s: f64,
    pub kind: ArrivalKind,
}

/// Shape of one open-loop run: a seeded arrival schedule pushed at the
/// scheduler regardless of how fast it serves (the `parlin serve
/// --arrival-rate` workload and the serving bench's knee sweep).
#[derive(Clone, Debug)]
pub struct OpenLoopConfig {
    /// Offered load, requests per second (`--arrival-rate`).
    pub rate_per_s: f64,
    /// Length of the schedule, seconds (`--duration`).
    pub duration_s: f64,
    pub process: ArrivalProcess,
    /// Seed of the arrival schedule (`--open-loop-seed`); the same seed
    /// reproduces the identical schedule, gaps and kinds alike.
    pub seed: u64,
    /// Examples per predict arrival.
    pub predict_batch: usize,
    /// Fraction of arrivals that are ingestion bursts instead of
    /// predicts, in `[0, 1)`; drawn per arrival from the schedule seed.
    pub ingest_fraction: f64,
    /// Freshly generated examples per ingest arrival.
    pub rows_per_ingest: usize,
    /// Dispatcher threads draining the schedule. An arrival whose slot
    /// finds every dispatcher busy is dispatched late — genuine open-loop
    /// queueing, charged to its latency via the scheduled timestamp.
    pub dispatchers: usize,
    /// Retain per-request [`OpenLoopOutcome`]s in the report (replay
    /// tests); off for benches — margins of every request are kept alive.
    pub record_outcomes: bool,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        OpenLoopConfig {
            rate_per_s: 500.0,
            duration_s: 1.0,
            process: ArrivalProcess::Poisson,
            seed: 42,
            predict_batch: 64,
            ingest_fraction: 0.0,
            rows_per_ingest: 32,
            dispatchers: 4,
            record_outcomes: false,
        }
    }
}

/// Pre-generate the whole arrival schedule from the config seed: gap
/// draws and kind draws come from one deterministic [`Rng`] stream, so
/// the same config reproduces the identical schedule bit-for-bit.
///
/// Panics on a non-finite/non-positive rate or duration and on an ingest
/// fraction outside `[0, 1)` — the CLI validates first, the library
/// re-checks loudly.
pub fn arrival_schedule(cfg: &OpenLoopConfig) -> Vec<Arrival> {
    assert!(
        cfg.rate_per_s.is_finite() && cfg.rate_per_s > 0.0,
        "arrival rate must be finite and positive, got {}",
        cfg.rate_per_s
    );
    assert!(
        cfg.duration_s.is_finite() && cfg.duration_s > 0.0,
        "duration must be finite and positive, got {}",
        cfg.duration_s
    );
    assert!(
        (0.0..1.0).contains(&cfg.ingest_fraction),
        "ingest fraction must be in [0, 1), got {}",
        cfg.ingest_fraction
    );
    let mut rng = Rng::new(cfg.seed);
    let mut schedule = Vec::new();
    let mut t = 0.0f64;
    loop {
        let gap = match cfg.process {
            // inverse-CDF exponential draw; 1 - u keeps ln's argument in
            // (0, 1] so the gap is always finite and positive
            ArrivalProcess::Poisson => -(1.0 - rng.next_f64()).ln() / cfg.rate_per_s,
            ArrivalProcess::Fixed => 1.0 / cfg.rate_per_s,
        };
        t += gap;
        if t >= cfg.duration_s {
            return schedule;
        }
        let kind = if cfg.ingest_fraction > 0.0 && rng.next_f64() < cfg.ingest_fraction {
            ArrivalKind::Ingest
        } else {
            ArrivalKind::Predict
        };
        schedule.push(Arrival { at_s: t, kind });
    }
}

/// Latency log of one request kind in an open-loop run. Both series are
/// measured from the request's *scheduled* arrival, so queueing delay —
/// invisible to a closed-loop log — is part of every sample.
#[derive(Clone, Debug, Default)]
pub struct OpenLoopKindStats {
    /// completion − scheduled arrival (queueing + service).
    pub latency_s: Vec<f64>,
    /// dispatch − scheduled arrival (pure open-loop queueing: the wait
    /// for a free dispatcher slot before service even starts).
    pub dispatch_delay_s: Vec<f64>,
}

impl OpenLoopKindStats {
    pub fn count(&self) -> usize {
        self.latency_s.len()
    }

    /// p50 total latency in seconds; 0 when no request completed.
    pub fn p50_s(&self) -> f64 {
        Percentiles::of(&self.latency_s).p50()
    }

    /// p99 total latency in seconds; 0 when no request completed.
    pub fn p99_s(&self) -> f64 {
        Percentiles::of(&self.latency_s).p99()
    }

    /// Worst total latency in seconds; 0 when no request completed.
    pub fn max_s(&self) -> f64 {
        Percentiles::of(&self.latency_s).max()
    }

    fn merge(&mut self, other: OpenLoopKindStats) {
        self.latency_s.extend(other.latency_s);
        self.dispatch_delay_s.extend(other.dispatch_delay_s);
    }

    fn line(&self, name: &str) -> String {
        if self.latency_s.is_empty() {
            return format!("  {name:<8} {:>6} reqs\n", 0);
        }
        let lat = Percentiles::of(&self.latency_s);
        format!(
            "  {name:<8} {:>6} reqs  p50 {:>9.3} ms  p99 {:>9.3} ms  max {:>9.3} ms  \
             (dispatch delay p99 {:>8.3} ms)\n",
            self.count(),
            lat.p50() * 1e3,
            lat.p99() * 1e3,
            lat.max() * 1e3,
            Percentiles::of(&self.dispatch_delay_s).p99() * 1e3
        )
    }
}

/// Per-request record of an open-loop run, retained only under
/// [`OpenLoopConfig::record_outcomes`] — everything the replay test needs
/// to compare a served predict bit-wise against its retained version.
#[derive(Clone, Debug)]
pub struct OpenLoopOutcome {
    /// Index of this arrival in the generated schedule.
    pub arrival: usize,
    pub kind: ArrivalKind,
    pub scheduled_s: f64,
    /// `false` when admission control shed the request.
    pub admitted: bool,
    /// Snapshot version that served an admitted predict.
    pub version: Option<u64>,
    /// Requested example indices (empty for ingests).
    pub idx: Vec<usize>,
    /// Served margins (empty for ingests and shed requests).
    pub margins: Vec<f64>,
}

/// What one open-loop run measured: per-kind latency distributions from
/// scheduled arrival, explicit shed accounting, and the per-class pool
/// queue delay over the window.
#[derive(Clone, Debug, Default)]
pub struct OpenLoopReport {
    pub offered_rate_per_s: f64,
    pub duration_s: f64,
    /// Arrivals in the generated schedule (served + shed).
    pub scheduled_arrivals: usize,
    pub predict: OpenLoopKindStats,
    pub ingest: OpenLoopKindStats,
    /// Predicts shed by admission control — counted, never dropped.
    pub rejected_predicts: u64,
    pub ingested_rows: u64,
    /// Per-class pool queue delay over the run window.
    pub queue_delay: QueueDelayReport,
    pub total_wall_s: f64,
    /// Scheduler health after the final flush.
    pub health: ServeHealth,
    /// Frozen [`obs::registry`] view as of the end of the run.
    pub metrics: obs::MetricsSnapshot,
    /// Per-request records (only under [`OpenLoopConfig::record_outcomes`]).
    pub outcomes: Vec<OpenLoopOutcome>,
}

impl OpenLoopReport {
    /// Requests actually served (admitted predicts + ingests).
    pub fn served(&self) -> usize {
        self.predict.count() + self.ingest.count()
    }

    /// Served requests per second of schedule time — diverges from the
    /// offered rate exactly when the system saturates (the knee).
    pub fn achieved_rate_per_s(&self) -> f64 {
        self.served() as f64 / self.duration_s.max(1e-9)
    }

    /// Human-readable offered-vs-achieved + per-kind latency table.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "  offered {:.0} req/s for {:.2}s: {} scheduled, {} served \
             ({:.1} req/s achieved), {} shed\n",
            self.offered_rate_per_s,
            self.duration_s,
            self.scheduled_arrivals,
            self.served(),
            self.achieved_rate_per_s(),
            self.rejected_predicts,
        ));
        s.push_str(&self.predict.line("predict"));
        s.push_str(&self.ingest.line("ingest"));
        s.push_str(&format!("  health: {}\n", self.health));
        s.push_str(&self.queue_delay.summary_line());
        if self.total_wall_s > 0.0 {
            s.push_str(&format!("  wall {:.3}s\n", self.total_wall_s));
        }
        s
    }
}

/// Dispatcher-local accumulator, merged under one lock when the
/// dispatcher finishes (the hot path never contends on shared state).
#[derive(Default)]
struct OpenLoopLocal {
    predict: OpenLoopKindStats,
    ingest: OpenLoopKindStats,
    rejected: u64,
    ingested_rows: u64,
    outcomes: Vec<OpenLoopOutcome>,
}

/// Drive a pre-generated open-loop schedule at the scheduler: dispatcher
/// threads claim arrivals in schedule order, park until each scheduled
/// instant, then issue the request through admission control
/// ([`Scheduler::try_predict`]) or [`Scheduler::ingest`]. Every latency
/// is measured from the *scheduled* arrival, so dispatcher and pool
/// queueing are charged to the request — the closed-loop blind spot this
/// driver exists to fix. Ends with a [`Scheduler::flush`] so every
/// ingested row is absorbed.
pub fn drive_open_loop<M>(sched: &Scheduler<M>, cfg: &OpenLoopConfig) -> OpenLoopReport
where
    M: SynthRows + Send + 'static,
{
    assert!(cfg.dispatchers >= 1, "open loop needs at least one dispatcher");
    let schedule = arrival_schedule(cfg);
    let delay_mark = QueueDelayReport::from_stats(&sched.pool_stats());
    let wall = Timer::start();
    let t0 = Instant::now();
    let next = AtomicUsize::new(0);
    let merged: Mutex<OpenLoopLocal> = Mutex::new(OpenLoopLocal::default());
    std::thread::scope(|scope| {
        for _ in 0..cfg.dispatchers {
            let (next, merged, schedule) = (&next, &merged, &schedule);
            scope.spawn(move || {
                let mut local = OpenLoopLocal::default();
                loop {
                    let k = next.fetch_add(1, Ordering::Relaxed);
                    if k >= schedule.len() {
                        break;
                    }
                    let arrival = schedule[k];
                    // park until the scheduled instant — arrival times are
                    // fixed up front, independent of service progress
                    loop {
                        let now = t0.elapsed().as_secs_f64();
                        if now >= arrival.at_s {
                            break;
                        }
                        std::thread::sleep(Duration::from_secs_f64(arrival.at_s - now));
                    }
                    let dispatch_delay = t0.elapsed().as_secs_f64() - arrival.at_s;
                    match arrival.kind {
                        ArrivalKind::Predict => {
                            // rotating deterministic window over the dataset
                            // as served by the *current* snapshot; datasets
                            // only grow, so the indices stay valid for
                            // whichever version serves them
                            let n = sched.current_n();
                            let idx: Vec<usize> = (0..cfg.predict_batch)
                                .map(|i| (k * 131 + i * 7) % n)
                                .collect();
                            match sched.try_predict(&idx) {
                                PredictAdmission::Served(out) => {
                                    let latency = t0.elapsed().as_secs_f64() - arrival.at_s;
                                    local.predict.latency_s.push(latency);
                                    local.predict.dispatch_delay_s.push(dispatch_delay);
                                    if cfg.record_outcomes {
                                        local.outcomes.push(OpenLoopOutcome {
                                            arrival: k,
                                            kind: arrival.kind,
                                            scheduled_s: arrival.at_s,
                                            admitted: true,
                                            version: Some(out.version),
                                            idx,
                                            margins: out.margins,
                                        });
                                    } else {
                                        std::hint::black_box(out.margins);
                                    }
                                }
                                PredictAdmission::Rejected { .. } => {
                                    local.rejected += 1;
                                    if cfg.record_outcomes {
                                        local.outcomes.push(OpenLoopOutcome {
                                            arrival: k,
                                            kind: arrival.kind,
                                            scheduled_s: arrival.at_s,
                                            admitted: false,
                                            version: None,
                                            idx,
                                            margins: Vec::new(),
                                        });
                                    }
                                }
                            }
                        }
                        ArrivalKind::Ingest => {
                            let rows = cfg.rows_per_ingest.max(1);
                            let fresh = M::synth_rows(
                                sched.d(),
                                sched.avg_nnz(),
                                rows,
                                cfg.seed ^ (k as u64).wrapping_mul(0x9E3779B97F4A7C15),
                            );
                            sched.ingest(fresh);
                            let latency = t0.elapsed().as_secs_f64() - arrival.at_s;
                            local.ingest.latency_s.push(latency);
                            local.ingest.dispatch_delay_s.push(dispatch_delay);
                            local.ingested_rows += rows as u64;
                            if cfg.record_outcomes {
                                local.outcomes.push(OpenLoopOutcome {
                                    arrival: k,
                                    kind: arrival.kind,
                                    scheduled_s: arrival.at_s,
                                    admitted: true,
                                    version: None,
                                    idx: Vec::new(),
                                    margins: Vec::new(),
                                });
                            }
                        }
                    }
                }
                let mut m = merged.lock().unwrap();
                m.predict.merge(local.predict);
                m.ingest.merge(local.ingest);
                m.rejected += local.rejected;
                m.ingested_rows += local.ingested_rows;
                m.outcomes.extend(local.outcomes);
            });
        }
    });
    // failure accounting (rollbacks, quarantine, health) lives in the
    // scheduler; the health stamp below carries the final state
    let _ = sched.flush();
    let all = merged.into_inner().unwrap();
    OpenLoopReport {
        offered_rate_per_s: cfg.rate_per_s,
        duration_s: cfg.duration_s,
        scheduled_arrivals: schedule.len(),
        predict: all.predict,
        ingest: all.ingest,
        rejected_predicts: all.rejected,
        ingested_rows: all.ingested_rows,
        queue_delay: QueueDelayReport::from_stats(&sched.pool_stats()).since(&delay_mark),
        total_wall_s: wall.elapsed_s(),
        health: sched.health(),
        metrics: obs::registry().snapshot(),
        outcomes: all.outcomes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataMatrix;

    #[test]
    fn script_round_trip() {
        let script = "\
# serving trace
predict 256
refit-rows 50   # fresh examples
refit-lambda 1e-3

retrain
";
        let reqs = parse_script(script).unwrap();
        assert_eq!(
            reqs,
            vec![
                Request::Predict { batch: 256 },
                Request::RefitRows { rows: 50 },
                Request::RefitLambda { lambda: 1e-3 },
                Request::Retrain,
            ]
        );
    }

    #[test]
    fn script_rejects_garbage() {
        assert!(parse_script("predict").is_err()); // missing batch
        assert!(parse_script("predict x").is_err()); // bad number
        assert!(parse_script("retrain 3").is_err()); // stray arg
        assert!(parse_script("evict 1").is_err()); // unknown verb
        assert!(parse_script("predict 1 2").is_err()); // too many fields
        assert!(parse_script("refit-lambda 0").is_err()); // 1/(λn) would blow up
        assert!(parse_script("refit-lambda -1e-3").is_err());
        assert!(parse_script("refit-lambda NaN").is_err());
        assert!(parse_script("refit-lambda inf").is_err());
    }

    #[test]
    fn synthetic_mix_is_deterministic_and_mostly_predicts() {
        let a = synthetic_mix(500, 128, 16, 9);
        let b = synthetic_mix(500, 128, 16, 9);
        assert_eq!(a, b);
        let predicts = a
            .iter()
            .filter(|r| matches!(r, Request::Predict { .. }))
            .count();
        assert!(predicts > 400, "predicts={predicts}");
        assert!(predicts < 500, "mix must contain refits");
    }

    #[test]
    fn arrival_schedule_same_seed_same_schedule() {
        let cfg = OpenLoopConfig {
            rate_per_s: 1000.0,
            duration_s: 0.25,
            ingest_fraction: 0.2,
            seed: 7,
            ..OpenLoopConfig::default()
        };
        let a = arrival_schedule(&cfg);
        let b = arrival_schedule(&cfg);
        assert_eq!(a, b, "same seed must reproduce the schedule bit-for-bit");
        assert!(!a.is_empty());
        let other = arrival_schedule(&OpenLoopConfig { seed: 8, ..cfg });
        assert_ne!(a, other, "a different seed must change the schedule");
    }

    #[test]
    fn fixed_schedule_spaces_arrivals_exactly() {
        // powers of two keep every 1/rate gap and partial sum exact in f64,
        // so the boundary count is deterministic, not rounding luck
        let cfg = OpenLoopConfig {
            rate_per_s: 512.0,
            duration_s: 0.125,
            process: ArrivalProcess::Fixed,
            ..OpenLoopConfig::default()
        };
        let schedule = arrival_schedule(&cfg);
        // arrivals at 1/rate, 2/rate, ... strictly below the duration
        assert_eq!(schedule.len(), 63);
        for (i, a) in schedule.iter().enumerate() {
            let want = (i + 1) as f64 / cfg.rate_per_s;
            assert!((a.at_s - want).abs() < 1e-9, "arrival {i}: {} vs {want}", a.at_s);
            assert_eq!(a.kind, ArrivalKind::Predict, "ingest_fraction 0 ⇒ all predicts");
        }
    }

    #[test]
    fn ingest_fraction_controls_the_mix() {
        let cfg = OpenLoopConfig {
            rate_per_s: 5000.0,
            duration_s: 1.0,
            ingest_fraction: 0.1,
            ..OpenLoopConfig::default()
        };
        let schedule = arrival_schedule(&cfg);
        let ingests = schedule
            .iter()
            .filter(|a| a.kind == ArrivalKind::Ingest)
            .count();
        let share = ingests as f64 / schedule.len() as f64;
        assert!((0.05..0.15).contains(&share), "ingest share {share:.3}");
        // times must be strictly increasing — dispatchers claim in order
        for w in schedule.windows(2) {
            assert!(w[0].at_s < w[1].at_s);
        }
    }

    #[test]
    #[should_panic(expected = "arrival rate must be finite and positive")]
    fn schedule_rejects_zero_rate() {
        arrival_schedule(&OpenLoopConfig {
            rate_per_s: 0.0,
            ..OpenLoopConfig::default()
        });
    }

    #[test]
    fn synth_rows_match_shape() {
        let dense = DenseMatrix::synth_rows(12, 12.0, 5, 1);
        assert_eq!((dense.n(), dense.d()), (5, 12));
        let sparse = CscMatrix::synth_rows(100, 5.0, 7, 2);
        assert_eq!((sparse.n(), sparse.d()), (7, 100));
        assert!(sparse.x.nnz() >= 7); // ~5 nnz per example
    }
}
