//! Serving requests: the wire-level model of `parlin serve` — a parsed
//! request script or a deterministic synthetic mix — plus two closed-loop
//! drivers: [`drive`] replays requests one at a time against a
//! [`Session`], [`drive_concurrent`] runs a predict storm on reader
//! threads against a [`Scheduler`](crate::serve::Scheduler) while an
//! append stream triggers background refits.

use crate::data::{synthetic, AppendExamples, CscMatrix, Dataset, DenseMatrix};
use crate::serve::scheduler::{SchedReport, Scheduler};
use crate::serve::session::Session;
use crate::util::{percentile, Rng, Timer};
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};

/// One serving request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Margins for `batch` examples (the driver picks a deterministic
    /// rotating window over the resident dataset).
    Predict { batch: usize },
    /// Append `rows` freshly generated examples and warm-start refit.
    RefitRows { rows: usize },
    /// Change the regularization strength and warm-start refit.
    RefitLambda { lambda: f64 },
    /// Cold retrain with the session's current configuration.
    Retrain,
}

/// Parse a request script: one request per line, `#` comments, blank
/// lines ignored.
///
/// ```text
/// predict 256        # margins for 256 examples
/// refit-rows 50      # append 50 rows, warm refit
/// refit-lambda 1e-3  # change λ, warm refit
/// retrain            # cold retrain
/// ```
pub fn parse_script(text: &str) -> Result<Vec<Request>> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let lineno = i + 1;
        let mut parts = line.split_whitespace();
        let verb = parts.next().unwrap_or("");
        let arg = parts.next();
        if parts.next().is_some() {
            bail!("line {lineno}: too many fields in '{line}'");
        }
        let req = match (verb, arg) {
            ("predict", Some(k)) => Request::Predict {
                batch: k
                    .parse()
                    .map_err(|e| anyhow!("line {lineno}: predict batch '{k}': {e}"))?,
            },
            ("refit-rows", Some(k)) => Request::RefitRows {
                rows: k
                    .parse()
                    .map_err(|e| anyhow!("line {lineno}: refit-rows count '{k}': {e}"))?,
            },
            ("refit-lambda", Some(l)) => {
                let lambda: f64 = l
                    .parse()
                    .map_err(|e| anyhow!("line {lineno}: refit-lambda value '{l}': {e}"))?;
                if !lambda.is_finite() || lambda <= 0.0 {
                    bail!("line {lineno}: refit-lambda must be finite and positive, got '{l}'");
                }
                Request::RefitLambda { lambda }
            }
            ("retrain", None) => Request::Retrain,
            _ => bail!(
                "line {lineno}: unknown request '{line}' \
                 (expected: predict K | refit-rows K | refit-lambda X | retrain)"
            ),
        };
        out.push(req);
    }
    Ok(out)
}

/// Deterministic synthetic request mix: ~90% predicts, ~8% row refits,
/// ~2% λ refits — the serving workload of `parlin serve --requests
/// synthetic` and `benches/serving.rs`.
pub fn synthetic_mix(
    count: usize,
    predict_batch: usize,
    refit_rows: usize,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let r = rng.next_f64();
            if r < 0.90 {
                Request::Predict {
                    batch: predict_batch,
                }
            } else if r < 0.98 {
                Request::RefitRows { rows: refit_rows }
            } else {
                Request::RefitLambda {
                    lambda: 10f64.powf(-2.0 - 2.0 * rng.next_f64()),
                }
            }
        })
        .collect()
}

/// Generate fresh labelled examples shaped like the session's dataset —
/// the data source behind synthetic `refit-rows` requests.
pub trait SynthRows: AppendExamples {
    fn synth_rows(d: usize, avg_nnz: f64, k: usize, seed: u64) -> Dataset<Self>;
}

impl SynthRows for DenseMatrix {
    fn synth_rows(d: usize, _avg_nnz: f64, k: usize, seed: u64) -> Dataset<DenseMatrix> {
        synthetic::dense_classification(k, d, seed)
    }
}

impl SynthRows for CscMatrix {
    fn synth_rows(d: usize, avg_nnz: f64, k: usize, seed: u64) -> Dataset<CscMatrix> {
        let density = (avg_nnz / d as f64).clamp(1.0 / d as f64, 1.0);
        synthetic::sparse_classification(k, d, density, seed)
    }
}

/// Latency log of one closed-loop run (seconds per request, by kind).
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub predict_s: Vec<f64>,
    pub refit_s: Vec<f64>,
    pub retrain_s: Vec<f64>,
    pub total_wall_s: f64,
    /// Solver epochs consumed by warm `refit-*` requests.
    pub refit_epochs: u64,
    /// Solver epochs consumed by cold `retrain` requests.
    pub retrain_epochs: u64,
}

impl ServeReport {
    pub fn requests(&self) -> usize {
        self.predict_s.len() + self.refit_s.len() + self.retrain_s.len()
    }

    /// Human-readable per-kind p50/p99 latency + throughput table.
    pub fn summary(&self) -> String {
        fn line(name: &str, xs: &[f64]) -> String {
            if xs.is_empty() {
                return format!("  {name:<8} {:>6} reqs\n", 0);
            }
            format!(
                "  {name:<8} {:>6} reqs  p50 {:>9.3} ms  p99 {:>9.3} ms\n",
                xs.len(),
                percentile(xs, 50.0) * 1e3,
                percentile(xs, 99.0) * 1e3
            )
        }
        let mut s = String::new();
        s.push_str(&line("predict", &self.predict_s));
        s.push_str(&line("refit", &self.refit_s));
        s.push_str(&line("retrain", &self.retrain_s));
        s.push_str(&format!(
            "  total    {:>6} reqs in {:.3}s  ({:.1} req/s)\n",
            self.requests(),
            self.total_wall_s,
            self.requests() as f64 / self.total_wall_s.max(1e-9)
        ));
        s
    }
}

/// Replay `reqs` against the session, closed-loop (next request issues
/// when the previous one completes), recording per-request latency.
pub fn drive<M: SynthRows>(sess: &mut Session<M>, reqs: &[Request], seed: u64) -> ServeReport {
    let mut report = ServeReport::default();
    let total = Timer::start();
    let mut cursor = 0usize; // rotating predict window over the dataset
    let mut row_seed = seed;
    for req in reqs {
        match req {
            Request::Predict { batch } => {
                let n = sess.n();
                let idx: Vec<usize> = (0..*batch).map(|k| (cursor + k) % n).collect();
                cursor = (cursor + batch) % n;
                let t = Timer::start();
                let margins = sess.predict(&idx);
                report.predict_s.push(t.elapsed_s());
                std::hint::black_box(margins);
            }
            Request::RefitRows { rows } => {
                row_seed = row_seed.wrapping_add(1);
                let fresh = M::synth_rows(sess.d(), sess.avg_nnz(), (*rows).max(1), row_seed);
                let t = Timer::start();
                let r = sess.partial_fit_rows(&fresh);
                report.refit_s.push(t.elapsed_s());
                report.refit_epochs += r.epochs as u64;
            }
            Request::RefitLambda { lambda } => {
                let t = Timer::start();
                let r = sess.partial_fit_lambda(*lambda);
                report.refit_s.push(t.elapsed_s());
                report.refit_epochs += r.epochs as u64;
            }
            Request::Retrain => {
                let t = Timer::start();
                let r = sess.retrain_same();
                report.retrain_s.push(t.elapsed_s());
                report.retrain_epochs += r.epochs as u64;
            }
        }
    }
    report.total_wall_s = total.elapsed_s();
    report
}

/// Shape of one concurrent closed-loop run: a predict storm spread over
/// `readers` threads, interleaved with `appends` ingestion bursts paced
/// across the storm (the `parlin serve --concurrency N` workload and the
/// serving bench's overlap demonstration).
#[derive(Clone, Debug)]
pub struct StormConfig {
    /// Concurrent reader threads (`--concurrency`).
    pub readers: usize,
    /// Total predict requests across all readers.
    pub predicts: usize,
    /// Examples per predict request.
    pub predict_batch: usize,
    /// Ingestion bursts issued while the storm runs.
    pub appends: usize,
    /// Freshly generated examples per burst.
    pub rows_per_append: usize,
}

/// Run a predict storm against the scheduler from `readers` threads while
/// the driver thread streams `appends` ingestion bursts, paced evenly
/// across the storm so background refits genuinely overlap reads. Closed
/// loop per reader (a reader issues its next predict when the previous
/// one returns). Ends with a [`Scheduler::flush`] so every ingested row
/// is absorbed, then returns the scheduler's per-version report with the
/// wall clock stamped.
pub fn drive_concurrent<M>(sched: &Scheduler<M>, storm: &StormConfig, seed: u64) -> SchedReport
where
    M: SynthRows + Send + 'static,
{
    assert!(storm.readers >= 1, "storm needs at least one reader");
    let total = Timer::start();
    let issued = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for reader in 0..storm.readers {
            let issued = &issued;
            scope.spawn(move || {
                loop {
                    let k = issued.fetch_add(1, Ordering::Relaxed);
                    if k >= storm.predicts {
                        break;
                    }
                    // rotating window over the dataset as of the *current*
                    // snapshot; datasets only grow, so the indices stay
                    // valid for whichever version actually serves them
                    let n = sched.current_n();
                    let idx: Vec<usize> = (0..storm.predict_batch)
                        .map(|i| (k * 131 + i * 7 + reader) % n)
                        .collect();
                    let out = sched.predict(&idx);
                    std::hint::black_box(out.margins);
                }
            });
        }
        // the append stream, paced so each burst lands mid-storm instead
        // of front-loading the whole stream before the readers start
        let gap = (storm.predicts / (storm.appends + 1)).max(1);
        let mut row_seed = seed;
        for burst in 0..storm.appends {
            // capped at the storm size so a burst count larger than the
            // storm cannot wait for progress that will never come
            let due = ((burst + 1) * gap).min(storm.predicts);
            // parked waiting, not a spin: the pacer must not burn a core
            // the readers need (that would skew the very latencies this
            // driver reports). The wait is also bounded so a storm whose
            // readers all died (a panicking assert) stops pacing and lets
            // the scope join surface the panic instead of hanging.
            let mut waited_ms = 0u32;
            while issued.load(Ordering::Relaxed) < due {
                std::thread::sleep(std::time::Duration::from_millis(1));
                waited_ms += 1;
                if waited_ms > 30_000 {
                    break;
                }
            }
            row_seed = row_seed.wrapping_add(1);
            let fresh = M::synth_rows(
                sched.d(),
                sched.avg_nnz(),
                storm.rows_per_append.max(1),
                row_seed,
            );
            sched.ingest(fresh);
        }
    });
    sched.flush();
    let mut report = sched.report();
    report.total_wall_s = total.elapsed_s();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataMatrix;

    #[test]
    fn script_round_trip() {
        let script = "\
# serving trace
predict 256
refit-rows 50   # fresh examples
refit-lambda 1e-3

retrain
";
        let reqs = parse_script(script).unwrap();
        assert_eq!(
            reqs,
            vec![
                Request::Predict { batch: 256 },
                Request::RefitRows { rows: 50 },
                Request::RefitLambda { lambda: 1e-3 },
                Request::Retrain,
            ]
        );
    }

    #[test]
    fn script_rejects_garbage() {
        assert!(parse_script("predict").is_err()); // missing batch
        assert!(parse_script("predict x").is_err()); // bad number
        assert!(parse_script("retrain 3").is_err()); // stray arg
        assert!(parse_script("evict 1").is_err()); // unknown verb
        assert!(parse_script("predict 1 2").is_err()); // too many fields
        assert!(parse_script("refit-lambda 0").is_err()); // 1/(λn) would blow up
        assert!(parse_script("refit-lambda -1e-3").is_err());
        assert!(parse_script("refit-lambda NaN").is_err());
        assert!(parse_script("refit-lambda inf").is_err());
    }

    #[test]
    fn synthetic_mix_is_deterministic_and_mostly_predicts() {
        let a = synthetic_mix(500, 128, 16, 9);
        let b = synthetic_mix(500, 128, 16, 9);
        assert_eq!(a, b);
        let predicts = a
            .iter()
            .filter(|r| matches!(r, Request::Predict { .. }))
            .count();
        assert!(predicts > 400, "predicts={predicts}");
        assert!(predicts < 500, "mix must contain refits");
    }

    #[test]
    fn synth_rows_match_shape() {
        let dense = DenseMatrix::synth_rows(12, 12.0, 5, 1);
        assert_eq!((dense.n(), dense.d()), (5, 12));
        let sparse = CscMatrix::synth_rows(100, 5.0, 7, 2);
        assert_eq!((sparse.n(), sparse.d()), (7, 100));
        assert!(sparse.x.nnz() >= 7); // ~5 nnz per example
    }
}
