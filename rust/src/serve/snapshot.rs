//! Versioned, immutable model snapshots — the read side of the concurrent
//! serving scheduler ([`crate::serve::scheduler`]).
//!
//! A [`ModelSnapshot`] freezes everything a predict needs: the primal
//! weights, the dataset as of a given ingestion epoch, and the resident
//! interleaved [`ShardedLayout`] that streams the margins. All three are
//! `Arc`'d, so
//!
//! * publishing a new version is a pointer swap (the writer builds the
//!   next snapshot off to the side and installs it atomically),
//! * any number of readers can hold and serve version `k` while a writer
//!   produces `k+1` — a snapshot is never mutated after construction, so
//!   a reader cannot observe a torn model,
//! * the dataset inside a snapshot is a **segment list**
//!   ([`crate::data`]): successive versions share all common segments by
//!   `Arc`, so holding many versions of a growing dataset costs one
//!   payload plus the per-version tails — version `k` and `k+1` differ
//!   only by the appended segment(s), and
//! * memory for version `k` is reclaimed exactly when its last reader
//!   drops it (segments individually, once no retained version lists
//!   them).
//!
//! Margins are computed by [`sharded_margins`] — one contiguous shard per
//! pool worker, merged in job order — which is the *same* code path
//! [`Session::predict`](crate::serve::Session::predict) uses, so a
//! snapshot predict is bit-wise identical to the session's single-request
//! path and to the sequential batch path [`glm::model::margins`]
//! (argument in the [`crate::serve`] module docs; locked in by
//! `rust/tests/serving.rs` and `rust/tests/scheduler.rs`).

use crate::data::{DataMatrix, Dataset, ShardedLayout};
use crate::glm;
use crate::solver::{kernel, JobClass, WorkerPool};
use std::sync::Arc;
use std::time::Instant;

/// One immutable, versioned view of the served model. Cheap to clone
/// (four `Arc`s and a few words); see the module docs for the sharing
/// contract.
#[derive(Clone)]
pub struct ModelSnapshot<M: DataMatrix> {
    version: u64,
    /// Which request published this version ("initial-train",
    /// "refit-rows", "refit-lambda", "retrain").
    produced_by: &'static str,
    /// Monotone ingestion counter: how many append batches the session
    /// had absorbed when this version was published.
    dataset_epoch: u64,
    ds: Arc<Dataset<M>>,
    weights: Arc<Vec<f64>>,
    /// Single-shard resident interleaved layout of `ds` (absent under
    /// `LayoutPolicy::Csc`; predicts then walk the source matrix).
    layout: Option<Arc<ShardedLayout>>,
    published_at: Instant,
}

impl<M: DataMatrix> ModelSnapshot<M> {
    pub(crate) fn new(
        version: u64,
        produced_by: &'static str,
        dataset_epoch: u64,
        ds: Arc<Dataset<M>>,
        weights: Arc<Vec<f64>>,
        layout: Option<Arc<ShardedLayout>>,
    ) -> Self {
        debug_assert!(
            layout.as_ref().is_none_or(|l| l.covers_examples(ds.n(), ds.d(), ds.x.nnz())),
            "snapshot layout must encode exactly the snapshot dataset"
        );
        ModelSnapshot {
            version,
            produced_by,
            dataset_epoch,
            ds,
            weights,
            layout,
            published_at: Instant::now(),
        }
    }

    #[inline]
    pub fn version(&self) -> u64 {
        self.version
    }

    #[inline]
    pub fn produced_by(&self) -> &'static str {
        self.produced_by
    }

    #[inline]
    pub fn dataset_epoch(&self) -> u64 {
        self.dataset_epoch
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.ds.n()
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.ds.d()
    }

    /// Mean stored non-zeros per example (shape information for synthetic
    /// ingestion streams).
    pub fn avg_nnz(&self) -> f64 {
        self.ds.x.nnz() as f64 / self.ds.n().max(1) as f64
    }

    /// Seconds since this version was published — the "snapshot age" a
    /// request served from this version observes.
    pub fn age_s(&self) -> f64 {
        self.published_at.elapsed().as_secs_f64()
    }

    /// Primal weights of this version.
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    pub fn dataset(&self) -> &Dataset<M> {
        &self.ds
    }

    /// Margins `⟨x_j, w⟩` computed sequentially on the calling thread — a
    /// pure function of this (immutable) snapshot, usable from any reader
    /// thread without touching the pool. Bit-wise equal to
    /// [`ModelSnapshot::predict_on`]: both compute each margin with the
    /// identical kernel and emit them in request order.
    pub fn predict(&self, idx: &[usize]) -> Vec<f64> {
        match self.layout.as_deref() {
            Some(l) => {
                let sh = l.shard(0);
                idx.iter()
                    .map(|&j| kernel::dot_entries(sh.entries(j), &self.weights))
                    .collect()
            }
            None => glm::model::margins(&self.ds, &self.weights, idx),
        }
    }

    /// Margins computed as parallel shards on `pool`, merged in job order
    /// — the throughput path for large batches. Bit-wise equal to
    /// [`ModelSnapshot::predict`] and to `glm::model::margins` on the
    /// snapshot weights.
    pub fn predict_on(&self, pool: &WorkerPool, idx: &[usize]) -> Vec<f64> {
        sharded_margins(&self.ds, &self.weights, self.layout.as_deref(), pool, idx)
    }
}

impl<M: DataMatrix> std::fmt::Debug for ModelSnapshot<M> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ModelSnapshot(v{}, n={}, d={}, epoch={}, by={})",
            self.version,
            self.n(),
            self.d(),
            self.dataset_epoch,
            self.produced_by
        )
    }
}

/// Margins for `idx` computed in one contiguous shard per pool worker,
/// shard `s` tagged with worker `s`'s NUMA node, merged in job order —
/// bit-wise equal to the sequential batch path (`glm::model::margins` /
/// [`ModelSnapshot::predict`]); see the determinism argument in the
/// [`crate::serve`] module docs. Shared by `Session::predict` and the
/// scheduler's concurrent readers, so the equality is structural.
///
/// Shards are dispatched as [`JobClass::Reader`] jobs: on every worker
/// they drain ahead of queued refit merge rounds (writer class), which is
/// what keeps predict tail latency flat under a live refit. The class
/// changes only *when* a shard starts — inputs are this frozen snapshot
/// and the merge below is in job order — so the bit-wise guarantees hold
/// verbatim.
pub(crate) fn sharded_margins<M: DataMatrix>(
    ds: &Dataset<M>,
    w: &[f64],
    layout: Option<&ShardedLayout>,
    pool: &WorkerPool,
    idx: &[usize],
) -> Vec<f64> {
    if idx.is_empty() {
        return Vec::new();
    }
    let workers = pool.workers();
    // one contiguous shard per worker; shard s carries worker s's node
    // tag so its column reads stay node-local under the pool's layout
    let per = idx.len().div_ceil(workers);
    let jobs: Vec<(usize, _)> = idx
        .chunks(per)
        .enumerate()
        .map(|(s, chunk)| {
            // margins stream the resident interleaved layout when one is
            // materialized — bit-wise equal to `glm::model::margins`
            // (kernel::dot_entries reproduces dot_col's reduction)
            let shard = layout.map(|l| l.shard(0));
            let node = pool.node_of_worker(s % workers);
            (node, move || match shard {
                Some(sh) => chunk
                    .iter()
                    .map(|&j| kernel::dot_entries(sh.entries(j), w))
                    .collect(),
                None => glm::model::margins(ds, w, chunk),
            })
        })
        .collect();
    let parts = pool.run_tagged_as(JobClass::Reader, jobs);
    let mut out = Vec::with_capacity(idx.len());
    for part in parts {
        out.extend_from_slice(&part);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, LayoutPolicy};
    use crate::glm::Objective;
    use crate::serve::Session;
    use crate::solver::{SolverConfig, Variant};
    use crate::sysinfo::Topology;

    fn session(layout: LayoutPolicy) -> Session<crate::data::DenseMatrix> {
        let ds = synthetic::dense_classification(160, 7, 61);
        let cfg = SolverConfig::new(Objective::Logistic { lambda: 1.0 / 160.0 })
            .with_variant(Variant::Domesticated)
            .with_threads(2)
            .with_topology(Topology::flat(2))
            .with_layout(layout)
            .with_tol(1e-4)
            .with_max_epochs(300);
        Session::new(ds, cfg)
    }

    #[test]
    fn sequential_and_pooled_predicts_agree_bitwise() {
        for layout in [LayoutPolicy::Interleaved, LayoutPolicy::Csc] {
            let sess = session(layout);
            let snap = sess.snapshot(3, "initial-train");
            assert_eq!(snap.version(), 3);
            assert_eq!((snap.n(), snap.d()), (160, 7));
            let idx: Vec<usize> = (0..160).rev().chain([5, 5, 0]).collect();
            let seq = snap.predict(&idx);
            let pooled = snap.predict_on(&sess.pool_arc(), &idx);
            assert_eq!(seq, pooled, "layout {layout:?}");
            let batch = glm::model::margins(snap.dataset(), snap.weights(), &idx);
            assert_eq!(seq, batch, "layout {layout:?} vs batch path");
            assert!(snap.predict(&[]).is_empty());
        }
    }

    #[test]
    fn snapshot_is_frozen_while_session_moves_on() {
        let mut sess = session(LayoutPolicy::Interleaved);
        let snap = sess.snapshot(0, "initial-train");
        let before = snap.predict(&[0, 1, 2]);
        let w_before = snap.weights().to_vec();
        // the writer appends + refits; version-0 readers must be unaffected
        let fresh = synthetic::dense_classification(16, 7, 62);
        let r = sess.partial_fit_rows(&fresh).expect("clean refit");
        assert_eq!(r.n, 176);
        assert_eq!(snap.n(), 160, "snapshot keeps its dataset version");
        assert_eq!(snap.weights(), &w_before[..]);
        assert_eq!(snap.predict(&[0, 1, 2]), before);
        // while the *new* snapshot serves the grown dataset
        let next = sess.snapshot(1, "refit-rows");
        assert_eq!(next.n(), 176);
        assert_eq!(next.dataset_epoch(), 1);
        assert_eq!(
            next.predict(&[175]),
            glm::model::margins(next.dataset(), next.weights(), &[175])
        );
        assert!(snap.age_s() >= 0.0);
    }
}
