//! The resident serving session: one pool, one dataset, one model —
//! reused across every predict/refit/retrain request (see the module docs
//! in [`crate::serve`] for the determinism and warm-start arguments).
//!
//! The dataset, the primal weights and the resident layout are held in
//! `Arc`s so the session can hand out immutable, versioned
//! [`ModelSnapshot`]s ([`Session::snapshot`]) that stay valid while the
//! session itself moves on — the substrate of the concurrent
//! [`Scheduler`](crate::serve::Scheduler).
//!
//! Appends are **clone-free**: the dataset is segment-chunked
//! ([`crate::data`]), so `partial_fit_rows` builds the successor dataset
//! by sharing every existing segment and sealing the fresh rows into a
//! new tail segment ([`Dataset::appended`]) — `O(rows added)` storage no
//! matter how many snapshots still hold earlier versions. There is no
//! `Arc::make_mut` on the dataset and therefore no `O(nnz)` copy-on-write
//! cliff on the refit path. Layout maintenance is the `O(rows added)`
//! tail re-encode ([`ShardedLayout::append_tail`]); the resident
//! *encoding* still copies under `Arc::make_mut` when a snapshot shares
//! it (see the note on [`Session::partial_fit_rows`]).

use crate::data::{AppendExamples, Dataset, LayoutPolicy, ShardedLayout};
use crate::glm::{self, GapReport, ModelState, Objective};
use crate::serve::snapshot::{sharded_margins, ModelSnapshot};
use crate::solver::{train, Buckets, ExecPolicy, PoolStats, SolverConfig, Variant, WorkerPool};
use crate::sysinfo::Topology;
use crate::util::Timer;
use std::sync::Arc;

/// Outcome of one training-shaped request (initial train, partial refit,
/// retrain).
#[derive(Clone, Debug)]
pub struct RefitReport {
    /// Which request produced this ("initial-train", "refit-rows",
    /// "refit-lambda", "retrain").
    pub kind: &'static str,
    /// Solver epochs the request consumed — the number the warm-start
    /// claim is about (warm refits must beat cold retrains here).
    pub epochs: usize,
    pub converged: bool,
    /// Duality gap of the model now being served.
    pub gap: f64,
    pub wall_s: f64,
    /// Dataset size after the request.
    pub n: usize,
}

/// Lifetime counters of one session.
#[derive(Clone, Debug, Default)]
pub struct SessionStats {
    pub predicts: u64,
    pub predicted_examples: u64,
    pub refits: u64,
    pub retrains: u64,
    /// Solver epochs across the initial train and every refit/retrain.
    pub epochs_total: u64,
}

/// A long-lived serving session: owns the dataset, the trained model and
/// a shared [`WorkerPool`] that answers every request without respawning
/// workers. A bare session serves requests one at a time (the parallelism
/// lives *inside* a request: sharded predict, replica training rounds);
/// the [`Scheduler`](crate::serve::Scheduler) wraps one to run readers
/// concurrently against published snapshots while writers serialize here.
pub struct Session<M: AppendExamples> {
    ds: Arc<Dataset<M>>,
    cfg: SolverConfig,
    topo: Topology,
    pool: Arc<WorkerPool>,
    state: ModelState,
    /// Primal weights of `state` — cached because every predict reads
    /// them; `Arc`'d so snapshots share them with zero copies.
    weights: Arc<Vec<f64>>,
    /// Session-resident interleaved layout ([`ShardedLayout`]) streaming
    /// every predict's margins, and shared with the solver on every
    /// refit/retrain via [`SolverConfig::layout_cache`] (so a training
    /// request re-uses this encoding instead of rebuilding it). Appends
    /// extend it incrementally ([`ShardedLayout::append_tail`]); a
    /// retrain may swap the config and rebuild. `None` under
    /// [`LayoutPolicy::Csc`].
    layout: Option<Arc<ShardedLayout>>,
    /// Cached per-node layout for `Variant::Numa` training requests,
    /// keyed on (placement, bucket size) and gated on the dataset shape
    /// via [`ShardedLayout::matches_nodes`] — NUMA refits stop paying the
    /// `O(nnz)` per-node re-encode per `train()`.
    ///
    /// Memory note: a NUMA session under the default Interleaved layout
    /// therefore keeps **two** 16 B/entry encodings resident (this one
    /// for training, `layout` for predicts) on top of the source matrix —
    /// roughly 3.7× a sparse dataset's 12 B/nnz payload in total. `--layout
    /// csc` drops both encodings (bit-wise identical results) if memory
    /// is the binding constraint.
    node_layout: Option<Arc<ShardedLayout>>,
    /// Monotone ingestion counter: +1 per absorbed append batch. Carried
    /// by every published [`ModelSnapshot`].
    ds_epoch: u64,
    stats: SessionStats,
}

impl<M: AppendExamples> Session<M> {
    /// Build the resident pool from `cfg.threads` on the (detected or
    /// configured) topology, then train the initial model on it.
    pub fn new(ds: Dataset<M>, cfg: SolverConfig) -> Self {
        let topo = cfg.topology.clone().unwrap_or_else(Topology::detect);
        let pool = Arc::new(WorkerPool::new(cfg.threads.max(1), &topo));
        let mut cfg = cfg;
        cfg.topology = Some(topo.clone());
        cfg.exec = ExecPolicy::Shared(Arc::clone(&pool));
        cfg.warm_start = None;
        let mut sess = Session {
            ds: Arc::new(ds),
            cfg,
            topo,
            pool,
            state: ModelState::zeros(0, 0),
            weights: Arc::new(Vec::new()),
            layout: None,
            node_layout: None,
            ds_epoch: 0,
            stats: SessionStats::default(),
        };
        sess.rebuild_layout();
        sess.fit(None, "initial-train");
        sess
    }

    /// (Re)materialize the resident interleaved layout from the current
    /// dataset — called at session start and whenever the layout-relevant
    /// config changes, or when an append flips the bucket geometry. A
    /// no-op plain-matrix session under [`LayoutPolicy::Csc`].
    fn rebuild_layout(&mut self) {
        self.layout = (self.cfg.layout == LayoutPolicy::Interleaved).then(|| {
            let n = self.ds.n();
            let buckets = Buckets::new(n, self.cfg.bucket.resolve_host(n));
            Arc::new(ShardedLayout::single(&self.ds.x, &buckets))
        });
    }

    /// Bring the resident layout up to date after an append. Appended
    /// examples land at the tail, so as long as the bucket geometry is
    /// unchanged this is the `O(rows added)` incremental re-encode; the
    /// full rebuild only happens when `BucketPolicy::Auto` flips the
    /// bucket size (the grown model vector crossed the LLC boundary).
    /// `Arc::make_mut` keeps outstanding snapshots intact: they hold the
    /// previous encoding, the session mutates its own — a copy of the
    /// 16 B/entry *encoding* when a snapshot shares it (the dataset
    /// payload itself is never copied; `--layout csc` drops the resident
    /// encoding and with it this residual cost — see ROADMAP).
    fn refresh_layout_after_append(&mut self) {
        if self.layout.is_none() {
            return;
        }
        let want = self.cfg.bucket.resolve_host(self.ds.n());
        if self.layout.as_ref().is_some_and(|l| l.bucket_size() == want) {
            let ds = &self.ds;
            if let Some(arc) = self.layout.as_mut() {
                Arc::make_mut(arc).append_tail(&ds.x);
            }
        } else {
            self.rebuild_layout();
        }
    }

    /// Margins `⟨x_j, w⟩` for the requested examples, computed in parallel
    /// shards on the resident pool and merged in job order — bit-wise
    /// equal to [`glm::model::margins`] on the same weights (see the
    /// module-level determinism argument). Shards are dispatched as
    /// reader-class jobs ([`crate::solver::JobClass::Reader`]), so on a
    /// shared pool they jump ahead of queued refit merge rounds without
    /// changing any computed value.
    pub fn predict(&mut self, idx: &[usize]) -> Vec<f64> {
        self.stats.predicts += 1;
        self.stats.predicted_examples += idx.len() as u64;
        sharded_margins(
            &self.ds,
            &self.weights,
            self.layout.as_deref(),
            &self.pool,
            idx,
        )
    }

    /// `±1` predictions for classification objectives (margin sign).
    pub fn predict_labels(&mut self, idx: &[usize]) -> Vec<f64> {
        self.predict(idx)
            .into_iter()
            .map(|m| if m >= 0.0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Append freshly arrived examples and warm-start refit: `α` is
    /// extended with zeros for the new rows, `v` is rebuilt exactly from
    /// `α`, and the solver resumes from that state on the same pool.
    ///
    /// The successor dataset is built functionally: every existing
    /// segment is shared by `Arc` with whatever snapshots are still
    /// serving, the fresh rows become a sealed tail segment, and only the
    /// flat label/norm vectors are copied (`O(n)` floats). No `O(nnz)`
    /// clone happens even under a permanent read load — asserted by
    /// `append_with_snapshot_outstanding_is_clone_free` below.
    ///
    /// (A sole-owner session could append in place via `Arc::make_mut`;
    /// the unconditional functional build is deliberate — the `O(n)`
    /// label copy is noise next to the refit's training pass, and the
    /// append cost model stays identical with and without readers.)
    pub fn partial_fit_rows(&mut self, rows: &Dataset<M>) -> RefitReport {
        assert_eq!(rows.d(), self.ds.d(), "appended rows must match d");
        self.stats.refits += 1;
        self.ds = Arc::new(self.ds.appended(rows));
        self.ds_epoch += 1;
        self.refresh_layout_after_append();
        let mut warm = self.state.extended(self.ds.n());
        warm.rebuild_v(&self.ds);
        self.fit(Some(warm), "refit-rows")
    }

    /// Change the regularization strength and warm-start refit from the
    /// current state (`α` stays dual-feasible under a new λ; `v` does not
    /// depend on λ at all).
    ///
    /// Panics on a non-finite or non-positive λ — `1/(λn)` would poison
    /// every coordinate update and the session would silently serve NaN
    /// margins afterwards.
    pub fn partial_fit_lambda(&mut self, lambda: f64) -> RefitReport {
        assert!(
            lambda.is_finite() && lambda > 0.0,
            "refit lambda must be finite and positive, got {lambda}"
        );
        self.stats.refits += 1;
        self.cfg.obj = self.cfg.obj.with_lambda(lambda);
        let mut warm = self.state.clone();
        warm.rebuild_v(&self.ds);
        self.fit(Some(warm), "refit-lambda")
    }

    /// Cold retrain under a new configuration, reusing the resident pool.
    /// If the new config asks for a different worker count the session
    /// pool is rebuilt to match (logged) — the one situation where workers
    /// are respawned mid-session.
    pub fn retrain(&mut self, cfg: SolverConfig) -> RefitReport {
        self.stats.retrains += 1;
        let mut cfg = cfg;
        cfg.topology = Some(self.topo.clone());
        let want = cfg.threads.max(1);
        if want != self.pool.workers() {
            crate::diag!(
                Warn,
                "parlin serve: retrain wants {want} workers, session pool has {}; \
                 rebuilding the resident pool",
                self.pool.workers()
            );
            self.pool = Arc::new(WorkerPool::new(want, &self.topo));
        }
        cfg.exec = ExecPolicy::Shared(Arc::clone(&self.pool));
        cfg.warm_start = None;
        self.cfg = cfg;
        // a retrain may change the layout policy or bucket geometry
        self.rebuild_layout();
        self.fit(None, "retrain")
    }

    /// Cold retrain with the session's current configuration (the baseline
    /// warm refits are measured against).
    pub fn retrain_same(&mut self) -> RefitReport {
        let cfg = self.cfg.clone();
        self.retrain(cfg)
    }

    /// The per-node layout to hand a `Variant::Numa` training request:
    /// the cached one when it still describes this exact (dataset,
    /// bucket size, thread placement), a fresh build otherwise. Appends
    /// and config changes invalidate through the key itself — a stale
    /// cache simply fails [`ShardedLayout::matches_nodes`] and is
    /// replaced.
    fn node_layout_cache(&mut self, cfg: &SolverConfig) -> Option<Arc<ShardedLayout>> {
        if cfg.layout != LayoutPolicy::Interleaved {
            return None;
        }
        let (n, d, nnz) = (self.ds.n(), self.ds.d(), self.ds.x.nnz());
        let bucket_size = cfg.bucket.resolve_host(n);
        let buckets = Buckets::new(n, bucket_size);
        let placement = self.topo.place_threads(cfg.threads.max(1));
        let ranges = crate::solver::numa::node_bucket_ranges(buckets.count(), &placement);
        let hit = self
            .node_layout
            .as_ref()
            .is_some_and(|l| l.matches_nodes(n, d, nnz, bucket_size, &ranges));
        if !hit {
            crate::diag!(
                Info,
                "parlin serve: per-node layout cache miss (n={n}, bucket={bucket_size}); \
                 re-encoding {nnz} entries"
            );
            self.node_layout = Some(Arc::new(ShardedLayout::for_nodes(
                &self.ds.x,
                &buckets,
                &ranges,
            )));
        }
        self.node_layout.clone()
    }

    /// Run the solver on the session dataset (optionally warm) and install
    /// the resulting model as the served one.
    fn fit(&mut self, warm: Option<ModelState>, kind: &'static str) -> RefitReport {
        let t = Timer::start();
        let mut cfg = self.cfg.clone();
        cfg.warm_start = warm;
        // hand the resident encoding to the solver instead of re-encoding
        // the dataset: the hierarchical solver gets the cached per-node
        // shards, everything else the session's single-shard layout
        cfg.layout_cache = match cfg.resolve_variant(&self.topo) {
            Variant::Numa => self.node_layout_cache(&cfg),
            _ => self.layout.clone(),
        };
        let out = train(&self.ds, &cfg);
        self.stats.epochs_total += out.epochs_run as u64;
        let report = RefitReport {
            kind,
            epochs: out.epochs_run,
            converged: out.converged,
            gap: out.final_gap,
            wall_s: t.elapsed_s(),
            n: self.ds.n(),
        };
        self.weights = Arc::new(out.state.w(&self.cfg.obj));
        self.state = out.state;
        report
    }

    /// Freeze the served model as an immutable, versioned snapshot —
    /// `Arc` clones only, no data copies. The scheduler assigns versions;
    /// the session only stamps its ingestion epoch.
    pub fn snapshot(&self, version: u64, produced_by: &'static str) -> ModelSnapshot<M> {
        ModelSnapshot::new(
            version,
            produced_by,
            self.ds_epoch,
            Arc::clone(&self.ds),
            Arc::clone(&self.weights),
            self.layout.clone(),
        )
    }

    pub fn n(&self) -> usize {
        self.ds.n()
    }

    pub fn d(&self) -> usize {
        self.ds.d()
    }

    /// Mean non-zeros per example (shape information for synthetic
    /// refit-row generation).
    pub fn avg_nnz(&self) -> f64 {
        self.ds.x.nnz() as f64 / self.ds.n().max(1) as f64
    }

    /// Monotone ingestion counter (+1 per absorbed append batch).
    pub fn ds_epoch(&self) -> u64 {
        self.ds_epoch
    }

    /// Primal weights of the currently served model.
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    pub fn state(&self) -> &ModelState {
        &self.state
    }

    pub fn dataset(&self) -> &Dataset<M> {
        &self.ds
    }

    pub fn objective(&self) -> &Objective {
        &self.cfg.obj
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// The resident pool itself — the scheduler shards concurrent reader
    /// predicts on it (the pool accepts dispatch from any thread).
    pub fn pool_arc(&self) -> Arc<WorkerPool> {
        Arc::clone(&self.pool)
    }

    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }

    /// Busy-time census of the resident pool (see [`PoolStats`]).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Duality gap of the currently served model (`O(nnz)`).
    pub fn gap(&self) -> GapReport {
        glm::duality_gap(&self.ds, &self.cfg.obj, &self.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::solver::Variant;

    fn cfg(n: usize, threads: usize) -> SolverConfig {
        SolverConfig::new(Objective::Logistic {
            lambda: 1.0 / n as f64,
        })
        .with_variant(Variant::Domesticated)
        .with_threads(threads)
        .with_topology(Topology::flat(threads))
        .with_tol(1e-4)
        .with_max_epochs(300)
    }

    #[test]
    fn session_trains_and_predicts() {
        let ds = synthetic::dense_classification(200, 8, 41);
        let mut sess = Session::new(ds, cfg(200, 2));
        assert_eq!((sess.n(), sess.d(), sess.workers()), (200, 8, 2));
        assert!(sess.gap().gap < 1e-2, "gap={}", sess.gap().gap);
        let m = sess.predict(&[0, 5, 199]);
        assert_eq!(m.len(), 3);
        assert!(sess.predict(&[]).is_empty());
        let labels = sess.predict_labels(&[0, 1, 2, 3]);
        assert!(labels.iter().all(|&l| l == 1.0 || l == -1.0));
        assert_eq!(sess.stats().predicts, 3);
    }

    #[test]
    fn lambda_refit_updates_objective() {
        let ds = synthetic::dense_classification(150, 6, 42);
        let mut sess = Session::new(ds, cfg(150, 2));
        let r = sess.partial_fit_lambda(0.05);
        assert_eq!(r.kind, "refit-lambda");
        assert!(r.converged);
        assert!((sess.objective().lambda() - 0.05).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn lambda_refit_rejects_nonpositive() {
        let ds = synthetic::dense_classification(80, 4, 48);
        let mut sess = Session::new(ds, cfg(80, 2));
        let _ = sess.partial_fit_lambda(0.0);
    }

    #[test]
    fn rows_refit_grows_dataset_and_stays_consistent() {
        let ds = synthetic::dense_classification(100, 5, 43);
        let mut sess = Session::new(ds, cfg(100, 2));
        let fresh = synthetic::dense_classification(10, 5, 44);
        let r = sess.partial_fit_rows(&fresh);
        assert_eq!((r.n, sess.n()), (110, 110));
        assert!(r.converged);
        assert!(sess.state().v_drift(sess.dataset()) < 1e-6);
        assert_eq!(sess.stats().refits, 1);
        assert_eq!(sess.ds_epoch(), 1);
    }

    /// The PR-5 tentpole claim, asserted at the session level: appending
    /// rows while a reader still holds a snapshot performs no `O(nnz)`
    /// dataset clone. Counted structurally — the pre-append segments of
    /// the new dataset are the *same allocations* (same pointers, Arc
    /// refcount ≥ 2) the snapshot serves, and exactly one sealed tail
    /// segment was added per append.
    #[test]
    fn append_with_snapshot_outstanding_is_clone_free() {
        use crate::data::DataMatrix;
        let ds = synthetic::dense_classification(150, 6, 77);
        let mut sess = Session::new(ds, cfg(150, 2));
        let snap = sess.snapshot(0, "initial-train");
        assert_eq!(snap.dataset().x.num_segments(), 1);
        let head_ptr = snap.dataset().x.col(0).as_ptr();
        for round in 0..3u64 {
            let fresh = synthetic::dense_classification(8, 6, 78 + round);
            let fresh_ptr = fresh.x.col(0).as_ptr();
            sess.partial_fit_rows(&fresh);
            let x = &sess.dataset().x;
            // segment census: original head + one sealed segment per append
            assert_eq!(x.num_segments(), 2 + round as usize);
            // the head segment is the snapshot's allocation, shared not copied
            assert_eq!(x.col(0).as_ptr(), head_ptr);
            assert!(x.segment_rc(0) >= 2, "head segment must be shared");
            // the appended rows were attached by Arc, not re-copied either
            assert_eq!(x.col((150 + 8 * round) as usize).as_ptr(), fresh_ptr);
        }
        // the outstanding snapshot still serves its own version untouched
        assert_eq!(snap.n(), 150);
        assert_eq!(snap.dataset().x.col(0).as_ptr(), head_ptr);
        // and the grown session stays numerically consistent
        assert_eq!(sess.n(), 174);
        assert!(sess.state().v_drift(sess.dataset()) < 1e-6);
    }

    #[test]
    fn incremental_layout_append_serves_correct_margins() {
        // several appends in a row exercise the O(rows added) tail
        // re-encode; every predict must stay bit-wise on the batch path
        let ds = synthetic::sparse_classification(120, 40, 0.1, 51);
        let mut sess = Session::new(ds, cfg(120, 2));
        for round in 0..3u64 {
            let fresh = synthetic::sparse_classification(9, 40, 0.1, 52 + round);
            sess.partial_fit_rows(&fresh);
            let idx: Vec<usize> = (0..sess.n()).step_by(7).collect();
            let got = sess.predict(&idx);
            let want = glm::model::margins(sess.dataset(), &sess.weights().to_vec(), &idx);
            assert_eq!(got, want, "append round {round}");
        }
        assert_eq!(sess.n(), 147);
        assert_eq!(sess.ds_epoch(), 3);
    }

    #[test]
    fn retrain_rebuilds_pool_on_thread_change() {
        use crate::obs::diag::{DiagCapture, Level};
        let ds = synthetic::dense_classification(120, 5, 45);
        let mut sess = Session::new(ds, cfg(120, 2));
        assert_eq!(sess.workers(), 2);
        let cap = DiagCapture::start();
        let r = sess.retrain(cfg(120, 3));
        let recs = cap.take();
        drop(cap);
        assert_eq!(sess.workers(), 3);
        assert!(r.converged);
        assert_eq!(sess.stats().retrains, 1);
        // the rebuild announced itself through the diag channel, not by
        // writing to stderr behind the capture's back
        let hit = recs
            .iter()
            .any(|d| d.level == Level::Warn && d.message.contains("rebuilding the resident pool"));
        assert!(hit, "expected a Warn diag about the pool rebuild, got {recs:?}");
        // the rebuilt pool serves predicts too
        assert_eq!(sess.predict(&[0, 1]).len(), 2);
    }

    #[test]
    fn sparse_sessions_work_end_to_end() {
        let ds = synthetic::sparse_classification(300, 80, 0.05, 46);
        let mut sess = Session::new(ds, cfg(300, 2));
        let fresh = synthetic::sparse_classification(15, 80, 0.05, 47);
        let r = sess.partial_fit_rows(&fresh);
        assert_eq!(sess.n(), 315);
        assert!(r.converged);
        assert_eq!(sess.predict(&[0, 314]).len(), 2);
    }

    #[test]
    fn numa_session_caches_node_layout_across_refits() {
        let topo = Topology::uniform(2, 2);
        let cfg = SolverConfig::new(Objective::Logistic { lambda: 1.0 / 240.0 })
            .with_variant(Variant::Numa)
            .with_threads(4)
            .with_topology(topo)
            .with_tol(1e-3)
            .with_max_epochs(300);
        let ds = synthetic::dense_classification(240, 9, 49);
        let mut sess = Session::new(ds, cfg);
        assert!(sess.node_layout.is_some(), "numa train must seed the cache");
        let first = Arc::as_ptr(sess.node_layout.as_ref().unwrap());
        // λ refit keeps the dataset: the cache must be reused, not rebuilt
        let r = sess.partial_fit_lambda(0.01);
        assert!(r.epochs >= 1);
        assert_eq!(
            Arc::as_ptr(sess.node_layout.as_ref().unwrap()),
            first,
            "same-geometry refit must hit the per-node layout cache"
        );
        // an append changes (n, nnz): the key misses and the cache rolls
        let fresh = synthetic::dense_classification(12, 9, 50);
        sess.partial_fit_rows(&fresh);
        assert_ne!(Arc::as_ptr(sess.node_layout.as_ref().unwrap()), first);
        let idx: Vec<usize> = (0..sess.n()).collect();
        let got = sess.predict(&idx);
        let want = glm::model::margins(sess.dataset(), &sess.weights().to_vec(), &idx);
        assert_eq!(got, want);
    }
}
