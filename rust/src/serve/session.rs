//! The resident serving session: one pool, one dataset, one model —
//! reused across every predict/refit/retrain request (see the module docs
//! in [`crate::serve`] for the determinism and warm-start arguments).

use crate::data::{AppendExamples, Dataset, LayoutPolicy, ShardedLayout};
use crate::glm::{self, GapReport, ModelState, Objective};
use crate::solver::{kernel, train, Buckets, ExecPolicy, PoolStats, SolverConfig, WorkerPool};
use crate::sysinfo::Topology;
use crate::util::Timer;
use std::sync::Arc;

/// Outcome of one training-shaped request (initial train, partial refit,
/// retrain).
#[derive(Clone, Debug)]
pub struct RefitReport {
    /// Which request produced this ("initial-train", "refit-rows",
    /// "refit-lambda", "retrain").
    pub kind: &'static str,
    /// Solver epochs the request consumed — the number the warm-start
    /// claim is about (warm refits must beat cold retrains here).
    pub epochs: usize,
    pub converged: bool,
    /// Duality gap of the model now being served.
    pub gap: f64,
    pub wall_s: f64,
    /// Dataset size after the request.
    pub n: usize,
}

/// Lifetime counters of one session.
#[derive(Clone, Debug, Default)]
pub struct SessionStats {
    pub predicts: u64,
    pub predicted_examples: u64,
    pub refits: u64,
    pub retrains: u64,
    /// Solver epochs across the initial train and every refit/retrain.
    pub epochs_total: u64,
}

/// A long-lived serving session: owns the dataset, the trained model and
/// a shared [`WorkerPool`] that answers every request without respawning
/// workers. Requests are served one at a time (the parallelism lives
/// *inside* a request: sharded predict, replica training rounds).
pub struct Session<M: AppendExamples> {
    ds: Dataset<M>,
    cfg: SolverConfig,
    topo: Topology,
    pool: Arc<WorkerPool>,
    state: ModelState,
    /// Primal weights of `state` — cached because every predict reads them.
    weights: Vec<f64>,
    /// Session-resident interleaved layout ([`ShardedLayout`]) streaming
    /// every predict's margins, and shared with the solver on every
    /// refit/retrain via [`SolverConfig::layout_cache`] (so a training
    /// request re-uses this encoding instead of rebuilding it). Rebuilt
    /// only when the dataset changes (`refit-rows` appends) or a retrain
    /// swaps the config. `None` under [`LayoutPolicy::Csc`].
    layout: Option<Arc<ShardedLayout>>,
    stats: SessionStats,
}

impl<M: AppendExamples> Session<M> {
    /// Build the resident pool from `cfg.threads` on the (detected or
    /// configured) topology, then train the initial model on it.
    pub fn new(ds: Dataset<M>, cfg: SolverConfig) -> Self {
        let topo = cfg.topology.clone().unwrap_or_else(Topology::detect);
        let pool = Arc::new(WorkerPool::new(cfg.threads.max(1), &topo));
        let mut cfg = cfg;
        cfg.topology = Some(topo.clone());
        cfg.exec = ExecPolicy::Shared(Arc::clone(&pool));
        cfg.warm_start = None;
        let mut sess = Session {
            ds,
            cfg,
            topo,
            pool,
            state: ModelState::zeros(0, 0),
            weights: Vec::new(),
            layout: None,
            stats: SessionStats::default(),
        };
        sess.rebuild_layout();
        sess.fit(None, "initial-train");
        sess
    }

    /// (Re)materialize the resident interleaved layout from the current
    /// dataset — called at session start and whenever the dataset or the
    /// layout-relevant config changes. A no-op plain-matrix session under
    /// [`LayoutPolicy::Csc`].
    fn rebuild_layout(&mut self) {
        self.layout = (self.cfg.layout == LayoutPolicy::Interleaved).then(|| {
            let n = self.ds.n();
            let buckets = Buckets::new(n, self.cfg.bucket.resolve_host(n));
            Arc::new(ShardedLayout::single(&self.ds.x, &buckets))
        });
    }

    /// Margins `⟨x_j, w⟩` for the requested examples, computed in parallel
    /// shards on the resident pool and merged in job order — bit-wise
    /// equal to [`glm::model::margins`] on the same weights (see the
    /// module-level determinism argument).
    pub fn predict(&mut self, idx: &[usize]) -> Vec<f64> {
        self.stats.predicts += 1;
        self.stats.predicted_examples += idx.len() as u64;
        if idx.is_empty() {
            return Vec::new();
        }
        let workers = self.pool.workers();
        // one contiguous shard per worker; shard s carries worker s's node
        // tag so its column reads stay node-local under the pool's layout
        let per = idx.len().div_ceil(workers);
        let jobs: Vec<(usize, _)> = idx
            .chunks(per)
            .enumerate()
            .map(|(s, chunk)| {
                let (ds, w) = (&self.ds, &self.weights[..]);
                // margins stream the resident interleaved layout when one
                // is materialized — bit-wise equal to `glm::model::margins`
                // (kernel::dot_entries reproduces dot_col's reduction)
                let shard = self.layout.as_ref().map(|l| l.shard(0));
                let node = self.pool.node_of_worker(s % workers);
                (node, move || match shard {
                    Some(sh) => chunk
                        .iter()
                        .map(|&j| kernel::dot_entries(sh.entries(j), w))
                        .collect(),
                    None => glm::model::margins(ds, w, chunk),
                })
            })
            .collect();
        let parts = self.pool.run_tagged(jobs);
        let mut out = Vec::with_capacity(idx.len());
        for part in parts {
            out.extend_from_slice(&part);
        }
        out
    }

    /// `±1` predictions for classification objectives (margin sign).
    pub fn predict_labels(&mut self, idx: &[usize]) -> Vec<f64> {
        self.predict(idx)
            .into_iter()
            .map(|m| if m >= 0.0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Append freshly arrived examples and warm-start refit: `α` is
    /// extended with zeros for the new rows, `v` is rebuilt exactly from
    /// `α`, and the solver resumes from that state on the same pool.
    pub fn partial_fit_rows(&mut self, rows: &Dataset<M>) -> RefitReport {
        assert_eq!(rows.d(), self.ds.d(), "appended rows must match d");
        self.stats.refits += 1;
        self.ds.append(rows);
        // the dataset changed shape: the resident interleaved encoding is
        // stale and must be rematerialized before the next predict
        self.rebuild_layout();
        let mut warm = self.state.extended(self.ds.n());
        warm.rebuild_v(&self.ds);
        self.fit(Some(warm), "refit-rows")
    }

    /// Change the regularization strength and warm-start refit from the
    /// current state (`α` stays dual-feasible under a new λ; `v` does not
    /// depend on λ at all).
    ///
    /// Panics on a non-finite or non-positive λ — `1/(λn)` would poison
    /// every coordinate update and the session would silently serve NaN
    /// margins afterwards.
    pub fn partial_fit_lambda(&mut self, lambda: f64) -> RefitReport {
        assert!(
            lambda.is_finite() && lambda > 0.0,
            "refit lambda must be finite and positive, got {lambda}"
        );
        self.stats.refits += 1;
        self.cfg.obj = self.cfg.obj.with_lambda(lambda);
        let mut warm = self.state.clone();
        warm.rebuild_v(&self.ds);
        self.fit(Some(warm), "refit-lambda")
    }

    /// Cold retrain under a new configuration, reusing the resident pool.
    /// If the new config asks for a different worker count the session
    /// pool is rebuilt to match (logged) — the one situation where workers
    /// are respawned mid-session.
    pub fn retrain(&mut self, cfg: SolverConfig) -> RefitReport {
        self.stats.retrains += 1;
        let mut cfg = cfg;
        cfg.topology = Some(self.topo.clone());
        let want = cfg.threads.max(1);
        if want != self.pool.workers() {
            eprintln!(
                "parlin serve: retrain wants {want} workers, session pool has {}; \
                 rebuilding the resident pool",
                self.pool.workers()
            );
            self.pool = Arc::new(WorkerPool::new(want, &self.topo));
        }
        cfg.exec = ExecPolicy::Shared(Arc::clone(&self.pool));
        cfg.warm_start = None;
        self.cfg = cfg;
        // a retrain may change the layout policy or bucket geometry
        self.rebuild_layout();
        self.fit(None, "retrain")
    }

    /// Cold retrain with the session's current configuration (the baseline
    /// warm refits are measured against).
    pub fn retrain_same(&mut self) -> RefitReport {
        let cfg = self.cfg.clone();
        self.retrain(cfg)
    }

    /// Run the solver on the session dataset (optionally warm) and install
    /// the resulting model as the served one.
    fn fit(&mut self, warm: Option<ModelState>, kind: &'static str) -> RefitReport {
        let t = Timer::start();
        let mut cfg = self.cfg.clone();
        cfg.warm_start = warm;
        // hand the resident encoding to the solver — `seq`/`dom`/`wild`
        // reuse it when the geometry fits instead of re-encoding the
        // dataset (the hierarchical solver builds its own per-node shards)
        cfg.layout_cache = self.layout.clone();
        let out = train(&self.ds, &cfg);
        self.stats.epochs_total += out.epochs_run as u64;
        let report = RefitReport {
            kind,
            epochs: out.epochs_run,
            converged: out.converged,
            gap: out.final_gap,
            wall_s: t.elapsed_s(),
            n: self.ds.n(),
        };
        self.weights = out.state.w(&self.cfg.obj);
        self.state = out.state;
        report
    }

    pub fn n(&self) -> usize {
        self.ds.n()
    }

    pub fn d(&self) -> usize {
        self.ds.d()
    }

    /// Mean non-zeros per example (shape information for synthetic
    /// refit-row generation).
    pub fn avg_nnz(&self) -> f64 {
        self.ds.x.nnz() as f64 / self.ds.n().max(1) as f64
    }

    /// Primal weights of the currently served model.
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    pub fn state(&self) -> &ModelState {
        &self.state
    }

    pub fn dataset(&self) -> &Dataset<M> {
        &self.ds
    }

    pub fn objective(&self) -> &Objective {
        &self.cfg.obj
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }

    /// Busy-time census of the resident pool (see [`PoolStats`]).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Duality gap of the currently served model (`O(nnz)`).
    pub fn gap(&self) -> GapReport {
        glm::duality_gap(&self.ds, &self.cfg.obj, &self.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::solver::Variant;

    fn cfg(n: usize, threads: usize) -> SolverConfig {
        SolverConfig::new(Objective::Logistic {
            lambda: 1.0 / n as f64,
        })
        .with_variant(Variant::Domesticated)
        .with_threads(threads)
        .with_topology(Topology::flat(threads))
        .with_tol(1e-4)
        .with_max_epochs(300)
    }

    #[test]
    fn session_trains_and_predicts() {
        let ds = synthetic::dense_classification(200, 8, 41);
        let mut sess = Session::new(ds, cfg(200, 2));
        assert_eq!((sess.n(), sess.d(), sess.workers()), (200, 8, 2));
        assert!(sess.gap().gap < 1e-2, "gap={}", sess.gap().gap);
        let m = sess.predict(&[0, 5, 199]);
        assert_eq!(m.len(), 3);
        assert!(sess.predict(&[]).is_empty());
        let labels = sess.predict_labels(&[0, 1, 2, 3]);
        assert!(labels.iter().all(|&l| l == 1.0 || l == -1.0));
        assert_eq!(sess.stats().predicts, 3);
    }

    #[test]
    fn lambda_refit_updates_objective() {
        let ds = synthetic::dense_classification(150, 6, 42);
        let mut sess = Session::new(ds, cfg(150, 2));
        let r = sess.partial_fit_lambda(0.05);
        assert_eq!(r.kind, "refit-lambda");
        assert!(r.converged);
        assert!((sess.objective().lambda() - 0.05).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn lambda_refit_rejects_nonpositive() {
        let ds = synthetic::dense_classification(80, 4, 48);
        let mut sess = Session::new(ds, cfg(80, 2));
        let _ = sess.partial_fit_lambda(0.0);
    }

    #[test]
    fn rows_refit_grows_dataset_and_stays_consistent() {
        let ds = synthetic::dense_classification(100, 5, 43);
        let mut sess = Session::new(ds, cfg(100, 2));
        let fresh = synthetic::dense_classification(10, 5, 44);
        let r = sess.partial_fit_rows(&fresh);
        assert_eq!((r.n, sess.n()), (110, 110));
        assert!(r.converged);
        assert!(sess.state().v_drift(sess.dataset()) < 1e-6);
        assert_eq!(sess.stats().refits, 1);
    }

    #[test]
    fn retrain_rebuilds_pool_on_thread_change() {
        let ds = synthetic::dense_classification(120, 5, 45);
        let mut sess = Session::new(ds, cfg(120, 2));
        assert_eq!(sess.workers(), 2);
        let r = sess.retrain(cfg(120, 3));
        assert_eq!(sess.workers(), 3);
        assert!(r.converged);
        assert_eq!(sess.stats().retrains, 1);
        // the rebuilt pool serves predicts too
        assert_eq!(sess.predict(&[0, 1]).len(), 2);
    }

    #[test]
    fn sparse_sessions_work_end_to_end() {
        let ds = synthetic::sparse_classification(300, 80, 0.05, 46);
        let mut sess = Session::new(ds, cfg(300, 2));
        let fresh = synthetic::sparse_classification(15, 80, 0.05, 47);
        let r = sess.partial_fit_rows(&fresh);
        assert_eq!(sess.n(), 315);
        assert!(r.converged);
        assert_eq!(sess.predict(&[0, 314]).len(), 2);
    }
}
