//! The resident serving session: one pool, one dataset, one model —
//! reused across every predict/refit/retrain request (see the module docs
//! in [`crate::serve`] for the determinism and warm-start arguments).
//!
//! The dataset, the primal weights and the resident layout are held in
//! `Arc`s so the session can hand out immutable, versioned
//! [`ModelSnapshot`]s ([`Session::snapshot`]) that stay valid while the
//! session itself moves on — the substrate of the concurrent
//! [`Scheduler`](crate::serve::Scheduler).
//!
//! Appends are **clone-free**: the dataset is segment-chunked
//! ([`crate::data`]), so `partial_fit_rows` builds the successor dataset
//! by sharing every existing segment and sealing the fresh rows into a
//! new tail segment ([`Dataset::appended`]) — `O(rows added)` storage no
//! matter how many snapshots still hold earlier versions. There is no
//! `Arc::make_mut` on the dataset and therefore no `O(nnz)` copy-on-write
//! cliff on the refit path. Layout maintenance is the `O(rows added)`
//! tail re-encode ([`ShardedLayout::append_tail`]); the resident
//! *encoding* still copies under `Arc::make_mut` when a snapshot shares
//! it (see the note on [`Session::partial_fit_rows`]).
//!
//! Writer requests are **fault-contained**: every refit/retrain runs
//! between a checkpoint of the served state and a publish health gate,
//! inside `catch_unwind`. A panic (genuine or injected via
//! [`crate::fault`]) or a non-finite result restores the checkpoint and
//! returns a typed [`ServeError`] — the session keeps serving the
//! last-known-good model and no mutex above it is ever poisoned (see
//! `docs/ROBUSTNESS.md` and the "Why a failed writer cannot corrupt a
//! reader" section of `docs/ARCHITECTURE.md`).

use crate::data::{AppendExamples, Dataset, LayoutPolicy, ShardedLayout};
use crate::fault::{self, FaultAction, FaultSite, InjectedFault};
use crate::glm::{self, GapReport, ModelState, Objective};
use crate::serve::error::ServeError;
use crate::serve::snapshot::{sharded_margins, ModelSnapshot};
use crate::solver::{
    train, Buckets, CancelToken, ExecPolicy, PoolStats, SolverConfig, TrainCancelled, TuneLog,
    Variant, WorkerPool,
};
use crate::sysinfo::Topology;
use crate::util::Timer;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

/// Outcome of one training-shaped request (initial train, partial refit,
/// retrain).
#[derive(Clone, Debug)]
pub struct RefitReport {
    /// Which request produced this ("initial-train", "refit-rows",
    /// "refit-lambda", "retrain").
    pub kind: &'static str,
    /// Solver epochs the request consumed — the number the warm-start
    /// claim is about (warm refits must beat cold retrains here).
    pub epochs: usize,
    pub converged: bool,
    /// Duality gap of the model now being served.
    pub gap: f64,
    pub wall_s: f64,
    /// Dataset size after the request.
    pub n: usize,
    /// Per-epoch convergence telemetry of the run that produced this
    /// model (see [`ConvergenceTrace`](crate::obs::ConvergenceTrace)) —
    /// what `--convergence-log` exports for serve-side refits.
    pub convergence: crate::obs::ConvergenceTrace,
    /// Replayable auto-tuner decision log — `Some` iff the session config
    /// ran with [`TunePolicy::On`](crate::solver::TunePolicy).
    pub tune_log: Option<TuneLog>,
}

/// Lifetime counters of one session.
#[derive(Clone, Debug, Default)]
pub struct SessionStats {
    pub predicts: u64,
    pub predicted_examples: u64,
    pub refits: u64,
    pub retrains: u64,
    /// Solver epochs across the initial train and every refit/retrain.
    pub epochs_total: u64,
}

/// A long-lived serving session: owns the dataset, the trained model and
/// a shared [`WorkerPool`] that answers every request without respawning
/// workers. A bare session serves requests one at a time (the parallelism
/// lives *inside* a request: sharded predict, replica training rounds);
/// the [`Scheduler`](crate::serve::Scheduler) wraps one to run readers
/// concurrently against published snapshots while writers serialize here.
pub struct Session<M: AppendExamples> {
    ds: Arc<Dataset<M>>,
    cfg: SolverConfig,
    topo: Topology,
    pool: Arc<WorkerPool>,
    state: ModelState,
    /// Primal weights of `state` — cached because every predict reads
    /// them; `Arc`'d so snapshots share them with zero copies.
    weights: Arc<Vec<f64>>,
    /// Session-resident interleaved layout ([`ShardedLayout`]) streaming
    /// every predict's margins, and shared with the solver on every
    /// refit/retrain via [`SolverConfig::layout_cache`] (so a training
    /// request re-uses this encoding instead of rebuilding it). Appends
    /// extend it incrementally ([`ShardedLayout::append_tail`]); a
    /// retrain may swap the config and rebuild. `None` under
    /// [`LayoutPolicy::Csc`].
    layout: Option<Arc<ShardedLayout>>,
    /// Cached per-node layout for `Variant::Numa` training requests,
    /// keyed on (placement, bucket size) and gated on the dataset shape
    /// via [`ShardedLayout::matches_nodes`] — NUMA refits stop paying the
    /// `O(nnz)` per-node re-encode per `train()`.
    ///
    /// Memory note: a NUMA session under the default Interleaved layout
    /// therefore keeps **two** 16 B/entry encodings resident (this one
    /// for training, `layout` for predicts) on top of the source matrix —
    /// roughly 3.7× a sparse dataset's 12 B/nnz payload in total. `--layout
    /// csc` drops both encodings (bit-wise identical results) if memory
    /// is the binding constraint.
    node_layout: Option<Arc<ShardedLayout>>,
    /// Monotone ingestion counter: +1 per absorbed append batch. Carried
    /// by every published [`ModelSnapshot`].
    ds_epoch: u64,
    /// Cooperative cancellation token threaded into every solver run this
    /// session launches (checked once per epoch). Tripping it makes the
    /// in-flight refit unwind into [`Session::guarded`], which restores
    /// the last-known-good model and reports
    /// [`ServeError::Cancelled`] — the drain watchdog's force-recovery
    /// lever. The session never resets it on its own; callers (the
    /// scheduler's drain loop) reset it at the start of each attempt.
    cancel: CancelToken,
    stats: SessionStats,
}

/// Everything a writer request may mutate, captured (by `Arc` clone —
/// cheap) at writer entry. A session *between* writer requests is by
/// construction healthy (its last writer either published or was rolled
/// back), so the entry checkpoint IS the last-known-good model; restoring
/// it after a panic or a refused publish returns the session to exactly
/// the state readers are being served from.
struct Checkpoint<M: AppendExamples> {
    ds: Arc<Dataset<M>>,
    ds_epoch: u64,
    state: ModelState,
    weights: Arc<Vec<f64>>,
    layout: Option<Arc<ShardedLayout>>,
    node_layout: Option<Arc<ShardedLayout>>,
    cfg: SolverConfig,
    pool: Arc<WorkerPool>,
}

impl<M: AppendExamples> Session<M> {
    /// Build the resident pool from `cfg.threads` on the (detected or
    /// configured) topology, then train the initial model on it.
    pub fn new(ds: Dataset<M>, cfg: SolverConfig) -> Self {
        let topo = cfg.topology.clone().unwrap_or_else(Topology::detect);
        let pool = Arc::new(WorkerPool::new(cfg.threads.max(1), &topo));
        let mut cfg = cfg;
        cfg.topology = Some(topo.clone());
        cfg.exec = ExecPolicy::Shared(Arc::clone(&pool));
        cfg.warm_start = None;
        // the session owns its cancellation token; whatever the caller put
        // in cfg.cancel is replaced so external code cannot abort refits
        // behind the scheduler's back
        let cancel = CancelToken::new();
        cfg.cancel = Some(cancel.clone());
        let mut sess = Session {
            ds: Arc::new(ds),
            cfg,
            topo,
            pool,
            state: ModelState::zeros(0, 0),
            weights: Arc::new(Vec::new()),
            layout: None,
            node_layout: None,
            ds_epoch: 0,
            cancel,
            stats: SessionStats::default(),
        };
        sess.rebuild_layout();
        sess.fit(None, "initial-train");
        assert!(
            sess.health_violation().is_none(),
            "initial train produced a non-finite model — refusing to serve it"
        );
        sess
    }

    /// Capture the served state at writer entry (Arc clones + one
    /// `ModelState` clone — the α/v copy is O(n+d), noise next to the
    /// training pass that follows).
    fn checkpoint(&self) -> Checkpoint<M> {
        Checkpoint {
            ds: Arc::clone(&self.ds),
            ds_epoch: self.ds_epoch,
            state: self.state.clone(),
            weights: Arc::clone(&self.weights),
            layout: self.layout.clone(),
            node_layout: self.node_layout.clone(),
            cfg: self.cfg.clone(),
            pool: Arc::clone(&self.pool),
        }
    }

    /// Put the session back exactly where [`Session::checkpoint`] found
    /// it. Overwrites every field a writer body may have touched, so it
    /// is safe to call even after that body panicked halfway through.
    fn restore(&mut self, cp: Checkpoint<M>) {
        self.ds = cp.ds;
        self.ds_epoch = cp.ds_epoch;
        self.state = cp.state;
        self.weights = cp.weights;
        self.layout = cp.layout;
        self.node_layout = cp.node_layout;
        self.cfg = cp.cfg;
        self.pool = cp.pool;
    }

    /// First health-gate violation in the served model, if any: the
    /// primal weights, the dual state (α and the shared vector v), and
    /// the margins of a small probe batch must all be finite. `None`
    /// means the model is publishable.
    fn health_violation(&self) -> Option<&'static str> {
        if !self.weights.iter().all(|w| w.is_finite()) {
            return Some("weights");
        }
        if !self.state.alpha.iter().all(|a| a.is_finite())
            || !self.state.v.iter().all(|v| v.is_finite())
        {
            return Some("duals");
        }
        // end-to-end probe: a handful of margins through the real predict
        // math catches poison the element-wise scans cannot see (e.g. a
        // layout that decodes garbage)
        let probe: Vec<usize> = (0..self.ds.n().min(4)).collect();
        let margins = glm::model::margins(&self.ds, &self.weights, &probe);
        if !margins.iter().all(|m| m.is_finite()) {
            return Some("probe margins");
        }
        None
    }

    /// Run a writer body between a checkpoint and the publish health
    /// gate, inside `catch_unwind`. On a panic (genuine or injected) or a
    /// non-finite result the checkpoint is restored — the session keeps
    /// serving the last-known-good model — and the failure comes back as
    /// a typed [`ServeError`].
    fn guarded(
        &mut self,
        kind: &'static str,
        body: impl FnOnce(&mut Self) -> RefitReport,
    ) -> Result<RefitReport, ServeError> {
        let cp = self.checkpoint();
        // AssertUnwindSafe: on the Err path `restore` overwrites every
        // field the body may have left half-mutated, so the "broken
        // invariant" unwind safety protects against cannot escape
        match catch_unwind(AssertUnwindSafe(|| body(self))) {
            Ok(report) => match self.health_violation() {
                None => Ok(report),
                Some(what) => {
                    self.restore(cp);
                    Err(ServeError::NonFinite { kind, what })
                }
            },
            Err(payload) => {
                self.restore(cp);
                Err(classify_panic(kind, payload))
            }
        }
    }

    /// (Re)materialize the resident interleaved layout from the current
    /// dataset — called at session start and whenever the layout-relevant
    /// config changes, or when an append flips the bucket geometry. A
    /// no-op plain-matrix session under [`LayoutPolicy::Csc`].
    fn rebuild_layout(&mut self) {
        self.layout = (self.cfg.layout == LayoutPolicy::Interleaved).then(|| {
            let n = self.ds.n();
            let buckets = Buckets::new(n, self.cfg.bucket.resolve_host(n));
            Arc::new(ShardedLayout::single(&self.ds.x, &buckets))
        });
    }

    /// Bring the resident layout up to date after an append. Appended
    /// examples land at the tail, so as long as the bucket geometry is
    /// unchanged this is the `O(rows added)` incremental re-encode; the
    /// full rebuild only happens when `BucketPolicy::Auto` flips the
    /// bucket size (the grown model vector crossed the LLC boundary).
    /// `Arc::make_mut` keeps outstanding snapshots intact: they hold the
    /// previous encoding, the session mutates its own — a copy of the
    /// 16 B/entry *encoding* when a snapshot shares it (the dataset
    /// payload itself is never copied; `--layout csc` drops the resident
    /// encoding and with it this residual cost — see ROADMAP).
    fn refresh_layout_after_append(&mut self) {
        if self.layout.is_none() {
            return;
        }
        let want = self.cfg.bucket.resolve_host(self.ds.n());
        if self.layout.as_ref().is_some_and(|l| l.bucket_size() == want) {
            let ds = &self.ds;
            if let Some(arc) = self.layout.as_mut() {
                Arc::make_mut(arc).append_tail(&ds.x);
            }
        } else {
            self.rebuild_layout();
        }
    }

    /// Margins `⟨x_j, w⟩` for the requested examples, computed in parallel
    /// shards on the resident pool and merged in job order — bit-wise
    /// equal to [`glm::model::margins`] on the same weights (see the
    /// module-level determinism argument). Shards are dispatched as
    /// reader-class jobs ([`crate::solver::JobClass::Reader`]), so on a
    /// shared pool they jump ahead of queued refit merge rounds without
    /// changing any computed value.
    pub fn predict(&mut self, idx: &[usize]) -> Vec<f64> {
        self.stats.predicts += 1;
        self.stats.predicted_examples += idx.len() as u64;
        sharded_margins(
            &self.ds,
            &self.weights,
            self.layout.as_deref(),
            &self.pool,
            idx,
        )
    }

    /// `±1` predictions for classification objectives (margin sign).
    pub fn predict_labels(&mut self, idx: &[usize]) -> Vec<f64> {
        self.predict(idx)
            .into_iter()
            .map(|m| if m >= 0.0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Append freshly arrived examples and warm-start refit: `α` is
    /// extended with zeros for the new rows, `v` is rebuilt exactly from
    /// `α`, and the solver resumes from that state on the same pool.
    ///
    /// The successor dataset is built functionally: every existing
    /// segment is shared by `Arc` with whatever snapshots are still
    /// serving, the fresh rows become a sealed tail segment, and only the
    /// flat label/norm vectors are copied (`O(n)` floats). No `O(nnz)`
    /// clone happens even under a permanent read load — asserted by
    /// `append_with_snapshot_outstanding_is_clone_free` below.
    ///
    /// (A sole-owner session could append in place via `Arc::make_mut`;
    /// the unconditional functional build is deliberate — the `O(n)`
    /// label copy is noise next to the refit's training pass, and the
    /// append cost model stays identical with and without readers.)
    /// A non-matching feature dimension, a panicking solver, or a
    /// non-finite result all come back as `Err` with the session restored
    /// to the last-known-good model (see [`Session::guarded`]).
    pub fn partial_fit_rows(&mut self, rows: &Dataset<M>) -> Result<RefitReport, ServeError> {
        if rows.d() != self.ds.d() {
            return Err(ServeError::ShapeMismatch { expected: self.ds.d(), got: rows.d() });
        }
        self.stats.refits += 1;
        self.guarded("refit-rows", |sess| {
            sess.ds = Arc::new(sess.ds.appended(rows));
            sess.ds_epoch += 1;
            sess.refresh_layout_after_append();
            let mut warm = sess.state.extended(sess.ds.n());
            warm.rebuild_v(&sess.ds);
            sess.fit(Some(warm), "refit-rows")
        })
    }

    /// Change the regularization strength and warm-start refit from the
    /// current state (`α` stays dual-feasible under a new λ; `v` does not
    /// depend on λ at all).
    ///
    /// A non-finite or non-positive λ is a typed
    /// [`ServeError::InvalidLambda`] — `1/(λn)` would poison every
    /// coordinate update and the session would silently serve NaN margins
    /// afterwards.
    pub fn partial_fit_lambda(&mut self, lambda: f64) -> Result<RefitReport, ServeError> {
        if !(lambda.is_finite() && lambda > 0.0) {
            return Err(ServeError::InvalidLambda { lambda });
        }
        self.stats.refits += 1;
        self.guarded("refit-lambda", |sess| {
            sess.cfg.obj = sess.cfg.obj.with_lambda(lambda);
            let mut warm = sess.state.clone();
            warm.rebuild_v(&sess.ds);
            sess.fit(Some(warm), "refit-lambda")
        })
    }

    /// Cold retrain under a new configuration, reusing the resident pool.
    /// If the new config asks for a different worker count the session
    /// pool is rebuilt to match (logged) — the one situation where workers
    /// are respawned mid-session. A failed retrain restores the previous
    /// config, pool and model ([`Session::guarded`]).
    pub fn retrain(&mut self, cfg: SolverConfig) -> Result<RefitReport, ServeError> {
        self.stats.retrains += 1;
        self.guarded("retrain", move |sess| {
            let mut cfg = cfg;
            cfg.topology = Some(sess.topo.clone());
            let want = cfg.threads.max(1);
            if want != sess.pool.workers() {
                crate::diag!(
                    Warn,
                    "parlin serve: retrain wants {want} workers, session pool has {}; \
                     rebuilding the resident pool",
                    sess.pool.workers()
                );
                sess.pool = Arc::new(WorkerPool::new(want, &sess.topo));
            }
            cfg.exec = ExecPolicy::Shared(Arc::clone(&sess.pool));
            cfg.warm_start = None;
            sess.cfg = cfg;
            // a retrain may change the layout policy or bucket geometry
            sess.rebuild_layout();
            sess.fit(None, "retrain")
        })
    }

    /// Cold retrain with the session's current configuration (the baseline
    /// warm refits are measured against).
    pub fn retrain_same(&mut self) -> Result<RefitReport, ServeError> {
        let cfg = self.cfg.clone();
        self.retrain(cfg)
    }

    /// The per-node layout to hand a `Variant::Numa` training request:
    /// the cached one when it still describes this exact (dataset,
    /// bucket size, thread placement), a fresh build otherwise. Appends
    /// and config changes invalidate through the key itself — a stale
    /// cache simply fails [`ShardedLayout::matches_nodes`] and is
    /// replaced.
    fn node_layout_cache(&mut self, cfg: &SolverConfig) -> Option<Arc<ShardedLayout>> {
        if cfg.layout != LayoutPolicy::Interleaved {
            return None;
        }
        let (n, d, nnz) = (self.ds.n(), self.ds.d(), self.ds.x.nnz());
        let bucket_size = cfg.bucket.resolve_host(n);
        let buckets = Buckets::new(n, bucket_size);
        let placement = self.topo.place_threads(cfg.threads.max(1));
        let ranges = crate::solver::numa::node_bucket_ranges(buckets.count(), &placement);
        let hit = self
            .node_layout
            .as_ref()
            .is_some_and(|l| l.matches_nodes(n, d, nnz, bucket_size, &ranges));
        if !hit {
            crate::diag!(
                Info,
                "parlin serve: per-node layout cache miss (n={n}, bucket={bucket_size}); \
                 re-encoding {nnz} entries"
            );
            self.node_layout = Some(Arc::new(ShardedLayout::for_nodes(
                &self.ds.x,
                &buckets,
                &ranges,
            )));
        }
        self.node_layout.clone()
    }

    /// Run the solver on the session dataset (optionally warm) and install
    /// the resulting model as the served one.
    fn fit(&mut self, warm: Option<ModelState>, kind: &'static str) -> RefitReport {
        let t = Timer::start();
        let mut cfg = self.cfg.clone();
        cfg.warm_start = warm;
        // always run under the session token (a retrain config may have
        // arrived without one). Deliberately NOT reset here: a token
        // tripped before entry aborts at the first epoch checkpoint —
        // that pre-arming is exactly how the drain watchdog kills a stuck
        // attempt; the drain loop resets it when it starts a fresh one.
        cfg.cancel = Some(self.cancel.clone());
        // hand the resident encoding to the solver instead of re-encoding
        // the dataset: the hierarchical solver gets the cached per-node
        // shards, everything else the session's single-shard layout
        cfg.layout_cache = match cfg.resolve_variant(&self.topo) {
            Variant::Numa => self.node_layout_cache(&cfg),
            _ => self.layout.clone(),
        };
        let out = train(&self.ds, &cfg);
        self.stats.epochs_total += out.epochs_run as u64;
        let report = RefitReport {
            kind,
            epochs: out.epochs_run,
            converged: out.converged,
            gap: out.final_gap,
            wall_s: t.elapsed_s(),
            n: self.ds.n(),
            convergence: out.convergence,
            tune_log: out.tune_log,
        };
        let mut w = out.state.w(&self.cfg.obj);
        // fault site "publish": the last instant before the freshly
        // trained model is installed. A `nan` action poisons one seeded
        // coordinate here — the publish health gate above must refuse it.
        if matches!(fault::poke(FaultSite::Publish), Some(FaultAction::Nan)) {
            if let Some(wi) = w.get_mut(fault::poison_index(self.ds.d())) {
                *wi = f64::NAN;
            }
        }
        self.weights = Arc::new(w);
        self.state = out.state;
        report
    }

    /// Freeze the served model as an immutable, versioned snapshot —
    /// `Arc` clones only, no data copies. The scheduler assigns versions;
    /// the session only stamps its ingestion epoch.
    pub fn snapshot(&self, version: u64, produced_by: &'static str) -> ModelSnapshot<M> {
        ModelSnapshot::new(
            version,
            produced_by,
            self.ds_epoch,
            Arc::clone(&self.ds),
            Arc::clone(&self.weights),
            self.layout.clone(),
        )
    }

    pub fn n(&self) -> usize {
        self.ds.n()
    }

    pub fn d(&self) -> usize {
        self.ds.d()
    }

    /// Mean non-zeros per example (shape information for synthetic
    /// refit-row generation).
    pub fn avg_nnz(&self) -> f64 {
        self.ds.x.nnz() as f64 / self.ds.n().max(1) as f64
    }

    /// Monotone ingestion counter (+1 per absorbed append batch).
    pub fn ds_epoch(&self) -> u64 {
        self.ds_epoch
    }

    /// Primal weights of the currently served model.
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    pub fn state(&self) -> &ModelState {
        &self.state
    }

    pub fn dataset(&self) -> &Dataset<M> {
        &self.ds
    }

    pub fn objective(&self) -> &Objective {
        &self.cfg.obj
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// The resident pool itself — the scheduler shards concurrent reader
    /// predicts on it (the pool accepts dispatch from any thread).
    pub fn pool_arc(&self) -> Arc<WorkerPool> {
        Arc::clone(&self.pool)
    }

    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }

    /// Busy-time census of the resident pool (see [`PoolStats`]).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// The session's cooperative cancellation token. Tripping it aborts
    /// the in-flight (or next) refit at its epoch checkpoint with
    /// [`ServeError::Cancelled`]; callers reset it before a fresh attempt.
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Duality gap of the currently served model (`O(nnz)`).
    pub fn gap(&self) -> GapReport {
        glm::duality_gap(&self.ds, &self.cfg.obj, &self.state)
    }
}

/// Map a caught panic payload to a [`ServeError`]: an
/// [`InjectedFault`] marker (the fault harness's `error` action) becomes
/// [`ServeError::Injected`]; anything else is a genuine
/// [`ServeError::RefitPanicked`] with the panic message when it carried
/// one.
fn classify_panic(kind: &'static str, payload: Box<dyn std::any::Any + Send>) -> ServeError {
    if let Some(injected) = payload.downcast_ref::<InjectedFault>() {
        return ServeError::Injected { site: injected.site };
    }
    if let Some(cancelled) = payload.downcast_ref::<TrainCancelled>() {
        return ServeError::Cancelled { kind, epoch: cancelled.epoch };
    }
    let message = if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    };
    ServeError::RefitPanicked { kind, message }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::solver::Variant;

    fn cfg(n: usize, threads: usize) -> SolverConfig {
        SolverConfig::new(Objective::Logistic {
            lambda: 1.0 / n as f64,
        })
        .with_variant(Variant::Domesticated)
        .with_threads(threads)
        .with_topology(Topology::flat(threads))
        .with_tol(1e-4)
        .with_max_epochs(300)
    }

    #[test]
    fn session_trains_and_predicts() {
        let ds = synthetic::dense_classification(200, 8, 41);
        let mut sess = Session::new(ds, cfg(200, 2));
        assert_eq!((sess.n(), sess.d(), sess.workers()), (200, 8, 2));
        assert!(sess.gap().gap < 1e-2, "gap={}", sess.gap().gap);
        let m = sess.predict(&[0, 5, 199]);
        assert_eq!(m.len(), 3);
        assert!(sess.predict(&[]).is_empty());
        let labels = sess.predict_labels(&[0, 1, 2, 3]);
        assert!(labels.iter().all(|&l| l == 1.0 || l == -1.0));
        assert_eq!(sess.stats().predicts, 3);
    }

    #[test]
    fn lambda_refit_updates_objective() {
        let ds = synthetic::dense_classification(150, 6, 42);
        let mut sess = Session::new(ds, cfg(150, 2));
        let r = sess.partial_fit_lambda(0.05).expect("valid λ refit");
        assert_eq!(r.kind, "refit-lambda");
        assert!(r.converged);
        assert!((sess.objective().lambda() - 0.05).abs() < 1e-15);
    }

    #[test]
    fn lambda_refit_rejects_nonpositive_as_typed_error() {
        let ds = synthetic::dense_classification(80, 4, 48);
        let mut sess = Session::new(ds, cfg(80, 2));
        let before = sess.objective().lambda();
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            match sess.partial_fit_lambda(bad) {
                Err(ServeError::InvalidLambda { lambda }) => {
                    assert!(lambda == bad || (lambda.is_nan() && bad.is_nan()))
                }
                other => panic!("λ={bad} must be InvalidLambda, got {other:?}"),
            }
        }
        // the rejection mutated nothing: same objective, still serving
        assert_eq!(sess.objective().lambda(), before);
        assert_eq!(sess.predict(&[0, 1]).len(), 2);
    }

    #[test]
    fn rows_refit_rejects_shape_mismatch_without_mutating() {
        let ds = synthetic::dense_classification(90, 5, 58);
        let mut sess = Session::new(ds, cfg(90, 2));
        let wrong = synthetic::dense_classification(10, 4, 59);
        match sess.partial_fit_rows(&wrong) {
            Err(ServeError::ShapeMismatch { expected: 5, got: 4 }) => {}
            other => panic!("expected ShapeMismatch, got {other:?}"),
        }
        assert_eq!(sess.n(), 90, "rejected rows must not be absorbed");
        assert_eq!(sess.ds_epoch(), 0);
        assert_eq!(sess.predict(&[0, 89]).len(), 2);
    }

    /// The tentpole claim at the session level: a panic mid-refit (here
    /// injected at the first solver epoch) is contained, the session is
    /// restored to the last-known-good model bit-for-bit, and a later
    /// clean refit succeeds.
    #[test]
    fn injected_panic_is_contained_and_restored() {
        use crate::fault::FaultPlan;
        let ds = synthetic::dense_classification(100, 6, 65);
        let mut sess = Session::new(ds, cfg(100, 2));
        let before = sess.predict(&[0, 1, 2, 3]);
        let w_before = sess.weights().to_vec();
        {
            let _fault = FaultPlan::parse("panic@epoch#1", 2).unwrap().arm();
            let fresh = synthetic::dense_classification(10, 6, 66);
            match sess.partial_fit_rows(&fresh) {
                Err(ServeError::RefitPanicked { kind: "refit-rows", .. }) => {}
                other => panic!("expected RefitPanicked, got {other:?}"),
            }
        }
        // restored: dataset, epoch counter and weights exactly as before
        assert_eq!(sess.n(), 100);
        assert_eq!(sess.ds_epoch(), 0);
        assert_eq!(sess.weights(), &w_before[..]);
        assert_eq!(sess.predict(&[0, 1, 2, 3]), before, "bit-wise last-known-good");
        // the failure left nothing broken behind: a clean refit works
        let fresh = synthetic::dense_classification(10, 6, 67);
        let r = sess.partial_fit_rows(&fresh).expect("post-recovery refit");
        assert_eq!((r.n, sess.n()), (110, 110));
    }

    /// PR-10 force-recovery lever at the session level: a pre-tripped
    /// token aborts the next refit at its first epoch checkpoint with a
    /// typed `Cancelled`, the last-known-good model survives bit-wise,
    /// and a reset makes the session fully usable again.
    #[test]
    fn tripped_token_aborts_refit_and_restores() {
        let ds = synthetic::dense_classification(100, 6, 71);
        let mut sess = Session::new(ds, cfg(100, 2));
        let before = sess.predict(&[0, 1, 2]);
        sess.cancel_token().cancel();
        let fresh = synthetic::dense_classification(10, 6, 72);
        match sess.partial_fit_rows(&fresh) {
            Err(ServeError::Cancelled { kind: "refit-rows", epoch: 1 }) => {}
            other => panic!("expected Cancelled at epoch 1, got {other:?}"),
        }
        assert_eq!(sess.n(), 100, "cancelled rows must not be absorbed");
        assert_eq!(sess.predict(&[0, 1, 2]), before, "bit-wise last-known-good");
        sess.cancel_token().reset();
        let r = sess.partial_fit_rows(&fresh).expect("post-reset refit");
        assert_eq!((r.n, sess.n()), (110, 110));
    }

    #[test]
    fn rows_refit_grows_dataset_and_stays_consistent() {
        let ds = synthetic::dense_classification(100, 5, 43);
        let mut sess = Session::new(ds, cfg(100, 2));
        let fresh = synthetic::dense_classification(10, 5, 44);
        let r = sess.partial_fit_rows(&fresh).expect("clean refit");
        assert_eq!((r.n, sess.n()), (110, 110));
        assert!(r.converged);
        assert!(sess.state().v_drift(sess.dataset()) < 1e-6);
        assert_eq!(sess.stats().refits, 1);
        assert_eq!(sess.ds_epoch(), 1);
    }

    /// The PR-5 tentpole claim, asserted at the session level: appending
    /// rows while a reader still holds a snapshot performs no `O(nnz)`
    /// dataset clone. Counted structurally — the pre-append segments of
    /// the new dataset are the *same allocations* (same pointers, Arc
    /// refcount ≥ 2) the snapshot serves, and exactly one sealed tail
    /// segment was added per append.
    #[test]
    fn append_with_snapshot_outstanding_is_clone_free() {
        use crate::data::DataMatrix;
        let ds = synthetic::dense_classification(150, 6, 77);
        let mut sess = Session::new(ds, cfg(150, 2));
        let snap = sess.snapshot(0, "initial-train");
        assert_eq!(snap.dataset().x.num_segments(), 1);
        let head_ptr = snap.dataset().x.col(0).as_ptr();
        for round in 0..3u64 {
            let fresh = synthetic::dense_classification(8, 6, 78 + round);
            let fresh_ptr = fresh.x.col(0).as_ptr();
            sess.partial_fit_rows(&fresh).expect("clean refit");
            let x = &sess.dataset().x;
            // segment census: original head + one sealed segment per append
            assert_eq!(x.num_segments(), 2 + round as usize);
            // the head segment is the snapshot's allocation, shared not copied
            assert_eq!(x.col(0).as_ptr(), head_ptr);
            assert!(x.segment_rc(0) >= 2, "head segment must be shared");
            // the appended rows were attached by Arc, not re-copied either
            assert_eq!(x.col((150 + 8 * round) as usize).as_ptr(), fresh_ptr);
        }
        // the outstanding snapshot still serves its own version untouched
        assert_eq!(snap.n(), 150);
        assert_eq!(snap.dataset().x.col(0).as_ptr(), head_ptr);
        // and the grown session stays numerically consistent
        assert_eq!(sess.n(), 174);
        assert!(sess.state().v_drift(sess.dataset()) < 1e-6);
    }

    #[test]
    fn incremental_layout_append_serves_correct_margins() {
        // several appends in a row exercise the O(rows added) tail
        // re-encode; every predict must stay bit-wise on the batch path
        let ds = synthetic::sparse_classification(120, 40, 0.1, 51);
        let mut sess = Session::new(ds, cfg(120, 2));
        for round in 0..3u64 {
            let fresh = synthetic::sparse_classification(9, 40, 0.1, 52 + round);
            sess.partial_fit_rows(&fresh).expect("clean refit");
            let idx: Vec<usize> = (0..sess.n()).step_by(7).collect();
            let got = sess.predict(&idx);
            let want = glm::model::margins(sess.dataset(), &sess.weights().to_vec(), &idx);
            assert_eq!(got, want, "append round {round}");
        }
        assert_eq!(sess.n(), 147);
        assert_eq!(sess.ds_epoch(), 3);
    }

    #[test]
    fn retrain_rebuilds_pool_on_thread_change() {
        use crate::obs::diag::{DiagCapture, Level};
        let ds = synthetic::dense_classification(120, 5, 45);
        let mut sess = Session::new(ds, cfg(120, 2));
        assert_eq!(sess.workers(), 2);
        let cap = DiagCapture::start();
        let r = sess.retrain(cfg(120, 3)).expect("clean retrain");
        let recs = cap.take();
        drop(cap);
        assert_eq!(sess.workers(), 3);
        assert!(r.converged);
        assert_eq!(sess.stats().retrains, 1);
        // the rebuild announced itself through the diag channel, not by
        // writing to stderr behind the capture's back
        let hit = recs
            .iter()
            .any(|d| d.level == Level::Warn && d.message.contains("rebuilding the resident pool"));
        assert!(hit, "expected a Warn diag about the pool rebuild, got {recs:?}");
        // the rebuilt pool serves predicts too
        assert_eq!(sess.predict(&[0, 1]).len(), 2);
    }

    #[test]
    fn sparse_sessions_work_end_to_end() {
        let ds = synthetic::sparse_classification(300, 80, 0.05, 46);
        let mut sess = Session::new(ds, cfg(300, 2));
        let fresh = synthetic::sparse_classification(15, 80, 0.05, 47);
        let r = sess.partial_fit_rows(&fresh).expect("clean refit");
        assert_eq!(sess.n(), 315);
        assert!(r.converged);
        assert_eq!(sess.predict(&[0, 314]).len(), 2);
    }

    #[test]
    fn numa_session_caches_node_layout_across_refits() {
        let topo = Topology::uniform(2, 2);
        let cfg = SolverConfig::new(Objective::Logistic { lambda: 1.0 / 240.0 })
            .with_variant(Variant::Numa)
            .with_threads(4)
            .with_topology(topo)
            .with_tol(1e-3)
            .with_max_epochs(300);
        let ds = synthetic::dense_classification(240, 9, 49);
        let mut sess = Session::new(ds, cfg);
        assert!(sess.node_layout.is_some(), "numa train must seed the cache");
        let first = Arc::as_ptr(sess.node_layout.as_ref().unwrap());
        // λ refit keeps the dataset: the cache must be reused, not rebuilt
        let r = sess.partial_fit_lambda(0.01).expect("clean refit");
        assert!(r.epochs >= 1);
        assert_eq!(
            Arc::as_ptr(sess.node_layout.as_ref().unwrap()),
            first,
            "same-geometry refit must hit the per-node layout cache"
        );
        // an append changes (n, nnz): the key misses and the cache rolls
        let fresh = synthetic::dense_classification(12, 9, 50);
        sess.partial_fit_rows(&fresh).expect("clean refit");
        assert_ne!(Arc::as_ptr(sess.node_layout.as_ref().unwrap()), first);
        let idx: Vec<usize> = (0..sess.n()).collect();
        let got = sess.predict(&idx);
        let want = glm::model::margins(sess.dataset(), &sess.weights().to_vec(), &idx);
        assert_eq!(got, want);
    }
}
