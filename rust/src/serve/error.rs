//! Typed writer-path failures and the serve-tier health state.
//!
//! Every writer entry point (`Session::{partial_fit_rows,
//! partial_fit_lambda, retrain}` and the scheduler methods built on them)
//! returns `Result<RefitReport, ServeError>` instead of panicking: a
//! failed refit is an *outcome*, recovered to the last-known-good model,
//! not a poisoned mutex. [`ServeHealth`] is the scheduler-level summary
//! stamped on every report — `Healthy` after a successful publish,
//! `Degraded` while the most recent writer attempt failed or the drain
//! thread is dead/stalled.

/// Why a refit/retrain did not publish. The session is already restored
/// to its last-known-good state when one of these is returned.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// The training body panicked (a genuine bug or a `panic` fault
    /// injection); `message` is the panic payload when it was a string.
    RefitPanicked { kind: &'static str, message: String },
    /// An armed [`FaultPlan`](crate::fault::FaultPlan) `error` action
    /// fired at `site` — distinguishable from [`ServeError::RefitPanicked`]
    /// so tests can tell injected failures from real ones.
    Injected { site: &'static str },
    /// The refit finished but produced a non-finite model (`what` names
    /// the first check that failed: weights, duals, or probe margins) —
    /// the publish health gate refused it.
    NonFinite { kind: &'static str, what: &'static str },
    /// The refit was cooperatively cancelled at the epoch-`epoch`
    /// checkpoint — the drain watchdog (or a caller) tripped the session's
    /// [`CancelToken`](crate::solver::CancelToken). Distinguishable from
    /// panics and injected faults so force-recovery shows up as itself.
    Cancelled { kind: &'static str, epoch: usize },
    /// Appended rows disagree with the session's feature dimension.
    ShapeMismatch { expected: usize, got: usize },
    /// `partial_fit_lambda` with a non-finite or non-positive λ (1/(λn)
    /// would poison the model).
    InvalidLambda { lambda: f64 },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::RefitPanicked { kind, message } => {
                write!(f, "{kind} panicked: {message}")
            }
            ServeError::Injected { site } => write!(f, "injected fault at {site}"),
            ServeError::Cancelled { kind, epoch } => {
                write!(f, "{kind} cancelled at epoch {epoch}")
            }
            ServeError::NonFinite { kind, what } => {
                write!(f, "{kind} produced a non-finite model ({what})")
            }
            ServeError::ShapeMismatch { expected, got } => {
                write!(f, "appended rows have d={got}, session serves d={expected}")
            }
            ServeError::InvalidLambda { lambda } => {
                write!(f, "refit lambda must be finite and positive, got {lambda}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Scheduler-level health, stamped on `SchedReport`/`OpenLoopReport`
/// (and `ServeReport` for the single-session driver). `parlin serve`
/// exits 0 only when the final state is `Healthy`.
#[derive(Clone, Debug, PartialEq, Default)]
pub enum ServeHealth {
    /// The most recent writer outcome was a successful publish (or no
    /// writer has run yet — the initial train published version 0).
    #[default]
    Healthy,
    /// The most recent writer attempt failed, rows sit quarantined, or
    /// the background drain is dead/stalled. Readers keep serving the
    /// last-known-good version throughout.
    Degraded { reason: String },
}

impl ServeHealth {
    pub fn is_healthy(&self) -> bool {
        matches!(self, ServeHealth::Healthy)
    }

    pub fn degraded(reason: impl Into<String>) -> ServeHealth {
        ServeHealth::Degraded { reason: reason.into() }
    }
}

impl std::fmt::Display for ServeHealth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeHealth::Healthy => f.write_str("Healthy"),
            ServeHealth::Degraded { reason } => write!(f, "Degraded ({reason})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_the_diagnosis() {
        let e = ServeError::NonFinite { kind: "refit-rows", what: "weights" };
        assert_eq!(e.to_string(), "refit-rows produced a non-finite model (weights)");
        let e = ServeError::ShapeMismatch { expected: 8, got: 5 };
        assert!(e.to_string().contains("d=5"));
        let e = ServeError::Cancelled { kind: "refit-rows", epoch: 3 };
        assert_eq!(e.to_string(), "refit-rows cancelled at epoch 3");
        assert_eq!(ServeHealth::default(), ServeHealth::Healthy);
        assert!(ServeHealth::Healthy.is_healthy());
        let d = ServeHealth::degraded("drain failed");
        assert!(!d.is_healthy());
        assert_eq!(d.to_string(), "Degraded (drain failed)");
    }
}
