//! `parlin report` — regression diffing of run artifacts.
//!
//! A CI run (or a human) saves a [`BenchRecord`] per serve run via
//! `--bench-json`; `parlin report --baseline a.json --current b.json`
//! diffs the two and exits nonzero when any metric regressed past the
//! threshold. The point is a *stable, file-based* contract: the committed
//! baseline in `ci/` is a plain JSON file anyone can read and regenerate,
//! and the comparison logic lives here where unit tests can pin it, not
//! in a shell pipeline.
//!
//! Inputs are deliberately liberal: a `BenchRecord` JSON, a
//! [`ConvergenceTrace`] CSV (`--convergence-log` output) or a
//! [`RunRecord`](crate::metrics::RunRecord) CSV (`train --csv` output)
//! all load — the CSVs map onto the epochs/gap/wall subset of the
//! metrics, so convergence artifacts can be diffed with the same command.
//!
//! The JSON dialect is a single flat object with string / number / bool /
//! null values, written and parsed by this module with no dependencies —
//! same spirit as the strict chrome-trace parser in
//! `examples/check_trace.rs`.

use std::path::Path;

use crate::metrics::{RunRecord, Table};
use crate::obs::ConvergenceTrace;

/// Schema tag embedded in every [`BenchRecord`] JSON artifact.
pub const SCHEMA: &str = "parlin-bench-v1";

/// One run's headline numbers, as persisted by `--bench-json`. Metrics a
/// given run kind does not produce are `None` (and `null` on disk) — the
/// comparison only diffs metrics present on both sides.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// What produced this ("serve-open-loop", "serve-concurrent",
    /// "serve", "train-csv", "convergence-csv", …).
    pub kind: String,
    /// Completed requests per second (serve runs).
    pub throughput_rps: Option<f64>,
    /// Median / tail predict latency, milliseconds (serve runs).
    pub p50_ms: Option<f64>,
    pub p99_ms: Option<f64>,
    /// Solver epochs consumed (training-shaped runs).
    pub epochs: Option<f64>,
    /// Auto-tuner decisions taken (`--tune on` runs and tune-log
    /// artifacts): a run that suddenly needs far more knob moves to reach
    /// the same gap is drifting, so higher is worse.
    pub decisions: Option<f64>,
    /// Final duality gap of the model.
    pub gap: Option<f64>,
    /// Total wall clock, seconds.
    pub wall_s: Option<f64>,
    /// Final [`ServeHealth`](crate::serve::ServeHealth): a healthy
    /// baseline vs a degraded current run is always a regression.
    pub healthy: bool,
}

impl BenchRecord {
    /// An empty record of the given kind (all metrics absent, healthy).
    pub fn new(kind: impl Into<String>) -> Self {
        BenchRecord {
            kind: kind.into(),
            throughput_rps: None,
            p50_ms: None,
            p99_ms: None,
            epochs: None,
            decisions: None,
            gap: None,
            wall_s: None,
            healthy: true,
        }
    }

    /// Render as the flat JSON object [`BenchRecord::from_json`] parses.
    /// Absent or non-finite metrics emit as `null` (JSON has no inf/nan).
    pub fn to_json(&self) -> String {
        let num = |x: Option<f64>| match x {
            Some(v) if v.is_finite() => format!("{v}"),
            _ => "null".to_string(),
        };
        format!(
            "{{\"schema\":\"{}\",\"kind\":\"{}\",\"healthy\":{},\
             \"throughput_rps\":{},\"p50_ms\":{},\"p99_ms\":{},\
             \"epochs\":{},\"decisions\":{},\"gap\":{},\"wall_s\":{}}}\n",
            SCHEMA,
            escape_json(&self.kind),
            self.healthy,
            num(self.throughput_rps),
            num(self.p50_ms),
            num(self.p99_ms),
            num(self.epochs),
            num(self.decisions),
            num(self.gap),
            num(self.wall_s),
        )
    }

    /// Parse a [`BenchRecord::to_json`] artifact. Strict about shape
    /// (flat object, known value types, matching schema tag, no trailing
    /// garbage), tolerant about *unknown keys* so older binaries can read
    /// artifacts from newer ones.
    pub fn from_json(text: &str) -> Result<BenchRecord, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        p.eat(b'{')?;
        let mut rec = BenchRecord::new("");
        let mut schema_seen = false;
        p.ws();
        if p.peek() == Some(b'}') {
            p.i += 1;
        } else {
            loop {
                p.ws();
                let key = p.string()?;
                p.ws();
                p.eat(b':')?;
                p.ws();
                let val = p.value()?;
                let num = |v: Value| -> Result<Option<f64>, String> {
                    match v {
                        Value::Num(x) => Ok(Some(x)),
                        Value::Null => Ok(None),
                        other => Err(format!("key {key:?}: expected number or null, got {other:?}")),
                    }
                };
                match key.as_str() {
                    "schema" => match val {
                        Value::Str(s) if s == SCHEMA => schema_seen = true,
                        Value::Str(s) => return Err(format!("unsupported schema {s:?}")),
                        other => return Err(format!("schema must be a string, got {other:?}")),
                    },
                    "kind" => match val {
                        Value::Str(s) => rec.kind = s,
                        other => return Err(format!("kind must be a string, got {other:?}")),
                    },
                    "healthy" => match val {
                        Value::Bool(b) => rec.healthy = b,
                        other => return Err(format!("healthy must be a bool, got {other:?}")),
                    },
                    "throughput_rps" => rec.throughput_rps = num(val)?,
                    "p50_ms" => rec.p50_ms = num(val)?,
                    "p99_ms" => rec.p99_ms = num(val)?,
                    "epochs" => rec.epochs = num(val)?,
                    "decisions" => rec.decisions = num(val)?,
                    "gap" => rec.gap = num(val)?,
                    "wall_s" => rec.wall_s = num(val)?,
                    _ => {} // forward compatibility: unknown keys skip
                }
                p.ws();
                match p.next()? {
                    b',' => continue,
                    b'}' => break,
                    c => return Err(format!("expected ',' or '}}', got {:?}", c as char)),
                }
            }
        }
        p.ws();
        if p.i != p.b.len() {
            return Err("trailing garbage after the bench object".to_string());
        }
        if !schema_seen {
            return Err(format!("missing \"schema\":\"{SCHEMA}\" tag"));
        }
        Ok(rec)
    }

    /// Derive the training-shaped subset from a convergence trace.
    pub fn from_convergence(trace: &ConvergenceTrace) -> BenchRecord {
        let mut rec = BenchRecord::new("convergence-csv");
        rec.epochs = Some(trace.len() as f64);
        rec.gap = trace.last_gap();
        rec.wall_s = trace.points.last().map(|p| p.wall_s);
        rec
    }

    /// Derive the training-shaped subset from a run-record CSV.
    pub fn from_run_record(record: &RunRecord) -> BenchRecord {
        let mut rec = BenchRecord::new("train-csv");
        rec.epochs = Some(record.epochs_run() as f64);
        rec.gap = record.epochs.iter().rev().find_map(|e| e.gap);
        rec.wall_s = Some(record.epochs.iter().map(|e| e.wall_s).sum());
        rec
    }

    /// Derive the decision-count subset from an auto-tuner log.
    pub fn from_tune_log(log: &crate::solver::TuneLog) -> BenchRecord {
        let mut rec = BenchRecord::new("tune-log");
        rec.decisions = Some(log.decisions.len() as f64);
        rec
    }

    /// Load any supported artifact: bench JSON, convergence-trace CSV,
    /// run-record CSV or tune-log CSV, sniffed by content, with the file
    /// named in errors.
    pub fn load(path: &Path) -> Result<BenchRecord, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let in_file = |msg: String| format!("{}: {msg}", path.display());
        if text.trim_start().starts_with('{') {
            return BenchRecord::from_json(&text).map_err(in_file);
        }
        if text.starts_with(crate::solver::tune::TUNE_LOG_MAGIC) {
            return crate::solver::TuneLog::from_csv(&text)
                .map(|l| BenchRecord::from_tune_log(&l))
                .ok_or_else(|| in_file("malformed tune-log csv".to_string()));
        }
        match text.lines().next() {
            Some(ConvergenceTrace::CSV_HEADER) => ConvergenceTrace::from_csv(&text)
                .map(|t| BenchRecord::from_convergence(&t))
                .ok_or_else(|| in_file("malformed convergence-trace csv".to_string())),
            Some(RunRecord::CSV_HEADER) => RunRecord::from_csv(&text)
                .map(|r| BenchRecord::from_run_record(&r))
                .ok_or_else(|| in_file("malformed run-record csv".to_string())),
            _ => Err(in_file(
                "not a bench json, convergence-trace csv, run-record csv or tune-log csv"
                    .to_string(),
            )),
        }
    }

    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// One metric that moved past the threshold.
#[derive(Clone, Debug)]
pub struct Regression {
    pub metric: &'static str,
    pub baseline: f64,
    pub current: f64,
    /// Worseness ratio, normalized so > 1 is always worse (inverted for
    /// throughput, where lower is worse).
    pub ratio: f64,
}

/// Diff `current` against `baseline`: any metric present and positive on
/// both sides whose worseness ratio exceeds `threshold` is a regression;
/// a healthy→degraded flip always is. `threshold` is a ratio (e.g. `1.5`
/// = "50% worse fails") — CI uses a deliberately loose one so shared-
/// runner variance cannot flake the gate.
pub fn compare(baseline: &BenchRecord, current: &BenchRecord, threshold: f64) -> Vec<Regression> {
    let mut out = Vec::new();
    {
        let mut check = |metric: &'static str, b: Option<f64>, c: Option<f64>, higher_worse: bool| {
            let (Some(b), Some(c)) = (b, c) else { return };
            if !(b.is_finite() && c.is_finite() && b > 0.0 && c > 0.0) {
                return;
            }
            let ratio = if higher_worse { c / b } else { b / c };
            if ratio > threshold {
                out.push(Regression { metric, baseline: b, current: c, ratio });
            }
        };
        check("throughput_rps", baseline.throughput_rps, current.throughput_rps, false);
        check("p50_ms", baseline.p50_ms, current.p50_ms, true);
        check("p99_ms", baseline.p99_ms, current.p99_ms, true);
        check("epochs", baseline.epochs, current.epochs, true);
        check("decisions", baseline.decisions, current.decisions, true);
        check("gap", baseline.gap, current.gap, true);
        check("wall_s", baseline.wall_s, current.wall_s, true);
    }
    if baseline.healthy && !current.healthy {
        out.push(Regression {
            metric: "healthy",
            baseline: 1.0,
            current: 0.0,
            ratio: f64::INFINITY,
        });
    }
    out.sort_by(|a, b| b.ratio.total_cmp(&a.ratio));
    out
}

/// Human-readable side-by-side table of every metric both records carry,
/// with the worseness ratio and a verdict column.
pub fn render_comparison(
    baseline: &BenchRecord,
    current: &BenchRecord,
    threshold: f64,
) -> String {
    let regressions = compare(baseline, current, threshold);
    let mut t = Table::new(&["metric", "baseline", "current", "worse x", "verdict"]);
    let rows: [(&str, Option<f64>, Option<f64>, bool); 7] = [
        ("throughput_rps", baseline.throughput_rps, current.throughput_rps, false),
        ("p50_ms", baseline.p50_ms, current.p50_ms, true),
        ("p99_ms", baseline.p99_ms, current.p99_ms, true),
        ("epochs", baseline.epochs, current.epochs, true),
        ("decisions", baseline.decisions, current.decisions, true),
        ("gap", baseline.gap, current.gap, true),
        ("wall_s", baseline.wall_s, current.wall_s, true),
    ];
    let cell = |x: Option<f64>| x.map(|v| format!("{v:.4}")).unwrap_or_else(|| "-".to_string());
    for (metric, b, c, higher_worse) in rows {
        let ratio = match (b, c) {
            (Some(b), Some(c)) if b > 0.0 && c > 0.0 => {
                Some(if higher_worse { c / b } else { b / c })
            }
            _ => None,
        };
        let verdict = if regressions.iter().any(|r| r.metric == metric) {
            "REGRESSED"
        } else if ratio.is_some() {
            "ok"
        } else {
            "-"
        };
        t.row(&[
            metric.to_string(),
            cell(b),
            cell(c),
            cell(ratio),
            verdict.to_string(),
        ]);
    }
    let health_verdict = if baseline.healthy && !current.healthy { "REGRESSED" } else { "ok" };
    t.row(&[
        "healthy".to_string(),
        baseline.healthy.to_string(),
        current.healthy.to_string(),
        "-".to_string(),
        health_verdict.to_string(),
    ]);
    format!(
        "baseline: {} | current: {} | threshold: {threshold}x\n{}",
        baseline.kind, current.kind, t.render()
    )
}

/// Minimal JSON string escaping for the writer (the reader undoes it).
fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[derive(Debug)]
enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Null,
}

/// Byte-walking parser for the flat bench object (the full recursive
/// dialect lives in `examples/check_trace.rs`; this one only needs
/// scalars).
struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn next(&mut self) -> Result<u8, String> {
        let c = self.peek().ok_or("unexpected end of input")?;
        self.i += 1;
        Ok(c)
    }

    fn eat(&mut self, want: u8) -> Result<(), String> {
        match self.next()? {
            c if c == want => Ok(()),
            c => Err(format!("expected {:?}, got {:?}", want as char, c as char)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.next()? {
                b'"' => return Ok(out),
                b'\\' => match self.next()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    c => return Err(format!("unsupported escape \\{}", c as char)),
                },
                c => out.push(c as char),
            }
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek().ok_or("unexpected end of input")? {
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true").map(|_| Value::Bool(true)),
            b'f' => self.literal("false").map(|_| Value::Bool(false)),
            b'n' => self.literal("null").map(|_| Value::Null),
            b'-' | b'0'..=b'9' => self.number().map(Value::Num),
            c => Err(format!("unexpected value start {:?}", c as char)),
        }
    }

    fn literal(&mut self, word: &str) -> Result<(), String> {
        for &w in word.as_bytes() {
            self.eat(w)?;
        }
        Ok(())
    }

    fn number(&mut self) -> Result<f64, String> {
        let start = self.i;
        while self
            .peek()
            .is_some_and(|c| matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| "malformed number".to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn serve_record() -> BenchRecord {
        let mut r = BenchRecord::new("serve-open-loop");
        r.throughput_rps = Some(900.0);
        r.p50_ms = Some(1.5);
        r.p99_ms = Some(4.0);
        r.epochs = Some(40.0);
        r.gap = Some(1e-4);
        r.wall_s = Some(2.5);
        r
    }

    #[test]
    fn json_roundtrips_including_null_metrics() {
        let mut r = serve_record();
        r.p99_ms = None;
        r.healthy = false;
        let json = r.to_json();
        assert!(json.contains("\"schema\":\"parlin-bench-v1\""));
        assert!(json.contains("\"p99_ms\":null"));
        let back = BenchRecord::from_json(&json).expect("own output must parse");
        assert_eq!(back, r);
    }

    #[test]
    fn non_finite_metrics_serialize_as_null() {
        let mut r = BenchRecord::new("serve");
        r.gap = Some(f64::NAN);
        r.wall_s = Some(f64::INFINITY);
        let back = BenchRecord::from_json(&r.to_json()).unwrap();
        assert_eq!(back.gap, None);
        assert_eq!(back.wall_s, None);
    }

    #[test]
    fn parser_rejects_garbage_and_wrong_schema() {
        assert!(BenchRecord::from_json("").is_err());
        assert!(BenchRecord::from_json("{}").is_err(), "schema tag is required");
        assert!(BenchRecord::from_json("{\"schema\":\"parlin-bench-v9\"}").is_err());
        let good = serve_record().to_json();
        assert!(BenchRecord::from_json(&format!("{good}x")).is_err(), "trailing garbage");
        assert!(BenchRecord::from_json("{\"schema\":\"parlin-bench-v1\",\"epochs\":\"40\"}")
            .is_err());
    }

    #[test]
    fn unknown_keys_are_tolerated() {
        let json = "{\"schema\":\"parlin-bench-v1\",\"kind\":\"serve\",\
                    \"future_metric\":1.25,\"note\":\"hi\",\"epochs\":7}";
        let r = BenchRecord::from_json(json).expect("unknown keys must not fail");
        assert_eq!(r.epochs, Some(7.0));
    }

    #[test]
    fn compare_flags_each_direction_correctly() {
        let base = serve_record();
        let mut cur = serve_record();
        assert!(compare(&base, &cur, 1.5).is_empty(), "identical runs never regress");

        cur.p99_ms = Some(base.p99_ms.unwrap() * 2.0); // higher is worse
        cur.throughput_rps = Some(base.throughput_rps.unwrap() / 3.0); // lower is worse
        let regs = compare(&base, &cur, 1.5);
        let metrics: Vec<_> = regs.iter().map(|r| r.metric).collect();
        assert!(metrics.contains(&"p99_ms"), "{metrics:?}");
        assert!(metrics.contains(&"throughput_rps"), "{metrics:?}");
        assert_eq!(regs[0].metric, "throughput_rps", "sorted worst-first: {metrics:?}");

        // better-than-baseline never flags
        cur = serve_record();
        cur.p99_ms = Some(0.1);
        cur.throughput_rps = Some(9000.0);
        assert!(compare(&base, &cur, 1.5).is_empty());
    }

    #[test]
    fn health_flip_is_always_a_regression() {
        let base = serve_record();
        let mut cur = serve_record();
        cur.healthy = false;
        let regs = compare(&base, &cur, 1000.0);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "healthy");
    }

    #[test]
    fn metrics_missing_on_either_side_are_skipped() {
        let mut base = serve_record();
        base.p99_ms = None;
        let mut cur = serve_record();
        cur.p99_ms = Some(1e9);
        assert!(compare(&base, &cur, 1.5).is_empty(), "no baseline → no verdict");
    }

    #[test]
    fn loads_convergence_and_run_record_csvs() {
        let dir = std::env::temp_dir();
        let mut trace = ConvergenceTrace::new("seq(bucket=4)", 1);
        trace.record(1, 0.5, 0.9, None, None, None);
        trace.record(2, 0.5, 0.1, Some(1e-3), None, None);
        let conv_path = dir.join(format!("parlin-report-conv-{}.csv", std::process::id()));
        trace.write_csv(&conv_path).unwrap();
        let rec = BenchRecord::load(&conv_path).expect("convergence csv loads");
        assert_eq!(rec.kind, "convergence-csv");
        assert_eq!(rec.epochs, Some(2.0));
        assert_eq!(rec.gap, Some(1e-3));
        assert_eq!(rec.wall_s, Some(1.0));
        let _ = std::fs::remove_file(&conv_path);

        let csv = format!("{}\nseq,1,1,5.000000e-1,1.000000e-1,1.000000e-3,\n", RunRecord::CSV_HEADER);
        let run_path = dir.join(format!("parlin-report-run-{}.csv", std::process::id()));
        std::fs::write(&run_path, csv).unwrap();
        let rec = BenchRecord::load(&run_path).expect("run-record csv loads");
        assert_eq!(rec.kind, "train-csv");
        assert_eq!(rec.epochs, Some(1.0));
        assert_eq!(rec.gap, Some(1e-3));
        let _ = std::fs::remove_file(&run_path);
    }

    #[test]
    fn loads_tune_log_csv_as_decision_count() {
        use crate::solver::{Knob, TuneCaps, TuneDecision, TuneInit, TuneLog};
        let log = TuneLog {
            solver: "dom".to_string(),
            init: TuneInit::new(7, TuneCaps { bucket: true, layout: true, workers: true })
                .with_knobs(64, false, 2, false),
            decisions: vec![TuneDecision {
                epoch: 8,
                knob: Knob::Layout,
                from: "csc".to_string(),
                to: "interleaved".to_string(),
                reason: "probe".to_string(),
            }],
        };
        let path =
            std::env::temp_dir().join(format!("parlin-report-tune-{}.csv", std::process::id()));
        log.write_csv(&path).unwrap();
        let rec = BenchRecord::load(&path).expect("tune-log csv loads");
        assert_eq!(rec.kind, "tune-log");
        assert_eq!(rec.decisions, Some(1.0));
        let _ = std::fs::remove_file(&path);
        // the decision count rides the bench-json round trip too
        let back = BenchRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(back.decisions, Some(1.0));
        // and diffs like any higher-is-worse metric
        let mut cur = rec.clone();
        cur.decisions = Some(9.0);
        let regs = compare(&rec, &cur, 1.5);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].metric, "decisions");
    }

    #[test]
    fn comparison_renders_a_table_with_verdicts() {
        let base = serve_record();
        let mut cur = serve_record();
        cur.p99_ms = Some(100.0);
        let text = render_comparison(&base, &cur, 1.5);
        assert!(text.contains("REGRESSED"), "{text}");
        assert!(text.contains("throughput_rps"), "{text}");
        let ok_rows = text.lines().filter(|l| l.trim_end().ends_with(" ok")).count();
        assert!(ok_rows >= 5, "{text}");
    }
}
