//! System-aware online auto-tuning of the solver's systems knobs.
//!
//! The source paper resolves its core tension — systems optimizations
//! (bucket size, cache layout, thread count) speed up epochs but can cost
//! convergence — by *measuring* both sides; the SySCD follow-on makes the
//! knobs self-tuning at runtime. This module is that loop: an
//! [`AutoTuner`] that rides the instrumentation the epoch loops already
//! emit (the per-epoch [`ConvergencePoint`] — wall time, rel-change,
//! pool imbalance; **zero new clock reads**) and, at epoch boundaries
//! only, adapts
//!
//! * **bucket size** (only under `BucketPolicy::Auto`) via a bounded
//!   hill-climb on the power-of-two ladder,
//! * **layout** interleaved ↔ csc — bit-wise *free* to switch, because
//!   both encodings route every dot product through [`crate::util::dot4_by`]
//!   (locked by `rust/tests/pool_equivalence.rs` and `rust/tests/tune.rs`),
//! * **work stealing / effective worker count** when the pool's measured
//!   busy imbalance (max/mean) is materially above 1.
//!
//! # Determinism contract
//!
//! With [`TunePolicy::Off`] (the default) no tuner is constructed and the
//! epoch loops are bit-for-bit the pre-tuner code paths. With
//! [`TunePolicy::On`], every decision is a **pure function** of the
//! fixed-size observation window (disjoint windows of
//! [`TuneInit::window`] epochs) plus the seed: no clock is read, no
//! global state is consulted, and the only randomness is a seeded
//! [`Rng`] draw for the initial hill-climb direction. The full decision
//! list is recorded as a [`TuneLog`] stamped on
//! `TrainOutput`/`RefitReport`, exported by `--tune-log`, and replayable:
//! feeding the run's own `ConvergenceTrace` back through
//! [`AutoTuner::replay`] reproduces the log byte-for-byte (locked by a
//! property test and `examples/check_tune.rs`).
//!
//! Applied decisions tick the repo's first *labelled* metric,
//! `tuner.decisions` with a `knob` label — rendered by the Prometheus
//! exposition as `parlin_tuner_decisions{knob="layout"}` etc.
//!
//! This module also owns the cooperative [`CancelToken`] checked once per
//! epoch by every solver: it shares the epoch-boundary-only philosophy
//! (never interrupt mid-bucket, unwind only at a checkpoint) and lets the
//! serve scheduler's drain watchdog force-recover a stuck refit instead
//! of merely reporting it.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::metrics::{csv_field, split_csv_row};
use crate::obs::ConvergencePoint;
use crate::util::Rng;

/// Whether a run auto-tunes its systems knobs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TunePolicy {
    /// No tuner is constructed; the epoch loops behave bit-for-bit as if
    /// this module did not exist.
    #[default]
    Off,
    /// Tune online. `seed` feeds the tuner's private [`Rng`]; same seed +
    /// same observation stream ⇒ byte-identical decisions.
    On { seed: u64 },
}

/// Cooperative cancellation flag checked once per epoch by every solver.
///
/// Cancellation unwinds via [`std::panic::panic_any`] with a
/// [`TrainCancelled`] payload — the same mechanism the fault harness uses
/// for injected faults — so `serve::Session::guarded` catches it, rolls
/// the session back to its checkpoint, and classifies it as the typed
/// `ServeError::Cancelled` instead of a generic panic.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        CancelToken::default()
    }

    /// Request cancellation; the next epoch-boundary checkpoint unwinds.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    /// Clear a previous request (e.g. before a drain retry attempt).
    pub fn reset(&self) {
        self.0.store(false, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }

    /// The once-per-epoch checkpoint: unwinds with a [`TrainCancelled`]
    /// payload when cancellation was requested, otherwise a single
    /// relaxed-ish atomic load.
    pub fn checkpoint(&self, solver: &str, epoch: usize) {
        if self.is_cancelled() {
            std::panic::panic_any(TrainCancelled { solver: solver.to_string(), epoch });
        }
    }
}

/// Two tokens are equal when they share the same flag (clone-of), which
/// is the only notion of equality a cancellation handle needs.
impl PartialEq for CancelToken {
    fn eq(&self, other: &Self) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }
}

/// Panic payload carried by a cooperative cancellation unwind.
#[derive(Clone, Debug)]
pub struct TrainCancelled {
    /// Solver label at the moment of cancellation.
    pub solver: String,
    /// Epoch whose boundary checkpoint observed the request (1-based).
    pub epoch: usize,
}

/// Which knobs a given solver lets the tuner touch. Capabilities are a
/// property of the (solver, config) pair: e.g. bucket adaptation needs
/// `BucketPolicy::Auto`, worker adaptation needs a pool that reports
/// imbalance, and `wild`/`numa` pin their bucketing by construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TuneCaps {
    pub bucket: bool,
    pub layout: bool,
    pub workers: bool,
}

impl TuneCaps {
    pub const NONE: TuneCaps = TuneCaps { bucket: false, layout: false, workers: false };

    fn encode(&self) -> String {
        let mut parts = Vec::new();
        if self.bucket {
            parts.push("bucket");
        }
        if self.layout {
            parts.push("layout");
        }
        if self.workers {
            parts.push("workers");
        }
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join(",")
        }
    }

    fn decode(s: &str) -> Option<TuneCaps> {
        let mut caps = TuneCaps::NONE;
        if s == "none" {
            return Some(caps);
        }
        for part in s.split(',') {
            match part {
                "bucket" => caps.bucket = true,
                "layout" => caps.layout = true,
                "workers" => caps.workers = true,
                _ => return None,
            }
        }
        Some(caps)
    }
}

/// Everything needed to reconstruct a tuner for replay: the seed, the
/// capability set, the observation window, and the knobs' starting
/// values. Serialized into the [`TuneLog`] header.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TuneInit {
    pub seed: u64,
    /// Observation window in epochs; decisions happen only when a full
    /// disjoint window has been observed.
    pub window: usize,
    pub caps: TuneCaps,
    /// Starting bucket size.
    pub bucket: usize,
    /// Starting layout: `true` = interleaved shards, `false` = csc.
    pub interleaved: bool,
    /// Starting effective worker count.
    pub workers: usize,
    /// Starting partitioning: `true` = dynamic (work stealing already on).
    pub dynamic: bool,
}

/// Default observation window: four epochs per decision boundary —
/// enough samples to smooth scheduler noise, short enough to adapt
/// within a typical run.
pub const TUNE_WINDOW: usize = 4;

impl TuneInit {
    pub fn new(seed: u64, caps: TuneCaps) -> TuneInit {
        TuneInit {
            seed,
            window: TUNE_WINDOW,
            caps,
            bucket: 1,
            interleaved: true,
            workers: 1,
            dynamic: false,
        }
    }

    pub fn with_knobs(mut self, bucket: usize, interleaved: bool, workers: usize, dynamic: bool) -> TuneInit {
        self.bucket = bucket;
        self.interleaved = interleaved;
        self.workers = workers;
        self.dynamic = dynamic;
        self
    }
}

/// The knob a [`TuneDecision`] moved. Doubles as the value vocabulary of
/// the `knob` label on the `tuner.decisions` metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Knob {
    /// Bucket size stepped on the power-of-two ladder.
    Bucket,
    /// Layout flipped interleaved ↔ csc (bit-wise free).
    Layout,
    /// Effective worker count reduced.
    Workers,
    /// Static partitioning upgraded to dynamic work stealing.
    Steal,
}

impl Knob {
    pub fn name(self) -> &'static str {
        match self {
            Knob::Bucket => "bucket",
            Knob::Layout => "layout",
            Knob::Workers => "workers",
            Knob::Steal => "steal",
        }
    }

    pub fn parse(s: &str) -> Option<Knob> {
        match s {
            "bucket" => Some(Knob::Bucket),
            "layout" => Some(Knob::Layout),
            "workers" => Some(Knob::Workers),
            "steal" => Some(Knob::Steal),
            _ => None,
        }
    }
}

/// One applied knob change, recorded at an epoch boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TuneDecision {
    /// Epoch whose boundary produced the decision (the change takes
    /// effect from epoch + 1).
    pub epoch: usize,
    pub knob: Knob,
    pub from: String,
    pub to: String,
    /// Human-readable rationale; deterministic for a given trace.
    pub reason: String,
}

const LAYOUT_NAMES: [&str; 2] = ["csc", "interleaved"];

fn layout_name(interleaved: bool) -> &'static str {
    LAYOUT_NAMES[interleaved as usize]
}

/// Layout probe state machine: probe the alternative encoding once, keep
/// whichever window was faster, re-probe only on drift.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Probe {
    Idle,
    Armed { baseline: f64 },
    Settled,
}

/// Bucket hill-climb state: at most [`AutoTuner::MAX_BUCKET_MOVES`]
/// steps, reverting the last step (and stopping) on a regression.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Climb {
    Idle,
    Climbing(i8),
    Done,
}

/// The online tuner. Feed it every recorded [`ConvergencePoint`] via
/// [`AutoTuner::observe`]; it returns the (usually empty) decision list
/// for that epoch's boundary. Decisions are pure: same `TuneInit` + same
/// point stream ⇒ same decisions, which is what makes the [`TuneLog`]
/// replayable after the fact.
#[derive(Clone, Debug)]
pub struct AutoTuner {
    solver: String,
    init: TuneInit,
    rng: Rng,
    // Current knob values (start at the TuneInit values).
    bucket: usize,
    interleaved: bool,
    workers: usize,
    dynamic: bool,
    // Window accumulation.
    win_points: usize,
    win_wall: f64,
    win_imb_sum: f64,
    win_imb_n: usize,
    win_reverted: bool,
    last_cum_wall: f64,
    // Cross-window trackers.
    prev_mean: Option<f64>,
    best_mean: f64,
    probe: Probe,
    climb: Climb,
    bucket_moves: usize,
    decisions: Vec<TuneDecision>,
}

impl AutoTuner {
    /// Total bucket-ladder steps allowed per run; bounds the numeric
    /// perturbation the tuner can introduce.
    pub const MAX_BUCKET_MOVES: usize = 4;
    /// Bucket sizes stay within this ladder.
    pub const MAX_BUCKET: usize = 1024;
    /// Window-mean imbalance above this enables work stealing.
    pub const STEAL_IMBALANCE: f64 = 1.25;
    /// Window-mean imbalance above this (with stealing already on)
    /// retires one effective worker.
    pub const SHRINK_IMBALANCE: f64 = 1.5;

    pub fn new(solver: impl Into<String>, init: TuneInit) -> AutoTuner {
        let rng = Rng::new(init.seed);
        AutoTuner {
            solver: solver.into(),
            bucket: init.bucket,
            interleaved: init.interleaved,
            workers: init.workers,
            dynamic: init.dynamic,
            rng,
            win_points: 0,
            win_wall: 0.0,
            win_imb_sum: 0.0,
            win_imb_n: 0,
            win_reverted: false,
            last_cum_wall: 0.0,
            prev_mean: None,
            best_mean: f64::INFINITY,
            probe: Probe::Idle,
            climb: Climb::Idle,
            bucket_moves: 0,
            decisions: Vec::new(),
            init,
        }
    }

    /// Observe one recorded epoch. Returns the decisions made at this
    /// boundary (empty unless the observation window just filled).
    pub fn observe(&mut self, p: &ConvergencePoint) -> Vec<TuneDecision> {
        // The trace stores cumulative wall clock; diff it back to the
        // per-epoch time the solver measured. No new clock read.
        let epoch_wall = (p.wall_s - self.last_cum_wall).max(0.0);
        self.last_cum_wall = p.wall_s;
        self.win_wall += epoch_wall;
        if let Some(i) = p.imbalance {
            self.win_imb_sum += i;
            self.win_imb_n += 1;
        }
        if p.rel_change.is_infinite() {
            self.win_reverted = true;
        }
        self.win_points += 1;
        if self.win_points < self.init.window.max(1) {
            return Vec::new();
        }
        let out = self.decide(p.epoch);
        self.win_points = 0;
        self.win_wall = 0.0;
        self.win_imb_sum = 0.0;
        self.win_imb_n = 0;
        self.win_reverted = false;
        out
    }

    /// Pure boundary logic over the just-closed window's aggregates.
    fn decide(&mut self, epoch: usize) -> Vec<TuneDecision> {
        let window = self.init.window.max(1) as f64;
        let mean = self.win_wall / window;
        let imbalance =
            (self.win_imb_n > 0).then(|| self.win_imb_sum / self.win_imb_n as f64);
        let reverted = self.win_reverted;
        let mut out = Vec::new();

        // (1) Layout: probe the alternative encoding once, keep the
        // faster window, re-probe only when the settled layout drifts
        // 50% past the best window ever seen. Switching is bit-free, so
        // this knob never perturbs numerics.
        if self.init.caps.layout {
            match self.probe {
                Probe::Idle => {
                    out.push(self.flip_layout(
                        epoch,
                        format!("probe alternative layout (baseline {:.3}ms/epoch)", mean * 1e3),
                    ));
                    self.probe = Probe::Armed { baseline: mean };
                }
                Probe::Armed { baseline } => {
                    if mean > baseline {
                        out.push(self.flip_layout(
                            epoch,
                            format!(
                                "probe lost: {:.3}ms/epoch vs baseline {:.3}ms/epoch",
                                mean * 1e3,
                                baseline * 1e3
                            ),
                        ));
                    }
                    self.probe = Probe::Settled;
                }
                Probe::Settled => {
                    if mean > 1.5 * self.best_mean && self.best_mean.is_finite() {
                        out.push(self.flip_layout(
                            epoch,
                            format!(
                                "drift: {:.3}ms/epoch vs best {:.3}ms/epoch, re-probing",
                                mean * 1e3,
                                self.best_mean * 1e3
                            ),
                        ));
                        self.probe = Probe::Armed { baseline: mean };
                    }
                }
            }
        }

        // (2) Bucket: bounded hill-climb on the power-of-two ladder,
        // only once the layout probe has settled (so the two knobs'
        // effects are not confounded) and never off the back of a window
        // containing a reverted (adaptive-σ backtracked) epoch.
        let layout_quiet = !self.init.caps.layout || self.probe == Probe::Settled;
        if self.init.caps.bucket
            && layout_quiet
            && !reverted
            && self.bucket_moves < Self::MAX_BUCKET_MOVES
        {
            if let Some(prev) = self.prev_mean {
                match self.climb {
                    Climb::Idle => {
                        if mean > prev * 1.05 {
                            // Seeded initial direction: the one rng draw.
                            let dir: i8 = if self.rng.next_u64() & 1 == 0 { 1 } else { -1 };
                            if let Some(d) = self.step_bucket(epoch, dir, mean, prev) {
                                out.push(d);
                                self.climb = Climb::Climbing(dir);
                            } else {
                                self.climb = Climb::Done;
                            }
                        }
                    }
                    Climb::Climbing(dir) => {
                        if mean <= prev * 0.95 {
                            // Still improving: take another step.
                            if let Some(d) = self.step_bucket(epoch, dir, mean, prev) {
                                out.push(d);
                            } else {
                                self.climb = Climb::Done;
                            }
                        } else if mean > prev * 1.05 {
                            // Regressed: revert the last step, stop.
                            if let Some(d) = self.step_bucket(epoch, -dir, mean, prev) {
                                out.push(d);
                            }
                            self.climb = Climb::Done;
                        } else {
                            // Flat: keep what we have.
                            self.climb = Climb::Done;
                        }
                    }
                    Climb::Done => {}
                }
            }
        }

        // (3) Workers: measured busy imbalance materially above 1 first
        // turns on work stealing, then — if stealing cannot fix it —
        // retires one effective worker per boundary. Skipped on reverted
        // windows (numerics already unstable there).
        if self.init.caps.workers && !reverted {
            if let Some(imb) = imbalance {
                if imb > Self::STEAL_IMBALANCE && !self.dynamic {
                    out.push(TuneDecision {
                        epoch,
                        knob: Knob::Steal,
                        from: "static".to_string(),
                        to: "dynamic".to_string(),
                        reason: format!(
                            "imbalance {:.3} > {:.2}: enable work stealing",
                            imb,
                            Self::STEAL_IMBALANCE
                        ),
                    });
                    self.dynamic = true;
                } else if imb > Self::SHRINK_IMBALANCE && self.dynamic && self.workers > 1 {
                    let to = self.workers - 1;
                    out.push(TuneDecision {
                        epoch,
                        knob: Knob::Workers,
                        from: self.workers.to_string(),
                        to: to.to_string(),
                        reason: format!(
                            "imbalance {:.3} > {:.2} despite stealing: retire one worker",
                            imb,
                            Self::SHRINK_IMBALANCE
                        ),
                    });
                    self.workers = to;
                }
            }
        }

        self.prev_mean = Some(mean);
        if mean < self.best_mean {
            self.best_mean = mean;
        }
        self.decisions.extend(out.iter().cloned());
        out
    }

    fn flip_layout(&mut self, epoch: usize, reason: String) -> TuneDecision {
        let from = layout_name(self.interleaved);
        self.interleaved = !self.interleaved;
        TuneDecision {
            epoch,
            knob: Knob::Layout,
            from: from.to_string(),
            to: layout_name(self.interleaved).to_string(),
            reason,
        }
    }

    /// One ladder step; `None` when clamped at an edge (no decision).
    fn step_bucket(&mut self, epoch: usize, dir: i8, mean: f64, prev: f64) -> Option<TuneDecision> {
        let next = if dir > 0 {
            (self.bucket.saturating_mul(2)).min(Self::MAX_BUCKET)
        } else {
            (self.bucket / 2).max(1)
        };
        if next == self.bucket {
            return None;
        }
        let d = TuneDecision {
            epoch,
            knob: Knob::Bucket,
            from: self.bucket.to_string(),
            to: next.to_string(),
            reason: format!("epoch wall {:.3}ms vs prev {:.3}ms", mean * 1e3, prev * 1e3),
        };
        self.bucket = next;
        self.bucket_moves += 1;
        Some(d)
    }

    /// Finish the run: the full, replayable decision log.
    pub fn into_log(self) -> TuneLog {
        TuneLog { solver: self.solver, init: self.init, decisions: self.decisions }
    }

    /// Replay a recorded observation stream through a fresh tuner. Pure:
    /// same `init` + same points ⇒ the very decisions the live tuner
    /// made (the points already reflect every applied decision, so no
    /// solver simulation is needed).
    pub fn replay(solver: &str, init: &TuneInit, points: &[ConvergencePoint]) -> TuneLog {
        let mut t = AutoTuner::new(solver, init.clone());
        for p in points {
            t.observe(p);
        }
        t.into_log()
    }
}

/// Tick the labelled `tuner.decisions` metric for each applied decision.
/// Kept out of [`AutoTuner::observe`] so replays never double-count.
pub fn record_decision_metrics(decisions: &[TuneDecision]) {
    for d in decisions {
        crate::obs::registry()
            .labelled_counter("tuner.decisions", &[("knob", d.knob.name())])
            .inc();
    }
}

/// What an epoch loop holds: a live [`AutoTuner`] under
/// [`TunePolicy::On`], nothing under `Off`. Keeps the per-solver wiring
/// to three calls (`for_run` / `observe` / `finish`) and guarantees the
/// `Off` path allocates and computes nothing.
#[derive(Debug)]
pub(crate) struct EpochTuner {
    inner: Option<AutoTuner>,
}

impl EpochTuner {
    pub(crate) fn for_run(
        policy: TunePolicy,
        caps: TuneCaps,
        solver: &str,
        bucket: usize,
        interleaved: bool,
        workers: usize,
        dynamic: bool,
    ) -> EpochTuner {
        let inner = match policy {
            TunePolicy::Off => None,
            TunePolicy::On { seed } => Some(AutoTuner::new(
                solver,
                TuneInit::new(seed, caps).with_knobs(bucket, interleaved, workers, dynamic),
            )),
        };
        EpochTuner { inner }
    }

    /// Feed the point the epoch loop just recorded; applied decisions are
    /// returned for the solver to act on and ticked on the labelled
    /// `tuner.decisions` metric.
    pub(crate) fn observe(&mut self, p: &ConvergencePoint) -> Vec<TuneDecision> {
        match &mut self.inner {
            Some(t) => {
                let decisions = t.observe(p);
                record_decision_metrics(&decisions);
                decisions
            }
            None => Vec::new(),
        }
    }

    pub(crate) fn finish(self) -> Option<TuneLog> {
        self.inner.map(AutoTuner::into_log)
    }
}

/// First line of every serialized tune log.
pub const TUNE_LOG_MAGIC: &str = "# parlin-tune-v1";

const TUNE_LOG_COLUMNS: &str = "epoch,knob,from,to,reason";

/// A run's complete, replayable tuning record: the [`TuneInit`] (header)
/// plus every applied [`TuneDecision`] (CSV rows). `to_csv`/`from_csv`
/// round-trip byte-exactly, which is what "same seed + same trace ⇒
/// byte-identical log" means operationally.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TuneLog {
    pub solver: String,
    pub init: TuneInit,
    pub decisions: Vec<TuneDecision>,
}

impl TuneLog {
    pub fn to_csv(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "{} seed={} window={} caps={} bucket0={} layout0={} workers0={} partition0={} solver={}",
            TUNE_LOG_MAGIC,
            self.init.seed,
            self.init.window,
            self.init.caps.encode(),
            self.init.bucket,
            layout_name(self.init.interleaved),
            self.init.workers,
            if self.init.dynamic { "dynamic" } else { "static" },
            self.solver,
        );
        s.push_str(TUNE_LOG_COLUMNS);
        s.push('\n');
        for d in &self.decisions {
            let _ = writeln!(
                s,
                "{},{},{},{},{}",
                d.epoch,
                d.knob.name(),
                csv_field(&d.from),
                csv_field(&d.to),
                csv_field(&d.reason),
            );
        }
        s
    }

    /// Parse a [`TuneLog::to_csv`] dump back; `None` on a wrong magic,
    /// malformed header token, or bad row.
    pub fn from_csv(csv: &str) -> Option<TuneLog> {
        let mut lines = csv.lines();
        let head = lines.next()?;
        let rest = head.strip_prefix(TUNE_LOG_MAGIC)?.strip_prefix(' ')?;
        // `solver=` takes the rest of the line: labels like
        // `numa(2n,bucket=4)` must survive verbatim.
        let (kvs, solver) = rest.split_once("solver=")?;
        let mut init = TuneInit::new(0, TuneCaps::NONE);
        for tok in kvs.split_whitespace() {
            let (k, v) = tok.split_once('=')?;
            match k {
                "seed" => init.seed = v.parse().ok()?,
                "window" => init.window = v.parse().ok()?,
                "caps" => init.caps = TuneCaps::decode(v)?,
                "bucket0" => init.bucket = v.parse().ok()?,
                "layout0" => {
                    init.interleaved = match v {
                        "interleaved" => true,
                        "csc" => false,
                        _ => return None,
                    }
                }
                "workers0" => init.workers = v.parse().ok()?,
                "partition0" => {
                    init.dynamic = match v {
                        "dynamic" => true,
                        "static" => false,
                        _ => return None,
                    }
                }
                _ => return None,
            }
        }
        if lines.next()? != TUNE_LOG_COLUMNS {
            return None;
        }
        let mut decisions = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let cells = split_csv_row(line);
            if cells.len() != 5 {
                return None;
            }
            decisions.push(TuneDecision {
                epoch: cells[0].parse().ok()?,
                knob: Knob::parse(&cells[1])?,
                from: cells[2].clone(),
                to: cells[3].clone(),
                reason: cells[4].clone(),
            });
        }
        Some(TuneLog { solver: solver.to_string(), init, decisions })
    }

    /// Replay this log's own observation stream and check every decision
    /// matches; `Err` describes the first divergence. Used by the
    /// property suite and `examples/check_tune.rs`.
    pub fn verify_replay(&self, points: &[ConvergencePoint]) -> Result<(), String> {
        let replayed = AutoTuner::replay(&self.solver, &self.init, points);
        if replayed.decisions.len() != self.decisions.len() {
            return Err(format!(
                "decision count diverged: log has {}, replay produced {}",
                self.decisions.len(),
                replayed.decisions.len()
            ));
        }
        for (i, (a, b)) in self.decisions.iter().zip(&replayed.decisions).enumerate() {
            if a != b {
                return Err(format!("decision {i} diverged: log {a:?}, replay {b:?}"));
            }
        }
        Ok(())
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(
        epoch: usize,
        wall_s: f64,
        rel: f64,
        imbalance: Option<f64>,
    ) -> ConvergencePoint {
        ConvergencePoint { epoch, wall_s, rel_change: rel, gap: None, imbalance, busy_s: None }
    }

    /// Cumulative-wall trace where each window of 4 epochs has the given
    /// mean epoch wall (seconds).
    fn trace_with_window_means(means: &[f64], imbalance: Option<f64>) -> Vec<ConvergencePoint> {
        let mut points = Vec::new();
        let mut wall = 0.0;
        let mut epoch = 0;
        for &m in means {
            for _ in 0..TUNE_WINDOW {
                epoch += 1;
                wall += m;
                points.push(point(epoch, wall, 0.1, imbalance));
            }
        }
        points
    }

    fn layout_init(seed: u64) -> TuneInit {
        TuneInit::new(seed, TuneCaps { bucket: false, layout: true, workers: false })
            .with_knobs(8, true, 1, true)
    }

    #[test]
    fn cancel_token_cancels_resets_and_unwinds_with_typed_payload() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        t.checkpoint("seq(bucket=8)", 1); // no-op while not cancelled
        t.cancel();
        assert!(t.is_cancelled());
        assert!(t.clone().is_cancelled(), "clones share the flag");
        let err = std::panic::catch_unwind(|| t.checkpoint("seq(bucket=8)", 3))
            .expect_err("cancelled checkpoint must unwind");
        let payload = err.downcast_ref::<TrainCancelled>().expect("typed payload");
        assert_eq!(payload.epoch, 3);
        assert_eq!(payload.solver, "seq(bucket=8)");
        t.reset();
        assert!(!t.is_cancelled());
        t.checkpoint("seq(bucket=8)", 4); // runs again after reset
    }

    #[test]
    fn no_decisions_before_a_full_window() {
        let mut tuner = AutoTuner::new("seq", layout_init(7));
        for e in 1..TUNE_WINDOW {
            assert!(tuner.observe(&point(e, e as f64 * 0.01, 0.1, None)).is_empty());
        }
        let at_boundary =
            tuner.observe(&point(TUNE_WINDOW, TUNE_WINDOW as f64 * 0.01, 0.1, None));
        assert_eq!(at_boundary.len(), 1, "first boundary probes the layout");
        assert_eq!(at_boundary[0].knob, Knob::Layout);
        assert_eq!(at_boundary[0].epoch, TUNE_WINDOW);
    }

    #[test]
    fn layout_probe_switches_back_when_it_loses() {
        // Window 1 fast (baseline), window 2 (the probe) slower, window 3
        // steady: expect probe at epoch 4, revert at epoch 8, silence after.
        let points = trace_with_window_means(&[0.010, 0.020, 0.010], None);
        let log = AutoTuner::replay("seq", &layout_init(1), &points);
        assert_eq!(log.decisions.len(), 2);
        assert_eq!(log.decisions[0].epoch, 4);
        assert_eq!((log.decisions[0].from.as_str(), log.decisions[0].to.as_str()), ("interleaved", "csc"));
        assert_eq!(log.decisions[1].epoch, 8);
        assert_eq!((log.decisions[1].from.as_str(), log.decisions[1].to.as_str()), ("csc", "interleaved"));
        assert!(log.decisions[1].reason.contains("probe lost"));
    }

    #[test]
    fn layout_probe_keeps_a_winning_layout_silently() {
        // Probe window is faster: keep it, no second decision.
        let points = trace_with_window_means(&[0.020, 0.010, 0.010, 0.011], None);
        let log = AutoTuner::replay("seq", &layout_init(1), &points);
        assert_eq!(log.decisions.len(), 1, "only the probe itself is logged");
        assert_eq!(log.decisions[0].to, "csc");
    }

    #[test]
    fn caps_gate_which_knobs_can_move() {
        // Worst-case trace (slow, imbalanced) but with all caps off:
        // zero decisions, ever.
        let points = trace_with_window_means(&[0.01, 0.05, 0.2, 0.9], Some(3.0));
        let log = AutoTuner::replay("seq", &TuneInit::new(9, TuneCaps::NONE), &points);
        assert!(log.decisions.is_empty());
        // Layout-only caps: every decision is a layout flip.
        let log = AutoTuner::replay("seq", &layout_init(9), &points);
        assert!(!log.decisions.is_empty());
        assert!(log.decisions.iter().all(|d| d.knob == Knob::Layout));
    }

    #[test]
    fn imbalance_turns_on_stealing_then_retires_workers() {
        let init = TuneInit::new(3, TuneCaps { bucket: false, layout: false, workers: true })
            .with_knobs(8, true, 4, false);
        let points = trace_with_window_means(&[0.01, 0.01, 0.01], Some(2.0));
        let log = AutoTuner::replay("dom", &init, &points);
        assert_eq!(log.decisions[0].knob, Knob::Steal);
        assert_eq!(log.decisions[0].from, "static");
        assert_eq!(log.decisions[0].to, "dynamic");
        assert_eq!(log.decisions[1].knob, Knob::Workers);
        assert_eq!((log.decisions[1].from.as_str(), log.decisions[1].to.as_str()), ("4", "3"));
        assert_eq!(log.decisions[2].knob, Knob::Workers);
        assert_eq!((log.decisions[2].from.as_str(), log.decisions[2].to.as_str()), ("3", "2"));
    }

    #[test]
    fn balanced_pools_and_reverted_windows_leave_workers_alone() {
        let init = TuneInit::new(3, TuneCaps { bucket: false, layout: false, workers: true })
            .with_knobs(8, true, 4, false);
        let balanced = trace_with_window_means(&[0.01, 0.01], Some(1.05));
        assert!(AutoTuner::replay("dom", &init, &balanced).decisions.is_empty());
        // Same imbalance, but every window contains a reverted epoch.
        let mut reverted = trace_with_window_means(&[0.01, 0.01], Some(2.0));
        for p in reverted.iter_mut().step_by(TUNE_WINDOW) {
            p.rel_change = f64::INFINITY;
        }
        assert!(AutoTuner::replay("dom", &init, &reverted).decisions.is_empty());
    }

    #[test]
    fn bucket_climb_is_bounded_and_stops_on_regression() {
        let init = TuneInit::new(5, TuneCaps { bucket: true, layout: false, workers: false })
            .with_knobs(8, true, 1, true);
        // Monotonically degrading epochs force a climb start; whatever
        // direction the seed picks, total moves stay ≤ MAX_BUCKET_MOVES
        // and every value stays on the clamped ladder.
        let means: Vec<f64> = (0..10).map(|i| 0.01 * 1.2f64.powi(i)).collect();
        let log = AutoTuner::replay("seq", &init, &trace_with_window_means(&means, None));
        let bucket_moves: Vec<_> =
            log.decisions.iter().filter(|d| d.knob == Knob::Bucket).collect();
        assert!(!bucket_moves.is_empty(), "degrading trace must trigger the climb");
        assert!(bucket_moves.len() <= AutoTuner::MAX_BUCKET_MOVES);
        for d in &bucket_moves {
            let v: usize = d.to.parse().expect("ladder values are integers");
            assert!((1..=AutoTuner::MAX_BUCKET).contains(&v));
            assert!(v.is_power_of_two());
        }
    }

    #[test]
    fn same_seed_same_trace_is_byte_identical() {
        let points = trace_with_window_means(&[0.01, 0.03, 0.02, 0.05, 0.01], Some(1.8));
        let init = TuneInit::new(42, TuneCaps { bucket: true, layout: true, workers: true })
            .with_knobs(16, true, 4, false);
        let a = AutoTuner::replay("dom-dynamic(bucket=16)", &init, &points);
        let b = AutoTuner::replay("dom-dynamic(bucket=16)", &init, &points);
        assert_eq!(a, b);
        assert_eq!(a.to_csv(), b.to_csv(), "byte-identical serialization");
    }

    #[test]
    fn log_csv_round_trips_byte_exactly() {
        let init = TuneInit::new(7, TuneCaps { bucket: true, layout: true, workers: true })
            .with_knobs(8, false, 3, false);
        let log = TuneLog {
            solver: "numa(2n,bucket=4)".to_string(),
            init,
            decisions: vec![
                TuneDecision {
                    epoch: 4,
                    knob: Knob::Layout,
                    from: "csc".to_string(),
                    to: "interleaved".to_string(),
                    reason: "probe alternative layout (baseline 1.250ms/epoch)".to_string(),
                },
                TuneDecision {
                    epoch: 8,
                    knob: Knob::Steal,
                    from: "static".to_string(),
                    to: "dynamic".to_string(),
                    reason: "imbalance 1.900 > 1.25: enable work stealing".to_string(),
                },
            ],
        };
        let csv = log.to_csv();
        assert!(csv.starts_with(TUNE_LOG_MAGIC));
        assert!(csv.contains("solver=numa(2n,bucket=4)"), "comma labels survive the header");
        let back = TuneLog::from_csv(&csv).expect("own output must parse");
        assert_eq!(back, log);
        assert_eq!(back.to_csv(), csv, "round trip is byte-exact");
    }

    #[test]
    fn from_csv_rejects_garbage() {
        assert!(TuneLog::from_csv("").is_none());
        assert!(TuneLog::from_csv("epoch,knob,from,to,reason\n").is_none());
        assert!(TuneLog::from_csv("# parlin-tune-v2 seed=1 solver=seq\n").is_none());
        let bad_knob = format!(
            "{TUNE_LOG_MAGIC} seed=1 window=4 caps=layout bucket0=8 layout0=interleaved \
             workers0=1 partition0=static solver=seq\n{TUNE_LOG_COLUMNS}\n4,warp,a,b,c\n"
        );
        assert!(TuneLog::from_csv(&bad_knob).is_none());
        let bad_layout = format!(
            "{TUNE_LOG_MAGIC} seed=1 window=4 caps=layout bucket0=8 layout0=diagonal \
             workers0=1 partition0=static solver=seq\n{TUNE_LOG_COLUMNS}\n"
        );
        assert!(TuneLog::from_csv(&bad_layout).is_none());
    }

    #[test]
    fn verify_replay_reports_the_first_divergence() {
        let points = trace_with_window_means(&[0.01, 0.02, 0.01], None);
        let mut log = AutoTuner::replay("seq", &layout_init(11), &points);
        log.verify_replay(&points).expect("own trace must replay");
        log.decisions[0].to = "csc-but-wrong".to_string();
        let err = log.verify_replay(&points).expect_err("tampered log must fail");
        assert!(err.contains("decision 0"), "got: {err}");
    }
}
