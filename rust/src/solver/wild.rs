//! Algorithm 1 — the "wild" asynchronous multi-threaded SDCA baseline.
//!
//! Every epoch the shuffled coordinates are divided among the threads; each
//! thread reads the *single shared* vector `v` and writes its rank-1
//! updates back without any synchronization ("opportunistically, in a wild
//! fashion"). No two threads touch the same `α_j`, but `v` is racy: reads
//! are stale, and concurrent read-modify-writes can lose updates. That is
//! the behaviour whose convergence/efficiency collapse on dense data and
//! multiple NUMA nodes motivates the whole paper (§2, Fig. 1).
//!
//! Implementation notes: the race is expressed through [`AtomicF64`] with
//! relaxed separate load/store (defined behaviour, same lost-update
//! semantics). Physical thread counts above the host's cores timeslice;
//! convergence-vs-thread-count studies on this 1-core box use the
//! deterministic lockstep engine in [`crate::vthread`] instead.

use crate::data::shard::RunLayout;
use crate::data::{DataMatrix, Dataset, LayoutPolicy, ShardedLayout};
use crate::glm::ModelState;
use crate::metrics::{EpochStats, RunRecord};
use crate::obs::{self, EventKind};
use crate::solver::tune::{EpochTuner, Knob, TuneCaps};
use crate::solver::{kernel, Buckets, ConvergenceMonitor, SolverConfig, TrainOutput};
use crate::util::atomic::{atomic_vec, padded_atomic_vec, snapshot, AtomicF64, PaddedAtomicF64};
use crate::util::{Rng, Timer};

pub fn train_wild<M: DataMatrix>(ds: &Dataset<M>, cfg: &SolverConfig) -> TrainOutput {
    let n = ds.n();
    let t_threads = cfg.threads.max(1);
    let obj = cfg.obj;
    let inv_lambda_n = 1.0 / (obj.lambda() * n as f64);
    // Persistent workers (or spawn-per-epoch / sequential, per config) —
    // the racy shared-vector semantics are identical either way because
    // the races live in the AtomicF64 accesses, not in the dispatcher.
    let topo = cfg
        .topology
        .clone()
        .unwrap_or_else(crate::sysinfo::Topology::detect);
    let exec = cfg.build_executor(&topo);

    // Per-example interleaved stream: wild walks a flat shuffled
    // permutation, so the layout's win here is the single interleaved
    // read per visit plus one-ahead prefetch off the permutation. Any
    // caller-cached single shard over the same examples serves (bucket
    // geometry is irrelevant to a per-example walk). Shared vector `v`
    // is cache-line padded — adjacent coordinates no longer false-share
    // under the unsynchronized ADDs.
    let mut use_interleaved = cfg.layout == LayoutPolicy::Interleaved;
    let mut layout = RunLayout::resolve(
        use_interleaved,
        cfg.layout_cache.as_ref(),
        |l| l.covers_examples(n, ds.d(), ds.x.nnz()),
        || ShardedLayout::single(&ds.x, &Buckets::new(n, 1)),
    );
    let init = crate::solver::initial_state(cfg, ds);
    let alpha: Vec<AtomicF64> = atomic_vec(n);
    let v: Vec<PaddedAtomicF64> = padded_atomic_vec(ds.d());
    for (slot, &a) in alpha.iter().zip(init.alpha.iter()) {
        if a != 0.0 {
            slot.store(a);
        }
    }
    for (slot, &x) in v.iter().zip(init.v.iter()) {
        if x != 0.0 {
            slot.store(x);
        }
    }
    let mut perm: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::new(cfg.seed);
    let mut mon = ConvergenceMonitor::new(n, cfg.tol, cfg.divergence_factor);
    if cfg.warm_start.is_some() {
        mon.seed(&init.alpha);
    }

    let total = Timer::start();
    let mut epochs = Vec::new();
    let mut converged = false;
    let mut diverged = false;
    // per-epoch convergence telemetry: reuses rel/wall_s below, adds no
    // clock read of its own (wild never evaluates the duality gap)
    let mut conv = obs::ConvergenceTrace::new("wild", t_threads);
    // Wild pins its bucketing (per-example walk) and worker split (one
    // contiguous permutation slice per thread), so the tuner may only
    // move the bit-free layout knob.
    let caps = TuneCaps { bucket: false, layout: true, workers: false };
    let mut tuner =
        EpochTuner::for_run(cfg.tune, caps, "wild", 1, use_interleaved, t_threads, false);
    let epoch_ctr = obs::registry().counter("solver.epochs");
    let epoch_wall_us = obs::registry().histogram("solver.epoch_wall_us");
    for epoch in 1..=cfg.max_epochs {
        let t = Timer::start();
        obs::emit(EventKind::EpochBegin, obs::CLASS_NONE, 0, epoch as u64);
        // armed fault plans fire here (coordinator thread, before any
        // dispatch) so an injected panic unwinds cleanly through the epoch
        crate::fault::poke(crate::fault::FaultSite::Epoch);
        // cooperative cancellation: the once-per-epoch checkpoint
        if let Some(c) = &cfg.cancel {
            c.checkpoint("wild", epoch);
        }
        let shard = if use_interleaved { layout.shard(0) } else { None };
        // Sequential shuffle — deliberately so; its serial cost is one of
        // the scalability bottlenecks the paper measures (Fig. 2a).
        rng.shuffle(&mut perm);
        let chunk = n.div_ceil(t_threads);
        let mut jobs = Vec::with_capacity(t_threads);
        for tid in 0..t_threads {
            let lo = tid * chunk;
            let hi = ((tid + 1) * chunk).min(n);
            if lo >= hi {
                continue;
            }
            let my = &perm[lo..hi];
            let alpha = &alpha;
            let v = &v;
            let ds = &ds;
            let obj = &obj;
            jobs.push(move || {
                if let Some(sh) = shard {
                    for (i, &jj) in my.iter().enumerate() {
                        let j = jj as usize;
                        // one-ahead prefetch off the thread's permutation
                        // slice
                        if let Some(&nj) = my.get(i + 1) {
                            sh.prefetch_example(nj as usize);
                        }
                        // READ current (possibly stale/racing) state
                        let a = alpha[j].load();
                        let entries = sh.entries(j);
                        let xw = kernel::dot_entries_atomic(entries, v) * inv_lambda_n;
                        let delta = obj.delta(a, xw, ds.norm_sq(j), ds.y[j], n);
                        if delta != 0.0 {
                            // WRITE α_j (exclusive), ADD to v (wild)
                            alpha[j].store(a + delta);
                            kernel::axpy_entries_wild(entries, delta, v);
                        }
                    }
                    return;
                }
                // source-matrix walk: per-thread cursor (the shuffled
                // permutation hops segments, but the seat check is one
                // branch and the thread shares nothing through it)
                let mut cur = ds.x.col_cursor();
                for &jj in my {
                    let j = jj as usize;
                    // READ current (possibly stale/racing) state
                    let a = alpha[j].load();
                    let xw = cur.dot_atomic(j, v) * inv_lambda_n;
                    let delta = obj.delta(a, xw, ds.norm_sq(j), ds.y[j], n);
                    if delta != 0.0 {
                        // WRITE α_j (exclusive), ADD to v (wild)
                        alpha[j].store(a + delta);
                        cur.axpy_wild(j, delta, v);
                    }
                }
            });
        }
        exec.run(jobs);
        let a_snap = snapshot(&alpha);
        let rel = mon.observe(&a_snap);
        let wall_s = t.elapsed_s();
        epochs.push(EpochStats {
            epoch,
            wall_s,
            rel_change: rel,
            gap: None,
            primal: None,
        });
        let pool_stats = exec.stats();
        conv.record(
            epoch,
            wall_s,
            rel,
            None,
            pool_stats.as_ref().map(|s| s.imbalance()),
            pool_stats.as_ref().map(|s| s.total_busy_s()),
        );
        // Epoch-boundary tuning: layout is the only knob wild exposes.
        for d in tuner.observe(conv.points.last().expect("recorded this epoch")) {
            if d.knob == Knob::Layout {
                use_interleaved = d.to == "interleaved";
                if use_interleaved && layout.shard(0).is_none() {
                    layout = RunLayout::resolve(true, None, |_| false, || {
                        ShardedLayout::single(&ds.x, &Buckets::new(n, 1))
                    });
                }
            }
        }
        epoch_ctr.inc();
        epoch_wall_us.record((wall_s * 1e6) as u64);
        obs::emit(EventKind::EpochEnd, obs::CLASS_NONE, 0, epoch as u64);
        if mon.diverged(&a_snap) {
            diverged = true;
            break;
        }
        if mon.converged() {
            converged = true;
            break;
        }
    }

    // The returned model is w(α): rebuild v exactly from α — the racy
    // in-training v may have drifted (lost updates), which is precisely why
    // wild can settle on an incorrect solution.
    let mut st = ModelState {
        alpha: snapshot(&alpha),
        v: vec![0.0; ds.d()],
    };
    st.rebuild_v(ds);
    let record = RunRecord {
        solver: "wild".into(),
        threads: t_threads,
        epochs,
        converged,
        diverged,
        total_wall_s: total.elapsed_s(),
    };
    TrainOutput::assemble(ds, &obj, st, record)
        .with_convergence(conv)
        .with_tune_log(tuner.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::Objective;
    use crate::data::synthetic;
    use crate::solver::Variant;

    fn cfg(lambda: f64, threads: usize) -> SolverConfig {
        SolverConfig::new(Objective::Logistic { lambda })
            .with_variant(Variant::Wild)
            .with_threads(threads)
            .with_tol(1e-5)
            .with_max_epochs(300)
    }

    #[test]
    fn single_thread_matches_sequential_quality() {
        let ds = synthetic::dense_classification(400, 20, 1);
        let out = train_wild(&ds, &cfg(1.0 / 400.0, 1));
        assert!(out.converged);
        assert!(out.final_gap < 1e-3, "gap={}", out.final_gap);
    }

    #[test]
    fn two_threads_converge_sparse() {
        // sparse + low thread count: the regime where wild works (Fig 1b)
        let ds = synthetic::sparse_classification(600, 200, 0.02, 2);
        let out = train_wild(&ds, &cfg(1.0 / 600.0, 2));
        assert!(out.converged);
        assert!(out.final_gap < 1e-2, "gap={}", out.final_gap);
        assert!(!out.record.diverged);
    }

    #[test]
    fn returned_v_is_consistent_with_alpha() {
        let ds = synthetic::dense_classification(200, 10, 3);
        let out = train_wild(&ds, &cfg(0.01, 2));
        assert!(out.state.v_drift(&ds) < 1e-9);
    }

    #[test]
    fn dual_domain_preserved() {
        // α updates are exclusive per coordinate, so even wild runs keep
        // y·α ∈ [0,1] for logistic
        let ds = synthetic::dense_classification(300, 10, 4);
        let out = train_wild(&ds, &cfg(1e-3, 4));
        let viol = ConvergenceMonitor::domain_violation(
            &Objective::Logistic { lambda: 1e-3 },
            &out.state.alpha,
            &ds.y,
        );
        assert_eq!(viol, 0.0);
    }
}
