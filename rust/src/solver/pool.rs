//! Persistent NUMA-aware worker pool — the resident execution runtime
//! behind [`Executor::Pool`](crate::solver::exec::Executor).
//!
//! ## Why a pool
//!
//! The replica solvers (`dom`, `numa`) dispatch one batch of worker jobs
//! per merge round — with up to 8 merges/epoch and hundreds of epochs, a
//! spawn-per-round executor pays thousands of OS thread spawn/join cycles
//! per `train()` call. SySCD-style systems avoid that with workers that
//! are created once and stay resident for the whole run. [`WorkerPool`]
//! does the same: `threads` long-lived workers, created once per
//! `train()` call, each owning a private job queue, fed per round over
//! reusable channels and torn down only when the pool is dropped.
//!
//! ## NUMA organization
//!
//! Workers are laid out by the paper's placement policy
//! ([`Topology::place_threads`]): the pool asks the topology how many
//! workers belong on each node and tags every worker with its node id.
//! [`WorkerPool::run_tagged`] routes node-tagged jobs to workers of that
//! node (round-robin within the node's bucket queue), which is what keeps
//! the hierarchical `numa` solver's per-node work on the node that owns
//! the corresponding replica and bucket range. Thread→core pinning itself
//! is not performed: `std` exposes no affinity API and the container
//! forbids new dependencies, so the grouping is structural (queue-per-
//! worker, worker-per-node) — the dispatch-overhead win does not depend
//! on pinning, and a `libc`/`hwloc`-backed pin can be slotted into
//! `worker_main` later without changing any caller.
//!
//! ## Two-level queues: reader-priority dispatch
//!
//! Each worker owns **two** FIFO deques, one per [`JobClass`]:
//! latency-sensitive `Reader` jobs (predict shards) and throughput
//! `Writer` jobs (training merge rounds, refit buckets). A worker always
//! drains pending readers before touching the writer deque, and stays
//! FIFO *within* each class. Under a live refit this keeps a predict
//! shard from queueing behind a long train-round batch — the tail-latency
//! fix the open-loop serving driver measures. The priority affects only
//! *when* a job starts, never its inputs or the order results are
//! returned in (see the determinism argument below). Per-class
//! enqueue→start waiting time is recorded ([`PoolStats::class_delay`]),
//! which is the measurable per-class queue-delay signal the SySCD
//! auto-tuning direction needs.
//!
//! ## Determinism argument
//!
//! The pool is bit-wise interchangeable with [`Executor::Threads`] and
//! [`Executor::Sequential`] for the replica solvers because:
//!
//! 1. every job a solver submits between two merge points reads only
//!    snapshot state (`v` at the round start) plus `α` coordinates that
//!    no other in-flight job touches — job outputs are a pure function of
//!    the epoch assignment, independent of *where* or *when* the job runs;
//! 2. [`WorkerPool::run`]/[`run_tagged`](WorkerPool::run_tagged) return
//!    results **in job order**, and the solvers reduce deltas in that
//!    order, so the floating-point merge order is identical across
//!    executors.
//!
//! Reader priority does not weaken either leg: results are delivered
//! through per-batch slots indexed by job position, so the merge order of
//! a batch is fixed at submission no matter which class jumped ahead on a
//! worker, and job inputs stay pure functions of the assignment.
//! `rust/tests/pool_equivalence.rs` locks this in by asserting bit-wise
//! equal `α`/`v` trajectories across all three executors, and the
//! priority-invariant unit tests below lock in drain order.
//!
//! ## Multiple in-flight requests
//!
//! Dispatch is re-entrant across threads: every `run*` call carries its
//! own completion latch and result slots, and the per-worker queues are
//! mutex-guarded, so any number of callers may have batches in flight at
//! once. The concurrent serving scheduler ([`crate::serve::Scheduler`])
//! relies on this — reader predict shards and a writer's merge-round jobs
//! interleave on the same queues at job granularity (readers first, FIFO
//! per class per worker). Interleaving affects only *when* a job runs,
//! never its inputs or the order results are returned in, so the
//! determinism argument above is untouched.
//!
//! ## Safety
//!
//! Jobs borrow solver state (`&Dataset`, `&[AtomicF64]`, replica slices),
//! so they are not `'static`. Like the classic scoped-thread-pool idiom,
//! dispatch transmutes the job's lifetime away and **blocks until every
//! job of the batch has completed** before returning — the borrows are
//! live for the whole time any worker can touch them. A panicking job is
//! caught on the worker (keeping the worker alive and the completion
//! latch counted) and re-raised as a panic on the submitting thread.

use crate::obs::{self, EventKind};
use crate::sysinfo::Topology;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A lifetime-erased job as stored on a worker queue.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// SAFETY: the transmute only erases the borrow lifetime of the closure's
/// captures. Soundness is restored by `run_routed`, which does not return
/// until every submitted job has run to completion (or panicked) — the
/// captures therefore outlive all worker-side use.
unsafe fn erase_lifetime<'a>(f: Box<dyn FnOnce() + Send + 'a>) -> Job {
    std::mem::transmute(f)
}

/// Which of a worker's two queues a dispatched batch lands on.
///
/// `Reader` jobs (predict shards) drain before any pending `Writer` job
/// (training merge rounds, refit buckets); within a class the queue is
/// FIFO, so merge order — which is fixed by result-slot position anyway —
/// matches submission order on every worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobClass {
    /// Latency-sensitive read-only work, served ahead of writers.
    Reader,
    /// Throughput work; drained FIFO once no reader is pending.
    Writer,
}

impl JobClass {
    #[inline]
    fn slot(self) -> usize {
        match self {
            JobClass::Reader => 0,
            JobClass::Writer => 1,
        }
    }

    /// Trace tag for this class ([`obs::CLASS_READER`]/[`obs::CLASS_WRITER`]).
    #[inline]
    fn trace_tag(self) -> u8 {
        match self {
            JobClass::Reader => obs::CLASS_READER,
            JobClass::Writer => obs::CLASS_WRITER,
        }
    }
}

/// One worker's two-level queue: a FIFO deque per [`JobClass`] (readers
/// drain first) + a closed flag. Jobs carry their enqueue instant so the
/// worker can attribute queueing delay per class.
struct JobQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    /// Indexed by `JobClass::slot()`: `[readers, writers]`.
    classes: [VecDeque<(Job, Instant)>; 2],
    closed: bool,
}

impl JobQueue {
    fn new() -> Self {
        JobQueue {
            state: Mutex::new(QueueState {
                classes: [VecDeque::new(), VecDeque::new()],
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    fn push(&self, job: Job, class: JobClass) {
        let mut g = self.state.lock().unwrap();
        g.classes[class.slot()].push_back((job, Instant::now()));
        self.ready.notify_one();
    }

    fn close(&self) {
        let mut g = self.state.lock().unwrap();
        g.closed = true;
        self.ready.notify_all();
    }

    /// Block until a job is available; `None` once closed and drained.
    /// Readers are always preferred over writers; each deque is FIFO.
    fn pop(&self) -> Option<(Job, Instant, JobClass)> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some((job, at)) = g.classes[JobClass::Reader.slot()].pop_front() {
                return Some((job, at, JobClass::Reader));
            }
            if let Some((job, at)) = g.classes[JobClass::Writer.slot()].pop_front() {
                return Some((job, at, JobClass::Writer));
            }
            if g.closed {
                return None;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    /// Pending (not yet started) jobs as `(readers, writers)`.
    fn depths(&self) -> (usize, usize) {
        let g = self.state.lock().unwrap();
        (
            g.classes[JobClass::Reader.slot()].len(),
            g.classes[JobClass::Writer.slot()].len(),
        )
    }
}

/// Countdown latch for one dispatch batch.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(count: usize) -> Self {
        Latch {
            remaining: Mutex::new(count),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn count_down(&self) {
        let mut g = self.remaining.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock().unwrap();
        while *g > 0 {
            g = self.done.wait(g).unwrap();
        }
    }
}

/// Raw slot pointer that may cross a thread boundary (each job writes a
/// distinct slot; see `run_routed`).
struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}

impl<T> Copy for SendPtr<T> {}

/// Per-worker accounting: the worker adds each job's measured duration
/// and its enqueue→start wait, the latter split by [`JobClass`] (one
/// `Instant` pair per job — nanoseconds of overhead against worker jobs
/// that run for micro- to milliseconds).
#[derive(Default)]
struct WorkerTiming {
    busy_ns: AtomicU64,
    jobs: AtomicU64,
    /// Enqueue→start wait per class, indexed by `JobClass::slot()`.
    wait_ns: [AtomicU64; 2],
    /// Completed jobs per class, indexed by `JobClass::slot()`.
    class_jobs: [AtomicU64; 2],
}

/// One worker's timing census (see [`WorkerPool::stats`]).
#[derive(Clone, Debug)]
pub struct WorkerStats {
    pub worker: usize,
    pub node: usize,
    /// Total seconds this worker spent executing jobs.
    pub busy_s: f64,
    /// Jobs completed (panicked jobs count — they occupied the worker).
    pub jobs: u64,
    /// Reader-class jobs completed and their summed enqueue→start wait.
    pub reader_jobs: u64,
    pub reader_wait_s: f64,
    /// Writer-class jobs completed and their summed enqueue→start wait.
    pub writer_jobs: u64,
    pub writer_wait_s: f64,
}

/// Aggregate queue delay of one [`JobClass`] across the pool: completed
/// jobs and their summed enqueue→start wait. Counters are monotone, so a
/// window is measured as a delta of two snapshots ([`ClassDelay::since`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ClassDelay {
    pub jobs: u64,
    pub wait_s: f64,
}

impl ClassDelay {
    /// Mean enqueue→start wait per job; 0 when no job completed.
    pub fn mean_wait_s(&self) -> f64 {
        if self.jobs == 0 {
            0.0
        } else {
            self.wait_s / self.jobs as f64
        }
    }

    /// Counter delta against an earlier snapshot of the same pool.
    pub fn since(&self, earlier: &ClassDelay) -> ClassDelay {
        ClassDelay {
            jobs: self.jobs.saturating_sub(earlier.jobs),
            wait_s: (self.wait_s - earlier.wait_s).max(0.0),
        }
    }
}

/// Per-class queue delay over a measured window — the report stamped by
/// the closed- and open-loop serving drivers so both report the
/// scheduled-vs-dispatch queueing that used to be invisible.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueDelayReport {
    pub reader: ClassDelay,
    pub writer: ClassDelay,
}

impl QueueDelayReport {
    /// Snapshot both class counters from a pool census.
    pub fn from_stats(stats: &PoolStats) -> Self {
        QueueDelayReport {
            reader: stats.class_delay(JobClass::Reader),
            writer: stats.class_delay(JobClass::Writer),
        }
    }

    /// Window delta against an earlier snapshot of the same pool.
    pub fn since(&self, earlier: &QueueDelayReport) -> Self {
        QueueDelayReport {
            reader: self.reader.since(&earlier.reader),
            writer: self.writer.since(&earlier.writer),
        }
    }

    /// One human-readable line for the serve/bench reports.
    pub fn summary_line(&self) -> String {
        format!(
            "  queue delay: reader {:>6} jobs mean {:>8.3} ms | writer {:>6} jobs mean {:>8.3} ms\n",
            self.reader.jobs,
            self.reader.mean_wait_s() * 1e3,
            self.writer.jobs,
            self.writer.mean_wait_s() * 1e3
        )
    }
}

/// Aggregated per-worker busy-time statistics — the straggler-imbalance
/// measurement the work-stealing roadmap item needs, and the load report
/// `parlin serve` prints.
#[derive(Clone, Debug)]
pub struct PoolStats {
    pub per_worker: Vec<WorkerStats>,
}

impl PoolStats {
    pub fn total_jobs(&self) -> u64 {
        self.per_worker.iter().map(|w| w.jobs).sum()
    }

    pub fn total_busy_s(&self) -> f64 {
        self.per_worker.iter().map(|w| w.busy_s).sum()
    }

    /// Max/mean busy-time ratio across workers: 1.0 means perfectly
    /// balanced; large values mean stragglers dominate the batch critical
    /// path (the signal that would justify intra-node work stealing).
    pub fn imbalance(&self) -> f64 {
        let mean = self.total_busy_s() / self.per_worker.len().max(1) as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        let max = self
            .per_worker
            .iter()
            .map(|w| w.busy_s)
            .fold(0.0f64, f64::max);
        max / mean
    }

    /// Pool-wide queue delay of one class (jobs + summed wait across all
    /// workers since pool creation).
    pub fn class_delay(&self, class: JobClass) -> ClassDelay {
        let mut agg = ClassDelay::default();
        for w in &self.per_worker {
            let (jobs, wait_s) = match class {
                JobClass::Reader => (w.reader_jobs, w.reader_wait_s),
                JobClass::Writer => (w.writer_jobs, w.writer_wait_s),
            };
            agg.jobs += jobs;
            agg.wait_s += wait_s;
        }
        agg
    }
}

/// Persistent worker pool with two job queues per worker (reader-priority;
/// see [`JobClass`]), workers grouped per NUMA node (see the module docs).
pub struct WorkerPool {
    queues: Vec<Arc<JobQueue>>,
    handles: Vec<JoinHandle<()>>,
    /// Node id of each worker (aligned with `queues`).
    node_of: Vec<usize>,
    /// Worker ids grouped per node: `node_workers[k]` = workers on node k.
    node_workers: Vec<Vec<usize>>,
    /// Per-worker busy-time counters (aligned with `queues`).
    timings: Vec<Arc<WorkerTiming>>,
}

impl WorkerPool {
    /// Spawn `threads` resident workers laid out on `topo` by the paper's
    /// thread-placement policy (data node first, minimal node count).
    pub fn new(threads: usize, topo: &Topology) -> Self {
        let threads = threads.max(1);
        let placement = topo.place_threads(threads);
        let mut queues = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        let mut node_of = Vec::with_capacity(threads);
        let mut node_workers = vec![Vec::new(); placement.len()];
        let mut timings = Vec::with_capacity(threads);
        let mut wid = 0usize;
        for (node, &count) in placement.iter().enumerate() {
            for _ in 0..count {
                let queue = Arc::new(JobQueue::new());
                let worker_queue = Arc::clone(&queue);
                let timing = Arc::new(WorkerTiming::default());
                let worker_timing = Arc::clone(&timing);
                let handle = std::thread::Builder::new()
                    .name(format!("parlin-pool-n{node}-w{wid}"))
                    .spawn(move || worker_main(worker_queue, worker_timing, node as u16))
                    .expect("spawn pool worker");
                queues.push(queue);
                handles.push(handle);
                node_of.push(node);
                node_workers[node].push(wid);
                timings.push(timing);
                wid += 1;
            }
        }
        WorkerPool {
            queues,
            handles,
            node_of,
            node_workers,
            timings,
        }
    }

    /// Number of resident workers.
    pub fn workers(&self) -> usize {
        self.queues.len()
    }

    /// NUMA node a worker is assigned to.
    pub fn node_of_worker(&self, worker: usize) -> usize {
        self.node_of[worker]
    }

    /// Workers per node, aligned with the construction topology.
    pub fn workers_per_node(&self) -> Vec<usize> {
        self.node_workers.iter().map(|w| w.len()).collect()
    }

    /// Pending (not yet started) jobs per worker as `(readers, writers)`
    /// — introspection for admission control and the priority-invariant
    /// tests; jobs currently executing are not counted.
    pub fn queue_depths(&self) -> Vec<(usize, usize)> {
        self.queues.iter().map(|q| q.depths()).collect()
    }

    /// Snapshot of the per-worker counters accumulated since the pool was
    /// created (jobs in flight are not yet counted).
    ///
    /// Every census also publishes the pool-wide aggregates into the
    /// global metrics [registry](obs::registry) under `pool.*` — the
    /// registry is the one aggregation point observers read, while
    /// [`PoolStats`]/[`QueueDelayReport`] remain the typed views the
    /// existing report paths consume.
    pub fn stats(&self) -> PoolStats {
        let stats = PoolStats {
            per_worker: self
                .timings
                .iter()
                .enumerate()
                .map(|(w, t)| WorkerStats {
                    worker: w,
                    node: self.node_of[w],
                    busy_s: t.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9,
                    jobs: t.jobs.load(Ordering::Relaxed),
                    reader_jobs: t.class_jobs[JobClass::Reader.slot()].load(Ordering::Relaxed),
                    reader_wait_s: t.wait_ns[JobClass::Reader.slot()].load(Ordering::Relaxed)
                        as f64
                        * 1e-9,
                    writer_jobs: t.class_jobs[JobClass::Writer.slot()].load(Ordering::Relaxed),
                    writer_wait_s: t.wait_ns[JobClass::Writer.slot()].load(Ordering::Relaxed)
                        as f64
                        * 1e-9,
                })
                .collect(),
        };
        let reg = obs::registry();
        reg.gauge("pool.workers").set(stats.per_worker.len() as u64);
        reg.gauge("pool.jobs").set(stats.total_jobs());
        reg.gauge("pool.busy_us").set((stats.total_busy_s() * 1e6) as u64);
        reg.gauge("pool.imbalance_milli").set((stats.imbalance() * 1e3) as u64);
        let r = stats.class_delay(JobClass::Reader);
        let w = stats.class_delay(JobClass::Writer);
        reg.gauge("pool.reader.jobs").set(r.jobs);
        reg.gauge("pool.reader.wait_us").set((r.wait_s * 1e6) as u64);
        reg.gauge("pool.writer.jobs").set(w.jobs);
        reg.gauge("pool.writer.wait_us").set((w.wait_s * 1e6) as u64);
        stats
    }

    /// Run all jobs to completion as [`JobClass::Writer`] work (the
    /// solvers' merge-round shape), returning results in job order.
    /// Job `i` goes to worker `i % workers` — with one job per worker
    /// every worker gets exactly one.
    pub fn run<R, F>(&self, jobs: Vec<F>) -> Vec<R>
    where
        R: Send,
        F: FnOnce() -> R + Send,
    {
        self.run_as(JobClass::Writer, jobs)
    }

    /// [`run`](WorkerPool::run) with an explicit job class — readers jump
    /// ahead of queued writer jobs on every worker.
    pub fn run_as<R, F>(&self, class: JobClass, jobs: Vec<F>) -> Vec<R>
    where
        R: Send,
        F: FnOnce() -> R + Send,
    {
        let routes: Vec<usize> = (0..jobs.len()).map(|i| i % self.workers()).collect();
        self.run_routed(class, jobs, &routes)
    }

    /// Run node-tagged jobs as [`JobClass::Writer`] work: each job is
    /// queued on a worker of the tagged node (round-robin within that
    /// node's workers); tags naming a node with no workers fall back to
    /// the whole pool. Results are returned in job order.
    pub fn run_tagged<R, F>(&self, jobs: Vec<(usize, F)>) -> Vec<R>
    where
        R: Send,
        F: FnOnce() -> R + Send,
    {
        self.run_tagged_as(JobClass::Writer, jobs)
    }

    /// [`run_tagged`](WorkerPool::run_tagged) with an explicit job class
    /// — the predict path dispatches its shards as [`JobClass::Reader`]
    /// so they drain before any queued refit round.
    pub fn run_tagged_as<R, F>(&self, class: JobClass, jobs: Vec<(usize, F)>) -> Vec<R>
    where
        R: Send,
        F: FnOnce() -> R + Send,
    {
        let mut rr_node = vec![0usize; self.node_workers.len()];
        let mut rr_any = 0usize;
        let mut routes = Vec::with_capacity(jobs.len());
        let mut fns = Vec::with_capacity(jobs.len());
        for (node, f) in jobs {
            let worker = match self.node_workers.get(node) {
                Some(ws) if !ws.is_empty() => {
                    let w = ws[rr_node[node] % ws.len()];
                    rr_node[node] += 1;
                    w
                }
                _ => {
                    let w = rr_any % self.workers();
                    rr_any += 1;
                    w
                }
            };
            routes.push(worker);
            fns.push(f);
        }
        self.run_routed(class, fns, &routes)
    }

    fn run_routed<R, F>(&self, class: JobClass, jobs: Vec<F>, routes: &[usize]) -> Vec<R>
    where
        R: Send,
        F: FnOnce() -> R + Send,
    {
        let count = jobs.len();
        if count == 0 {
            return Vec::new();
        }
        let mut results: Vec<Option<R>> = Vec::with_capacity(count);
        results.resize_with(count, || None);
        let latch = Latch::new(count);
        let slots = SendPtr(results.as_mut_ptr());
        for (i, (job, &worker)) in jobs.into_iter().zip(routes.iter()).enumerate() {
            let latch_ref = &latch;
            let thunk = move || {
                match catch_unwind(AssertUnwindSafe(job)) {
                    // SAFETY: slot i is written by exactly this job, and
                    // `results` stays alive and unmoved until the latch
                    // below confirms every job finished.
                    Ok(r) => unsafe { *slots.0.add(i) = Some(r) },
                    Err(_) => latch_ref.panicked.store(true, Ordering::SeqCst),
                }
                latch_ref.count_down();
            };
            let boxed: Box<dyn FnOnce() + Send + '_> = Box::new(thunk);
            self.queues[worker].push(unsafe { erase_lifetime(boxed) }, class);
            // one relaxed load when tracing is off; the event goes into
            // the *dispatching* thread's ring (arg = batch slot index)
            obs::emit(
                EventKind::JobEnqueue,
                class.trace_tag(),
                self.node_of[worker] as u16,
                i as u64,
            );
        }
        latch.wait();
        if latch.panicked.load(Ordering::SeqCst) {
            panic!("a WorkerPool job panicked");
        }
        results
            .into_iter()
            .map(|r| r.expect("completed job left no result"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for queue in &self.queues {
            queue.close();
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_main(queue: Arc<JobQueue>, timing: Arc<WorkerTiming>, node: u16) {
    while let Some((job, enqueued, class)) = queue.pop() {
        let wait = enqueued.elapsed();
        // start/finish trace events reuse the wait/busy instants the
        // timing census takes anyway — tracing adds no clock reads, and
        // with tracing off each emit is one relaxed load
        obs::emit(EventKind::JobStart, class.trace_tag(), node, wait.as_nanos() as u64);
        let start = Instant::now();
        job();
        let busy = start.elapsed();
        obs::emit(EventKind::JobFinish, class.trace_tag(), node, busy.as_nanos() as u64);
        timing
            .busy_ns
            .fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
        timing.jobs.fetch_add(1, Ordering::Relaxed);
        timing.wait_ns[class.slot()].fetch_add(wait.as_nanos() as u64, Ordering::Relaxed);
        timing.class_jobs[class.slot()].fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn results_in_job_order() {
        let pool = WorkerPool::new(3, &Topology::flat(3));
        let jobs: Vec<_> = (0..10).map(|i| move || i * 7).collect();
        assert_eq!(pool.run(jobs), (0..10).map(|i| i * 7).collect::<Vec<_>>());
    }

    #[test]
    fn jobs_run_concurrently_on_distinct_workers() {
        use std::sync::Barrier;
        let pool = WorkerPool::new(4, &Topology::flat(4));
        let barrier = Barrier::new(4);
        // all four jobs must be in flight at once to pass the barrier
        let jobs: Vec<_> = (0..4)
            .map(|i| {
                let b = &barrier;
                move || {
                    b.wait();
                    i
                }
            })
            .collect();
        let mut got = pool.run(jobs);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn borrows_non_static_state() {
        let pool = WorkerPool::new(2, &Topology::flat(2));
        let data = vec![1.0f64; 64];
        let sums = pool.run(
            (0..2)
                .map(|_| {
                    let d = &data;
                    move || d.iter().sum::<f64>()
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(sums, vec![64.0, 64.0]);
        drop(data);
    }

    #[test]
    fn numa_layout_follows_placement() {
        let topo = Topology::uniform(2, 4);
        let pool = WorkerPool::new(6, &topo);
        assert_eq!(pool.workers(), 6);
        assert_eq!(pool.workers_per_node(), topo.place_threads(6));
        let nodes: Vec<usize> = (0..6).map(|w| pool.node_of_worker(w)).collect();
        let on0 = nodes.iter().filter(|&&n| n == 0).count();
        assert_eq!(on0, topo.place_threads(6)[0]);
    }

    #[test]
    fn tagged_jobs_land_on_their_node() {
        let topo = Topology::uniform(2, 2);
        let pool = WorkerPool::new(4, &topo);
        let hits: Vec<(usize, std::thread::ThreadId)> = pool
            .run_tagged(
                [(0usize, ()), (1, ()), (0, ()), (1, ())]
                    .into_iter()
                    .map(|(node, _)| (node, move || (node, std::thread::current().id())))
                    .collect(),
            )
            .into_iter()
            .collect();
        // jobs tagged with different nodes must run on disjoint workers
        let node0: Vec<_> = hits.iter().filter(|(n, _)| *n == 0).map(|(_, t)| *t).collect();
        let node1: Vec<_> = hits.iter().filter(|(n, _)| *n == 1).map(|(_, t)| *t).collect();
        for t0 in &node0 {
            assert!(!node1.contains(t0), "node-tagged jobs shared a worker");
        }
    }

    #[test]
    fn tag_fallback_when_node_has_no_workers() {
        // 2 workers fit on node 0 of a 2-node box; tags for node 1 must
        // still execute (fall back to the whole pool)
        let topo = Topology::uniform(2, 4);
        let pool = WorkerPool::new(2, &topo);
        let five: fn() -> i32 = || 5;
        let six: fn() -> i32 = || 6;
        let out = pool.run_tagged(vec![(1usize, five), (7, six)]);
        assert_eq!(out, vec![5, 6]);
    }

    #[test]
    fn reusable_across_many_rounds() {
        let pool = WorkerPool::new(2, &Topology::flat(2));
        let counter = AtomicUsize::new(0);
        for _ in 0..500 {
            let jobs: Vec<_> = (0..2)
                .map(|_| {
                    let c = &counter;
                    move || c.fetch_add(1, Ordering::Relaxed)
                })
                .collect();
            pool.run(jobs);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn per_job_timing_accumulates() {
        let pool = WorkerPool::new(2, &Topology::flat(2));
        assert_eq!(pool.stats().total_jobs(), 0);
        // 6 jobs route i % 2, so each worker gets exactly 3
        let jobs: Vec<_> = (0..6usize)
            .map(|i| {
                move || {
                    let mut s = 0.0f64;
                    for k in 0..20_000usize {
                        s += ((i * 20_000 + k) as f64).sqrt();
                    }
                    s
                }
            })
            .collect();
        pool.run(jobs);
        let stats = pool.stats();
        assert_eq!(stats.total_jobs(), 6);
        assert!(stats.per_worker.iter().all(|w| w.jobs == 3), "{stats:?}");
        assert!(stats.total_busy_s() >= 0.0);
        assert!(stats.imbalance() >= 1.0 - 1e-9, "{}", stats.imbalance());
        // node attribution follows the construction layout
        for w in &stats.per_worker {
            assert_eq!(w.node, pool.node_of_worker(w.worker));
        }
    }

    #[test]
    fn concurrent_dispatchers_share_the_pool() {
        // the serving scheduler's shape: several request threads, each
        // with its own batch in flight on ONE resident pool — every
        // caller must get exactly its own results, in its own job order
        let pool = WorkerPool::new(3, &Topology::uniform(3, 1));
        std::thread::scope(|s| {
            let pool = &pool;
            let handles: Vec<_> = (0..6usize)
                .map(|caller| {
                    s.spawn(move || {
                        for round in 0..40usize {
                            let jobs: Vec<_> = (0..5usize)
                                .map(|i| {
                                    let node = i % 3;
                                    (node, move || caller * 1000 + round * 10 + i)
                                })
                                .collect();
                            let got = pool.run_tagged(jobs);
                            let want: Vec<usize> =
                                (0..5).map(|i| caller * 1000 + round * 10 + i).collect();
                            assert_eq!(got, want, "caller {caller} round {round}");
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("dispatcher thread panicked");
            }
        });
        assert_eq!(pool.stats().total_jobs(), 6 * 40 * 5);
    }

    #[test]
    fn empty_batch_is_noop() {
        let pool = WorkerPool::new(2, &Topology::flat(2));
        let out: Vec<u32> = pool.run(Vec::<fn() -> u32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn job_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2, &Topology::flat(2));
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run(vec![Box::new(|| panic!("boom")) as Box<dyn FnOnce() + Send>]);
        }));
        assert!(res.is_err(), "panic must propagate to the submitter");
        // the worker that caught the panic is still serving jobs
        let one: fn() -> i32 = || 1;
        let two: fn() -> i32 = || 2;
        assert_eq!(pool.run(vec![one, two]), vec![1, 2]);
    }

    // ---- observability invariants ----

    /// The acceptance-criterion zero-cost assertion: with `ObsConfig` off,
    /// dispatching work through the pool must build and register no ring —
    /// the no-op branch of `obs::emit` is the entire observability cost.
    #[test]
    fn tracing_off_builds_no_rings() {
        let _session = obs::TraceSession::start(obs::ObsConfig::off());
        let pool = WorkerPool::new(2, &Topology::flat(2));
        pool.run((0..8).map(|i| move || i).collect::<Vec<_>>());
        pool.run_as(JobClass::Reader, (0..4).map(|i| move || i).collect::<Vec<_>>());
        assert!(!obs::tracing_enabled());
        assert_eq!(obs::ring_count(), 0, "off path must never register a ring");
        drop(pool);
        assert_eq!(obs::ring_count(), 0);
    }

    /// The fault-injection analogue of `tracing_off_builds_no_rings`:
    /// with no plan armed, every `fault::poke` site reduces to one
    /// relaxed atomic load — no hit counters tick, no plan is consulted,
    /// and pool work that crosses the sites observes nothing.
    #[test]
    fn faults_disarmed_cost_one_relaxed_load() {
        let _guard = crate::fault::disarmed();
        assert!(!crate::fault::armed());
        let pool = WorkerPool::new(2, &Topology::flat(2));
        let out = pool.run(
            (0..8)
                .map(|i| {
                    move || {
                        // the solver-epoch site, exercised from pool jobs
                        assert!(crate::fault::poke(crate::fault::FaultSite::Epoch).is_none());
                        i
                    }
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(out, (0..8).collect::<Vec<_>>());
        assert!(crate::fault::poke(crate::fault::FaultSite::Epoch).is_none());
        assert_eq!(
            crate::fault::hits(crate::fault::FaultSite::Epoch),
            0,
            "disarmed pokes must not even count hits"
        );
    }

    /// With tracing on, every job yields an enqueue event on the
    /// dispatcher's ring and start/finish events on its worker's ring,
    /// tagged with the dispatched class.
    #[test]
    fn tracing_on_records_the_job_lifecycle() {
        let session = obs::TraceSession::start(obs::ObsConfig::on(1024));
        let pool = WorkerPool::new(2, &Topology::flat(2));
        pool.run((0..6).map(|i| move || i).collect::<Vec<_>>());
        pool.run_as(JobClass::Reader, (0..2).map(|i| move || i).collect::<Vec<_>>());
        // joining the workers (Drop) sequences every worker-side emit
        // before the drain below
        drop(pool);
        let dump = session.finish();
        // concurrently running tests may emit into the same session, so
        // pin exact counts to THIS test thread's ring (the dispatcher)
        // and lower-bound the worker-side counts
        let me = std::thread::current().name().unwrap_or("").to_string();
        let my_enqueues = dump
            .threads
            .iter()
            .filter(|t| t.name == me)
            .flat_map(|t| &t.events)
            .filter(|e| e.kind == EventKind::JobEnqueue)
            .count();
        assert_eq!(my_enqueues, 8);
        assert!(dump.count_of(EventKind::JobStart) >= 8);
        assert!(dump.count_of(EventKind::JobFinish) >= 8);
        assert!(
            dump.threads.iter().any(|t| t.name.starts_with("parlin-pool-n")),
            "worker events must sit on the workers' own rings: {:?}",
            dump.threads.iter().map(|t| &t.name).collect::<Vec<_>>()
        );
        let reader_starts = dump
            .threads
            .iter()
            .flat_map(|t| &t.events)
            .filter(|e| e.kind == EventKind::JobStart && e.class == obs::CLASS_READER)
            .count();
        assert!(reader_starts >= 2);
    }

    /// The census publishes pool-wide aggregates into the global registry
    /// (`PoolStats` stays the typed view over the same counters).
    #[test]
    fn stats_census_feeds_the_metrics_registry() {
        let pool = WorkerPool::new(2, &Topology::flat(2));
        pool.run((0..4).map(|i| move || i).collect::<Vec<_>>());
        let stats = pool.stats();
        assert!(stats.total_jobs() >= 4);
        // the registry is process-global and other tests census their own
        // pools concurrently, so assert presence rather than exact values
        let snap = obs::registry().snapshot();
        for key in [
            "pool.workers",
            "pool.jobs",
            "pool.busy_us",
            "pool.imbalance_milli",
            "pool.reader.jobs",
            "pool.reader.wait_us",
            "pool.writer.jobs",
            "pool.writer.wait_us",
        ] {
            assert!(snap.gauge(key).is_some(), "gauge {key} missing from census");
        }
    }

    // ---- two-level queue (reader-priority) invariants ----

    /// Poll `cond` for up to ~5 s; panic with `what` if it never holds.
    fn wait_until(what: &str, cond: impl Fn() -> bool) {
        for _ in 0..5000 {
            if cond() {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        panic!("timed out waiting for: {what}");
    }

    /// Readers enqueued AFTER a writer batch must still drain first, and
    /// each class must stay FIFO in submission order. A single worker is
    /// blocked so both batches pile up behind it, then released — the
    /// execution log decides.
    #[test]
    fn readers_enqueued_after_writers_drain_first() {
        let pool = WorkerPool::new(1, &Topology::flat(1));
        let log: Mutex<Vec<String>> = Mutex::new(Vec::new());
        let release = AtomicBool::new(false);
        let started = AtomicBool::new(false);
        std::thread::scope(|s| {
            let (pool2, log2) = (&pool, &log);
            let (release2, started2) = (&release, &started);
            let blocker = s.spawn(move || {
                pool2.run(vec![move || {
                    started2.store(true, Ordering::SeqCst);
                    while !release2.load(Ordering::SeqCst) {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }]);
            });
            wait_until("blocker occupies the worker", || {
                started.load(Ordering::SeqCst)
            });
            // a writer batch queues behind the blocker...
            let writers = s.spawn(move || {
                pool2.run(
                    (0..3)
                        .map(|i| {
                            let log = log2;
                            move || log.lock().unwrap().push(format!("w{i}"))
                        })
                        .collect::<Vec<_>>(),
                );
            });
            wait_until("writer batch queued", || pool.queue_depths()[0].1 >= 3);
            // ...then readers arrive later and must still jump ahead
            let readers = s.spawn(move || {
                pool2.run_as(
                    JobClass::Reader,
                    (0..3)
                        .map(|i| {
                            let log = log2;
                            move || log.lock().unwrap().push(format!("r{i}"))
                        })
                        .collect::<Vec<_>>(),
                );
            });
            wait_until("reader batch queued", || pool.queue_depths()[0].0 >= 3);
            release.store(true, Ordering::SeqCst);
            blocker.join().expect("blocker dispatcher panicked");
            writers.join().expect("writer dispatcher panicked");
            readers.join().expect("reader dispatcher panicked");
        });
        // readers first even though they were enqueued last; FIFO within
        // each class (this is the merge-order-preservation invariant)
        assert_eq!(
            log.into_inner().unwrap(),
            vec!["r0", "r1", "r2", "w0", "w1", "w2"]
        );
        assert_eq!(pool.queue_depths(), vec![(0, 0)]);
    }

    /// Re-entrant dispatch with mixed classes: every caller gets exactly
    /// its own results in its own job order, whichever class it used.
    #[test]
    fn mixed_class_reentrant_dispatch_keeps_each_callers_job_order() {
        let pool = WorkerPool::new(3, &Topology::uniform(3, 1));
        std::thread::scope(|s| {
            let pool = &pool;
            let handles: Vec<_> = (0..6usize)
                .map(|caller| {
                    s.spawn(move || {
                        let class = if caller % 2 == 0 {
                            JobClass::Reader
                        } else {
                            JobClass::Writer
                        };
                        for round in 0..30usize {
                            let jobs: Vec<_> = (0..5usize)
                                .map(|i| {
                                    let node = i % 3;
                                    (node, move || caller * 1000 + round * 10 + i)
                                })
                                .collect();
                            let got = pool.run_tagged_as(class, jobs);
                            let want: Vec<usize> =
                                (0..5).map(|i| caller * 1000 + round * 10 + i).collect();
                            assert_eq!(got, want, "caller {caller} round {round}");
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("dispatcher thread panicked");
            }
        });
        let stats = pool.stats();
        assert_eq!(stats.total_jobs(), 6 * 30 * 5);
        // 3 reader callers and 3 writer callers → an even class split
        assert_eq!(stats.class_delay(JobClass::Reader).jobs, 3 * 30 * 5);
        assert_eq!(stats.class_delay(JobClass::Writer).jobs, 3 * 30 * 5);
    }

    /// Per-class queue-delay counters: jobs are attributed to the class
    /// they were dispatched as, and window deltas subtract cleanly.
    #[test]
    fn per_class_queue_delay_is_recorded() {
        let pool = WorkerPool::new(2, &Topology::flat(2));
        pool.run((0..4).map(|i| move || i).collect::<Vec<_>>());
        pool.run_as(JobClass::Reader, (0..4).map(|i| move || i).collect::<Vec<_>>());
        let stats = pool.stats();
        let r = stats.class_delay(JobClass::Reader);
        let w = stats.class_delay(JobClass::Writer);
        assert_eq!(r.jobs, 4);
        assert_eq!(w.jobs, 4);
        assert!(r.wait_s >= 0.0 && w.wait_s >= 0.0);
        assert!(r.mean_wait_s() >= 0.0);
        // a window delta counts only the jobs inside the window
        let mark = QueueDelayReport::from_stats(&stats);
        pool.run_as(JobClass::Reader, (0..2).map(|i| move || i).collect::<Vec<_>>());
        let delta = QueueDelayReport::from_stats(&pool.stats()).since(&mark);
        assert_eq!(delta.reader.jobs, 2);
        assert_eq!(delta.writer.jobs, 0);
        assert!(!delta.summary_line().is_empty());
    }
}
